"""Network-planning benchmark: plan whole conv networks (LeNet-5, ResNet-8,
tight-budget variants) and compare the predicted schedule against the
per-layer-greedy baseline (best feasible Row-by-Row/ZigZag heuristic or S2
fallback, no polish, no inter-layer reuse).

Emits one JSON per run with planning throughput (layers/sec), the total
predicted duration for plan vs. baseline, per-layer critical-path rows, the
solve-cache hit rate, and — with ``--sweep-mem`` — a tight-memory sweep
over a (size_mem x network) grid showing the S1→S2 crossover: budgets
below the largest layer's kernel set force the kernel-group-swapping
fallback, and the plan must stay feasible and keep beating greedy.

    PYTHONPATH=src python -m benchmarks.network_plan \
        [--networks lenet5 resnet8 tight4] [--size-mem N] \
        [--sweep-mem auto | --sweep-mem 2000 8000 ...] \
        [--restarts 4] [--iters 6000] [--fast] \
        [--out benchmarks/results/network_plan.json]

``--fast`` is the CI smoke target: tiny polish budgets, the small
networks, and an automatic sweep (seconds, not minutes).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.configs.networks import NETWORKS
from repro.configs.tight import budget_points
from repro.core import solver
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import InfeasibleNetworkError, plan_network


def bench_network(name: str, hw: HardwareModel, *, iters: int,
                  restarts: int, rng_seed: int) -> dict:
    specs = NETWORKS[name]
    t0 = time.perf_counter()
    try:
        plan = plan_network(specs, hw, name=name, polish_iters=iters,
                            polish_restarts=restarts, rng_seed=rng_seed)
    except InfeasibleNetworkError as e:
        return {"network": name, "feasible": False, "error": str(e)}
    wall = time.perf_counter() - t0
    return {
        "network": name,
        "feasible": True,
        "n_layers": plan.n_layers,
        "n_s2_layers": plan.n_s2_layers,
        "peak_footprint": plan.peak_footprint,
        "planning_wall_s": round(wall, 4),
        "planning_layers_per_s": round(plan.n_layers / max(wall, 1e-9), 2),
        "solver_calls": plan.solver_calls,
        "cache_hits": plan.cache_hits,
        "total_duration": plan.total_duration,
        "gross_duration": plan.gross_duration,
        "greedy_baseline_duration": plan.baseline_duration,
        "gain_vs_baseline": round(plan.gain_vs_baseline, 4),
        "beats_baseline": plan.total_duration < plan.baseline_duration,
        "critical_path": [
            {"layer": i, "duration": d, "fraction": round(f, 4)}
            for i, d, f in plan.critical_path()],
        "layers": [
            {"index": lp.index,
             "shape": f"{lp.spec.c_in}x{lp.spec.h_in}x{lp.spec.w_in}"
                      f"->{lp.spec.c_out}x{lp.spec.h_out}x{lp.spec.w_out}",
             "p": lp.p,
             "mode": lp.mode,
             "strategy": lp.strategy.name,
             "steps": lp.strategy.n_steps,
             "peak_footprint": lp.strategy.peak_footprint_elements(),
             "duration": lp.duration,
             "gross_duration": lp.gross_duration,
             "optimality_gap": round(lp.result.gap, 4),
             "reuse_input": lp.reuse_input,
             "reuse_output": lp.reuse_output,
             "window_rows": lp.window_rows}
            for lp in plan.layers],
    }


def sweep_tight_memory(name: str, budgets: list[int], *, nbop_pe: int,
                       iters: int, restarts: int, rng_seed: int) -> dict:
    """Plan ``name`` under every budget: the S1→S2 crossover grid."""
    rows = []
    for size_mem in budgets:
        hw = HardwareModel(nbop_pe=nbop_pe, size_mem=size_mem)
        try:
            plan = plan_network(NETWORKS[name], hw, name=name,
                                polish_iters=iters,
                                polish_restarts=restarts, rng_seed=rng_seed)
        except InfeasibleNetworkError as e:
            rows.append({"size_mem": size_mem, "feasible": False,
                         "error": str(e)})
            continue
        rows.append({
            "size_mem": size_mem,
            "feasible": True,
            "n_s2_layers": plan.n_s2_layers,
            "peak_footprint": plan.peak_footprint,
            "total_duration": plan.total_duration,
            "greedy_baseline_duration": plan.baseline_duration,
            "gain_vs_baseline": round(plan.gain_vs_baseline, 4),
            "beats_baseline": plan.total_duration < plan.baseline_duration,
            "layer_modes": [lp.mode for lp in plan.layers],
        })
    return {"network": name, "points": rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="+", default=None,
                    choices=sorted(NETWORKS))
    ap.add_argument("--size-mem", type=int, default=None,
                    help="on-chip budget in elements (default: unconstrained,"
                         " the paper's Sec-7.1 setting)")
    ap.add_argument("--sweep-mem", nargs="+", default=None,
                    help="budgets for the tight-memory sweep: explicit "
                         "element counts, or 'auto' for fractions of each "
                         "network's largest kernel set")
    ap.add_argument("--nbop-pe", type=int, default=10 ** 9)
    ap.add_argument("--iters", type=int, default=6000)
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--rng-seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="smoke preset: small networks, tiny polish budget, "
                         "auto sweep")
    ap.add_argument("--out", default="benchmarks/results/network_plan.json")
    args = ap.parse_args(argv)

    if args.fast:
        args.networks = args.networks or ["lenet5", "tight2"]
        args.iters = min(args.iters, 300)
        args.restarts = min(args.restarts, 1)
        args.sweep_mem = args.sweep_mem or ["auto"]
    networks = args.networks or sorted(NETWORKS)

    hw = HardwareModel(nbop_pe=args.nbop_pe, size_mem=args.size_mem)
    solver.solve_cached.cache_clear()
    rows = [bench_network(n, hw, iters=args.iters, restarts=args.restarts,
                          rng_seed=args.rng_seed) for n in networks]

    sweeps = []
    if args.sweep_mem:
        for n in networks:
            if args.sweep_mem == ["auto"]:
                budgets = budget_points(NETWORKS[n])
            else:
                budgets = sorted(int(b) for b in args.sweep_mem)
            sweeps.append(sweep_tight_memory(
                n, budgets, nbop_pe=args.nbop_pe, iters=args.iters,
                restarts=args.restarts, rng_seed=args.rng_seed))

    result = {"hw": {"nbop_pe": args.nbop_pe, "size_mem": args.size_mem,
                     "t_l": hw.t_l, "t_w": hw.t_w, "t_acc": hw.t_acc},
              "polish": {"iters": args.iters, "restarts": args.restarts},
              "networks": rows,
              "tight_memory_sweep": sweeps}
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    for r in rows:
        if not r["feasible"]:
            print(f"[network_plan] {r['network']}: INFEASIBLE under "
                  f"size_mem={args.size_mem} ({r['error']})")
            continue
        print(f"[network_plan] {r['network']}: "
              f"planned {r['n_layers']} layers in {r['planning_wall_s']}s "
              f"({r['planning_layers_per_s']} layers/s, "
              f"{r['cache_hits']}/{r['solver_calls']} cache hits); "
              f"predicted {r['total_duration']:g} vs greedy "
              f"{r['greedy_baseline_duration']:g} "
              f"(gain {r['gain_vs_baseline']:.1%})")
    for sw in sweeps:
        for pt in sw["points"]:
            if not pt["feasible"]:
                print(f"[sweep] {sw['network']} mem={pt['size_mem']}: "
                      f"infeasible")
                continue
            print(f"[sweep] {sw['network']} mem={pt['size_mem']}: "
                  f"{pt['n_s2_layers']} S2 layers, "
                  f"plan {pt['total_duration']:g} vs greedy "
                  f"{pt['greedy_baseline_duration']:g} "
                  f"(gain {pt['gain_vs_baseline']:.1%})")
    print("saved ->", args.out)

    ok = all(r["feasible"] and r["beats_baseline"] for r in rows)
    # the sweep must stay feasible and beat greedy on >= 1 budget point
    for sw in sweeps:
        feas = [p for p in sw["points"] if p["feasible"]]
        ok = ok and bool(feas) and any(p["beats_baseline"] for p in feas)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
