"""Network-planning benchmark: plan whole conv networks (LeNet-5, ResNet-8,
tight-budget variants) and compare the predicted schedule against the
per-layer-greedy baseline (best feasible Row-by-Row/ZigZag heuristic or S2
fallback, no polish, no inter-layer reuse).

Emits one JSON per run with planning throughput (layers/sec), the total
predicted duration for plan vs. baseline, per-layer critical-path rows, the
solve-cache hit rate, and — with ``--sweep-mem`` — a tight-memory sweep
over a (size_mem x network) grid showing the S1→S2 crossover: budgets
below the largest layer's kernel set force the kernel-group-swapping
fallback, and the plan must stay feasible and keep beating greedy.
``--sweep-chips`` adds the multi-chip scaling curve: each network is
planned on 1/2/4/8-chip clusters (``core.multichip``) at the tight
budget where sharding matters (half the largest kernel set), recording —
for both the serialised PR-3 accounting and the overlap + duration-
balanced model — the chosen mode string, ICI fraction, and speedup over
the 1-chip plan.  ``--topology`` adds a topology axis to that sweep:
the unidirectional ``ring`` baseline (bit-exact PR-3/PR-4 numbers),
``biring``, and bidirectional 2-D tori (``torus2x2``/``torus2x4``/... or
``torus`` for auto-dims) whose halved bottleneck hops and hybrid
row x channel sharding move the 4/8-chip points.

``--profile`` emits per-stage planner wall-clock and solver-LRU hit
rates (stable keys ``planner_seconds`` / ``gain_vs_pr3`` against the
frozen ``PR3_BASELINE`` numbers) so future PRs can diff the planner-perf
trajectory, and ``--max-planner-seconds`` turns the total planner
wall-clock into a CI pass/fail guard.  The timing itself lives in the
``repro.obs`` metrics registry (stage timers here, per-call planner
hooks in ``core``), and every run whose scope includes the canary
network replays ``tight4`` on a 2x2 torus through the full
observability loop — plan, functional simulation, kernel trace, Chrome-
trace export, drift reconciliation (``repro.obs.report``) — pinning
``obs_trace_valid`` / ``max_drift_elements`` into the summary and the
exit code: predictability is a postcondition, not a hope.
``--faults`` extends that postcondition to the failure cases: the
canary point is replayed under the ``repro.resil`` chip-death and
link-degradation scenarios (each run twice for bit-for-bit determinism,
verification forced on), pinning ``recovery_exact`` /
``degraded_slowdown`` into the summary and the exit code.

Every run also executes the plan-cache canary: a cold-then-warm double
pass of a networks x {ring, torus2x2} x budget-point sweep through the
persistent ``repro.plancache`` store (a throwaway directory, via
``repro.launch.plan_server``), pinning ``plan_cache_warm_speedup`` and
``plan_cache_hit_rate`` into the summary and the exit code — the warm
pass must be bit-identical (plan fingerprints), verifier-clean, and
beat the amortisation floor (5x full scope, 1.2x ``--fast``).

Full-scope runs (no ``--fast``, no ``--networks`` filter) also refresh
``BENCH_network_plan.json`` at the repo root — a stable, compact summary
(per-network duration, gain_vs_baseline, wall-clock, chip-scaling points)
that accumulates the perf trajectory across PRs; smoke/scoped runs leave
it untouched so degraded numbers never clobber the trajectory.

    PYTHONPATH=src python -m benchmarks.network_plan \
        [--networks lenet5 resnet8 tight4] [--size-mem N] \
        [--sweep-mem auto | --sweep-mem 2000 8000 ...] \
        [--sweep-chips auto | --sweep-chips 1 2 4 ...] \
        [--topology ring biring torus2x2 ...] \
        [--restarts 4] [--iters 6000] [--fast] [--profile] [--faults] \
        [--max-planner-seconds S] \
        [--out benchmarks/results/network_plan.json] \
        [--bench-out BENCH_network_plan.json]

``--fast`` is the CI smoke target: tiny polish budgets, the small
networks, and automatic sweeps (seconds, not minutes).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.analysis import kerncheck
from repro.analysis.diagnostics import PlanVerificationError
from repro.analysis.verifier import assert_verified
from repro.configs.clusters import make_cluster, torus_dims
from repro.configs.networks import NETWORKS
from repro.configs.tight import budget_points
from repro.core import solver
from repro.core.cost_model import HardwareModel, Topology
from repro.core.multichip import plan_multichip_network
from repro.core.network_planner import InfeasibleNetworkError, plan_network
from repro.obs import REGISTRY
from repro.obs import report as obs_report
from repro.obs.chrome import write_chrome_trace

# ------------------------------------------------------------------ #
# Frozen PR-3 planner numbers (full-scope defaults, rng_seed=0): the
# fixed reference for the ``gain_vs_pr3`` trajectory series.  Values are
# modeled total durations; chip points are the serialised-accounting
# totals at the tight budget (half the largest kernel set).
# ------------------------------------------------------------------ #
PR3_BASELINE = {
    "networks": {
        "lenet5": 3845.0, "resnet8": 75798.0,
        "tight2": 5903.0, "tight4": 24439.0,
    },
    "tight_sweep": {
        ("lenet5", 600): 9938.0, ("lenet5", 1200): 7722.0,
        ("lenet5", 2400): 6242.0, ("lenet5", 4800): 4629.0,
        ("tight2", 1152): 8596.0, ("tight2", 2304): 7152.0,
        ("tight2", 4608): 7146.0, ("tight2", 9216): 5903.0,
        ("tight4", 4608): 26769.0, ("tight4", 9216): 25450.0,
        ("tight4", 18432): 25448.0, ("tight4", 36864): 24439.0,
        ("resnet8", 9216): 99022.0, ("resnet8", 18432): 89228.0,
        ("resnet8", 36864): 81090.0, ("resnet8", 73728): 75798.0,
    },
    "chip_sweep": {
        ("lenet5", 1): 7722.0, ("lenet5", 2): 7722.0,
        ("lenet5", 4): 7722.0, ("lenet5", 8): 7722.0,
        ("resnet8", 1): 89228.0, ("resnet8", 2): 90668.0,
        ("resnet8", 4): 85422.0, ("resnet8", 8): 83758.0,
        ("tight2", 1): 7152.0, ("tight2", 2): 7152.0,
        ("tight2", 4): 7152.0, ("tight2", 8): 7152.0,
        ("tight4", 1): 25450.0, ("tight4", 2): 20669.0,
        ("tight4", 4): 17529.0, ("tight4", 8): 16209.0,
    },
}


def _gain_vs_pr3(table: str, key, duration: float) -> float | None:
    base = PR3_BASELINE[table].get(key)
    if not base:
        return None
    return round(1.0 - duration / base, 4)


def _verify_plan(plan) -> bool:
    """Static postcondition on every benchmarked plan (repro.analysis):
    a False here is a planner/cost-model bug, and the run fails."""
    try:
        assert_verified(plan)
        return True
    except PlanVerificationError as e:
        print(f"[verify] FAIL:\n{e.report.render()}", file=sys.stderr)
        return False


def _kerncheck_clean(networks: list[str]) -> bool:
    """Kernel-contract postcondition (repro.analysis.kerncheck): every
    benchmarked network's emitted Pallas kernels are statically proven
    contract-equivalent to their plans.  A False is an emitter or
    kernel bug, and the run fails."""
    report = kerncheck.run_all(sorted(networks))
    if not report.ok:
        print(f"[kerncheck] FAIL:\n{report.render()}", file=sys.stderr)
    return report.ok


def _record_lru_stats() -> None:
    """Mirror the solver LRU counters into the obs metrics registry.
    ``evictions`` is ``misses - currsize`` (exact within a clear-epoch:
    main() clears both LRUs at start): nonzero means the sweep visited
    more distinct keys than ``maxsize`` holds and silently re-solved —
    raise ``REPRO_SOLVE_CACHE_SIZE`` (0 = unbounded) to stop the thrash."""
    for name, info in (("solve_cached", solver.solve_cached.cache_info()),
                       ("best_s2_cached",
                        solver.best_s2_cached.cache_info())):
        REGISTRY.set(f"lru/{name}/hits", info.hits)
        REGISTRY.set(f"lru/{name}/misses", info.misses)
        REGISTRY.set(f"lru/{name}/hit_rate",
                     round(info.hits / max(1, info.hits + info.misses), 4))
        REGISTRY.set(f"lru/{name}/evictions",
                     max(0, info.misses - info.currsize))
        REGISTRY.set(f"lru/{name}/maxsize", info.maxsize or 0)


def build_profile() -> dict:
    """The ``--profile`` payload, read back from the obs metrics registry
    (stage timers accumulated in :func:`main`, LRU counters mirrored by
    :func:`_record_lru_stats`, per-call planner detail from the hooks in
    ``core.network_planner`` / ``core.multichip``).  The
    ``planner_seconds`` / ``stages`` / ``lru`` keys and shapes are byte-
    stable against the pre-obs inline implementation — they are the
    frozen trajectory vocabulary; ``planner`` is the additive detail."""
    stage_keys = ("networks_s", "mem_sweep_s", "chip_sweep_s")
    stages = {k: round(REGISTRY.get(f"bench/{k}"), 4) for k in stage_keys}
    profile = {
        "planner_seconds": round(
            sum(REGISTRY.get(f"bench/{k}") for k in stage_keys), 4),
        "stages": stages,
        "lru": {
            name: {"hits": int(REGISTRY.get(f"lru/{name}/hits")),
                   "misses": int(REGISTRY.get(f"lru/{name}/misses")),
                   "hit_rate": REGISTRY.get(f"lru/{name}/hit_rate"),
                   "evictions": int(REGISTRY.get(f"lru/{name}/evictions")),
                   "maxsize": int(REGISTRY.get(f"lru/{name}/maxsize"))}
            for name in ("solve_cached", "best_s2_cached")},
    }
    planner = REGISTRY.snapshot("planner")
    if planner:
        profile["planner"] = planner
    return profile


#: The observability canary: the network x topology point every in-scope
#: benchmark run replays through plan -> simulate -> kernel-trace ->
#: drift reconciliation.  tight4 exercises the S2 fallback and all four
#: sharding modes on the 2x2 torus while staying seconds-fast.
OBS_CANARY = ("tight4", "torus2x2")


def run_obs_canary(*, iters: int, restarts: int, rng_seed: int,
                   out_dir: str) -> dict:
    """Plan the canary point, execute it functionally, statically trace
    its kernels, export the unified Chrome trace, and reconcile the
    three timelines (``repro.obs.report``).  ``reconciled`` is folded
    into the benchmark exit code — nonzero drift between the planner's
    predictions and what the simulator measured is a cost-model bug."""
    network, topology = OBS_CANARY
    with REGISTRY.timer("bench/obs_canary_s"):
        rep = obs_report.build_report(
            network, topology=topology, iters=iters,
            restarts=restarts, rng_seed=rng_seed)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    trace_path = os.path.join(
        out_dir, f"obs_trace_{network}_{topology}.json")
    write_chrome_trace(rep.trace, trace_path)
    if not rep.ok:
        print(f"[obs] canary FAIL:\n{rep.render()}", file=sys.stderr)
    return {
        "network": network,
        "topology": topology,
        "obs_trace_valid": rep.trace_valid,
        "max_drift_elements": rep.max_drift_elements,
        "max_drift_cycles": rep.max_drift_cycles,
        "trace_events": len(rep.trace["traceEvents"]),
        "trace_path": trace_path,
        "reconciled": rep.ok,
    }


#: The resilience canary scenarios every ``--faults`` run replays on the
#: canary point: a chip death (wasted stage + detection + degraded
#: re-plan + restage + retry) and an ICI link degradation (boundary
#: re-plan, no recompute).  Each runs twice (bit-for-bit determinism
#: check) with plan verification forced on.
FAULT_SCENARIOS = ("chip-death", "link-degrade")


def run_fault_canary(*, iters: int, restarts: int, rng_seed: int,
                     seed: int = 0) -> dict:
    """Fault-injection postcondition (``repro.resil``): the canary
    network must recover from both scenarios with exactly-once outputs
    equal to the fault-free reference, verified degraded re-plans, and a
    reproducible fingerprint.  ``recovery_exact`` / ``degraded_slowdown``
    are pinned into the summary and the exit code."""
    from repro.resil import faultsim
    network, topology = OBS_CANARY
    specs = NETWORKS[network]
    rows = []
    with REGISTRY.timer("bench/faultsim_s"):
        for scenario in FAULT_SCENARIOS:
            schedule = faultsim.build_schedule(
                scenario, seed, n_layers=len(specs), n_chips=4)
            rep, findings = faultsim.run_checked(
                network, schedule, topology=topology, seed=seed,
                iters=iters, restarts=restarts, rng_seed=rng_seed)
            if findings:
                print(f"[faults] {scenario} FAIL: {findings}",
                      file=sys.stderr)
            rows.append({
                "scenario": scenario,
                "schedule": schedule.describe(),
                "recovery_exact": rep.recovery_exact,
                "exactly_once": rep.write_counts_ok,
                "no_free_lunch": rep.no_free_lunch,
                "degraded_slowdown": round(rep.degraded_slowdown, 4),
                "replans": len(rep.recoveries),
                "wasted_cycles": rep.wasted_cycles,
                "recovery_cycles": rep.recovery_cycles,
                "findings": findings,
                "ok": rep.ok and not findings,
            })
    return {
        "network": network, "topology": topology, "seed": seed,
        "scenarios": rows,
        "recovery_exact": all(r["recovery_exact"] for r in rows),
        "degraded_slowdown": max(r["degraded_slowdown"] for r in rows),
        "ok": all(r["ok"] for r in rows),
    }


#: Topology axis of the plan-cache canary sweep — ring (the PR-3
#: baseline wiring) plus the 2x2 torus that exercises the hybrid modes.
CACHE_TOPOLOGIES = ("ring", "torus2x2")


def run_cache_canary(*, networks: list, iters: int, restarts: int,
                     rng_seed: int, nbop_pe: int, fast: bool) -> dict:
    """Cold-then-warm double pass through the persistent plan cache
    (``repro.plancache`` behind ``repro.launch.plan_server``): sweep
    networks x {ring, torus2x2} x budget points into a throwaway store,
    clear the in-memory LRUs, and replay the identical sweep.  Pins
    ``plan_cache_warm_speedup`` and ``plan_cache_hit_rate`` (folded into
    the exit code): the warm pass must answer from the store, at least
    ``min_speedup`` x faster, bit-identical (plan fingerprints), and
    verifier-clean.  Runs in its own timer/store and restores the env,
    so it never pollutes the planner profile or a user-configured
    cache."""
    import shutil
    import tempfile

    from repro.launch.plan_server import PlanService
    from repro.plancache import store as plan_store

    if fast:
        nets = sorted(n for n in networks if n in ("lenet5", "tight2")) \
            or ["tight2"]
        budgets = {n: budget_points(NETWORKS[n])[-2:] for n in nets}
    else:
        nets = sorted(networks)
        budgets = {n: budget_points(NETWORKS[n]) for n in nets}
    chip_counts = (1, 4)              # 4 so torus2x2 exists

    prev_root = os.environ.get(plan_store.ENV_VAR)
    tmp = tempfile.mkdtemp(prefix="plancache-canary-")
    try:
        plan_store.configure(tmp)
        service = PlanService()

        # server-grade knobs: the canary measures the cache, not plan
        # quality — sweep-query polish budgets keep the cold pass
        # tractable at full scope (plan_server's own defaults)
        canary_iters = min(iters, 600)

        def run_pass() -> tuple[list, float]:
            solver.solve_cached.cache_clear()
            solver.best_s2_cached.cache_clear()
            t0 = time.perf_counter()
            rows = []
            for n in nets:
                rows.extend(service.sweep(
                    n, budgets=budgets[n], topologies=CACHE_TOPOLOGIES,
                    chip_counts=chip_counts, nbop_pe=nbop_pe,
                    polish_iters=canary_iters, polish_restarts=1,
                    rng_seed=rng_seed))
            return rows, time.perf_counter() - t0

        with REGISTRY.timer("bench/cache_canary_s"):
            store = plan_store.active_store()
            cold_rows, cold_s = run_pass()
            hits0, misses0 = store.hits, store.misses
            warm_rows, warm_s = run_pass()
            warm_hits = store.hits - hits0
            warm_misses = store.misses - misses0
    finally:
        if prev_root is None:
            plan_store.configure(None)
        else:
            plan_store.configure(prev_root)
        plan_store.reset()
        shutil.rmtree(tmp, ignore_errors=True)
        solver.solve_cached.cache_clear()
        solver.best_s2_cached.cache_clear()

    speedup = cold_s / max(warm_s, 1e-9)
    hit_rate = warm_hits / max(1, warm_hits + warm_misses)
    bit_identical = len(cold_rows) == len(warm_rows) and all(
        c["feasible"] == w["feasible"]
        and c.get("fingerprint") == w.get("fingerprint")
        for c, w in zip(cold_rows, warm_rows))
    verified = all(r["verified"] for r in cold_rows + warm_rows
                   if r["feasible"])
    # a --fast cold pass is already seconds-cheap, so the amortisation
    # floor is relaxed there; full runs must clear the ISSUE-10 5x bar
    min_speedup = 1.2 if fast else 5.0
    ok = bit_identical and verified and speedup >= min_speedup
    if not ok:
        print(f"[plancache] canary FAIL: speedup {speedup:.1f}x "
              f"(floor {min_speedup}x), bit_identical={bit_identical}, "
              f"verified={verified}", file=sys.stderr)
    return {
        "networks": nets,
        "topologies": list(CACHE_TOPOLOGIES),
        "chip_counts": list(chip_counts),
        "scenarios": len(cold_rows),
        "cold_seconds": round(cold_s, 4),
        "warm_seconds": round(warm_s, 4),
        "plan_cache_warm_speedup": round(speedup, 2),
        "plan_cache_hit_rate": round(hit_rate, 4),
        "min_speedup": min_speedup,
        "bit_identical": bit_identical,
        "verified": verified,
        "ok": ok,
    }


def bench_network(name: str, hw: HardwareModel, *, iters: int,
                  restarts: int, rng_seed: int) -> dict:
    specs = NETWORKS[name]
    t0 = time.perf_counter()
    try:
        plan = plan_network(specs, hw, name=name, polish_iters=iters,
                            polish_restarts=restarts, rng_seed=rng_seed)
    except InfeasibleNetworkError as e:
        return {"network": name, "feasible": False, "error": str(e)}
    wall = time.perf_counter() - t0
    return {
        "network": name,
        "feasible": True,
        "verifier_clean": _verify_plan(plan),
        "n_layers": plan.n_layers,
        "n_s2_layers": plan.n_s2_layers,
        "peak_footprint": plan.peak_footprint,
        "planning_wall_s": round(wall, 4),
        "planner_seconds": round(wall, 4),
        "gain_vs_pr3": _gain_vs_pr3("networks", name, plan.total_duration),
        "planning_layers_per_s": round(plan.n_layers / max(wall, 1e-9), 2),
        "solver_calls": plan.solver_calls,
        "cache_hits": plan.cache_hits,
        "total_duration": plan.total_duration,
        "gross_duration": plan.gross_duration,
        "greedy_baseline_duration": plan.baseline_duration,
        "gain_vs_baseline": round(plan.gain_vs_baseline, 4),
        "beats_baseline": plan.total_duration < plan.baseline_duration,
        "critical_path": [
            {"layer": i, "duration": d, "fraction": round(f, 4)}
            for i, d, f in plan.critical_path()],
        "layers": [
            {"index": lp.index,
             "shape": f"{lp.spec.c_in}x{lp.spec.h_in}x{lp.spec.w_in}"
                      f"->{lp.spec.c_out}x{lp.spec.h_out}x{lp.spec.w_out}",
             "p": lp.p,
             "mode": lp.mode,
             "strategy": lp.strategy.name,
             "steps": lp.strategy.n_steps,
             "peak_footprint": lp.strategy.peak_footprint_elements(),
             "duration": lp.duration,
             "gross_duration": lp.gross_duration,
             "optimality_gap": round(lp.result.gap, 4),
             "reuse_input": lp.reuse_input,
             "reuse_output": lp.reuse_output,
             "window_rows": lp.window_rows}
            for lp in plan.layers],
    }


def sweep_tight_memory(name: str, budgets: list[int], *, nbop_pe: int,
                       iters: int, restarts: int, rng_seed: int) -> dict:
    """Plan ``name`` under every budget: the S1→S2 crossover grid."""
    rows = []
    for size_mem in budgets:
        hw = HardwareModel(nbop_pe=nbop_pe, size_mem=size_mem)
        try:
            plan = plan_network(NETWORKS[name], hw, name=name,
                                polish_iters=iters,
                                polish_restarts=restarts, rng_seed=rng_seed)
        except InfeasibleNetworkError as e:
            rows.append({"size_mem": size_mem, "feasible": False,
                         "error": str(e)})
            continue
        rows.append({
            "size_mem": size_mem,
            "feasible": True,
            "verifier_clean": _verify_plan(plan),
            "n_s2_layers": plan.n_s2_layers,
            "peak_footprint": plan.peak_footprint,
            "total_duration": plan.total_duration,
            "greedy_baseline_duration": plan.baseline_duration,
            "gain_vs_baseline": round(plan.gain_vs_baseline, 4),
            "gain_vs_pr3": _gain_vs_pr3("tight_sweep", (name, size_mem),
                                        plan.total_duration),
            "beats_baseline": plan.total_duration < plan.baseline_duration,
            "layer_modes": [lp.mode for lp in plan.layers],
        })
    return {"network": name, "points": rows}


def _resolve_topology(topology: str, n_chips: int) -> str | None:
    """Concrete topology label for a sweep point, or None when the
    combination does not exist (torus needs a 2-D grid of exactly
    ``n_chips``).  One chip has no links, so every wiring resolves to
    the same ``ring`` baseline point there (deduped by the caller)."""
    if n_chips == 1:
        return "ring"
    if topology in ("ring", "biring"):
        return topology
    if topology == "torus":                # auto: squarest grid
        dims = torus_dims(n_chips)
        return None if dims is None else f"torus{dims[0]}x{dims[1]}"
    ny, nx = Topology.parse(topology).dims
    return topology if ny * nx == n_chips else None


def sweep_chip_counts(name: str, chip_counts: list[int],
                      topologies: list[str], *, nbop_pe: int,
                      iters: int, restarts: int, rng_seed: int) -> dict:
    """Plan ``name`` on each (chip count x topology) at the tight budget
    (half the largest kernel set Λ — the regime where sharding either
    restores S1 feasibility or loses to resharding ICI traffic).
    Topologies: ``ring`` (PR-3 unidirectional baseline), ``biring``,
    ``torusRxC`` or ``torus`` (auto-dims: 2x2 at 4 chips, 2x4 at 8).
    Every point is planned twice: with the serialised PR-3 accounting
    (``overlap=False``) and with overlap + duration-balanced bands — the
    LRU-shared shard solves make the later plans nearly free (shard
    sub-convolutions are identical across topologies)."""
    specs = NETWORKS[name]
    size_mem = max(s.kernel_elements for s in specs) // 2
    rows = []
    single = None
    for n_chips in chip_counts:
        seen: set[str] = set()
        for topology in topologies:
            label = _resolve_topology(topology, n_chips)
            if label is None or label in seen:
                continue            # e.g. '--topology torus torus2x2'
            seen.add(label)         # resolves to one 4-chip point
            cluster = make_cluster(n_chips, nbop_pe=nbop_pe,
                                   size_mem=size_mem, topology=label)
            t0 = time.perf_counter()
            try:
                ser = plan_multichip_network(
                    specs, cluster, name=name, polish_iters=iters,
                    polish_restarts=restarts, rng_seed=rng_seed,
                    include_single_chip_baseline=False)
                plan = plan_multichip_network(
                    specs, cluster, name=name, polish_iters=iters,
                    polish_restarts=restarts, rng_seed=rng_seed,
                    include_single_chip_baseline=False,
                    overlap=True, balance_rows=True)
            except InfeasibleNetworkError as e:
                rows.append({"n_chips": n_chips, "topology": label,
                             "feasible": False, "error": str(e)})
                continue
            wall = time.perf_counter() - t0
            if n_chips == 1 and single is None:
                single = plan.total_duration
            rows.append({
                "n_chips": n_chips,
                "topology": label,
                "feasible": True,
                "verifier_clean": _verify_plan(ser) and _verify_plan(plan),
                "total_duration": plan.total_duration,
                "serialized_duration": ser.total_duration,
                "modes": plan.mode_string,
                "serialized_modes": ser.mode_string,
                "n_sharded_layers": plan.n_sharded_layers,
                "ici_fraction": round(plan.ici_fraction, 4),
                "peak_footprint": plan.peak_footprint,
                "planning_wall_s": round(wall, 4),
                "speedup_vs_1chip": (round(single / plan.total_duration, 4)
                                     if single else None),
                "gain_vs_pr3": _gain_vs_pr3("chip_sweep", (name, n_chips),
                                            plan.total_duration),
            })
    return {"network": name, "size_mem": size_mem,
            "t_ici": make_cluster(1, nbop_pe=nbop_pe).t_ici,
            "points": rows}


def _all_verifier_clean(rows: list[dict], chip_sweeps: list[dict],
                        sweeps: list[dict] | None) -> bool:
    """True when every feasible plan the run built passed the static
    verifier (the ISSUE-6 pin: a False is a planner/cost-model bug)."""
    points = list(rows)
    for sw in list(sweeps or []) + list(chip_sweeps):
        points.extend(sw["points"])
    return all(p.get("verifier_clean", True) for p in points
               if p["feasible"])


def write_bench_summary(path: str, rows: list[dict],
                        chip_sweeps: list[dict],
                        sweeps: list[dict] | None = None,
                        profile: dict | None = None,
                        kerncheck_clean: bool = True,
                        obs_canary: dict | None = None,
                        fault_canary: dict | None = None,
                        cache_canary: dict | None = None) -> None:
    """Stable repo-root summary: the perf-trajectory file other PRs diff.
    ``planner_seconds`` and ``gain_vs_pr3`` are the stable trajectory
    keys (baseline: the frozen ``PR3_BASELINE`` table);
    ``obs_trace_valid`` / ``max_drift_elements`` pin the observability
    canary's drift reconciliation (``repro.obs``)."""
    summary = {
        "benchmark": "network_plan",
        "verifier_clean": _all_verifier_clean(rows, chip_sweeps, sweeps),
        "kerncheck_clean": kerncheck_clean,
        "networks": [
            {"network": r["network"],
             "feasible": r["feasible"],
             **({"total_duration": r["total_duration"],
                 "gain_vs_baseline": r["gain_vs_baseline"],
                 "gain_vs_pr3": r["gain_vs_pr3"],
                 "planning_wall_s": r["planning_wall_s"],
                 "planner_seconds": r["planner_seconds"]}
                if r["feasible"] else {})}
            for r in sorted(rows, key=lambda r: r["network"])],
        "tight_sweep": [
            {"network": sw["network"],
             "points": [
                 {"size_mem": p["size_mem"], "feasible": p["feasible"],
                  **({"total_duration": p["total_duration"],
                      "gain_vs_pr3": p["gain_vs_pr3"]}
                     if p["feasible"] else {})}
                 for p in sw["points"]]}
            for sw in sorted(sweeps or [], key=lambda s: s["network"])],
        "chip_sweep": [
            {"network": sw["network"], "size_mem": sw["size_mem"],
             "points": [
                 {"n_chips": p["n_chips"],
                  "topology": p.get("topology", "ring"),
                  "feasible": p["feasible"],
                  **({"total_duration": p["total_duration"],
                      "serialized_duration": p["serialized_duration"],
                      "modes": p["modes"],
                      "speedup_vs_1chip": p["speedup_vs_1chip"],
                      "gain_vs_pr3": p["gain_vs_pr3"]}
                     if p["feasible"] else {})}
                 for p in sw["points"]]}
            for sw in sorted(chip_sweeps, key=lambda s: s["network"])],
    }
    if obs_canary is not None:
        summary["obs_trace_valid"] = obs_canary["obs_trace_valid"]
        summary["max_drift_elements"] = obs_canary["max_drift_elements"]
        summary["obs_canary"] = {
            k: obs_canary[k] for k in
            ("network", "topology", "obs_trace_valid",
             "max_drift_elements", "max_drift_cycles", "trace_events",
             "reconciled")}
    if fault_canary is not None:
        summary["recovery_exact"] = fault_canary["recovery_exact"]
        summary["degraded_slowdown"] = fault_canary["degraded_slowdown"]
        summary["fault_canary"] = {
            "network": fault_canary["network"],
            "topology": fault_canary["topology"],
            "seed": fault_canary["seed"],
            "scenarios": [
                {k: r[k] for k in
                 ("scenario", "recovery_exact", "exactly_once",
                  "no_free_lunch", "degraded_slowdown", "replans", "ok")}
                for r in fault_canary["scenarios"]],
        }
    if cache_canary is not None:
        summary["plan_cache_warm_speedup"] = \
            cache_canary["plan_cache_warm_speedup"]
        summary["plan_cache_hit_rate"] = cache_canary["plan_cache_hit_rate"]
        summary["cache_canary"] = {
            k: cache_canary[k] for k in
            ("networks", "topologies", "chip_counts", "scenarios",
             "cold_seconds", "warm_seconds", "plan_cache_warm_speedup",
             "plan_cache_hit_rate", "bit_identical", "verified", "ok")}
    if profile is not None:
        summary["profile"] = profile
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="+", default=None,
                    choices=sorted(NETWORKS))
    ap.add_argument("--size-mem", type=int, default=None,
                    help="on-chip budget in elements (default: unconstrained,"
                         " the paper's Sec-7.1 setting)")
    ap.add_argument("--sweep-mem", nargs="+", default=None,
                    help="budgets for the tight-memory sweep: explicit "
                         "element counts, or 'auto' for fractions of each "
                         "network's largest kernel set")
    ap.add_argument("--sweep-chips", nargs="+", default=None,
                    help="chip counts for the multi-chip scaling sweep: "
                         "explicit counts, or 'auto' for 1 2 4 8")
    ap.add_argument("--topology", nargs="+", default=None,
                    help="topologies for the chip sweep: 'ring' (PR-3 "
                         "unidirectional baseline), 'biring', 'torusRxC', "
                         "or 'torus' (auto-dims per chip count); default "
                         "'ring torus'")
    ap.add_argument("--nbop-pe", type=int, default=10 ** 9)
    ap.add_argument("--iters", type=int, default=6000)
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--rng-seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="smoke preset: small networks, tiny polish budget, "
                         "auto sweeps")
    ap.add_argument("--profile", action="store_true",
                    help="emit per-stage planner wall-clock and solver-LRU "
                         "hit rates (stable keys planner_seconds / "
                         "gain_vs_pr3) for the perf trajectory")
    ap.add_argument("--faults", action="store_true",
                    help="replay the fault-injection canary (chip-death "
                         "+ link-degrade on the canary point, "
                         "repro.resil) and pin recovery_exact / "
                         "degraded_slowdown into the summary and exit "
                         "code")
    ap.add_argument("--max-planner-seconds", type=float, default=None,
                    help="fail (exit 1) when the total planner wall-clock "
                         "exceeds this bound — the CI guardrail against "
                         "accidentally un-capping polish budgets")
    ap.add_argument("--out", default="benchmarks/results/network_plan.json")
    ap.add_argument("--bench-out", default="BENCH_network_plan.json",
                    help="stable perf-trajectory summary at the repo root "
                         "(written only by full-scope runs: no --fast, no "
                         "--networks filter — smoke numbers must not "
                         "clobber the trajectory)")
    args = ap.parse_args(argv)

    trajectory_grade = not args.fast and args.networks is None
    if args.fast:
        args.networks = args.networks or ["lenet5", "tight2", "tight4"]
        args.iters = min(args.iters, 300)
        args.restarts = min(args.restarts, 1)
        args.sweep_mem = args.sweep_mem or ["auto"]
        args.sweep_chips = args.sweep_chips or ["1", "2", "4"]
    if args.sweep_chips == ["auto"]:
        args.sweep_chips = ["1", "2", "4", "8"]
    topologies = args.topology or ["ring", "torus"]
    for t in topologies:
        if t != "torus":                   # 'torus' = auto-dims
            try:
                Topology.parse(t)
            except ValueError as e:
                ap.error(f"--topology: {e}")
    networks = args.networks or sorted(NETWORKS)

    hw = HardwareModel(nbop_pe=args.nbop_pe, size_mem=args.size_mem)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    REGISTRY.clear()
    with REGISTRY.timer("bench/networks_s"):
        rows = [bench_network(n, hw, iters=args.iters,
                              restarts=args.restarts,
                              rng_seed=args.rng_seed) for n in networks]

    sweeps = []
    with REGISTRY.timer("bench/mem_sweep_s"):
        if args.sweep_mem:
            for n in networks:
                if args.sweep_mem == ["auto"]:
                    budgets = budget_points(NETWORKS[n])
                else:
                    budgets = sorted(int(b) for b in args.sweep_mem)
                sweeps.append(sweep_tight_memory(
                    n, budgets, nbop_pe=args.nbop_pe, iters=args.iters,
                    restarts=args.restarts, rng_seed=args.rng_seed))

    chip_sweeps = []
    with REGISTRY.timer("bench/chip_sweep_s"):
        if args.sweep_chips:
            counts = sorted({int(c) for c in args.sweep_chips})
            for t in topologies:           # a torus matching no swept
                if t.startswith("torus") and not any(  # count (beyond
                        _resolve_topology(t, n)       # the shared n=1
                        for n in counts if n > 1):    # ring baseline)
                    print(f"[network_plan] --topology {t} matches no "
                          f"--sweep-chips count in {counts}",
                          file=sys.stderr)    # is a typo, not an empty
                    return 2                  # sweep
            for n in networks:
                chip_sweeps.append(sweep_chip_counts(
                    n, counts, topologies, nbop_pe=args.nbop_pe,
                    iters=args.iters, restarts=args.restarts,
                    rng_seed=args.rng_seed))

    # total planner wall-clock (the --max-planner-seconds guard) = the
    # three stage timers; the obs canary below is excluded by design —
    # it measures the *simulator*, not the planner
    total_wall = sum(REGISTRY.get(f"bench/{k}") for k in
                     ("networks_s", "mem_sweep_s", "chip_sweep_s"))
    _record_lru_stats()
    profile = build_profile() if args.profile else None

    verifier_clean = _all_verifier_clean(rows, chip_sweeps, sweeps)
    kerncheck_clean = _kerncheck_clean(networks)
    out_dir = os.path.dirname(args.out)
    obs_canary = None
    if OBS_CANARY[0] in networks:
        obs_canary = run_obs_canary(
            iters=args.iters, restarts=args.restarts,
            rng_seed=args.rng_seed,
            out_dir=out_dir or "benchmarks/results")
    fault_canary = None
    if args.faults:
        fault_canary = run_fault_canary(
            iters=args.iters, restarts=args.restarts,
            rng_seed=args.rng_seed)
    # after the profile is built: the canary's throwaway store and LRU
    # clears must not pollute the planner trajectory numbers
    cache_canary = run_cache_canary(
        networks=networks, iters=args.iters, restarts=args.restarts,
        rng_seed=args.rng_seed, nbop_pe=args.nbop_pe, fast=args.fast)
    result = {"hw": {"nbop_pe": args.nbop_pe, "size_mem": args.size_mem,
                     "t_l": hw.t_l, "t_w": hw.t_w, "t_acc": hw.t_acc},
              "polish": {"iters": args.iters, "restarts": args.restarts},
              "verifier_clean": verifier_clean,
              "kerncheck_clean": kerncheck_clean,
              "networks": rows,
              "tight_memory_sweep": sweeps,
              "chip_sweep": chip_sweeps}
    if obs_canary is not None:
        result["obs_canary"] = obs_canary
        result["obs_trace_valid"] = obs_canary["obs_trace_valid"]
        result["max_drift_elements"] = obs_canary["max_drift_elements"]
    if fault_canary is not None:
        result["fault_canary"] = fault_canary
        result["recovery_exact"] = fault_canary["recovery_exact"]
        result["degraded_slowdown"] = fault_canary["degraded_slowdown"]
    result["cache_canary"] = cache_canary
    result["plan_cache_warm_speedup"] = \
        cache_canary["plan_cache_warm_speedup"]
    result["plan_cache_hit_rate"] = cache_canary["plan_cache_hit_rate"]
    if profile is not None:
        result["profile"] = profile
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    if trajectory_grade:
        write_bench_summary(args.bench_out, rows, chip_sweeps,
                            sweeps=sweeps, profile=profile,
                            kerncheck_clean=kerncheck_clean,
                            obs_canary=obs_canary,
                            fault_canary=fault_canary,
                            cache_canary=cache_canary)

    for r in rows:
        if not r["feasible"]:
            print(f"[network_plan] {r['network']}: INFEASIBLE under "
                  f"size_mem={args.size_mem} ({r['error']})")
            continue
        print(f"[network_plan] {r['network']}: "
              f"planned {r['n_layers']} layers in {r['planning_wall_s']}s "
              f"({r['planning_layers_per_s']} layers/s, "
              f"{r['cache_hits']}/{r['solver_calls']} cache hits); "
              f"predicted {r['total_duration']:g} vs greedy "
              f"{r['greedy_baseline_duration']:g} "
              f"(gain {r['gain_vs_baseline']:.1%})")
    for sw in sweeps:
        for pt in sw["points"]:
            if not pt["feasible"]:
                print(f"[sweep] {sw['network']} mem={pt['size_mem']}: "
                      f"infeasible")
                continue
            print(f"[sweep] {sw['network']} mem={pt['size_mem']}: "
                  f"{pt['n_s2_layers']} S2 layers, "
                  f"plan {pt['total_duration']:g} vs greedy "
                  f"{pt['greedy_baseline_duration']:g} "
                  f"(gain {pt['gain_vs_baseline']:.1%})")
    for sw in chip_sweeps:
        for pt in sw["points"]:
            if not pt["feasible"]:
                print(f"[chips] {sw['network']} n={pt['n_chips']} "
                      f"{pt['topology']}: infeasible")
                continue
            sp = pt["speedup_vs_1chip"]
            print(f"[chips] {sw['network']} mem={sw['size_mem']} "
                  f"n={pt['n_chips']} {pt['topology']}: [{pt['modes']}] "
                  f"dur {pt['total_duration']:g} "
                  f"(serialized {pt['serialized_duration']:g}, "
                  f"ici {pt['ici_fraction']:.1%}"
                  f"{f', {sp}x vs 1 chip' if sp else ''})")
    if obs_canary is not None:
        print(f"[obs] canary {obs_canary['network']}@"
              f"{obs_canary['topology']}: "
              f"trace {'valid' if obs_canary['obs_trace_valid'] else 'INVALID'} "
              f"({obs_canary['trace_events']} events), max drift "
              f"{obs_canary['max_drift_elements']} el / "
              f"{obs_canary['max_drift_cycles']:g} cy -> "
              f"{'reconciled' if obs_canary['reconciled'] else 'FAIL'} "
              f"({obs_canary['trace_path']})")
    if fault_canary is not None:
        for r in fault_canary["scenarios"]:
            print(f"[faults] {fault_canary['network']}@"
                  f"{fault_canary['topology']} {r['scenario']}: "
                  f"recovery_exact={r['recovery_exact']} "
                  f"exactly_once={r['exactly_once']} "
                  f"slowdown={r['degraded_slowdown']}x "
                  f"({r['replans']} re-plans) -> "
                  f"{'ok' if r['ok'] else 'FAIL'}")
    print(f"[plancache] canary: {cache_canary['scenarios']} scenarios, "
          f"cold {cache_canary['cold_seconds']}s -> warm "
          f"{cache_canary['warm_seconds']}s "
          f"({cache_canary['plan_cache_warm_speedup']}x, hit rate "
          f"{cache_canary['plan_cache_hit_rate']:.0%}, "
          f"bit_identical={cache_canary['bit_identical']}) -> "
          f"{'ok' if cache_canary['ok'] else 'FAIL'}")
    if profile is not None:
        lru = profile["lru"]
        print(f"[profile] planner {profile['planner_seconds']}s "
              f"(networks {profile['stages']['networks_s']}s, "
              f"mem sweep {profile['stages']['mem_sweep_s']}s, "
              f"chip sweep {profile['stages']['chip_sweep_s']}s); "
              f"solve LRU {lru['solve_cached']['hit_rate']:.0%} hits "
              f"({lru['solve_cached']['evictions']} evictions), "
              f"S2 LRU {lru['best_s2_cached']['hit_rate']:.0%} hits")
    print("saved ->", args.out,
          *(["and", args.bench_out] if trajectory_grade else []))

    if not verifier_clean:
        print("[verify] at least one emitted plan failed static "
              "verification — planner/cost-model bug", file=sys.stderr)
    if not kerncheck_clean:
        print("[kerncheck] at least one emitted kernel failed the "
              "contract check — emitter/kernel bug", file=sys.stderr)
    if obs_canary is not None and not obs_canary["reconciled"]:
        print("[obs] the observability canary found drift between the "
              "plan's predictions and the simulator's measurements (or "
              "an invalid trace) — cost-model/simulator bug",
              file=sys.stderr)
    if fault_canary is not None and not fault_canary["ok"]:
        print("[faults] the fault-injection canary broke a recovery "
              "invariant (exactly-once, exact stitching, accounting, "
              "determinism, or verification) — resil/engine bug",
              file=sys.stderr)
    if not cache_canary["ok"]:
        print("[plancache] the cold/warm cache canary failed (speedup "
              "floor, bit-identicality, or verification) — plancache/"
              "plan_server bug", file=sys.stderr)
    ok = verifier_clean and kerncheck_clean
    ok = ok and (obs_canary is None or obs_canary["reconciled"])
    ok = ok and (fault_canary is None or fault_canary["ok"])
    ok = ok and cache_canary["ok"]
    ok = ok and all(r["feasible"] and r["beats_baseline"] for r in rows)
    # the sweep must stay feasible and beat greedy on >= 1 budget point
    for sw in sweeps:
        feas = [p for p in sw["points"] if p["feasible"]]
        ok = ok and bool(feas) and any(p["beats_baseline"] for p in feas)
    # the chip sweep must stay feasible at every requested count, and the
    # overlap model must never lose to the serialised accounting
    for sw in chip_sweeps:
        ok = ok and all(p["feasible"] for p in sw["points"])
        ok = ok and all(p["total_duration"] <= p["serialized_duration"]
                        for p in sw["points"] if p["feasible"])
    if args.max_planner_seconds is not None and \
            total_wall > args.max_planner_seconds:
        print(f"[guard] planner wall-clock {total_wall:.1f}s exceeds "
              f"--max-planner-seconds {args.max_planner_seconds:.1f}s")
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
