"""Network-planning benchmark: plan whole conv networks (LeNet-5, ResNet-8)
and compare the predicted schedule against the per-layer-greedy baseline
(best Row-by-Row/ZigZag heuristic, no polish, no inter-layer reuse).

Emits one JSON per run with planning throughput (layers/sec), the total
predicted duration for plan vs. baseline, per-layer critical-path rows, and
the solve-cache hit rate.

    PYTHONPATH=src python -m benchmarks.network_plan \
        [--networks lenet5 resnet8] [--size-mem N] [--restarts 4] \
        [--iters 6000] [--out benchmarks/results/network_plan.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.configs.networks import NETWORKS
from repro.core import solver
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import plan_network


def bench_network(name: str, hw: HardwareModel, *, iters: int,
                  restarts: int, rng_seed: int) -> dict:
    specs = NETWORKS[name]
    t0 = time.perf_counter()
    plan = plan_network(specs, hw, name=name, polish_iters=iters,
                        polish_restarts=restarts, rng_seed=rng_seed)
    wall = time.perf_counter() - t0
    return {
        "network": name,
        "n_layers": plan.n_layers,
        "planning_wall_s": round(wall, 4),
        "planning_layers_per_s": round(plan.n_layers / max(wall, 1e-9), 2),
        "solver_calls": plan.solver_calls,
        "cache_hits": plan.cache_hits,
        "total_duration": plan.total_duration,
        "gross_duration": plan.gross_duration,
        "greedy_baseline_duration": plan.baseline_duration,
        "gain_vs_baseline": round(plan.gain_vs_baseline, 4),
        "beats_baseline": plan.total_duration < plan.baseline_duration,
        "critical_path": [
            {"layer": i, "duration": d, "fraction": round(f, 4)}
            for i, d, f in plan.critical_path()],
        "layers": [
            {"index": lp.index,
             "shape": f"{lp.spec.c_in}x{lp.spec.h_in}x{lp.spec.w_in}"
                      f"->{lp.spec.c_out}x{lp.spec.h_out}x{lp.spec.w_out}",
             "p": lp.p,
             "strategy": lp.strategy.name,
             "steps": lp.strategy.n_steps,
             "duration": lp.duration,
             "gross_duration": lp.gross_duration,
             "optimality_gap": round(lp.result.gap, 4),
             "reuse_input": lp.reuse_input,
             "reuse_output": lp.reuse_output}
            for lp in plan.layers],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--networks", nargs="+", default=sorted(NETWORKS),
                    choices=sorted(NETWORKS))
    ap.add_argument("--size-mem", type=int, default=None,
                    help="on-chip budget in elements (default: unconstrained,"
                         " the paper's Sec-7.1 setting)")
    ap.add_argument("--nbop-pe", type=int, default=10 ** 9)
    ap.add_argument("--iters", type=int, default=6000)
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--rng-seed", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/results/network_plan.json")
    args = ap.parse_args(argv)

    hw = HardwareModel(nbop_pe=args.nbop_pe, size_mem=args.size_mem)
    solver.solve_cached.cache_clear()
    rows = [bench_network(n, hw, iters=args.iters, restarts=args.restarts,
                          rng_seed=args.rng_seed) for n in args.networks]

    result = {"hw": {"nbop_pe": args.nbop_pe, "size_mem": args.size_mem,
                     "t_l": hw.t_l, "t_w": hw.t_w, "t_acc": hw.t_acc},
              "polish": {"iters": args.iters, "restarts": args.restarts},
              "networks": rows}
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)

    for r in rows:
        print(f"[network_plan] {r['network']}: "
              f"planned {r['n_layers']} layers in {r['planning_wall_s']}s "
              f"({r['planning_layers_per_s']} layers/s, "
              f"{r['cache_hits']}/{r['solver_calls']} cache hits); "
              f"predicted {r['total_duration']:g} vs greedy "
              f"{r['greedy_baseline_duration']:g} "
              f"(gain {r['gain_vs_baseline']:.1%})")
    print("saved ->", args.out)
    return 0 if all(r["beats_baseline"] for r in rows) else 1


if __name__ == "__main__":
    sys.exit(main())
