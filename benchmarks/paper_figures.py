"""Reproductions of the paper's experimental figures (Sec 7).

fig11 — ZigZag vs Row-by-Row duration on LeNet-5 conv layers across group
        sizes (paper Fig 11): same-shape curves, ZigZag wins small groups,
        crossover, identical at multiples of W_out.
fig12 — duration vs input size at group size 4 for OPL(solver) / ZigZag /
        Row-by-Row / S1-baseline (paper Fig 12).
fig13 — relative gain of the solver over best(ZigZag, RbR) across
        (input size x group size) (paper Fig 13): ~0% when the group covers
        the image, up to tens of % lower-left.

All durations use the paper's Sec 7.1 metric: t_l = t_acc = 1, delta =
sum |I_slice| + n.  Each entry is verified by functionally executing the
strategy in the simulator before timing is reported.
"""
from __future__ import annotations

import sys
import time

from repro.configs.lenet5 import LENET5_L1, LENET5_L2
from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import (best_heuristic, lower_bound, row_by_row,
                                   s1_baseline, zigzag)
from repro.sim import ConvLayer, System

HW = HardwareModel(nbop_pe=10 ** 12, size_mem=None)


def _verify(spec, strat):
    hw = HardwareModel(nbop_pe=10 ** 12, size_mem=None)
    rep = System(ConvLayer.random(spec), hw).run(strat)
    assert rep.correct, f"functional check failed for {strat.name}"


def fig11(rows: list[str], verify: bool = True) -> None:
    """name,us_per_call,derived csv rows for ZigZag vs RbR."""
    for lname, spec in (("lenet5_l1", LENET5_L1), ("lenet5_l2", LENET5_L2)):
        w_out = spec.w_out
        for p in range(2, 11):
            t0 = time.perf_counter()
            z = zigzag(spec, p)
            r = row_by_row(spec, p)
            us = (time.perf_counter() - t0) * 1e6
            if verify and p <= 4:
                _verify(spec, z)
                _verify(spec, r)
            zo, ro = z.objective(HW), r.objective(HW)
            rows.append(
                f"fig11_{lname}_p{p},{us:.1f},"
                f"zigzag={zo:.0f};row={ro:.0f};"
                f"winner={'zigzag' if zo < ro else 'row' if ro < zo else 'tie'};"
                f"multiple_of_wout={p % w_out == 0}")


def fig12(rows: list[str], time_limit: float = 10.0,
          polish_iters: int = 12_000) -> None:
    p = 4
    for n in range(4, 13):
        spec = ConvSpec(1, n, n, 1, 3, 3)
        t0 = time.perf_counter()
        res = solver.solve(spec, p=p, hw=HW, time_limit=time_limit,
                           polish_iters=polish_iters,
                           use_milp=(n <= 8))
        us = (time.perf_counter() - t0) * 1e6
        _verify(spec, res.strategy)
        zo = zigzag(spec, p).objective(HW)
        ro = row_by_row(spec, p).objective(HW)
        so = s1_baseline(spec).objective(HW)
        rows.append(
            f"fig12_n{n},{us:.0f},"
            f"opl={res.objective:.0f};zigzag={zo:.0f};row={ro:.0f};"
            f"s1_baseline={so:.0f};lb={res.lower_bound:.0f};"
            f"milp={res.milp_status}")


def fig13(rows: list[str], time_limit: float = 5.0,
          polish_iters: int = 8_000) -> None:
    for n in range(4, 13):
        for p in range(2, 11):
            spec = ConvSpec(1, n, n, 1, 3, 3)
            if spec.num_patches < 1:
                continue
            t0 = time.perf_counter()
            res = solver.solve(spec, p=p, hw=HW, time_limit=time_limit,
                               polish_iters=polish_iters,
                               use_milp=(n <= 6))
            us = (time.perf_counter() - t0) * 1e6
            gain = res.gain_vs_seed * 100.0
            rows.append(
                f"fig13_n{n}_p{p},{us:.0f},"
                f"gain_pct={gain:.1f};opl={res.objective:.0f};"
                f"seed={res.seed_objective:.0f};gap={res.gap * 100:.1f}%")


def fig_s2(rows: list[str]) -> None:
    """Beyond-paper figure (the paper's Sec-9 future work): S1 vs S2 under
    shrinking on-chip memory budgets on LeNet-5 L2.  S1 dies below
    'all kernels + one patch'; S2 keeps running (kernel subsets swap),
    paying duration for the reloads."""
    from repro.core import strategies_s2 as s2
    from repro.core.strategies import zigzag
    from repro.sim.s2 import run_s2
    from repro.sim import ConvLayer

    spec = LENET5_L2
    s1 = zigzag(spec, 8)
    s1_min_mem = (spec.kernel_elements
                  + s1.peak_input_footprint() * spec.c_in
                  + 8 * spec.c_out * 2)
    for frac in (2.0, 1.0, 0.5, 0.25, 0.1):
        budget = int(s1_min_mem * frac)
        t0 = time.perf_counter()
        try:
            res = s2.best_s2(spec, HardwareModel(nbop_pe=10 ** 9,
                                                 size_mem=budget))
            us = (time.perf_counter() - t0) * 1e6
            rep = run_s2(ConvLayer.random(spec),
                         HardwareModel(nbop_pe=10 ** 9, size_mem=budget),
                         res.strategy)
            assert rep.correct
            rows.append(
                f"figS2_mem{frac},{us:.0f},"
                f"budget={budget};s2={res.objective:.0f};"
                f"s1_feasible={res.feasible_s1};"
                f"strategy={res.strategy.name};peak={res.peak_memory}")
        except ValueError:
            rows.append(f"figS2_mem{frac},0,budget={budget};infeasible")


def main(fast: bool = False):
    rows: list[str] = ["name,us_per_call,derived"]
    fig11(rows)
    if fast:
        fig12(rows, time_limit=2.0, polish_iters=3000)
    else:
        fig12(rows)
        fig13(rows)
    fig_s2(rows)
    print("\n".join(rows))
    return rows


if __name__ == "__main__":
    main(fast="--fast" in sys.argv)
