"""§Roofline table builder: reads the dry-run JSONs and derives the three
roofline terms per (arch x shape x mesh).

    compute term    = HLO_matmul_FLOPs / (chips x 197e12)
    memory term     = HLO_bytes / (chips x 819e9)
    collective term = collective_bytes / (chips x 50e9)

All three use the trip-count-corrected per-device numbers from
launch/hlo_stats.py (the per-device value IS the per-chip share, so the
formulas reduce to per_device / unit_rate).  MODEL_FLOPS comes from
launch/model_flops.py; the ratio exposes remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9          # v5e

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_cells(pattern: str = "*.json") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _compulsory_bytes_per_device(cell: dict) -> float | None:
    """Analytic LOWER bound on HBM traffic per device per step: parameters
    touched once (+ optimizer state r/w for train), inputs/cache once.
    The HLO count is the conservative UPPER bound; real TPU traffic lies
    between — both roofline fractions are reported."""
    try:
        from repro.models import registry
        from repro.models.common import SHAPES, count_params
        api = registry.get(cell["arch"])
        cellspec = SHAPES[cell["shape"]]
        n = count_params(api.param_defs())
        chips = cell["chips"]
        if cellspec.kind == "train":
            per_param = 2 + 2 + 16 + 8      # p r/w bf16, m+v r/w f32, grad f32 r/w... lower bound
            act = cellspec.global_batch * cellspec.seq_len * 4 / chips
            return per_param * n / chips + act
        if cellspec.kind == "prefill":
            cache = cell["memory"]["output_bytes"]      # written once
            return 2 * n / chips + cache
        # decode: weights once + cache once
        cache = cell["memory"]["argument_bytes"] \
            - 2 * n / chips                              # cache-ish args
        return 2 * n / chips + max(cache, 0)
    except Exception:
        return None


def derive(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    a = cell["analyzed"]
    t_comp = a["matmul_flops_per_device"] / PEAK_FLOPS
    t_mem = a["bytes_accessed_per_device"] / HBM_BW
    t_coll = a["collective_bytes_total"] / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    bound = max(t_comp, t_mem, t_coll)
    lb = _compulsory_bytes_per_device(cell)
    t_mem_lb = (lb / HBM_BW) if lb else t_mem
    bound_opt = max(t_comp, t_mem_lb, t_coll)
    out = {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "chips": cell["chips"],
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_lb_s": t_mem_lb,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
        "roofline_fraction_opt": t_comp / bound_opt if bound_opt > 0
        else 0.0,
        "peak_device_gb": cell["memory"]["peak_device_bytes"] / 1e9,
        "fits_v5e": cell["memory"]["peak_device_bytes"] <= HBM_PER_CHIP,
        "compile_s": cell["compile_s"],
    }
    # MODEL_FLOPS ratio
    try:
        from repro.launch.model_flops import model_flops
        from repro.models import registry
        from repro.models.common import SHAPES
        api = registry.get(cell["arch"])
        mf = model_flops(api, SHAPES[cell["shape"]])
        hlo_total = a["matmul_flops_per_device"] * cell["chips"]
        out["model_flops"] = mf
        out["model_over_hlo"] = mf / hlo_total if hlo_total else 0.0
    except Exception as e:                      # pragma: no cover
        out["model_flops_error"] = str(e)
    return out


def table(rows: list[dict]) -> str:
    hdr = (f"{'arch':17s} {'shape':12s} {'mesh':10s} "
           f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
           f"{'dom':>5s} {'roofl%':>13s} {'MF/HLO':>7s} {'GB':>6s} fits")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        frac = (f"{100 * r['roofline_fraction']:5.1f}-"
                f"{100 * r.get('roofline_fraction_opt', 0):5.1f}%")
        lines.append(
            f"{r['arch']:17s} {r['shape']:12s} {r['mesh']:10s} "
            f"{r['t_compute_s']:9.3e} {r['t_memory_s']:9.3e} "
            f"{r['t_collective_s']:9.3e} {r['dominant'][:5]:>5s} "
            f"{frac:>13s} "
            f"{r.get('model_over_hlo', 0):7.3f} "
            f"{r['peak_device_gb']:6.2f} {'y' if r['fits_v5e'] else 'N'}")
    return "\n".join(lines)


def main():
    cells = load_cells()
    rows = [d for d in (derive(c) for c in cells) if d]
    skips = [c for c in cells if c.get("status") == "skipped"]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(table(rows))
    print(f"\n{len(rows)} compiled cells, {len(skips)} recorded skips")
    for c in skips:
        print(f"  SKIP {c['arch']} {c['shape']} ({c['mesh'] if 'mesh' in c else ''}): {c['reason']}")
    out = os.path.join(RESULTS, "..", "roofline_table.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print("saved ->", os.path.normpath(out))


if __name__ == "__main__":
    sys.exit(main())
