"""Benchmark harness entry point — one section per paper table/figure plus
kernel microbenches and the roofline summary.  Prints
``name,us_per_call,derived`` CSV rows (scaffold contract).

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import sys
import time


def _kernel_microbench(rows: list[str]) -> None:
    """Interpret-mode kernels vs jnp oracles: correctness + derived
    schedule stats from the planner (CPU wall time is NOT a TPU proxy; the
    derived column carries the planner's byte/step model)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import planner
    from repro.core.conv_spec import ConvSpec
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)

    x = rng.standard_normal((3, 16, 18)).astype(np.float32)
    w = rng.standard_normal((8, 3, 3, 3)).astype(np.float32)
    t0 = time.perf_counter()
    out = ops.conv2d(x, w, t_run=4)
    us = (time.perf_counter() - t0) * 1e6
    err = float(jnp.max(jnp.abs(out - ref.conv2d(jnp.asarray(x),
                                                 jnp.asarray(w)))))
    spec = ConvSpec(3, 16, 18, 8, 3, 3)
    plan = planner.plan_conv(spec, dtype_bytes=4)
    rows.append(f"kernel_conv2d_offload,{us:.0f},"
                f"max_err={err:.1e};t_run={plan.tiles['t']};"
                f"steps={plan.steps};hbm_bytes={plan.hbm_bytes}")

    a = rng.standard_normal((256, 256)).astype(np.float32)
    b = rng.standard_normal((256, 256)).astype(np.float32)
    t0 = time.perf_counter()
    o = ops.matmul(a, b, bm=128, bn=128, bk=128, order="mnk")
    us = (time.perf_counter() - t0) * 1e6
    err = float(np.max(np.abs(np.asarray(o) - a @ b)))
    plan = planner.plan_matmul(4096, 4096, 4096)
    rows.append(f"kernel_block_matmul,{us:.0f},"
                f"max_err={err:.1e};plan4096={plan.tiles}|{plan.order};"
                f"AI={plan.arithmetic_intensity:.0f}")

    q = rng.standard_normal((2, 8, 64)).astype(np.float32)
    k = rng.standard_normal((2, 512, 2, 64)).astype(np.float32)
    v = rng.standard_normal((2, 512, 2, 64)).astype(np.float32)
    t0 = time.perf_counter()
    o = ops.decode_attention(q, k, v, bkv=128)
    us = (time.perf_counter() - t0) * 1e6
    assert o.shape == (2, 8, 64)
    plan = planner.plan_decode_attention(32768, 128, 8)
    rows.append(f"kernel_flash_decode,{us:.0f},"
                f"bkv32k={plan.tiles['bkv']};steps={plan.steps};"
                f"mem_bound_s={plan.duration_overlapped:.2e}")


def _roofline_summary(rows: list[str]) -> None:
    from benchmarks import roofline

    cells = roofline.load_cells()
    derived = [d for d in (roofline.derive(c) for c in cells) if d]
    for r in derived:
        rows.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0,"
            f"dom={r['dominant']};roofline_frac={r['roofline_fraction']:.3f};"
            f"t=({r['t_compute_s']:.2e}/{r['t_memory_s']:.2e}/"
            f"{r['t_collective_s']:.2e});fits={r['fits_v5e']}")
    if not derived:
        rows.append("roofline_pending,0,run benchmarks/run_dryrun_all.py first")


def main() -> None:
    fast = "--fast" in sys.argv
    rows: list[str] = ["name,us_per_call,derived"]
    from benchmarks import paper_figures
    paper_figures.fig11(rows, verify=not fast)
    paper_figures.fig12(rows, time_limit=2.0 if fast else 10.0,
                        polish_iters=3000 if fast else 12_000)
    if not fast:
        paper_figures.fig13(rows)
    paper_figures.fig_s2(rows)
    _kernel_microbench(rows)
    _roofline_summary(rows)
    print("\n".join(rows))


if __name__ == "__main__":
    main()
