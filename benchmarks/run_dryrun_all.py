"""Sweep driver: run the multi-pod dry-run for every (arch x shape x mesh)
cell as an isolated subprocess (the 512-device XLA flag must be set before
jax init, and a fresh process per cell also bounds compile-cache memory).

Resumable: cells whose JSON already exists are skipped.

    PYTHONPATH=src python benchmarks/run_dryrun_all.py [--only-missing]
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import subprocess
import sys
import time

ARCHS = [
    # cheap first: early signal
    "tinyllama-1.1b", "whisper-medium", "mamba2-2.7b", "zamba2-2.7b",
    "qwen2-7b", "qwen2.5-14b", "qwen2.5-32b", "chameleon-34b",
    "dbrx-132b", "deepseek-v2-236b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
OUT = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    tag = "pod" if multi_pod else "single"
    return os.path.join(OUT, f"{arch}_{shape}_{tag}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--timeout", type=float, default=3600)
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    archs = args.archs.split(",")
    shapes = args.shapes.split(",")
    todo = [(a, s, mp) for mp in (False, True)
            for a in archs for s in shapes]
    done = failed = skipped = 0
    for arch, shape, mp in todo:
        path = cell_path(arch, shape, mp)
        if os.path.exists(path):
            skipped += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", OUT]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        print(f"[dryrun] {arch} {shape} {'pod' if mp else 'single'} ...",
              flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            dt = time.time() - t0
            if r.returncode == 0:
                done += 1
                print(f"  ok in {dt:.0f}s", flush=True)
            else:
                failed += 1
                err = (r.stderr or r.stdout).strip().splitlines()
                print(f"  FAIL in {dt:.0f}s: {err[-3:] if err else '?'}",
                      flush=True)
                with open(path.replace(".json", ".err"), "w") as f:
                    f.write(r.stdout + "\n--- stderr ---\n" + r.stderr)
        except subprocess.TimeoutExpired:
            failed += 1
            print(f"  TIMEOUT after {args.timeout}s", flush=True)
            with open(path.replace(".json", ".err"), "w") as f:
                f.write("timeout")
    print(f"[dryrun] done={done} failed={failed} cached={skipped}")
    return 0 if failed == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
