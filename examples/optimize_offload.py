"""Optimise offloading strategies for the paper's LeNet-5 / ResNet8 conv
layers and for the TPU kernel planner (the beyond-paper bridge).

    PYTHONPATH=src python examples/optimize_offload.py
"""
from repro.configs.lenet5 import LENET5_L1, LENET5_L2
from repro.configs.resnet8 import RESNET8_L1, RESNET8_L2, RESNET8_L3
from repro.core import planner, solver
from repro.core.cost_model import HardwareModel, TPU_V5E
from repro.core.strategies import best_heuristic

hw = HardwareModel(nbop_pe=10**9)
print("== paper workloads: solver vs best heuristic (eq. 15 duration) ==")
for name, spec in [("lenet5_l1", LENET5_L1), ("lenet5_l2", LENET5_L2),
                   ("resnet8_l1", RESNET8_L1), ("resnet8_l2", RESNET8_L2),
                   ("resnet8_l3", RESNET8_L3)]:
    p = 8
    res = solver.solve(spec, p=p, hw=hw, use_milp=False, polish_iters=6000)
    print(f"{name:11s} p={p} seed={res.seed_objective:7.0f} "
          f"solver={res.objective:7.0f} (-{res.gain_vs_seed*100:4.1f}%) "
          f"LB={res.lower_bound:7.0f}")

print("\n== TPU planner: same formalism choosing Pallas schedules ==")
for m, n, k in [(4096, 4096, 4096), (8192, 1024, 8192), (512, 512, 65536)]:
    pl = planner.plan_matmul(m, n, k)
    print(f"matmul {m}x{n}x{k}: tiles={pl.tiles} order={pl.order} "
          f"AI={pl.arithmetic_intensity:.0f} "
          f"t={pl.duration_overlapped*1e3:.3f}ms")
for s in (32768, 524288):
    pl = planner.plan_decode_attention(s, 128, 8)
    print(f"decode S={s}: bkv={pl.tiles['bkv']} steps={pl.steps} "
          f"t={pl.duration_overlapped*1e6:.0f}us (memory-bound)")
