"""Demo: plan a conv network on a multi-chip ICI ring, print the cluster
schedule, validate every shard functionally through the Sec-6 / S2
simulators — and show the replicate→shard crossover: as the per-chip
budget shrinks (kernel sets stop fitting) or the chip count grows, layers
flip from the single-chip replicate path to row/channel sharding, paying
ICI for halo exchanges, input broadcasts, and resharding.

    PYTHONPATH=src python examples/plan_multichip.py [network] \
        [--chips 4] [--size-mem N] [--ici-factor 4] \
        [--topology ring|biring|torusRxC]
    PYTHONPATH=src python examples/plan_multichip.py tight4 --crossover \
        --topology torus2x2
"""
import argparse

from repro.configs.clusters import ICI_FACTOR, make_cluster
from repro.core.cost_model import Topology
from repro.configs.networks import NETWORKS
from repro.configs.tight import budget_points
from repro.core.multichip import plan_multichip_network
from repro.core.network_planner import InfeasibleNetworkError
from repro.sim import simulate_multichip

FAST = dict(polish_iters=2000, polish_restarts=2)


def run_once(name: str, n_chips: int, size_mem: int | None,
             nbop_pe: int, ici_factor: float, topology: str = "ring",
             overlap: bool = False, balance_rows: bool = False) -> None:
    cluster = make_cluster(n_chips, nbop_pe=nbop_pe, size_mem=size_mem,
                           ici_factor=ici_factor, topology=topology)
    plan = plan_multichip_network(NETWORKS[name], cluster, name=name,
                                  overlap=overlap,
                                  balance_rows=balance_rows, **FAST)
    print(plan.report())
    print()
    rep = simulate_multichip(plan)
    print(rep.summary())
    assert rep.correct, "functional check failed"
    assert rep.accounting_exact, "duration model disagrees with simulator"
    assert rep.peak_within_budget, "a shard's footprint exceeds size_mem"
    print("functional + accounting + per-chip memory checks passed")


def crossover(name: str, nbop_pe: int, ici_factor: float,
              topology: str = "ring",
              overlap: bool = False, balance_rows: bool = False) -> None:
    """Budgets shrink top-to-bottom, chips grow left-to-right: watch the
    mode string flip from all-replicate to row (W) / channel (K) shards
    exactly where sharding buys back S1 feasibility."""
    Topology.parse(topology)        # reject typos before the sweep —
    # inside the loop only dims-vs-chip-count mismatches may pass as n/a
    specs = NETWORKS[name]
    budgets = budget_points(specs, fractions=(4.0, 2.0, 1.0, 0.5, 0.25))
    print(f"{name}: replicate→shard crossover "
          f"(largest Λ = {max(s.kernel_elements for s in specs)} elements, "
          f"t_ici = {ici_factor:g} * t_l, topology = {topology})")
    for size_mem in sorted(budgets, reverse=True):
        cells = []
        for n_chips in (1, 2, 4, 8):
            # one chip has no links: every wiring shares the ring
            # baseline column (same rule as the benchmark sweep)
            topo = "ring" if n_chips == 1 else topology
            try:
                cluster = make_cluster(n_chips, nbop_pe=nbop_pe,
                                       size_mem=size_mem,
                                       ici_factor=ici_factor,
                                       topology=topo)
            except ValueError:           # torus dims don't tile n_chips
                cells.append(f"n{n_chips}: n/a")
                continue
            try:
                plan = plan_multichip_network(
                    specs, cluster, name=name, polish_iters=800,
                    polish_restarts=1, include_single_chip_baseline=False,
                    overlap=overlap, balance_rows=balance_rows)
            except InfeasibleNetworkError:
                cells.append(f"n{n_chips}: infeasible")
                continue
            cells.append(f"n{n_chips}:[{plan.mode_string}] "
                         f"{plan.total_duration:g}")
        print(f"  mem={size_mem:>8}:  " + "   ".join(cells))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("network", nargs="?", default="tight4",
                    choices=sorted(NETWORKS))
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--size-mem", type=int, default=None,
                    help="per-chip on-chip budget in elements (default: "
                         "half the largest kernel set — the sharding "
                         "regime)")
    ap.add_argument("--nbop-pe", type=int, default=10 ** 9)
    ap.add_argument("--ici-factor", type=float, default=ICI_FACTOR,
                    help="t_ici as a multiple of t_l")
    ap.add_argument("--topology", default="ring",
                    help="ICI wiring: ring (unidirectional, default), "
                         "biring, or torusRxC (bidirectional links; "
                         "enables hybrid row x channel sharding)")
    ap.add_argument("--crossover", action="store_true",
                    help="sweep (budget x chip count) and show the mode "
                         "string at each point")
    ap.add_argument("--overlap", action="store_true",
                    help="price double-buffered halo exchange: per-layer "
                         "duration max(compute, ICI) instead of the sum")
    ap.add_argument("--balance-rows", action="store_true",
                    help="size row bands by solved per-chip duration "
                         "instead of raw row counts")
    args = ap.parse_args()

    if args.crossover:
        crossover(args.network, args.nbop_pe, args.ici_factor,
                  topology=args.topology, overlap=args.overlap,
                  balance_rows=args.balance_rows)
        return
    size_mem = args.size_mem
    if size_mem is None:
        specs = NETWORKS[args.network]
        size_mem = max(s.kernel_elements for s in specs) // 2
    run_once(args.network, args.chips, size_mem, args.nbop_pe,
             args.ici_factor, topology=args.topology,
             overlap=args.overlap, balance_rows=args.balance_rows)


if __name__ == "__main__":
    main()
