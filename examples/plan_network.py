"""Demo: plan a whole conv network, print the schedule, and validate it
functionally with the Sec-6 simulator.

    PYTHONPATH=src python examples/plan_network.py [lenet5|resnet8]
"""
import sys

from repro.configs.networks import NETWORKS
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import plan_network
from repro.sim import simulate_network


def main() -> None:
    name = sys.argv[1] if len(sys.argv) > 1 else "lenet5"
    if name not in NETWORKS:
        sys.exit(f"unknown network {name!r}; choose from "
                 f"{', '.join(sorted(NETWORKS))}")
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=None)
    plan = plan_network(NETWORKS[name], hw, name=name,
                        polish_iters=4000, polish_restarts=4)
    print(plan.report())
    print()
    rep = simulate_network(plan)
    print(rep.summary())
    assert rep.correct, "functional check failed"
    assert rep.accounting_exact, "duration model disagrees with simulator"
    print("functional + accounting checks passed")


if __name__ == "__main__":
    main()
