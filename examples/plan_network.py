"""Demo: plan a whole conv network, print the schedule, validate it
functionally with the Sec-6 / S2 simulators — and show the S1→S2
crossover: shrinking the on-chip budget forces layers out of the paper's
all-kernels-resident S1 regime into S2 kernel-group swapping.

    PYTHONPATH=src python examples/plan_network.py [network] [--size-mem N]
    PYTHONPATH=src python examples/plan_network.py tight4 --crossover
"""
import argparse

from repro.configs.networks import NETWORKS
from repro.configs.tight import budget_points
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import InfeasibleNetworkError, plan_network
from repro.sim import simulate_network

FAST = dict(polish_iters=4000, polish_restarts=4)


def run_once(name: str, hw: HardwareModel) -> None:
    plan = plan_network(NETWORKS[name], hw, name=name, **FAST)
    print(plan.report())
    print()
    rep = simulate_network(plan)
    print(rep.summary())
    assert rep.correct, "functional check failed"
    assert rep.accounting_exact, "duration model disagrees with simulator"
    assert rep.peak_within_budget, "simulated footprint exceeds size_mem"
    print("functional + accounting + memory checks passed")


def crossover(name: str, nbop_pe: int) -> None:
    """Sweep budgets from above the largest kernel set to far below it and
    print which layers flip from S1 to S2 at each point."""
    specs = NETWORKS[name]
    budgets = budget_points(specs, fractions=(4.0, 2.0, 1.0, 0.5, 0.25,
                                              0.125))
    print(f"{name}: S1→S2 crossover "
          f"(largest Λ = {max(s.kernel_elements for s in specs)} elements)")
    for size_mem in sorted(budgets, reverse=True):
        hw = HardwareModel(nbop_pe=nbop_pe, size_mem=size_mem)
        try:
            plan = plan_network(specs, hw, name=name,
                                polish_iters=800, polish_restarts=1)
        except InfeasibleNetworkError:
            print(f"  mem={size_mem:>8}: infeasible (below any S2 window)")
            continue
        modes = " ".join(lp.mode.upper() for lp in plan.layers)
        print(f"  mem={size_mem:>8}: [{modes}]  "
              f"plan {plan.total_duration:g} vs greedy "
              f"{plan.baseline_duration:g} "
              f"(gain {plan.gain_vs_baseline:.1%}, "
              f"peak {plan.peak_footprint})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("network", nargs="?", default="lenet5",
                    choices=sorted(NETWORKS))
    ap.add_argument("--size-mem", type=int, default=None,
                    help="on-chip budget in elements (default: "
                         "unconstrained)")
    ap.add_argument("--nbop-pe", type=int, default=10 ** 9)
    ap.add_argument("--crossover", action="store_true",
                    help="sweep budgets and show the S1→S2 flip per layer")
    args = ap.parse_args()

    if args.crossover:
        crossover(args.network, args.nbop_pe)
        return
    hw = HardwareModel(nbop_pe=args.nbop_pe, size_mem=args.size_mem)
    run_once(args.network, hw)


if __name__ == "__main__":
    main()
