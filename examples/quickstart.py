"""Quickstart: the paper's pipeline end to end on one conv layer.

1. Describe a convolution + accelerator (paper Sec 2).
2. Build the heuristic strategies (Row-by-Row, ZigZag — Sec 7.2) and the
   grouped S1 strategy (Sec 4.2).
3. Optimise with the ILP+polish solver (Sec 5).
4. Execute the winner functionally in the simulator (Sec 6) and check it
   computes the exact convolution.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import (nb_patches_max_s1, row_by_row,
                                   s1_baseline, tiled, zigzag)
from repro.core import solver
from repro.sim import ConvLayer, System
from repro.sim.trace import render_group_grid

# the paper's Example 1 layer: 2x5x5 input, two 2x3x3 kernels
spec = ConvSpec(c_in=2, h_in=5, w_in=5, n_kernels=2, h_k=3, w_k=3)
hw = HardwareModel(nbop_pe=120, size_mem=4096)
p = nb_patches_max_s1(spec, hw)
print(f"patches={spec.num_patches} nb_patches_max_S1={p}")

for strat in (s1_baseline(spec), row_by_row(spec, p), zigzag(spec, p),
              tiled(spec, p)):
    print(f"{strat.name:12s} delta={strat.objective(hw):6.1f} "
          f"steps={strat.n_steps} reloads<= {strat.max_reloads()}")

res = solver.solve(spec, p=p, hw=hw, time_limit=10, polish_iters=5000)
print(f"solver       delta={res.objective:6.1f} (seed {res.seed_objective}, "
      f"LB {res.lower_bound}, milp={res.milp_status}, "
      f"gain {res.gain_vs_seed*100:.1f}%)")
print(render_group_grid(res.strategy))

report = System(ConvLayer.random(spec), hw).run(res.strategy)
print("simulator:", report.summary())
assert report.correct
