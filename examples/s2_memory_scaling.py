"""S2 strategies (the paper's Sec-9 future work): keep running as the
on-chip memory shrinks BELOW what S1 fundamentally needs (all kernels +
one patch), by swapping kernel subsets through the accelerator.

    PYTHONPATH=src python examples/s2_memory_scaling.py
"""
from repro.configs.lenet5 import LENET5_L2
from repro.core import strategies_s2 as s2
from repro.core.cost_model import HardwareModel
from repro.core.strategies import zigzag
from repro.sim import ConvLayer
from repro.sim.s2 import run_s2

spec = LENET5_L2
layer = ConvLayer.random(spec)
s1 = zigzag(spec, 8)
s1_min = (spec.kernel_elements + s1.peak_input_footprint() * spec.c_in
          + 8 * spec.c_out * 2)
print(f"LeNet-5 L2: {spec.n_kernels} kernels "
      f"({spec.kernel_elements} elements); S1 needs >= ~{s1_min} on-chip")
print(f"{'budget':>8s} {'S1?':>4s} {'best S2':>22s} {'duration':>9s} "
      f"{'peak':>6s} {'correct':>7s}")
for frac in (2.0, 1.0, 0.5, 0.25):
    budget = int(s1_min * frac)
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=budget)
    res = s2.best_s2(spec, hw)
    rep = run_s2(layer, hw, res.strategy)
    print(f"{budget:8d} {'yes' if res.feasible_s1 else 'NO':>4s} "
          f"{res.strategy.name:>22s} {res.objective:9.0f} "
          f"{res.peak_memory:6d} {str(rep.correct):>7s}")
print("\nS1 is infeasible below the kernel set size; S2 trades duration "
      "for residency\n(weight-stationary vs input-stationary order chosen "
      "per instance by best_s2).")
