"""Batched serving demo: prefill a prompt batch, decode greedily with the
KV cache (the S1 offloading schedule per DESIGN.md §4).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import serve

if __name__ == "__main__":
    gen = serve("tinyllama-1.1b", smoke=True, batch=4, prompt_len=32,
                gen_len=12)
    print("sampled continuation ids:\n", gen)
