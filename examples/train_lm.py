"""End-to-end training driver: trains a reduced-config LM on the synthetic
pipeline with checkpointing, on CPU.  Use --steps 200 for the full demo
(loss drops well below the ~5.5 random-vocab floor).

    PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse
import tempfile

from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as d:
        losses = train(args.arch, smoke=True, steps=args.steps,
                       batch=args.batch, seq_len=args.seq_len, ckpt_dir=d,
                       checkpoint_every=max(10, args.steps // 2),
                       lr=1e-3, log_every=5)
    k = max(1, min(5, len(losses) // 3))
    first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
    print(f"loss {first:.3f} (first {k}) -> {last:.3f} (last {k})")
    assert last < first, "training did not reduce loss"
