"""repro — "Convolutions Predictable Offloading to an Accelerator"
(Husson et al.) as a production-grade JAX framework.

Subpackages: core (formalism/strategies/ILP/planner), sim (functional
simulator), kernels (Pallas TPU), models (10 architectures), launch
(mesh/dryrun/train/serve), data, optim, checkpoint, runtime."""

__version__ = "1.0.0"
