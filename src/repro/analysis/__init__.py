"""Static analysis for offload plans (verifier) and the repo (lint).

``repro.analysis.verifier`` proves a ``NetworkPlan`` / ``MultiChipPlan``
legal *symbolically* — per-step residency ledger, coverage, shard/ICI
geometry, and analytic duration floors — without running the functional
simulator.  ``repro.analysis.lint`` is a repo-specific AST pass
(``python -m repro.analysis.lint``).
"""
from repro.analysis.diagnostics import (Diagnostic, PlanVerificationError,
                                        Severity, VerificationReport)
from repro.analysis.verifier import (verify_multichip_plan,
                                     verify_network_plan, verify_steps)

__all__ = [
    "Diagnostic",
    "PlanVerificationError",
    "Severity",
    "VerificationReport",
    "verify_multichip_plan",
    "verify_network_plan",
    "verify_steps",
]
