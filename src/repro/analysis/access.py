"""Symbolic region algebra + happens-before hazard analysis for kernels.

The kernel contract checker (:mod:`repro.analysis.kerncheck`) walks a
Pallas kernel's grid *symbolically* — evaluating BlockSpec index_maps and
``make_async_copy`` source slices on concrete grid indices, never
executing the kernel — and needs two pieces of machinery:

* **regions** — rectangular boxes over named tensors/buffers
  (:class:`Region`): the HBM window a DMA reads, the VMEM slice it
  writes, the output block a step writes back.  Boxes support exact
  element counts and overlap tests, which is all the hazard and
  contract rules need (conv windows, GeMM tiles and KV pages are all
  boxes; scattered sets are handled by the bitmask ledger in
  :mod:`repro.analysis.verifier`).

* **events** — a linear happens-before trace of the kernel's manual
  DMA pipeline (:class:`DmaStart`/:class:`DmaWait` on named semaphores,
  :class:`BufRead`/:class:`BufWrite` for compute-side accesses).  Grid
  steps execute sequentially on a TPU core, so program order *is* the
  happens-before order for issued operations; a DMA's effect (writing
  its destination, reading its source) is only ordered by the
  ``DmaWait`` that retires it.  :func:`hazard_scan` replays the trace
  under semaphore FIFO semantics and reports every access that races an
  in-flight DMA (RAW/WAR/WAW), every wait with no outstanding transfer
  (a lost-wait deadlock) and every transfer never retired (a leaked
  signal that desynchronises later waits).

:func:`timed_delivery_violations` is the *timed* variant used for
``overlap=True`` multi-chip halo schedules: there the consumer never
waits (that is the point of overlapping), so soundness is a timing
proof — every read of an in-flight transfer's destination must start
after the transfer completes.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Iterable, Sequence, Union

_ABS = 1e-6


# --------------------------------------------------------------------- #
# Regions
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Region:
    """A rectangular box over a named tensor or buffer.

    ``box`` is a tuple of half-open ``(lo, hi)`` intervals, one per axis.
    Two regions can only overlap when they name the same tensor.
    """

    tensor: str
    box: tuple[tuple[int, int], ...]

    def __post_init__(self) -> None:
        for lo, hi in self.box:
            if hi < lo:
                raise ValueError(f"empty axis interval ({lo}, {hi}) in "
                                 f"region of {self.tensor!r}")

    @property
    def elements(self) -> int:
        n = 1
        for lo, hi in self.box:
            n *= hi - lo
        return n

    def overlaps(self, other: "Region") -> bool:
        if self.tensor != other.tensor or len(self.box) != len(other.box):
            return False
        return all(lo < ohi and olo < hi
                   for (lo, hi), (olo, ohi) in zip(self.box, other.box))

    def contains(self, other: "Region") -> bool:
        if self.tensor != other.tensor or len(self.box) != len(other.box):
            return False
        return all(lo <= olo and ohi <= hi
                   for (lo, hi), (olo, ohi) in zip(self.box, other.box))

    def describe(self) -> str:
        spans = ",".join(f"{lo}:{hi}" for lo, hi in self.box)
        return f"{self.tensor}[{spans}]"


def box_region(tensor: str, *spans: tuple[int, int]) -> Region:
    """Convenience constructor: ``box_region("x", (0, 4), (2, 8))``."""
    return Region(tensor, tuple(spans))


# --------------------------------------------------------------------- #
# Happens-before events
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class DmaStart:
    """``make_async_copy(src, dst, sem).start()`` at grid step ``step``."""

    sem: str
    src: Region
    dst: Region
    step: int
    tag: str = ""               # human label ("win full", "col prefetch")


@dataclasses.dataclass(frozen=True)
class DmaWait:
    """``.wait()`` on ``sem`` — retires the oldest outstanding start."""

    sem: str
    step: int


@dataclasses.dataclass(frozen=True)
class BufRead:
    """Compute-side read of a buffer region (im2col, dot operand)."""

    region: Region
    step: int


@dataclasses.dataclass(frozen=True)
class BufWrite:
    """Compute-side write of a buffer region (shift, output store)."""

    region: Region
    step: int


Event = Union[DmaStart, DmaWait, BufRead, BufWrite]


@dataclasses.dataclass(frozen=True)
class Hazard:
    """One happens-before violation found by :func:`hazard_scan`."""

    kind: str                   # "raw" | "war" | "waw" | "lost-wait" | "leak"
    step: int                   # grid step of the violating event
    detail: str

    def describe(self) -> str:
        return f"[step {self.step}] {self.kind}: {self.detail}"


def hazard_scan(events: Iterable[Event]) -> list[Hazard]:
    """Replay a kernel's event trace under semaphore FIFO semantics.

    A started DMA is *in flight* (asynchronously writing ``dst`` and
    reading ``src``) until a ``DmaWait`` on its semaphore retires it —
    waits retire starts oldest-first, matching the hardware's counting
    semantics for the one-transfer-per-wait idiom the kernels use.
    Any program-ordered access that touches an in-flight transfer's
    destination (or overwrites its source) is unordered with the DMA
    engine and reported as a hazard.
    """
    hazards: list[Hazard] = []
    outstanding: dict[str, deque[DmaStart]] = {}
    in_flight: list[DmaStart] = []

    def _conflicts(region: Region, write: bool, step: int,
                   what: str) -> None:
        for d in in_flight:
            if region.overlaps(d.dst):
                kind = "waw" if write else "raw"
                hazards.append(Hazard(
                    kind, step,
                    f"{what} {region.describe()} while DMA "
                    f"{d.tag or d.sem} (started step {d.step}) is still "
                    f"writing {d.dst.describe()} — missing wait"))
            elif write and region.overlaps(d.src):
                hazards.append(Hazard(
                    "war", step,
                    f"{what} {region.describe()} while DMA "
                    f"{d.tag or d.sem} (started step {d.step}) still "
                    f"reads {d.src.describe()}"))

    for ev in events:
        if isinstance(ev, DmaStart):
            _conflicts(ev.dst, write=True, step=ev.step,
                       what=f"DMA {ev.tag or ev.sem} writes")
            # a start whose *source* is being written by an in-flight DMA
            for d in in_flight:
                if ev.src.overlaps(d.dst):
                    hazards.append(Hazard(
                        "raw", ev.step,
                        f"DMA {ev.tag or ev.sem} reads "
                        f"{ev.src.describe()} while DMA {d.tag or d.sem} "
                        f"is still writing {d.dst.describe()}"))
            outstanding.setdefault(ev.sem, deque()).append(ev)
            in_flight.append(ev)
        elif isinstance(ev, DmaWait):
            queue = outstanding.get(ev.sem)
            if not queue:
                hazards.append(Hazard(
                    "lost-wait", ev.step,
                    f"wait on semaphore {ev.sem!r} with no outstanding "
                    f"transfer — the kernel deadlocks here"))
                continue
            done = queue.popleft()
            in_flight.remove(done)
        elif isinstance(ev, BufRead):
            _conflicts(ev.region, write=False, step=ev.step, what="read of")
        elif isinstance(ev, BufWrite):
            _conflicts(ev.region, write=True, step=ev.step, what="write of")
        else:                                        # pragma: no cover
            raise TypeError(f"unknown event {ev!r}")

    for d in in_flight:
        hazards.append(Hazard(
            "leak", d.step,
            f"DMA {d.tag or d.sem} (started step {d.step}) is never "
            f"waited on — its completion signal desynchronises any later "
            f"wait on {d.sem!r}"))
    return hazards


# --------------------------------------------------------------------- #
# Timed delivery (overlapped transfers that are never waited on)
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class TimedViolation:
    """A read that starts before the transfer feeding it completes."""

    read_time: float
    complete_time: float
    region: Region


def timed_delivery_violations(
        transfers: Sequence[tuple[float, Region]],
        reads: Sequence[tuple[float, Region]],
) -> list[TimedViolation]:
    """Soundness of *overlapped* (wait-free) transfers, by timing.

    ``transfers`` are ``(complete_time, dst_region)`` pairs — e.g. the
    inbound halo exchange of an ``overlap=True`` multi-chip stage, which
    completes at ``ici_duration`` after stage start.  ``reads`` are
    ``(start_time, region)`` pairs from the consumer's step walk.  A read
    overlapping a transfer's destination must start at or after the
    transfer's completion; everything earlier is returned, earliest
    first.  An empty result is a proof that the overlap claim is sound
    under the plan's own step timing.
    """
    found: list[TimedViolation] = []
    for t_read, region in reads:
        for t_done, dst in transfers:
            if region.overlaps(dst) and t_read + _ABS < t_done:
                found.append(TimedViolation(t_read, t_done, region))
                break
    found.sort(key=lambda v: v.read_time)
    return found


def first_violation_or_none(
        transfers: Sequence[tuple[float, Region]],
        reads: Sequence[tuple[float, Region]],
) -> "TimedViolation | None":
    vs = timed_delivery_violations(transfers, reads)
    return vs[0] if vs else None


def total_order_ok(times: Sequence[float]) -> bool:
    """True when a step-time sequence is sane (monotone, finite)."""
    prev = -math.inf
    for t in times:
        if not math.isfinite(t) or t + _ABS < prev:
            return False
        prev = t
    return True
