"""Structured diagnostics for the static plan verifier.

A ``Diagnostic`` pins one rule violation to a locus (layer / chip / step)
with a machine-readable payload; a ``VerificationReport`` aggregates them
for one verified subject.  ``report.ok`` is the contract the planners and
tests assert on: no error-severity diagnostics.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Any


class Severity(enum.Enum):
    ERROR = "error"      # plan is illegal or the cost model lied
    WARNING = "warning"  # accounting is optimistic but self-consistent
    INFO = "info"        # documented approximation worth surfacing

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One rule violation at one locus.

    ``rule`` is a stable ``family/name`` identifier (e.g.
    ``mem/step-budget``); ``data`` carries the numbers behind the message
    as a sorted tuple of ``(key, value)`` pairs so diagnostics stay
    hashable and deterministic.
    """
    rule: str
    severity: Severity
    message: str
    layer: int | None = None
    chip: int | None = None
    step: int | None = None

    data: tuple[tuple[str, Any], ...] = ()

    @staticmethod
    def make(rule: str, severity: Severity, message: str, *,
             layer: int | None = None, chip: int | None = None,
             step: int | None = None, **data: Any) -> "Diagnostic":
        return Diagnostic(rule=rule, severity=severity, message=message,
                          layer=layer, chip=chip, step=step,
                          data=tuple(sorted(data.items())))

    @property
    def locus(self) -> str:
        parts = []
        if self.layer is not None:
            parts.append(f"layer {self.layer}")
        if self.chip is not None:
            parts.append(f"chip {self.chip}")
        if self.step is not None:
            parts.append(f"step {self.step}")
        return ", ".join(parts) if parts else "plan"

    def render(self) -> str:
        extra = ""
        if self.data:
            extra = " [" + ", ".join(f"{k}={v!r}" for k, v in self.data) + "]"
        return (f"{self.severity.value.upper():7s} {self.rule}: "
                f"{self.locus}: {self.message}{extra}")

    def to_json(self) -> dict[str, Any]:
        return {
            "rule": self.rule,
            "severity": self.severity.value,
            "message": self.message,
            "layer": self.layer,
            "chip": self.chip,
            "step": self.step,
            "data": dict(self.data),
        }


@dataclasses.dataclass
class VerificationReport:
    """All diagnostics for one verified subject (a plan or a step walk)."""
    subject: str
    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    checked_layers: int = 0
    checked_steps: int = 0

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, diags: list[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity is Severity.ERROR]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity is Severity.WARNING]

    @property
    def ok(self) -> bool:
        """True when no error-severity diagnostic was emitted."""
        return not self.errors

    def rules_fired(self) -> set[str]:
        return {d.rule for d in self.diagnostics}

    def render(self) -> str:
        head = (f"verify {self.subject}: "
                f"{'OK' if self.ok else 'FAIL'} "
                f"({self.checked_layers} layers, {self.checked_steps} steps, "
                f"{len(self.errors)} errors, {len(self.warnings)} warnings)")
        lines = [head] + [d.render() for d in self.diagnostics]
        return "\n".join(lines)

    def to_json(self) -> dict[str, Any]:
        return {
            "subject": self.subject,
            "ok": self.ok,
            "checked_layers": self.checked_layers,
            "checked_steps": self.checked_steps,
            "diagnostics": [d.to_json() for d in self.diagnostics],
        }

    def to_json_str(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True)


class PlanVerificationError(ValueError):
    """Raised by the planners when ``verify=True`` and the emitted plan
    fails static verification — always a planner or cost-model bug."""

    def __init__(self, report: VerificationReport):
        self.report = report
        super().__init__(report.render())
