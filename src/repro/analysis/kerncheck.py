"""Static kernel contract checker: the Pallas kernels vs their plans.

``repro.analysis.verifier`` proves emitted *plans* legal; this module
closes the remaining gap in the paper's "predictable offloading" claim:
that the **kernel** a plan is mapped onto (``kernels.emit``) provably
incurs exactly the traffic the plan priced.  Nothing is executed — the
checker walks the kernel's grid symbolically, evaluating BlockSpec
index_maps and ``make_async_copy`` source slices on every concrete grid
index (the same shared geometry helpers the kernel traces with), and
compares the resulting access sets against the plan's Def-3 step
sequence.

Rules (all ERROR severity — any finding means the kernel does not
implement the plan):

    rule                what it proves
    ------------------  -------------------------------------------------
    kern/emit           the layer maps onto an implemented kernel at all
    kern/step-islice    step k's DMA'd HBM region == the plan's I_slice_k
    kern/residency      step k's resident window == M_k.inp (eager-free)
    kern/write-back     output blocks == the plan's groups, each output
                        written exactly once (write-once coverage)
    kern/traffic        total elements DMA'd == what the plan charges to
                        t_l (I_slices x C_in + Λ) — traffic conservation
    kern/vmem           kernel VMEM occupancy (window + delta buffers +
                        Λ + double-buffered output blocks) <= the budget
                        the plan was solved under
    kern/hazard         the DMA pipeline's happens-before trace is free
                        of RAW/WAR/WAW races, lost-wait deadlocks and
                        leaked (never-retired) transfers
    kern/coverage       standalone kernels (block_matmul, flash_decode):
                        streamed blocks tile their operand disjointly,
                        resident blocks are truly resident, every output
                        tile is written back exactly once

Run ``python -m repro.analysis.kerncheck`` (CI lint job; exit 1 on
findings): plans every registered network with the emitable solver at a
2x-Λ VMEM budget and proves every conv layer contract-equivalent, then
statically checks the standalone GeMM/decode kernels.  The check
functions take the extracted :class:`KernelTrace` as *data*, so tests
seed mutations (shifted index_map, dropped wait, double write) into a
trace and assert the precise rule fires.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Sequence

from repro.analysis import access
from repro.analysis.diagnostics import (
    Diagnostic, Severity, VerificationReport)
from repro.configs.networks import NETWORKS
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import GroupedStrategy
from repro.kernels.block_matmul import matmul_grid
from repro.kernels.conv2d_offload import (
    CASE_COL, CASE_FULL, CASE_ROW, eff_tile, grid_sequence, moving_right,
    step_case, t_in_cols)
from repro.kernels.emit import (
    EmittedConv, KernelEmitError, emit_layer_kernel, kernel_vmem_elements,
    plan_emitable_network)
from repro.kernels.flash_decode import decode_specs

# Big enough that nb_patches_max_S1 (Sec 4.2) admits 16-patch groups on
# the deepest registered layer (64ch 3x3 -> 64ch: 36864 MACs/patch); the
# memory budget, not compute, is what kerncheck stresses.
_DEFAULT_NBOP = 1 << 20
_DEFAULT_BUDGET_FACTOR = 2.0


# --------------------------------------------------------------------- #
# Trace extraction (symbolic grid walk — no kernel execution)
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class StepTrace:
    """The access sets of one grid step of a conv offload kernel."""

    index: int
    x_load: access.Region               # HBM input region DMA'd for this step
    lam_elements: int                   # kernel elements fetched (Λ at step 0)
    window: access.Region               # resident VMEM window the step reads
    out: access.Region                  # output block written back


@dataclasses.dataclass
class KernelTrace:
    """Everything the checker extracts from one kernel instantiation."""

    name: str
    spec: ConvSpec
    t_run: int
    order: str
    vmem_elements: int
    steps: list[StepTrace]
    events: list[access.Event]


def build_conv_trace(emitted: EmittedConv) -> KernelTrace:
    """Symbolically walk ``conv2d_offload_planned``'s grid.

    Mirrors the kernel's ``pl.when`` structure exactly: per step, the
    retire-wait for the delta prefetched one step earlier, the window
    shift/splice, the next step's prefetch start, then the compute read
    and output-block write.  Every region comes from evaluating the same
    geometry helpers the kernel traces with, on concrete indices.
    """
    spec, t = emitted.spec, emitted.t_run
    return _conv_trace(spec, t, emitted.order,
                       name=f"conv2d_offload_planned[L{emitted.layer_index}]",
                       vmem_elements=emitted.vmem_elements)


def _conv_trace(spec: ConvSpec, t: int, order: str, *, name: str,
                vmem_elements: int) -> KernelTrace:
    c, hk, wk = spec.c_in, spec.h_k, spec.w_k
    sh, sw = spec.s_h, spec.s_w
    tiles = spec.w_out // t
    t_in = t_in_cols(t, sw, wk)
    nw = t * sw
    ov_w = t_in - nw
    keep = hk - sh
    geom = dict(t_run=t, s_h=sh, s_w=sw, h_k=hk, w_k=wk,
                w_out_tiles=tiles, order=order)
    seq = grid_sequence(spec.h_out, tiles)

    def x_box(r0, rn, c0, cn):
        return access.box_region("x", (0, c), (r0, r0 + rn), (c0, c0 + cn))

    def win_box(r0=0, rn=None, c0=0, cn=None):
        return access.box_region(
            "win", (0, c), (r0, r0 + (hk if rn is None else rn)),
            (c0, c0 + (t_in if cn is None else cn)))

    def delta(case, i, jt_eff):
        """The I_slice region of a step, by its fetch case."""
        h0, w0 = i * sh, jt_eff * nw
        if case == CASE_FULL:
            return x_box(h0, hk, w0, t_in)
        if case == CASE_ROW:
            return x_box(h0 + keep, sh, w0, t_in)
        off = ov_w if moving_right(i, order == "zigzag") else 0
        return x_box(h0, hk, w0 + off, nw)

    steps: list[StepTrace] = []
    events: list[access.Event] = []
    row_full = access.box_region("row_buf", (0, c), (0, max(1, sh)),
                                 (0, t_in))
    col_full = access.box_region("col_buf", (0, c), (0, hk), (0, nw))
    for k, (i, jt_raw) in enumerate(seq):
        jt = eff_tile(i, jt_raw, tiles, order == "zigzag")
        case = step_case(i, jt_raw, **geom)
        h0, w0 = i * sh, jt * nw
        load = delta(case, i, jt)

        if case == CASE_FULL:
            events.append(access.DmaStart("full", load, win_box(), k,
                                          tag="win full"))
            events.append(access.DmaWait("full", k))
        elif case == CASE_ROW:
            events.append(access.DmaWait("row", k))
            events.append(access.BufRead(win_box(r0=sh, rn=keep), k))
            events.append(access.BufWrite(win_box(r0=0, rn=keep), k))
            events.append(access.BufRead(row_full, k))
            events.append(access.BufWrite(win_box(r0=keep, rn=sh), k))
        else:                                           # CASE_COL
            right = moving_right(i, order == "zigzag")
            events.append(access.DmaWait("col", k))
            events.append(access.BufRead(
                win_box(c0=nw if right else 0, cn=ov_w), k))
            events.append(access.BufWrite(
                win_box(c0=0 if right else nw, cn=ov_w), k))
            events.append(access.BufRead(col_full, k))
            events.append(access.BufWrite(
                win_box(c0=ov_w if right else 0, cn=nw), k))

        if k + 1 < len(seq):                            # prefetch next delta
            i_n, jt_raw_n = seq[k + 1]
            jt_n = eff_tile(i_n, jt_raw_n, tiles, order == "zigzag")
            case_n = step_case(i_n, jt_raw_n, **geom)
            if case_n == CASE_ROW:
                events.append(access.DmaStart(
                    "row", delta(case_n, i_n, jt_n), row_full, k,
                    tag="row prefetch"))
            elif case_n == CASE_COL:
                events.append(access.DmaStart(
                    "col", delta(case_n, i_n, jt_n), col_full, k,
                    tag="col prefetch"))

        out = access.box_region("out", (0, spec.c_out), (i, i + 1),
                                (jt * t, jt * t + t))
        events.append(access.BufRead(win_box(), k))     # im2col + dot
        events.append(access.BufWrite(out, k))
        steps.append(StepTrace(
            index=k, x_load=load,
            lam_elements=spec.kernel_elements if k == 0 else 0,
            window=x_box(h0, hk, w0, t_in), out=out))
    return KernelTrace(name=name, spec=spec, t_run=t, order=order,
                       vmem_elements=vmem_elements, steps=steps,
                       events=events)


# --------------------------------------------------------------------- #
# Contract rules (pure functions of the trace — tests mutate the trace)
# --------------------------------------------------------------------- #

def _box_pixmask(spec: ConvSpec, region: access.Region) -> int:
    """Spatial-pixel bitmask of an input-region box (channel axis
    dropped — the plan ledger is in spatial units)."""
    (_, _), (r0, r1), (c0, c1) = region.box
    m = 0
    for h in range(r0, min(r1, spec.h_in)):
        m |= ((1 << (c1 - c0)) - 1) << (h * spec.w_in + c0)
    return m


def _out_patchmask(spec: ConvSpec, region: access.Region) -> int:
    """Patch bitmask of an output-block box."""
    (_, _), (r0, r1), (c0, c1) = region.box
    m = 0
    for i in range(r0, r1):
        for j in range(c0, c1):
            m |= 1 << spec.patch_id(i, j)
    return m


def check_conv_trace(trace: KernelTrace, strategy: GroupedStrategy,
                     budget: int | None, *,
                     layer: int | None = None) -> list[Diagnostic]:
    """All contract rules for one conv kernel trace vs its plan."""
    spec = trace.spec
    diags: list[Diagnostic] = []

    def err(rule: str, msg: str, *, step: int | None = None,
            **data) -> None:
        diags.append(Diagnostic.make(rule, Severity.ERROR, msg,
                                     layer=layer, step=step, **data))

    plan_steps = strategy.to_steps()[:-1]       # drop the terminal flush
    if len(trace.steps) != len(plan_steps):
        err("kern/step-islice",
            f"kernel has {len(trace.steps)} grid steps but the plan has "
            f"{len(plan_steps)} compute steps",
            kernel_steps=len(trace.steps), plan_steps=len(plan_steps))
        return diags

    total_loaded = 0
    write_counts: dict[int, int] = {}
    for st, ps in zip(trace.steps, plan_steps):
        got = _box_pixmask(spec, st.x_load)
        want = ps.i_slice
        if got != want:
            err("kern/step-islice",
                f"DMA'd region {st.x_load.describe()} != plan I_slice "
                f"({bin(got ^ want).count('1')} pixels differ)",
                step=st.index, dma_pixels=got.bit_count(),
                islice_pixels=want.bit_count())
        need = spec.group_mask(ps.group)
        win = _box_pixmask(spec, st.window)
        if win != need:
            err("kern/residency",
                f"resident window {st.window.describe()} != M_k.inp "
                f"(plan holds {need.bit_count()} pixels, kernel "
                f"{win.bit_count()})", step=st.index)
        out_got = _out_patchmask(spec, st.out)
        if out_got != ps.out:
            err("kern/write-back",
                f"output block {st.out.describe()} != plan group "
                f"(block covers {out_got.bit_count()} patches, group has "
                f"{ps.out.bit_count()})", step=st.index)
        for pid in spec.pixels_of_mask(out_got):
            write_counts[pid] = write_counts.get(pid, 0) + 1
        total_loaded += st.x_load.elements + st.lam_elements

    bad = {p: n for p, n in write_counts.items() if n != 1}
    missing = spec.num_patches - len(write_counts)
    if bad or missing:
        err("kern/write-back",
            f"output not covered write-once: {missing} patches never "
            f"written, {len(bad)} written more than once",
            missing=missing, multi=len(bad))

    want_traffic = (strategy.pixels_loaded() * spec.c_in
                    + spec.kernel_elements)
    if total_loaded != want_traffic:
        err("kern/traffic",
            f"kernel DMAs {total_loaded} elements but the plan charges "
            f"{want_traffic} to t_l — predicted duration would lie",
            loaded=total_loaded, charged=want_traffic)

    if budget is not None and trace.vmem_elements > budget:
        err("kern/vmem",
            f"kernel occupies {trace.vmem_elements} VMEM elements; the "
            f"plan was solved under size_mem={budget}",
            occupancy=trace.vmem_elements, budget=budget)

    for hz in access.hazard_scan(trace.events):
        err("kern/hazard", hz.describe(), step=hz.step, kind=hz.kind)
    return diags


# --------------------------------------------------------------------- #
# Standalone kernels: BlockSpec walks for GeMM / decode attention
# --------------------------------------------------------------------- #

def _lex_indices(grid: tuple[int, ...]):
    """Grid indices in Pallas execution order (last axis fastest)."""
    idx = [0] * len(grid)
    while True:
        yield tuple(idx)
        for ax in reversed(range(len(grid))):
            idx[ax] += 1
            if idx[ax] < grid[ax]:
                break
            idx[ax] = 0
        else:
            return


def check_block_matmul(m: int, n: int, k: int, *, bm: int, bn: int,
                       bk: int, order: str) -> list[Diagnostic]:
    """Static checks of ``block_matmul``'s BlockSpec schedule.

    Proves: A/B blocks stay in bounds; for the output-stationary order
    (k innermost) every C tile's visits are one contiguous run — the
    block is written back exactly once when it leaves VMEM; every C tile
    is visited (coverage); revisit counts match the planner's model (the
    k sweep revisits the C tile k_t times)."""
    diags: list[Diagnostic] = []
    grid, amap, bmap, cmap, _ = matmul_grid(m, n, k, bm=bm, bn=bn, bk=bk,
                                            order=order)

    def err(msg: str, *, step: int | None = None, **data) -> None:
        diags.append(Diagnostic.make("kern/coverage", Severity.ERROR, msg,
                                     step=step, **data))

    visits: dict[tuple[int, int], list[int]] = {}
    for step, ids in enumerate(_lex_indices(grid)):
        ai, ak = amap(*ids)
        bkk, bj = bmap(*ids)
        if not (0 <= ai * bm < m and 0 <= ak * bk < k):
            err(f"A block ({ai},{ak}) out of bounds", step=step)
        if not (0 <= bkk * bk < k and 0 <= bj * bn < n):
            err(f"B block ({bkk},{bj}) out of bounds", step=step)
        if ak != bkk:
            err(f"A reads k-tile {ak} but B reads {bkk} — the dot "
                f"contracts mismatched tiles", step=step)
        visits.setdefault(cmap(*ids), []).append(step)

    want_tiles = (m // bm) * (n // bn)
    if len(visits) != want_tiles:
        err(f"C coverage: {len(visits)} tiles visited, grid has "
            f"{want_tiles}", visited=len(visits), tiles=want_tiles)
    k_t = k // bk
    for tile, ss in visits.items():
        if len(ss) != k_t:
            err(f"C tile {tile} visited {len(ss)} times, k sweep "
                f"needs {k_t}")
        if ss != list(range(ss[0], ss[0] + len(ss))) and order[2] == "k":
            err(f"C tile {tile} leaves VMEM and returns (visits {ss}) — "
                f"the output-stationary kernel would write it back "
                f"twice")
    return diags


def check_decode(g: int, d: int, s: int, *, bkv: int) -> list[Diagnostic]:
    """Static checks of ``decode_attention``'s schedule: q and the output
    block resident (constant index_map), K/V blocks a disjoint exact
    cover of the cache."""
    diags: list[Diagnostic] = []
    grid, qmap, kvmap, omap = decode_specs(g, d, s, bkv)
    seen: set[int] = set()
    for i in range(grid[0]):
        if qmap(i) != (0, 0) or omap(i) != (0, 0):
            diags.append(Diagnostic.make(
                "kern/coverage", Severity.ERROR,
                f"q/output block moves at step {i} — the accumulator "
                f"state would be lost", step=i))
        row, col = kvmap(i)
        if col != 0 or row in seen or not 0 <= row * bkv < s:
            diags.append(Diagnostic.make(
                "kern/coverage", Severity.ERROR,
                f"KV block ({row},{col}) repeats or out of bounds",
                step=i))
        seen.add(row)
    if len(seen) * bkv != s:
        diags.append(Diagnostic.make(
            "kern/coverage", Severity.ERROR,
            f"KV blocks cover {len(seen) * bkv} of {s} cache positions"))
    return diags


# --------------------------------------------------------------------- #
# Whole-repo entry points (tests + CI)
# --------------------------------------------------------------------- #

def network_budget(specs: Sequence[ConvSpec],
                   factor: float = _DEFAULT_BUDGET_FACTOR) -> HardwareModel:
    """The budget kerncheck plans under: ``factor`` x the largest Λ."""
    lam = max(s.kernel_elements for s in specs)
    return HardwareModel(nbop_pe=_DEFAULT_NBOP,
                         size_mem=int(factor * lam))


def check_network(name: str, specs: Sequence[ConvSpec] | None = None, *,
                  hw: HardwareModel | None = None) -> VerificationReport:
    """Plan one network with the emitable solver and prove every conv
    layer's emitted kernel contract-equivalent to its LayerPlan."""
    specs = list(NETWORKS[name] if specs is None else specs)
    hw = hw or network_budget(specs)
    report = VerificationReport(subject=f"kerncheck {name}")
    plan = plan_emitable_network(specs, hw, name=name)
    for lp in plan.layers:
        try:
            emitted = emit_layer_kernel(lp)
        except KernelEmitError as e:
            report.add(Diagnostic.make(
                "kern/emit", Severity.ERROR, str(e), layer=lp.index))
            continue
        trace = build_conv_trace(emitted)
        report.extend(check_conv_trace(trace, lp.strategy, hw.size_mem,
                                       layer=lp.index))
        report.checked_layers += 1
        report.checked_steps += len(trace.steps)
    return report


_STANDALONE_GEMM = [
    dict(m=256, n=384, k=512, bm=128, bn=128, bk=128, order="mnk"),
    dict(m=256, n=256, k=256, bm=128, bn=128, bk=128, order="nmk"),
    dict(m=256, n=256, k=512, bm=128, bn=128, bk=128, order="kmn"),
    dict(m=384, n=256, k=256, bm=128, bn=128, bk=128, order="mkn"),
]
_STANDALONE_DECODE = [
    dict(g=8, d=64, s=2048, bkv=512),
    dict(g=4, d=128, s=4096, bkv=1024),
]


def run_all(networks: Sequence[str] | None = None) -> VerificationReport:
    """The CI entry: every registered network + the standalone kernels."""
    merged = VerificationReport(subject="kerncheck")
    for name in (networks or sorted(NETWORKS)):
        rep = check_network(name)
        merged.extend(rep.diagnostics)
        merged.checked_layers += rep.checked_layers
        merged.checked_steps += rep.checked_steps
    for cfg in _STANDALONE_GEMM:
        merged.extend(check_block_matmul(
            cfg["m"], cfg["n"], cfg["k"], bm=cfg["bm"], bn=cfg["bn"],
            bk=cfg["bk"], order=cfg["order"]))
    for cfg in _STANDALONE_DECODE:
        merged.extend(check_decode(cfg["g"], cfg["d"], cfg["s"],
                                   bkv=cfg["bkv"]))
    return merged


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.kerncheck",
        description="Prove the Pallas kernels implement their plans "
                    "(static access-set + hazard analysis).")
    ap.add_argument("--network", action="append", dest="networks",
                    choices=sorted(NETWORKS),
                    help="check only this network (repeatable)")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON")
    args = ap.parse_args(argv)
    report = run_all(args.networks)
    if args.json:
        print(report.to_json_str())
    else:
        print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":                      # pragma: no cover
    sys.exit(main())
