"""Repo-specific AST lint for ``src/repro`` — ``python -m repro.analysis.lint``.

Six rules tuned to this codebase's failure modes (generic style is
ruff's job; these are semantic):

``L001 frozen-mutation``
    Assignment to ``self.<attr>`` inside a method of a
    ``@dataclass(frozen=True)`` class (outside ``__post_init__``): raises
    ``FrozenInstanceError`` at runtime — always a latent bug.
``L002 float-eq``
    ``==`` / ``!=`` on duration/cost/objective-named operands: Def-3
    durations are floats built by summation; exact comparison is only
    safe against the literal ``0`` emptiness guard (which is allowed).
``L003 unseeded-random``
    Module-level ``random.*`` / ``np.random.*`` calls in library code:
    planners must be deterministic for a fixed ``rng_seed``; use
    ``random.Random(seed)`` / ``np.random.default_rng(seed)``.
``L004 lru-mutable-arg``
    An ``lru_cache``d function whose signature admits mutable
    (unhashable) arguments — ``TypeError`` at the first real call, or
    worse, a default that silently aliases across calls.
``L005 dead-public-api``
    A public function/method defined under ``core/`` that no code in
    ``src``, ``benchmarks`` or ``examples`` references (tests do not
    count — "priced and tested but unused" is exactly the finding).
    Suppress deliberate API with a ``# lint: public-api`` pragma, or
    mark a not-yet-wired entry point ``# lint: experimental-api``.
``L006 bare-assert``
    ``assert`` in ``core/``, ``sim/`` or ``kernels/``: planner,
    simulator and kernel-wrapper invariants vanish under ``python -O``
    — raise an explicit exception (``KernelShapeError`` for kernel
    geometry) instead.  (``models/`` keeps device-side shape asserts:
    they guard tracer shapes, not plan legality.)

Exit code 0 when clean, 1 when any finding fires — CI-ready.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import pathlib
import sys
from typing import Iterable

_FLOAT_NAME_PARTS = ("duration", "objective", "cost", "saved", "saving")
_SEEDED_NP_RANDOM = ("default_rng", "SeedSequence", "Generator", "Philox",
                     "PCG64")
_MUTABLE_TYPE_NAMES = {"list", "dict", "set", "List", "Dict", "Set",
                       "MutableSequence", "MutableMapping", "MutableSet",
                       "bytearray"}
_PRAGMAS = ("lint: public-api", "lint: experimental-api")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _rel(path: pathlib.Path, base: pathlib.Path) -> str:
    try:
        return str(path.relative_to(base))
    except ValueError:
        return str(path)


def _name_of(node: ast.AST) -> str | None:
    """Best-effort identifier of an expression (for name-pattern rules)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Call):
        return _name_of(node.func)
    return None


def _is_zero_constant(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and not isinstance(
        node.value, bool) and node.value == 0


def _is_frozen_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        if isinstance(dec, ast.Call) and \
                _name_of(dec.func) == "dataclass":
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    return True
    return False


def _has_pragma(lines: list[str], lineno: int) -> bool:
    """Pragma on the flagged line or the line above it."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and any(p in lines[ln - 1]
                                         for p in _PRAGMAS):
            return True
    return False


# --------------------------------------------------------------------- #
# Per-file rules (L001-L004, L006)
# --------------------------------------------------------------------- #

def _check_frozen_mutation(tree: ast.Module, rel: str,
                           out: list[Finding]) -> None:
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or not _is_frozen_dataclass(cls):
            continue
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name in ("__post_init__", "__new__"):
                continue   # object.__setattr__ territory
            for node in ast.walk(fn):
                targets: list[ast.expr] = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target] if node.target is not None else []
                for t in targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        out.append(Finding(
                            "L001 frozen-mutation", rel, node.lineno,
                            f"assignment to self.{t.attr} in frozen "
                            f"dataclass {cls.name}.{fn.name}"))


def _check_float_eq(tree: ast.Module, rel: str, out: list[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        operands = [node.left] + list(node.comparators)
        for i, op in enumerate(node.ops):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            pair = (operands[i], operands[i + 1])
            names = [(_name_of(x) or "").lower() for x in pair]
            if not any(any(p in n for p in _FLOAT_NAME_PARTS)
                       for n in names):
                continue
            if any(_is_zero_constant(x) for x in pair):
                continue   # emptiness guard: 0.0 is exactly representable
            shown = next(n for n in names
                         if any(p in n for p in _FLOAT_NAME_PARTS))
            out.append(Finding(
                "L002 float-eq", rel, node.lineno,
                f"exact float comparison on {shown!r} — use a tolerance "
                f"(math.isclose) or compare to literal 0"))


def _check_unseeded_random(tree: ast.Module, rel: str,
                           out: list[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or \
                not isinstance(node.func, ast.Attribute):
            continue
        func = node.func
        # random.<fn>(...)
        if isinstance(func.value, ast.Name) and func.value.id == "random":
            if func.attr == "Random" and node.args:
                continue   # random.Random(seed): deterministic
            out.append(Finding(
                "L003 unseeded-random", rel, node.lineno,
                f"random.{func.attr}(...) uses the unseeded global RNG — "
                f"pass a random.Random(seed) instance"))
        # np.random.<fn>(...) / numpy.random.<fn>(...)
        elif isinstance(func.value, ast.Attribute) and \
                func.value.attr == "random" and \
                isinstance(func.value.value, ast.Name) and \
                func.value.value.id in ("np", "numpy"):
            if func.attr in _SEEDED_NP_RANDOM and node.args:
                continue   # np.random.default_rng(seed) etc.
            out.append(Finding(
                "L003 unseeded-random", rel, node.lineno,
                f"np.random.{func.attr}(...) is unseeded (or legacy "
                f"global-state) — use np.random.default_rng(seed)"))


def _lru_decorated(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        if _name_of(dec) in ("lru_cache", "cache"):
            return True
    return False


def _annotation_mutable(ann: ast.expr | None) -> str | None:
    if ann is None:
        return None
    base = ann.value if isinstance(ann, ast.Subscript) else ann
    name = _name_of(base)
    if name in _MUTABLE_TYPE_NAMES:
        return name
    return None


def _check_lru_mutable(tree: ast.Module, rel: str,
                       out: list[Finding]) -> None:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _lru_decorated(fn):
            continue
        args = fn.args
        all_args = (args.posonlyargs + args.args + args.kwonlyargs)
        for a in all_args:
            bad = _annotation_mutable(a.annotation)
            if bad is not None:
                out.append(Finding(
                    "L004 lru-mutable-arg", rel, a.lineno,
                    f"lru_cached {fn.name}() takes {a.arg}: {bad} — "
                    f"unhashable at call time; use a tuple/frozen type"))
        for default in list(args.defaults) + [
                d for d in args.kw_defaults if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(default, ast.Call)
                    and _name_of(default.func) in ("list", "dict", "set")):
                out.append(Finding(
                    "L004 lru-mutable-arg", rel, default.lineno,
                    f"lru_cached {fn.name}() has a mutable default"))


def _check_bare_assert(tree: ast.Module, rel: str, lines: list[str],
                       out: list[Finding]) -> None:
    parts = pathlib.PurePath(rel).parts
    if not ("core" in parts or "sim" in parts or "kernels" in parts
            or "runtime" in parts or "resil" in parts):
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert) and not _has_pragma(
                lines, node.lineno):
            out.append(Finding(
                "L006 bare-assert", rel, node.lineno,
                "assert vanishes under python -O — raise an explicit "
                "exception for planner/simulator invariants"))


# --------------------------------------------------------------------- #
# Cross-file rule: L005 dead-public-api
# --------------------------------------------------------------------- #

def _public_core_defs(tree: ast.Module, rel: str, lines: list[str],
                      ) -> list[tuple[str, str, int]]:
    """(name, qualified label, line) of public defs in a core/ module."""
    if "core" not in pathlib.PurePath(rel).parts:
        return []
    defs = []

    def visit(body: Iterable[ast.stmt], prefix: str) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name.startswith("_"):
                    continue
                if _has_pragma(lines, node.lineno):
                    continue
                defs.append((node.name, f"{prefix}{node.name}",
                             node.lineno))
            elif isinstance(node, ast.ClassDef):
                visit(node.body, f"{node.name}.")

    visit(tree.body, "")
    return defs


def _collect_uses(tree: ast.Module) -> set[str]:
    uses: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            uses.add(node.id)
        elif isinstance(node, ast.Attribute):
            uses.add(node.attr)
        elif isinstance(node, (ast.ImportFrom, ast.Import)):
            for alias in node.names:
                uses.add(alias.name.split(".")[-1])
    return uses


# --------------------------------------------------------------------- #
# Driver
# --------------------------------------------------------------------- #

def iter_python_files(root: pathlib.Path) -> list[pathlib.Path]:
    if root.is_file():
        return [root]
    return sorted(root.rglob("*.py"))


def run_lint(paths: "list[pathlib.Path]",
             usage_paths: "list[pathlib.Path] | None" = None,
             base: "pathlib.Path | None" = None) -> list[Finding]:
    """Lint ``paths``; resolve L005 usages against ``usage_paths`` (which
    default to ``paths``).  Returns findings sorted by file/line."""
    base = base or pathlib.Path.cwd()
    findings: list[Finding] = []
    defs: list[tuple[str, str, int, str]] = []   # name, label, line, rel
    uses: set[str] = set()
    use_counts: dict[str, int] = {}

    lint_files = {f for p in paths for f in iter_python_files(p)}
    usage_files = set(lint_files)
    for p in (usage_paths or []):
        usage_files.update(iter_python_files(p))

    trees: dict[pathlib.Path, tuple[ast.Module, list[str]]] = {}
    for f in sorted(usage_files):
        try:
            src = f.read_text()
            trees[f] = (ast.parse(src, filename=str(f)), src.splitlines())
        except (SyntaxError, OSError) as e:
            findings.append(Finding("L000 parse-error", _rel(f, base),
                                    getattr(e, "lineno", 0) or 0, str(e)))

    for f, (tree, lines) in trees.items():
        rel = _rel(f, base)
        for name in _collect_uses(tree):
            use_counts[name] = use_counts.get(name, 0) + 1
        uses.update(_collect_uses(tree))
        if f not in lint_files:
            continue
        _check_frozen_mutation(tree, rel, findings)
        _check_float_eq(tree, rel, findings)
        _check_unseeded_random(tree, rel, findings)
        _check_lru_mutable(tree, rel, findings)
        _check_bare_assert(tree, rel, lines, findings)
        for name, label, line in _public_core_defs(tree, rel, lines):
            defs.append((name, label, line, rel))

    for name, label, line, rel in defs:
        if name not in uses:
            findings.append(Finding(
                "L005 dead-public-api", rel, line,
                f"public {label}() is never referenced from src/, "
                f"benchmarks/ or examples/ — wire it, delete it, or mark "
                f"it '# lint: experimental-api'"))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def main(argv: "list[str] | None" = None) -> int:
    repo_root = pathlib.Path(__file__).resolve().parents[3]
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific AST lint (see module docstring)")
    ap.add_argument("paths", nargs="*", type=pathlib.Path,
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    ns = ap.parse_args(argv)

    if ns.paths:
        paths = [p.resolve() for p in ns.paths]
        usage = []
    else:
        paths = [repo_root / "src" / "repro"]
        usage = [repo_root / d for d in ("benchmarks", "examples")
                 if (repo_root / d).is_dir()]
    findings = run_lint(paths, usage_paths=usage, base=repo_root)
    if ns.json:
        print(json.dumps([f.to_json() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        print(f"repro.analysis.lint: {len(findings)} finding(s) over "
              f"{len(paths)} root(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
