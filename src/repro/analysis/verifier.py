"""Static plan verifier: prove offload plans legal without simulating.

Every plan the planners emit is a *claim*: a Def-1/2 step sequence per
layer (or per shard), a Def-3 duration, inter-layer reuse savings, shard
geometry and ICI collective prices.  This module re-derives each claim
symbolically — a per-step residency ledger over the formalism's bitmask
semantics, exact tiling/halo geometry checks, a re-pricing of the ICI
schedule, and analytic duration floors — and emits structured
:class:`~repro.analysis.diagnostics.Diagnostic` records instead of
executing anything.

Rule families (see README for the full table):

=====================  ====================================================
``step/semantics``     a1..a6 violation: freeing/writing non-resident data,
                       re-loading resident data, read-before-load
``step/compute``       kernel-not-resident / pixels-not-resident / PE
                       overrun in a computing step
``cover/*``            write-back coverage: every output unit computed and
                       written exactly once, memory empty at the end,
                       kernel groups partition the kernel set
``mem/step-budget``    resident elements exceed ``hw.size_mem`` at some
                       step (held inter-layer activations included)
``dur/ledger``         claimed duration differs from the Def-3 sum over
                       the materialised steps
``dur/floor``          claimed duration beats the analytic roofline /
                       communication floor — a cost-model bug
``reuse/*``            inter-layer reuse: savings exceed measured traffic,
                       producer/consumer flags unpaired, bad row window
``shard/*``            multi-chip geometry: bands / kernel ranges must
                       tile the layer, hybrid grids must match the
                       topology, halo windows must stay in bounds,
                       ``same_pad`` savings must respect their clamps
``ici/conservation``   plan's ICI element counts differ from the
                       topology's re-priced collective schedule
``ici/war-overlap``    an overlapped halo exchange delivers rows after the
                       consumer first reads them — a write-after-read on
                       live input, proved/refuted per band through the
                       ``analysis.access`` timed-delivery model (ERROR:
                       the planner claims it only overlaps sound stages)
=====================  ====================================================

The verifier is intentionally conservative in the same places the
planners are (held activations double-count their first loads, Def-3
footprints are post-step states), so every legal plan passes with zero
error-severity diagnostics — asserted across the preset networks x
clusters x topologies in ``tests/test_verifier*.py``.

Degraded re-plans are not special: when ``repro.resil`` re-plans a
network's tail mid-run (chip death, link degradation, VMEM shrink), the
suffix plan flows through this same verifier unchanged — against the
*degraded* cluster's budget, link price and topology — via the
``verify`` knob ``core.multichip.replan_suffix`` forwards, and
``faultsim`` forces it on.  A recovery plan that only holds on the
healthy machine is exactly the kind of claim this module exists to
reject.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Sequence

from repro.analysis import access
from repro.analysis.diagnostics import (Diagnostic, PlanVerificationError,
                                        Severity, VerificationReport)
from repro.core import multichip as mc
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import (MemoryState, Step, StepError, apply_step,
                                  check_compute_feasible)
from repro.core.network_planner import (LayerPlan, NetworkPlan,
                                        _held_elements, _window_load_saved)
from repro.core.strategies import GroupedStrategy, k_min
from repro.core.strategies_s2 import S2Strategy, s2_lower_bound

_ABS = 1e-6      # duration comparisons: absolute slack (cycles)
_REL = 1e-9


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL, abs_tol=_ABS)


def env_verify_enabled() -> bool:
    """The ``REPRO_VERIFY_PLANS`` knob: truthy values turn the planners'
    opt-in verification postcondition on by default."""
    return os.environ.get("REPRO_VERIFY_PLANS", "").lower() in (
        "1", "true", "yes", "on")


def should_verify(verify: "bool | None") -> bool:
    """Resolve a planner's ``verify`` parameter against the env knob."""
    return env_verify_enabled() if verify is None else verify


# --------------------------------------------------------------------- #
# Step walk: the per-step residency ledger
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class StepWalk:
    """Symbolic execution trace of one strategy's step sequence."""

    durations: list[float]          # weighted Def-3 duration per step
    occupancies: list[int]          # resident elements after each step
    written_cum: list[int]          # output elements written back so far
    diagnostics: list[Diagnostic]
    aborted: bool                   # semantics broke; later checks skipped

    @property
    def total_duration(self) -> float:
        return sum(self.durations)

    @property
    def n_steps(self) -> int:
        return len(self.durations)


def _out_weights(spec: ConvSpec,
                 kernel_groups: "tuple[tuple[int, ...], ...] | None",
                 ) -> "tuple[int, list[int], list[int]]":
    """(number of output units, write-back weight per unit, footprint
    weight per unit).

    S1 output units are patches: one *spatial* write each (Example 2
    convention) draining ``c_out`` resident elements.  S2 units are
    (patch, kernel-group) cells: writes and residency both count the
    group's kernels (cf. ``sim.s2.run_s2``)."""
    if kernel_groups is None:
        n = spec.num_patches
        return n, [1] * n, [spec.c_out] * n
    g_count = len(kernel_groups)
    n = spec.num_patches * g_count
    wb = [len(kernel_groups[u % g_count]) for u in range(n)]
    return n, wb, list(wb)


def _mask_weight(mask: int, weights: list[int]) -> int:
    total = 0
    while mask:
        low = mask & -mask
        u = low.bit_length() - 1
        total += weights[u] if u < len(weights) else 1
        mask ^= low
    return total


def walk_steps(spec: ConvSpec, hw: HardwareModel, steps: Sequence[Step],
               *,
               kernel_groups: "tuple[tuple[int, ...], ...] | None" = None,
               layer: "int | None" = None,
               chip: "int | None" = None) -> StepWalk:
    """Execute the Def-1/2 semantics symbolically over ``steps``.

    Emits ``step/semantics``, ``step/compute`` and ``cover/*``
    diagnostics; returns the per-step duration and occupancy ledgers for
    the caller's budget / floor / ledger rules.  ``kernel_groups`` marks
    an S2 schedule (output units are (patch, kernel-group) cells)."""
    diags: list[Diagnostic] = []
    kelem = spec.c_in * spec.h_k * spec.w_k
    n_units, wb_w, fp_w = _out_weights(spec, kernel_groups)

    if kernel_groups is not None:
        kids = sorted(kid for g in kernel_groups for kid in g)
        if kids != list(range(spec.n_kernels)):
            diags.append(Diagnostic.make(
                "cover/outputs", Severity.ERROR,
                f"kernel groups do not partition the {spec.n_kernels} "
                f"kernels", layer=layer, chip=chip,
                kernel_groups=kernel_groups))

    m = MemoryState()
    computed = written = 0
    durations: list[float] = []
    occupancies: list[int] = []
    written_cum: list[int] = []
    written_elems = 0
    aborted = False
    for idx, s in enumerate(steps):
        dup = s.w & written
        if dup:
            diags.append(Diagnostic.make(
                "cover/write-exactly-once", Severity.ERROR,
                f"{dup.bit_count()} output unit(s) written back twice",
                layer=layer, chip=chip, step=idx, units=dup))
        if s.out & computed:
            diags.append(Diagnostic.make(
                "cover/compute-exactly-once", Severity.ERROR,
                f"{(s.out & computed).bit_count()} output unit(s) "
                f"computed twice", layer=layer, chip=chip, step=idx))
        try:
            m_next = apply_step(m, s)
        except StepError as e:
            if not dup:   # a duplicate write already explains the a3 fault
                diags.append(Diagnostic.make(
                    "step/semantics", Severity.ERROR, str(e),
                    layer=layer, chip=chip, step=idx))
            aborted = True
            break
        try:
            check_compute_feasible(s, spec, hw, m_next)
        except StepError as e:
            diags.append(Diagnostic.make(
                "step/compute", Severity.ERROR, str(e),
                layer=layer, chip=chip, step=idx))
        computed |= s.out
        written |= s.w
        written_elems += _mask_weight(s.w, wb_w)
        load = s.i_slice.bit_count() * hw.t_l \
            + s.k_sub.bit_count() * kelem * hw.t_l
        write = _mask_weight(s.w, wb_w) * hw.t_w
        durations.append(load + write + (hw.t_acc if s.computes else 0.0))
        occupancies.append(m_next.inp.bit_count() * spec.c_in
                           + m_next.ker.bit_count() * kelem
                           + _mask_weight(m_next.out, fp_w))
        written_cum.append(written_elems)
        m = m_next

    if not aborted:
        full = (1 << n_units) - 1
        if computed != full:
            diags.append(Diagnostic.make(
                "cover/outputs", Severity.ERROR,
                f"{(full & ~computed).bit_count()} of {n_units} output "
                f"unit(s) never computed", layer=layer, chip=chip))
        if written != full:
            diags.append(Diagnostic.make(
                "cover/outputs", Severity.ERROR,
                f"{(full & ~written).bit_count()} of {n_units} output "
                f"unit(s) never written back", layer=layer, chip=chip))
        if not m.empty:
            diags.append(Diagnostic.make(
                "cover/memory-empty", Severity.ERROR,
                "on-chip memory not empty after the last step",
                layer=layer, chip=chip,
                residual=m.footprint_elements(spec)))
    return StepWalk(durations=durations, occupancies=occupancies,
                    written_cum=written_cum, diagnostics=diags,
                    aborted=aborted)


def verify_steps(spec: ConvSpec, hw: HardwareModel, steps: Sequence[Step],
                 *,
                 kernel_groups: "tuple[tuple[int, ...], ...] | None" = None,
                 held_elements: int = 0,
                 outputs_stay_resident: bool = False,
                 layer: "int | None" = None,
                 chip: "int | None" = None,
                 subject: str = "steps") -> VerificationReport:
    """Verify a raw step sequence: semantics, coverage, and the per-step
    memory budget (``held_elements`` rides along at every step; with
    ``outputs_stay_resident`` written-back outputs keep occupying memory,
    the producer side of inter-layer reuse)."""
    report = VerificationReport(subject=subject)
    walk = walk_steps(spec, hw, steps, kernel_groups=kernel_groups,
                      layer=layer, chip=chip)
    report.extend(walk.diagnostics)
    report.checked_steps += walk.n_steps
    _check_budget(report, walk, hw, held_elements=held_elements,
                  outputs_stay_resident=outputs_stay_resident,
                  layer=layer, chip=chip)
    return report


def _check_budget(report: VerificationReport, walk: StepWalk,
                  hw: HardwareModel, *, held_elements: int,
                  outputs_stay_resident: bool,
                  layer: "int | None", chip: "int | None") -> None:
    if hw.size_mem is None:
        return
    for idx, occ in enumerate(walk.occupancies):
        extra = held_elements
        if outputs_stay_resident:
            extra += walk.written_cum[idx]
        if occ + extra > hw.size_mem:
            report.add(Diagnostic.make(
                "mem/step-budget", Severity.ERROR,
                f"resident elements {occ + extra} exceed "
                f"size_mem={hw.size_mem}",
                layer=layer, chip=chip, step=idx,
                occupancy=occ, held=extra, size_mem=hw.size_mem))


# --------------------------------------------------------------------- #
# Analytic duration floors
# --------------------------------------------------------------------- #

def strategy_floor(strategy, hw: HardwareModel) -> float:
    """Analytic lower bound on a strategy's *full* Def-3 duration: every
    needed pixel and every kernel element loaded at least once, every
    output written once, and at least ``ceil(units / PE capacity)``
    compute steps.  Any plan claiming less carries a cost-model bug."""
    spec = strategy.spec
    needed = spec.all_pixels_mask.bit_count()
    if isinstance(strategy, S2Strategy):
        return s2_lower_bound(spec, hw) \
            + spec.num_patches * spec.c_out * hw.t_w
    try:
        p_cap = hw.nb_patches_max_s1(spec.nb_op_value, spec.c_out)
    except ValueError:
        p_cap = 1        # PE-infeasible S1: step/compute flags it; the
        #                  floor stays a valid (weaker) bound
    p_cap = max(1, min(p_cap, spec.num_patches))
    return (hw.t_l * (needed + spec.kernel_elements)
            + k_min(spec, p_cap) * hw.t_acc
            + spec.num_patches * hw.t_w)


# --------------------------------------------------------------------- #
# LayerPlan / NetworkPlan
# --------------------------------------------------------------------- #

def _verify_layer_plan(report: VerificationReport, lp: LayerPlan,
                       hw: HardwareModel, *, held_in: int,
                       held_out: int = 0) -> None:
    strat = lp.strategy
    spec = lp.spec
    kernel_groups = strat.kernel_groups \
        if isinstance(strat, S2Strategy) else None
    walk = walk_steps(spec, hw, strat.to_steps(),
                      kernel_groups=kernel_groups, layer=lp.index)
    report.extend(walk.diagnostics)
    report.checked_layers += 1
    report.checked_steps += walk.n_steps
    _check_budget(report, walk, hw, held_elements=held_in + held_out,
                  outputs_stay_resident=lp.reuse_output,
                  layer=lp.index, chip=None)

    if not walk.aborted and not _close(walk.total_duration,
                                       lp.gross_duration):
        report.add(Diagnostic.make(
            "dur/ledger", Severity.ERROR,
            f"claimed gross duration {lp.gross_duration:g} != Def-3 step "
            f"sum {walk.total_duration:g}", layer=lp.index,
            claimed=lp.gross_duration, ledger=walk.total_duration))

    # reuse savings clamps: never save more than the measured traffic
    first_load = strat.first_load_duration(hw)
    wb = strat.write_back_duration(hw)
    if lp.input_load_saved > first_load + _ABS:
        report.add(Diagnostic.make(
            "reuse/savings-clamp", Severity.ERROR,
            f"input_load_saved {lp.input_load_saved:g} exceeds first-load "
            f"traffic {first_load:g}", layer=lp.index))
    if lp.window_rows:
        if not spec.h_k <= lp.window_rows <= spec.h_in:
            report.add(Diagnostic.make(
                "reuse/window", Severity.ERROR,
                f"row window {lp.window_rows} outside "
                f"[h_k={spec.h_k}, h_in={spec.h_in}]", layer=lp.index))
        win_cap = _window_load_saved(spec, min(lp.window_rows, spec.h_in),
                                     hw)
        if lp.input_load_saved > win_cap + _ABS:
            report.add(Diagnostic.make(
                "reuse/savings-clamp", Severity.ERROR,
                f"window saving {lp.input_load_saved:g} exceeds the "
                f"window rows' needed pixels {win_cap:g}", layer=lp.index))
    if lp.input_load_saved and not (lp.reuse_input or lp.window_rows):
        report.add(Diagnostic.make(
            "reuse/savings-clamp", Severity.ERROR,
            f"input_load_saved {lp.input_load_saved:g} without a reuse "
            f"source", layer=lp.index))
    if lp.write_back_saved > (wb if lp.reuse_output else 0.0) + _ABS:
        report.add(Diagnostic.make(
            "reuse/savings-clamp", Severity.ERROR,
            f"write_back_saved {lp.write_back_saved:g} exceeds write-back "
            f"traffic {wb if lp.reuse_output else 0.0:g}", layer=lp.index))

    floor = strategy_floor(strat, hw)
    if lp.gross_duration < floor - _ABS:
        report.add(Diagnostic.make(
            "dur/floor", Severity.ERROR,
            f"gross duration {lp.gross_duration:g} beats the analytic "
            f"floor {floor:g} — cost-model bug", layer=lp.index,
            floor=floor, claimed=lp.gross_duration))


def _held_in_elements(plan: NetworkPlan, i: int) -> int:
    """Elements layer ``i`` holds for its upstream reuse while executing."""
    lp = plan.layers[i]
    if lp.reuse_input and i > 0:
        return _held_elements(plan.layers[i - 1].spec, lp.spec)
    if lp.window_rows:
        return lp.window_rows * lp.spec.w_in * lp.spec.c_in
    return 0


def verify_network_plan(plan: NetworkPlan) -> VerificationReport:
    """Symbolically verify every layer of a single-chip network plan plus
    the plan-level reuse pairing and duration recomposition."""
    report = VerificationReport(subject=f"network:{plan.name}")
    hw = plan.hw
    for i, lp in enumerate(plan.layers):
        # a row-window cascade retains the consumer's window while the
        # producer still executes (the window is a copy: the producer
        # keeps writing back) — charge it on the producer side too.
        held_out = 0
        if i + 1 < len(plan.layers) and plan.layers[i + 1].window_rows:
            nxt_spec = plan.layers[i + 1].spec
            held_out = plan.layers[i + 1].window_rows \
                * nxt_spec.w_in * nxt_spec.c_in
        _verify_layer_plan(report, lp, hw,
                           held_in=_held_in_elements(plan, i),
                           held_out=held_out)
        # reuse flags must pair up across adjacent layers
        nxt = plan.layers[i + 1] if i + 1 < len(plan.layers) else None
        if lp.reuse_output != (nxt is not None and nxt.reuse_input):
            report.add(Diagnostic.make(
                "reuse/pairing", Severity.ERROR,
                "reuse_output without a consuming reuse_input downstream"
                if lp.reuse_output else
                "reuse_input without a producing reuse_output upstream",
                layer=lp.index))
        if i == 0 and (lp.reuse_input or lp.window_rows):
            report.add(Diagnostic.make(
                "reuse/pairing", Severity.ERROR,
                "first layer cannot reuse an upstream activation",
                layer=lp.index))

    total = sum(lp.duration for lp in plan.layers)
    gross = sum(lp.gross_duration for lp in plan.layers)
    if not _close(total, plan.total_duration):
        report.add(Diagnostic.make(
            "plan/total", Severity.ERROR,
            f"total_duration {plan.total_duration:g} != sum of layer "
            f"durations {total:g}"))
    if not _close(gross, plan.gross_duration):
        report.add(Diagnostic.make(
            "plan/total", Severity.ERROR,
            f"gross_duration {plan.gross_duration:g} != sum of layer "
            f"gross durations {gross:g}"))
    return report


# --------------------------------------------------------------------- #
# MultiChipPlan
# --------------------------------------------------------------------- #

def _expected_band_spec(spec: ConvSpec, rows: int,
                        n_kernels: "int | None" = None) -> ConvSpec:
    sub = dataclasses.replace(spec, h_in=(rows - 1) * spec.s_h + spec.h_k)
    if n_kernels is not None:
        sub = dataclasses.replace(sub, n_kernels=n_kernels)
    return sub


def _check_bands_tile(report: VerificationReport, layer: int,
                      bands: "list[tuple[int, int]]", h_out: int) -> None:
    bands = sorted(bands)
    pos = 0
    ok = True
    for r0, r1 in bands:
        if r0 != pos or r1 <= r0:
            ok = False
            break
        pos = r1
    if not ok or pos != h_out:
        report.add(Diagnostic.make(
            "shard/band-tiling", Severity.ERROR,
            f"row bands {bands} do not tile [0, {h_out})", layer=layer,
            bands=tuple(bands), h_out=h_out))


def _check_kranges_tile(report: VerificationReport, layer: int,
                        kranges: "list[tuple[int, int]]",
                        n_kernels: int) -> None:
    kranges = sorted(kranges)
    pos = 0
    ok = True
    for k0, k1 in kranges:
        if k0 != pos or k1 <= k0:
            ok = False
            break
        pos = k1
    if not ok or pos != n_kernels:
        report.add(Diagnostic.make(
            "shard/kernel-tiling", Severity.ERROR,
            f"kernel ranges {kranges} do not tile [0, {n_kernels})",
            layer=layer, kranges=tuple(kranges), n_kernels=n_kernels))


def _verify_shard(report: VerificationReport, layer: int,
                  shard: mc.ShardPlan, layer_spec: ConvSpec,
                  hw: HardwareModel) -> "StepWalk | None":
    strat = shard.strategy
    kernel_groups = strat.kernel_groups \
        if isinstance(strat, S2Strategy) else None
    walk = walk_steps(shard.spec, hw, strat.to_steps(),
                      kernel_groups=kernel_groups,
                      layer=layer, chip=shard.chip)
    report.extend(walk.diagnostics)
    report.checked_steps += walk.n_steps
    _check_budget(report, walk, hw, held_elements=0,
                  outputs_stay_resident=False, layer=layer,
                  chip=shard.chip)

    # gross excludes the same_pad credit; the ledger must recompose it
    if not walk.aborted and not _close(
            walk.total_duration, shard.gross_duration + shard.pad_saved):
        report.add(Diagnostic.make(
            "dur/ledger", Severity.ERROR,
            f"shard gross {shard.gross_duration:g} + pad_saved "
            f"{shard.pad_saved:g} != Def-3 step sum "
            f"{walk.total_duration:g}", layer=layer, chip=shard.chip,
            ledger=walk.total_duration))

    r0, r1 = shard.out_rows if shard.out_rows is not None \
        else (0, layer_spec.h_out)
    if shard.pad_saved < -_ABS:
        report.add(Diagnostic.make(
            "shard/pad-clamp", Severity.ERROR,
            f"negative pad_saved {shard.pad_saved:g}", layer=layer,
            chip=shard.chip))
    elif shard.pad_saved > _ABS:
        cap = min(
            mc.band_pad_rows(layer_spec, r0, r1) * layer_spec.w_in * hw.t_l,
            strat.first_load_duration(hw))
        if shard.pad_saved > cap + _ABS:
            report.add(Diagnostic.make(
                "shard/pad-clamp", Severity.ERROR,
                f"pad_saved {shard.pad_saved:g} exceeds the band's padding "
                f"rows' first-load traffic {cap:g}", layer=layer,
                chip=shard.chip, cap=cap))

    floor = strategy_floor(strat, hw)
    if shard.gross_duration + shard.pad_saved < floor - _ABS:
        report.add(Diagnostic.make(
            "dur/floor", Severity.ERROR,
            f"shard duration {shard.gross_duration:g} (+pad "
            f"{shard.pad_saved:g}) beats the analytic floor {floor:g} — "
            f"cost-model bug", layer=layer, chip=shard.chip, floor=floor))
    return walk


def _shard_spec_mismatch(report: VerificationReport, layer: int,
                         shard: mc.ShardPlan, want: ConvSpec) -> None:
    if shard.spec != want:
        report.add(Diagnostic.make(
            "shard/grid", Severity.ERROR,
            f"shard spec {shard.spec} is not the expected halo-extended "
            f"sub-convolution {want}", layer=layer, chip=shard.chip))


def _check_overlap_war(report: VerificationReport, layer: int,
                       lp: mc.MultiChipLayerPlan,
                       walks: "dict[int, StepWalk]") -> None:
    """An overlapped stage prices at max(compute, ICI): the inbound halo
    streams while the consumer computes.  The halo rows are live input —
    a band that reads them before the exchange can have delivered them
    has a write-after-read hazard, and the overlap claim is unsound.

    Precise verdict through the happens-before timing model
    (:mod:`repro.analysis.access`): the exchange is one timed transfer
    completing at ``ici_duration`` into each receiving band's halo rows;
    every step that touches those rows is a timed read at its Def-3
    start offset.  Since the planner only marks a stage overlapped after
    proving the window safe (``core.multichip.halo_first_use``), any
    violation here is a planner soundness bug — an ERROR, no longer an
    advisory warning."""
    bands = sorted((s.out_rows, s) for s in lp.shards
                   if s.out_rows is not None)
    last_r1 = bands[-1][0][1] if bands else None
    for (r0, r1), shard in bands:
        if r1 == last_r1:
            continue                      # bottom band: no lower neighbour
        sspec = shard.spec
        halo_rows = max(0, sspec.h_k - sspec.s_h)
        if halo_rows == 0:
            continue
        walk = walks.get(shard.chip)
        if walk is None or walk.aborted:
            continue
        tensor = f"chip{shard.chip}/x"
        dst = access.box_region(
            tensor, (sspec.h_in - halo_rows, sspec.h_in),
            (0, sspec.w_in))
        reads = []
        t = 0.0
        for dur, s in zip(walk.durations, shard.strategy.to_steps()):
            if s.i_slice:
                lo_row = ((s.i_slice & -s.i_slice).bit_length() - 1) \
                    // sspec.w_in
                hi_row = (s.i_slice.bit_length() - 1) // sspec.w_in + 1
                reads.append((t, access.box_region(
                    tensor, (lo_row, hi_row), (0, sspec.w_in))))
            t += dur
        v = access.first_violation_or_none(
            [(lp.ici_duration, dst)], reads)
        if v is not None:
            report.add(Diagnostic.make(
                "ici/war-overlap", Severity.ERROR,
                f"overlapped halo exchange completes at "
                f"t={v.complete_time:g} but the band reads its halo rows "
                f"at t={v.read_time:g} — write-after-read on the live "
                f"input window; this stage cannot price "
                f"max(compute, ICI)",
                layer=layer, chip=shard.chip,
                first_use=v.read_time, ici_duration=lp.ici_duration))


def verify_multichip_plan(plan: mc.MultiChipPlan) -> VerificationReport:
    """Symbolically verify a cluster schedule: every shard's step walk,
    the shard-grid tiling geometry, the re-priced ICI schedule, duration
    floors, and the total recomposition."""
    report = VerificationReport(subject=f"multichip:{plan.name}")
    cluster = plan.cluster
    hw = cluster.chip

    if plan.network_plan is not None:
        # 1-chip delegation: the embedded NetworkPlan carries the truth
        inner = verify_network_plan(plan.network_plan)
        report.extend(inner.diagnostics)
        report.checked_layers += inner.checked_layers
        report.checked_steps += inner.checked_steps
        if not _close(plan.total_duration,
                      plan.network_plan.total_duration):
            report.add(Diagnostic.make(
                "plan/total", Severity.ERROR,
                f"1-chip total {plan.total_duration:g} != delegated "
                f"network total {plan.network_plan.total_duration:g}"))
        return report

    grid = cluster.topo.grid(cluster.n_chips)
    t_ici = cluster.t_ici
    prev_mode: "str | None" = None
    for lp in plan.layers:
        spec = lp.spec
        report.checked_layers += 1
        walks: dict[int, StepWalk] = {}
        chips = [s.chip for s in lp.shards]
        if len(set(chips)) != len(chips) or not lp.shards:
            report.add(Diagnostic.make(
                "shard/grid", Severity.ERROR,
                f"shards map to duplicate chips {chips}", layer=lp.index))
        for shard in lp.shards:
            walk = _verify_shard(report, lp.index, shard, spec, hw)
            if walk is not None:
                walks[shard.chip] = walk

        if lp.mode == "replicate":
            if len(lp.shards) != 1:
                report.add(Diagnostic.make(
                    "shard/grid", Severity.ERROR,
                    f"replicate with {len(lp.shards)} shards",
                    layer=lp.index))
            for shard in lp.shards:
                _shard_spec_mismatch(report, lp.index, shard, spec)
        elif lp.mode == "row":
            bands = []
            for shard in lp.shards:
                if shard.out_rows is None:
                    report.add(Diagnostic.make(
                        "shard/band-tiling", Severity.ERROR,
                        "row shard without an output-row band",
                        layer=lp.index, chip=shard.chip))
                    continue
                r0, r1 = shard.out_rows
                bands.append((r0, r1))
                _shard_spec_mismatch(report, lp.index, shard,
                                     _expected_band_spec(spec, r1 - r0))
            _check_bands_tile(report, lp.index, bands, spec.h_out)
        elif lp.mode == "channel":
            kranges = []
            for shard in lp.shards:
                if shard.kernel_range is None:
                    report.add(Diagnostic.make(
                        "shard/kernel-tiling", Severity.ERROR,
                        "channel shard without a kernel range",
                        layer=lp.index, chip=shard.chip))
                    continue
                k0, k1 = shard.kernel_range
                kranges.append((k0, k1))
                _shard_spec_mismatch(
                    report, lp.index, shard,
                    dataclasses.replace(spec, n_kernels=k1 - k0))
            _check_kranges_tile(report, lp.index, kranges, spec.n_kernels)
        elif lp.mode == "hybrid":
            if lp.grid != grid:
                report.add(Diagnostic.make(
                    "shard/grid", Severity.ERROR,
                    f"hybrid grid {lp.grid} != topology grid {grid}",
                    layer=lp.index))
            cells = set()
            bands_set, kranges_set = set(), set()
            for shard in lp.shards:
                if shard.out_rows is None or shard.kernel_range is None:
                    report.add(Diagnostic.make(
                        "shard/grid", Severity.ERROR,
                        "hybrid shard missing its band or kernel range",
                        layer=lp.index, chip=shard.chip))
                    continue
                bands_set.add(shard.out_rows)
                kranges_set.add(shard.kernel_range)
                cells.add((shard.out_rows, shard.kernel_range))
                r0, r1 = shard.out_rows
                k0, k1 = shard.kernel_range
                _shard_spec_mismatch(
                    report, lp.index, shard,
                    _expected_band_spec(spec, r1 - r0, n_kernels=k1 - k0))
            _check_bands_tile(report, lp.index, sorted(bands_set),
                              spec.h_out)
            _check_kranges_tile(report, lp.index, sorted(kranges_set),
                                spec.n_kernels)
            if len(cells) != len(bands_set) * len(kranges_set):
                report.add(Diagnostic.make(
                    "shard/grid", Severity.ERROR,
                    f"hybrid shards cover {len(cells)} of the "
                    f"{len(bands_set)}x{len(kranges_set)} grid cells",
                    layer=lp.index))
        else:
            report.add(Diagnostic.make(
                "shard/grid", Severity.ERROR,
                f"unknown sharding mode {lp.mode!r}", layer=lp.index))

        # halo windows must stay inside the layer's (padded) input
        for shard in lp.shards:
            if shard.out_rows is None:
                continue
            r0, _ = shard.out_rows
            h0 = r0 * spec.s_h
            if h0 < 0 or h0 + shard.spec.h_in > spec.h_in:
                report.add(Diagnostic.make(
                    "shard/halo-source", Severity.ERROR,
                    f"band input window [{h0}, {h0 + shard.spec.h_in}) "
                    f"leaves the input [0, {spec.h_in}) — no neighbour "
                    f"holds those rows", layer=lp.index, chip=shard.chip))

        compute = max((s.gross_duration for s in lp.shards), default=0.0)
        if not _close(compute, lp.compute_duration):
            report.add(Diagnostic.make(
                "dur/ledger", Severity.ERROR,
                f"compute_duration {lp.compute_duration:g} != max over "
                f"shards {compute:g}", layer=lp.index))
        if not _close(lp.ici_duration, lp.ici_elements * t_ici):
            report.add(Diagnostic.make(
                "ici/conservation", Severity.ERROR,
                f"ici_duration {lp.ici_duration:g} != ici_elements "
                f"{lp.ici_elements} * t_ici {t_ici:g}", layer=lp.index))
        if lp.savings:
            report.add(Diagnostic.make(
                "reuse/savings-clamp", Severity.ERROR,
                f"sharded layer claims inter-layer savings "
                f"{lp.savings:g} (multi-chip residency is not modelled)",
                layer=lp.index))

        if lp.overlap and prev_mode == "row" and lp.mode == "row" \
                and lp.ici_elements == mc.halo_elements(spec) \
                and lp.ici_elements > 0:
            _check_overlap_war(report, lp.index, lp, walks)
        prev_mode = lp.mode

    # ICI re-pricing: element conservation against the pure schedule fn
    specs = [lp.spec for lp in plan.layers]
    modes = [lp.mode for lp in plan.layers]
    active = [lp.active_chips for lp in plan.layers]
    per_layer, final = mc.ici_schedule(specs, modes, active, cluster)
    for lp, want in zip(plan.layers, per_layer):
        if lp.ici_elements != want:
            report.add(Diagnostic.make(
                "ici/conservation", Severity.ERROR,
                f"inbound ICI {lp.ici_elements} elements != re-priced "
                f"collective schedule {want}", layer=lp.index,
                claimed=lp.ici_elements, repriced=want))
    if plan.final_gather_elements != final:
        report.add(Diagnostic.make(
            "ici/conservation", Severity.ERROR,
            f"final gather {plan.final_gather_elements} elements != "
            f"re-priced {final}", claimed=plan.final_gather_elements,
            repriced=final))
    if not _close(plan.final_gather_duration,
                  plan.final_gather_elements * t_ici):
        report.add(Diagnostic.make(
            "ici/conservation", Severity.ERROR,
            f"final gather duration {plan.final_gather_duration:g} != "
            f"elements {plan.final_gather_elements} * t_ici {t_ici:g}"))

    total = sum(lp.duration for lp in plan.layers) \
        + plan.final_gather_duration
    if not _close(total, plan.total_duration):
        report.add(Diagnostic.make(
            "plan/total", Severity.ERROR,
            f"total_duration {plan.total_duration:g} != stage sum + final "
            f"gather {total:g}"))
    return report


# --------------------------------------------------------------------- #
# Planner postcondition
# --------------------------------------------------------------------- #

def assert_verified(plan) -> VerificationReport:
    """Verify ``plan`` (NetworkPlan or MultiChipPlan); raise
    :class:`PlanVerificationError` on any error-severity diagnostic."""
    if isinstance(plan, NetworkPlan):
        report = verify_network_plan(plan)
    elif isinstance(plan, mc.MultiChipPlan):
        report = verify_multichip_plan(plan)
    else:
        raise TypeError(f"cannot verify {type(plan).__name__}")
    if not report.ok:
        raise PlanVerificationError(report)
    return report
