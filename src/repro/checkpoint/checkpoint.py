"""Fault-tolerant checkpointing.

Design (DESIGN.md §5, sized for 1000+ hosts):
  * **per-host shards** — every host writes only its addressable shard set
    (`.npz` per host) plus a tiny JSON manifest; no host ever materialises
    the global state;
  * **atomic commit** — writes go to ``step_N.tmp/``, fsync'd, then a
    single ``rename`` to ``step_N/`` publishes the checkpoint; readers only
    trust directories with a ``COMMIT`` marker, so a host crash mid-write
    can never corrupt the restore source;
  * **async save** — a background thread serialises device-fetched arrays
    so the train loop blocks only for the device->host copy;
  * **elastic restore** — restore re-shards to whatever mesh the new job
    has (`jax.device_put` against the new sharding), so recovery after
    losing hosts (or growing the fleet) is the same code path;
  * **retention** — keep the newest K committed checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree_like, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: shape {arr.shape} != {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 host_id: int = 0, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------
    def save(self, step: int, state: Any, extra: dict | None = None,
             block: bool = False) -> None:
        """Snapshot ``state`` (pytree of arrays) at ``step``."""
        self.wait()                      # one in-flight save at a time
        host_arrays = _flatten(state)    # device->host copy happens here
        meta = {"step": step, "time": time.time(),
                "extra": extra or {}, "host": self.host_id}

        def _write():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                os.makedirs(tmp, exist_ok=True)
                np.savez(os.path.join(tmp, f"host_{self.host_id}.npz"),
                         **host_arrays)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(meta, f)
                with open(os.path.join(tmp, "COMMIT"), "w") as f:
                    f.write(str(step))
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except Exception as e:          # surfaced on next wait()
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.committed_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------- restore ----------------
    def committed_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shardings: Any | None = None) -> tuple[Any, dict]:
        """Load ``step`` into the structure of ``like``.  If ``shardings``
        is given (pytree of jax.sharding.Sharding), arrays are device_put
        against it — the elastic-reshard path."""
        path = os.path.join(self.dir, f"step_{step}")
        if not os.path.exists(os.path.join(path, "COMMIT")):
            raise FileNotFoundError(f"no committed checkpoint at {path}")
        with np.load(os.path.join(path, f"host_{self.host_id}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        state = _unflatten_into(like, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, meta

    def restore_latest(self, like: Any, shardings: Any | None = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return self.restore(step, like, shardings)
