"""Cluster presets for the multi-chip planner: ICI rings (uni- and
bidirectional) and 2-D tori.

Abstract-unit clusters (``t_l = t_w = t_acc = 1`` cycle per element, the
paper's Sec-7 setting) with ``t_ici = ICI_FACTOR * t_l``.  On TPU v5e one
ICI link moves bytes ~16x slower than HBM (819 GB/s vs 50 GB/s per link,
see ``TpuChipModel``), but a chip drives 4 ICI ports, so collectives that
spread traffic across links see an *effective* per-element cost of ~4x an
HBM load — ``ICI_FACTOR = 4`` models that aggregate; pass
``ici_factor=16`` for the pessimistic single-link bound (the planner then
correctly refuses to shard small activations).  ``topology`` accepts
``'ring'`` (PR-3 unidirectional default), ``'biring'``, ``'torusRxC'``
(bidirectional links, v5e-style) or a ``Topology`` instance;
:func:`torus_dims` picks the squarest grid for a chip count (the shape
that minimises the longer axis ring, hence the bottleneck hop count).
``TPU_V5E_RING*`` are rings in the real chip's seconds/bytes units via
:meth:`TpuChipModel.as_cluster` (per-link pricing).
"""
from repro.core.cost_model import (TPU_V5E, ClusterModel, HardwareModel,
                                   Topology)

# effective t_ici / t_l across a v5e chip's 4 ICI ports (per-link: ~16)
ICI_FACTOR = 4.0


def make_cluster(n_chips: int, *, nbop_pe: int = 10 ** 9,
                 size_mem: int | None = None, t_l: float = 1.0,
                 t_w: float = 1.0, t_acc: float = 1.0,
                 ici_factor: float = ICI_FACTOR,
                 topology: "Topology | str" = "ring") -> ClusterModel:
    """An abstract-unit ICI cluster of ``n_chips`` identical chips."""
    chip = HardwareModel(nbop_pe=nbop_pe, size_mem=size_mem,
                         t_l=t_l, t_w=t_w, t_acc=t_acc)
    return ClusterModel(chip=chip, n_chips=n_chips, t_ici=t_l * ici_factor,
                        topology=topology)


def torus_dims(n_chips: int) -> tuple[int, int] | None:
    """Squarest (rows, cols) grid for ``n_chips``; None when no 2-D grid
    exists (primes and n < 4 only offer the degenerate 1xN ring)."""
    best = None
    for ny in range(2, int(n_chips ** 0.5) + 1):
        if n_chips % ny == 0:
            best = (ny, n_chips // ny)
    return best


RING1 = make_cluster(1)
RING2 = make_cluster(2)
RING4 = make_cluster(4)
RING8 = make_cluster(8)
RINGS = {1: RING1, 2: RING2, 4: RING4, 8: RING8}

BIRING4 = make_cluster(4, topology="biring")
BIRING8 = make_cluster(8, topology="biring")
TORUS2X2 = make_cluster(4, topology="torus2x2")
TORUS2X4 = make_cluster(8, topology="torus2x4")

# the topology matrix exercised by tests and the --topology bench axis
TOPOLOGY_PRESETS = {
    "ring": RING4,
    "biring": BIRING4,
    "torus2x2": TORUS2X2,
    "torus2x4": TORUS2X4,
}

TPU_V5E_RING4 = TPU_V5E.as_cluster(4)
TPU_V5E_RING8 = TPU_V5E.as_cluster(8)
