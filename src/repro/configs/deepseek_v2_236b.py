"""deepseek-v2-236b [moe]: 60L d_model=5120 128H (MLA) d_ff=1536/expert
vocab=102400, MoE 2 shared + 160 routed top-6, kv_lora=512
[arXiv:2405.04434; hf]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    n_experts=160, top_k=6, n_shared_experts=2,
    mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    policy="tp", supports_long=False)
