"""The paper's own conv workloads: LeNet-5 layers (Sec 7.2)."""
from repro.core.conv_spec import ConvSpec

# first conv layer of LeNet-5: 1x32x32 input (padded 28x28), six 5x5 kernels
LENET5_L1 = ConvSpec(c_in=1, h_in=32, w_in=32, n_kernels=6, h_k=5, w_k=5)
# second conv layer: 6x14x14 -> sixteen 5x5 kernels
LENET5_L2 = ConvSpec(c_in=6, h_in=14, w_in=14, n_kernels=16, h_k=5, w_k=5)

# the conv backbone in execution order (pooling between layers happens
# on-chip and is free in the planning model — see core.network_planner)
LAYERS = (LENET5_L1, LENET5_L2)
