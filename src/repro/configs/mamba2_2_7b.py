"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, ssm_state=128, SSD
[arXiv:2405.21060; unverified].  Sub-quadratic -> runs long_500k."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, head_dim=None,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    policy="tp", supports_long=True)
