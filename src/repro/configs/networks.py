"""Registry of conv-network workloads for the network-level planner."""
from repro.configs import lenet5, resnet8, tight

NETWORKS = {
    "lenet5": lenet5.LAYERS,
    "resnet8": resnet8.LAYERS,
    "tight4": tight.LAYERS,
    "tight2": tight.LAYERS_SMALL,
}
