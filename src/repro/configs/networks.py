"""Registry of conv-network workloads for the network-level planner."""
from repro.configs import lenet5, resnet8

NETWORKS = {
    "lenet5": lenet5.LAYERS,
    "resnet8": resnet8.LAYERS,
}
