"""The paper's ResNet8 conv layers (Sec 7.2): 3x3 kernels, stride 1."""
from repro.core.conv_spec import ConvSpec

RESNET8_L1 = ConvSpec(c_in=3, h_in=34, w_in=34, n_kernels=16, h_k=3, w_k=3)
RESNET8_L2 = ConvSpec(c_in=16, h_in=18, w_in=18, n_kernels=32, h_k=3, w_k=3)
RESNET8_L3 = ConvSpec(c_in=32, h_in=10, w_in=10, n_kernels=64, h_k=3, w_k=3)

# Channel-consistent CIFAR-style backbone: stem then three residual blocks
# of two 3x3 convs each; every c_in equals the previous layer's c_out
# (spatial dims already padded, stride-2 downsampling between blocks
# happens on-chip).  Block 1 repeats one shape — the repeated-layer
# pattern the network planner's solve cache exists for.
RESNET8_STEM = RESNET8_L1                                  # 3  -> 16
RESNET8_B1 = ConvSpec(c_in=16, h_in=34, w_in=34, n_kernels=16,
                      h_k=3, w_k=3)                        # 16 -> 16 (x2)
RESNET8_B2A = RESNET8_L2                                   # 16 -> 32
RESNET8_B2B = ConvSpec(c_in=32, h_in=18, w_in=18, n_kernels=32,
                       h_k=3, w_k=3)                       # 32 -> 32
RESNET8_B3A = RESNET8_L3                                   # 32 -> 64
RESNET8_B3B = ConvSpec(c_in=64, h_in=10, w_in=10, n_kernels=64,
                       h_k=3, w_k=3)                       # 64 -> 64
LAYERS = (RESNET8_STEM, RESNET8_B1, RESNET8_B1,
          RESNET8_B2A, RESNET8_B2B, RESNET8_B3A, RESNET8_B3B)
