"""The paper's ResNet8 conv layers (Sec 7.2): 3x3 kernels, stride 1."""
from repro.core.conv_spec import ConvSpec

RESNET8_L1 = ConvSpec(c_in=3, h_in=34, w_in=34, n_kernels=16, h_k=3, w_k=3)
RESNET8_L2 = ConvSpec(c_in=16, h_in=18, w_in=18, n_kernels=32, h_k=3, w_k=3)
RESNET8_L3 = ConvSpec(c_in=32, h_in=10, w_in=10, n_kernels=64, h_k=3, w_k=3)
