"""Tight-budget network variants: kernel-heavy backbones whose deepest
layers' kernel set Λ alone exceeds realistic on-chip budgets — the regime
where the network planner must swap kernel groups (S2) instead of the
paper's all-kernels-resident S1 assumption (Sec 9 future work).

The channel ramp is deliberately steep: early layers stay S1-feasible
under budgets that force the late layers into S2, so one network exercises
the S1→S2 crossover inside a single plan.  Spatial dims are kept small so
planning and functional simulation stay fast in tests and smoke runs.
"""
from repro.core.conv_spec import ConvSpec

# Λ = 72 / 1152 / 4608 / 18432 elements: each stage 4x the previous.
TIGHT_L1 = ConvSpec(c_in=1, h_in=12, w_in=12, n_kernels=8, h_k=3, w_k=3)
TIGHT_L2 = ConvSpec(c_in=8, h_in=10, w_in=10, n_kernels=16, h_k=3, w_k=3)
TIGHT_L3 = ConvSpec(c_in=16, h_in=8, w_in=8, n_kernels=32, h_k=3, w_k=3)
TIGHT_L4 = ConvSpec(c_in=32, h_in=6, w_in=6, n_kernels=64, h_k=3, w_k=3)

# deep ramp: the full S1→S2 crossover in one plan
LAYERS = (TIGHT_L1, TIGHT_L2, TIGHT_L3, TIGHT_L4)

# shallow variant for quick smoke runs (one S1 layer, one S2 candidate)
LAYERS_SMALL = (TIGHT_L2, TIGHT_L3)


def budget_points(specs, fractions=(0.25, 0.5, 1.0, 2.0)) -> list[int]:
    """On-chip budgets as fractions of the largest layer's kernel set Λ —
    below 1.0 the largest layer cannot keep its kernels resident and the
    planner must fall back to S2 kernel-group swapping."""
    biggest = max(s.kernel_elements for s in specs)
    return sorted({max(1, int(biggest * f)) for f in fractions})
