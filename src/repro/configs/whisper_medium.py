"""whisper-medium [audio]: 24L enc + 24L dec, d_model=1024 16H (MHA)
d_ff=4096 vocab=51865, enc-dec; conv frontend STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    dec_layers=24, dec_seq=448, causal=False,
    policy="tp", supports_long=False)
