"""zamba2-2.7b [hybrid]: 54L d_model=2560 Mamba2 backbone + shared attn
block (32H kv=32, d_ff=10240) every 6 layers, ssm_state=64
[arXiv:2411.15242; hf].  Hybrid -> runs long_500k."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=6,
    policy="tp", supports_long=True)
