"""The paper's primary contribution: the offloading formalism (Sec 2), conv
slicing (Sec 3), strategies S1-baseline/S1/RowByRow/ZigZag (Sec 4) plus the
beyond-paper Tiled/Hilbert and S2 families, the ILP (Sec 5) with its
HiGHS + polishing solver, and the TPU tile-schedule planner that carries
the same cost model into the Pallas kernels."""
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import (TPU_V5E, ClusterModel, HardwareModel,
                                   TpuChipModel)
from repro.core.formalism import MemoryState, Step, StepError, run_steps
from repro.core.strategies import (GroupedStrategy, best_heuristic, hilbert,
                                   row_by_row, s1_baseline, tiled, zigzag)

__all__ = [
    "ConvSpec", "HardwareModel", "TpuChipModel", "TPU_V5E", "ClusterModel",
    "MemoryState", "Step", "StepError", "run_steps",
    "GroupedStrategy", "best_heuristic", "hilbert", "row_by_row",
    "s1_baseline", "tiled", "zigzag",
]
