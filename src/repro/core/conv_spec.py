"""Convolution slicing (paper Sec 3, Defs 4-11).

A 2D convolution takes a 3D input ``(C_in, H_in, W_in)`` and N kernels
``(C_in, H_K, W_K)`` and produces ``(N, H_out, W_out)``.  The *patch*
``P_{i,j}`` is the input slice needed to compute output column ``O[:, i, j]``.

Per the paper's Remark 6 we work with 2-D *spatial* pixels — the channel
dimension is never sliced, so a spatial pixel stands for all its C_in channel
elements.  Per Remark 2 the input is assumed already padded.

Patches and pixels are linearised row-major (Remarks 4-5).  Pixel sets are
represented as Python int bitmasks over the H_in*W_in spatial grid: set ops
are then single integer ops and cardinality is ``int.bit_count()`` — this is
what makes the ILP polishing search and the simulator fast.
"""
from __future__ import annotations

import dataclasses
import functools


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """A convolution layer (already-padded input)."""

    c_in: int
    h_in: int
    w_in: int
    n_kernels: int          # N == C_out
    h_k: int
    w_k: int
    s_h: int = 1
    s_w: int = 1

    def __post_init__(self):
        if self.h_out < 1 or self.w_out < 1:
            raise ValueError(f"kernel larger than input: {self}")

    # --- Def 8 ------------------------------------------------------------
    @property
    def c_out(self) -> int:
        return self.n_kernels

    @property
    def h_out(self) -> int:
        return (self.h_in - self.h_k) // self.s_h + 1

    @property
    def w_out(self) -> int:
        return (self.w_in - self.w_k) // self.s_w + 1

    @property
    def num_patches(self) -> int:
        """|X| = H_out * W_out (Def 11)."""
        return self.h_out * self.w_out

    @property
    def num_pixels(self) -> int:
        """Spatial pixels of the input grid (Remark 6: channel collapsed)."""
        return self.h_in * self.w_in

    # --- Def 13 -----------------------------------------------------------
    @property
    def nb_op_value(self) -> int:
        """MACs to compute one output value."""
        return self.c_in * self.h_k * self.w_k

    @property
    def macs_total(self) -> int:
        return self.nb_op_value * self.c_out * self.num_patches

    # --- sizes in tensor elements (for memory-footprint accounting) -------
    @property
    def kernel_elements(self) -> int:
        """All kernels: C_out * C_in * H_K * W_K (term 2 of eq. 12)."""
        return self.c_out * self.c_in * self.h_k * self.w_k

    # --- linearisation (Remarks 4-5) ---------------------------------------
    def patch_id(self, i: int, j: int) -> int:
        """Row-major patch index for output position (i, j)."""
        return i * self.w_out + j

    def patch_pos(self, pid: int) -> tuple[int, int]:
        return divmod(pid, self.w_out)

    def pixel_id(self, h: int, w: int) -> int:
        """Row-major spatial pixel index."""
        return h * self.w_in + w

    def pixel_pos(self, jid: int) -> tuple[int, int]:
        return divmod(jid, self.w_in)

    # --- Def 10: patches as pixel bitmasks ---------------------------------
    def patch_bbox(self, pid: int) -> tuple[int, int, int, int]:
        """(h0, w0, h1, w1) half-open input window of patch ``pid``."""
        i, j = self.patch_pos(pid)
        h0, w0 = i * self.s_h, j * self.s_w
        return h0, w0, h0 + self.h_k, w0 + self.w_k

    @functools.cached_property
    def patch_masks(self) -> tuple[int, ...]:
        """Bitmask of spatial pixels for every patch, indexed by patch id."""
        masks = []
        for pid in range(self.num_patches):
            h0, w0, h1, w1 = self.patch_bbox(pid)
            m = 0
            for h in range(h0, h1):
                row = ((1 << (w1 - w0)) - 1) << (h * self.w_in + w0)
                m |= row
            masks.append(m)
        return tuple(masks)

    @functools.cached_property
    def all_pixels_mask(self) -> int:
        """Union of all patches — pixels that are ever needed."""
        m = 0
        for pm in self.patch_masks:
            m |= pm
        return m

    def group_mask(self, patch_ids) -> int:
        """Pixel bitmask of a patch group (union of its patches)."""
        m = 0
        masks = self.patch_masks
        for pid in patch_ids:
            m |= masks[pid]
        return m

    # --- pxl_in_P constant of Sec 5.1 --------------------------------------
    @functools.cached_property
    def pxl_in_p(self) -> frozenset[tuple[int, int]]:  # lint: public-api
        """{(patch_id, pixel_id) | pixel in patch} (Example 3)."""
        pairs = []
        for pid, m in enumerate(self.patch_masks):
            jid = 0
            mm = m
            while mm:
                low = mm & -mm
                pairs.append((pid, low.bit_length() - 1))
                mm ^= low
        return frozenset(pairs)

    def pixels_of_mask(self, mask: int) -> list[int]:
        """Sorted pixel ids present in a bitmask."""
        out = []
        while mask:
            low = mask & -mask
            out.append(low.bit_length() - 1)
            mask ^= low
        return out
