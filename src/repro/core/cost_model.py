"""Platform model (paper Sec 2.1) and duration model (Def 3).

The accelerator is capable of ``nbop_pe`` MAC operations per ``t_acc`` cycles.
The on-chip memory has size ``size_mem``.  Loading one element from DRAM to
on-chip memory costs ``t_l``; writing one element back costs ``t_w``.  All
durations are in accelerator cycles; all sizes are unit-less integers, as in
the paper.

Unit convention (see DESIGN.md §6): the paper's Example 2 counts *spatial*
pixels for duration (an I_slice listing 12 tensor elements over C_in=2
channels contributes ``6 * t_l``), while memory-footprint statements count
tensor *elements* (``M_2^inp = 32``).  We therefore keep sets of spatial
locations and expose both countings; duration uses spatial counts, footprint
uses element counts.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Generic accelerator of paper Fig. 1."""

    nbop_pe: int            # MAC ops available per t_acc window
    size_mem: int | None = None   # on-chip memory capacity (elements); None = unconstrained (paper Sec 7.1)
    t_l: float = 1.0        # cycles to load one (spatial) element DRAM -> on-chip
    t_w: float = 1.0        # cycles to write one (spatial) element on-chip -> DRAM
    t_acc: float = 1.0      # cycles per compute step

    def nb_patches_max_s1(self, nb_op_value: int, c_out: int) -> int:
        """Paper Sec 4.2: max patches the PE can consume in one S1 step."""
        cap = self.nbop_pe // (nb_op_value * c_out)
        if cap < 1:
            raise ValueError(
                f"accelerator too small: nbop_pe={self.nbop_pe} < one patch "
                f"({nb_op_value}*{c_out} MACs)")
        return cap


# ---------------------------------------------------------------------------
# Multi-chip cluster (beyond-paper: core.multichip).  Same unit system as
# HardwareModel — ``t_ici`` is the Def-3-style element-transfer cost of the
# inter-chip interconnect, sitting next to ``t_l``/``t_w``.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """``n_chips`` identical accelerators joined by ICI links in a ring.

    Units (matching the :class:`HardwareModel` docstring above): all
    durations are accelerator cycles and all sizes are unit-less element
    counts.  ``chip`` is the per-chip platform model (its ``t_l``/``t_w``
    price HBM traffic); ``t_ici`` is the cycles to move ONE tensor element
    across one ICI link — the inter-chip counterpart of ``t_l``.  The
    duration of an ICI phase is ``bottleneck_link_elements * t_ici``:
    links transfer in parallel (a ring halo exchange costs one boundary's
    elements, not the sum), but chips do NOT overlap ICI with compute —
    the same conservative sequential accounting as the paper's Def 3.
    On real hardware ``t_ici = dtype_bytes / ici_bw_per_link`` while
    ``t_l = dtype_bytes / hbm_bw``, so ``t_ici / t_l = hbm_bw /
    ici_bw_per_link`` (~16 on TPU v5e); see
    :meth:`TpuChipModel.as_cluster`.
    """

    chip: HardwareModel
    n_chips: int = 1
    t_ici: float = 0.0      # cycles to move one element across one ICI link
    topology: str = "ring"

    def __post_init__(self):
        if self.n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {self.n_chips}")
        if self.t_ici < 0:
            raise ValueError(f"t_ici must be >= 0, got {self.t_ici}")
        if self.topology != "ring":
            raise ValueError(
                f"only the ring topology is modelled (2-D tori are a "
                f"ROADMAP follow-up), got {self.topology!r}")


# ---------------------------------------------------------------------------
# TPU v5e preset — used by core.planner to drive Pallas BlockSpec choices.
# The paper's abstract units become bytes/seconds here.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TpuChipModel:
    """Roofline constants for the target chip (TPU v5e, per the brief)."""

    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s
    ici_bw_per_link: float = 50e9     # bytes/s per ICI link
    vmem_bytes: int = 128 * 1024 * 1024
    mxu_dim: int = 128                # systolic array edge; align matmul dims

    def as_hardware_model(self, dtype_bytes: int = 2) -> HardwareModel:
        """Express the chip in the paper's (t_l, t_w, t_acc, nbop) terms.

        Time unit = seconds.  ``t_acc = 1s`` window gives ``nbop_pe =
        peak_flops/2`` MACs (1 MAC = 2 FLOP); loading one element costs
        ``dtype_bytes / hbm_bw`` seconds; size_mem is VMEM in elements.
        """
        t_l = dtype_bytes / self.hbm_bw
        return HardwareModel(
            nbop_pe=int(self.peak_flops / 2.0),
            size_mem=self.vmem_bytes // dtype_bytes,
            t_l=t_l, t_w=t_l, t_acc=1.0)

    def as_cluster(self, n_chips: int, dtype_bytes: int = 2) -> ClusterModel:
        """A ring of ``n_chips`` of this chip: ``t_ici`` prices one element
        over one ICI link in the same seconds unit as ``t_l``."""
        return ClusterModel(
            chip=self.as_hardware_model(dtype_bytes),
            n_chips=n_chips,
            t_ici=dtype_bytes / self.ici_bw_per_link)


TPU_V5E = TpuChipModel()
