"""Platform model (paper Sec 2.1) and duration model (Def 3).

The accelerator is capable of ``nbop_pe`` MAC operations per ``t_acc`` cycles.
The on-chip memory has size ``size_mem``.  Loading one element from DRAM to
on-chip memory costs ``t_l``; writing one element back costs ``t_w``.  All
durations are in accelerator cycles; all sizes are unit-less integers, as in
the paper.

Unit convention (see DESIGN.md §6): the paper's Example 2 counts *spatial*
pixels for duration (an I_slice listing 12 tensor elements over C_in=2
channels contributes ``6 * t_l``), while memory-footprint statements count
tensor *elements* (``M_2^inp = 32``).  We therefore keep sets of spatial
locations and expose both countings; duration uses spatial counts, footprint
uses element counts.
"""
from __future__ import annotations

import dataclasses
import math
import re


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Generic accelerator of paper Fig. 1."""

    nbop_pe: int            # MAC ops available per t_acc window
    size_mem: int | None = None   # on-chip memory capacity (elements); None = unconstrained (paper Sec 7.1)
    t_l: float = 1.0        # cycles to load one (spatial) element DRAM -> on-chip
    t_w: float = 1.0        # cycles to write one (spatial) element on-chip -> DRAM
    t_acc: float = 1.0      # cycles per compute step

    def nb_patches_max_s1(self, nb_op_value: int, c_out: int) -> int:
        """Paper Sec 4.2: max patches the PE can consume in one S1 step."""
        cap = self.nbop_pe // (nb_op_value * c_out)
        if cap < 1:
            raise ValueError(
                f"accelerator too small: nbop_pe={self.nbop_pe} < one patch "
                f"({nb_op_value}*{c_out} MACs)")
        return cap


# ---------------------------------------------------------------------------
# Multi-chip cluster (beyond-paper: core.multichip).  Same unit system as
# HardwareModel — ``t_ici`` is the Def-3-style element-transfer cost of the
# inter-chip interconnect, sitting next to ``t_l``/``t_w``.
# ---------------------------------------------------------------------------

_TORUS_RE = re.compile(r"^torus(\d+)x(\d+)$")


@dataclasses.dataclass(frozen=True)
class Topology:
    """ICI wiring of a cluster, with per-topology collective pricing.

    ``kind`` is ``'ring'`` (1-D) or ``'torus'`` (2-D, ``dims=(rows,
    cols)`` rings along each axis — axis 0 is the *row-band* axis, axis 1
    the *kernel-channel* axis of ``core.multichip``'s hybrid sharding).
    ``bidirectional`` links carry traffic both ways, halving the
    bottleneck-link load of every split-table collective (the standard
    bidirectional-ring algorithm); a halo *shift* moves one boundary's
    rows one hop, so it costs the same either way.

    Every collective method returns the **bottleneck-link element
    count** of the phase — multiply by ``ClusterModel.t_ici`` for cycles.
    Links transfer in parallel; chips do not overlap ICI with compute
    unless the planner's ``overlap`` discipline says so.  2-D collectives
    run their two axis phases serially (axis 1 first, rows in parallel;
    then axis 0) — the conservative, predictable schedule in the spirit
    of the paper's Def 3.  A ``1xN`` (or ``Nx1``) torus therefore prices
    every collective exactly like the ``N``-ring with the same link
    direction — property-tested in ``tests/test_topology*.py``.

    The formulas follow the communication-lower-bound accounting of Chen
    et al. (arXiv:1911.05662): an all-gather / gather / scatter /
    reduce-scatter of ``A`` elements over a ``k``-ring keeps one link
    busy with ``ceil(A*(k-1)/k)`` elements; a pipelined broadcast pushes
    the full ``A`` through the source's link.
    """

    kind: str = "ring"                  # 'ring' | 'torus'
    dims: tuple[int, int] | None = None  # torus only: (rows, cols)
    bidirectional: bool = False

    def __post_init__(self):
        if self.kind not in ("ring", "torus"):
            raise ValueError(f"unknown topology kind {self.kind!r}")
        if self.kind == "torus":
            if (self.dims is None or len(self.dims) != 2
                    or min(self.dims) < 1):
                raise ValueError(
                    f"torus needs dims=(rows, cols) >= (1, 1), "
                    f"got {self.dims!r}")
            object.__setattr__(self, "dims", tuple(self.dims))
        elif self.dims is not None:
            raise ValueError("ring topology takes no dims")

    # ---- construction ------------------------------------------------ #

    @classmethod
    def parse(cls, s: "str | Topology") -> "Topology":
        """``'ring'`` | ``'biring'`` | ``'torusRxC'`` (bidirectional,
        v5e-style) — or an already-built :class:`Topology`."""
        if isinstance(s, Topology):
            return s
        if s == "ring":
            return cls("ring")
        if s == "biring":
            return cls("ring", bidirectional=True)
        m = _TORUS_RE.match(s)
        if m:
            return cls("torus", (int(m.group(1)), int(m.group(2))),
                       bidirectional=True)
        raise ValueError(
            f"unknown topology {s!r} (want 'ring', 'biring', 'torusRxC' "
            f"or a Topology instance)")

    def describe(self) -> str:
        if self.kind == "torus":
            ny, nx = self.dims
            link = "bidirectional" if self.bidirectional else \
                "unidirectional"
            return f"{ny}x{nx} torus, {link} links"
        return ("bidirectional ring" if self.bidirectional else
                "unidirectional ring")

    # ---- geometry ---------------------------------------------------- #

    def n_links_ok(self, n_chips: int) -> bool:
        """Does this wiring exist for ``n_chips`` chips?"""
        if self.kind == "torus":
            ny, nx = self.dims
            return ny * nx == n_chips
        return True

    def grid(self, n_chips: int) -> tuple[int, int]:
        """(rows, cols) — a ring is an ``n x 1`` grid (one band axis)."""
        if self.kind == "torus":
            return self.dims
        return (n_chips, 1)

    # ---- ring primitives --------------------------------------------- #

    def _dir(self, x: int) -> int:
        """Bidirectional links split a collective's bottleneck load."""
        return (x + 1) // 2 if self.bidirectional else x

    @staticmethod
    def _ring_split(k: int, a: int) -> int:
        """Uni-ring gather/scatter/all-gather/reduce-scatter bottleneck
        over ``k`` chips of an ``a``-element tensor."""
        if k <= 1:
            return 0
        return math.ceil(a * (k - 1) / k)

    # ---- whole-cluster collectives (bottleneck-link elements) --------- #

    def gather(self, n_chips: int, a: int) -> int:
        """Sharded-over-all-chips tensor collected onto one chip: axis-1
        rings funnel each band row (in parallel), then the axis-0 ring
        funnels the full tensor."""
        ny, nx = self.grid(n_chips)
        return (self._dir(self._ring_split(nx, math.ceil(a / ny)))
                + self._dir(self._ring_split(ny, a)))

    def scatter(self, n_chips: int, a: int) -> int:
        """One chip's tensor distributed into per-chip shards (reverse
        gather — same bottleneck)."""
        return self.gather(n_chips, a)

    def allgather(self, n_chips: int, a: int) -> int:
        """Every chip ends with the full ``a``-element tensor."""
        return self.gather(n_chips, a)

    def reduce_scatter(self, n_chips: int, a: int) -> int:  # lint: experimental-api
        """Per-chip partial sums combined and left sharded (the hybrid
        input-channel follow-up's collective; same ring bottleneck as
        the all-gather, per the standard ring algorithm).

        .. note:: **Experimental.**  No planner mode emits this collective
           yet — input-channel sharding is future work (see ROADMAP).  The
           pricing is pinned by ``tests/test_topology.py`` so the formula
           cannot drift before it is wired in.
        """
        return self.gather(n_chips, a)

    def all_to_all(self, n_chips: int, a: int) -> int:
        """Resharding bound (e.g. channel -> row): priced at the
        all-gather bottleneck, as in the PR-3 ring model."""
        return self.allgather(n_chips, a)

    def bcast(self, n_chips: int, a: int) -> int:
        """One chip's full tensor pipelined to every chip, axis by axis."""
        ny, nx = self.grid(n_chips)
        out = 0
        if ny > 1:
            out += self._dir(a)
        if nx > 1:
            out += self._dir(a)
        return out

    # ---- single-axis collectives (hybrid row x channel sharding) ------ #

    def allgather_axis1(self, n_chips: int, a: int) -> int:
        """Each band row all-gathers its own ``a/rows`` slice along the
        kernel-channel axis; rows run in parallel."""
        ny, nx = self.grid(n_chips)
        return self._dir(self._ring_split(nx, math.ceil(a / ny)))

    def scatter_axis0(self, n_chips: int, a: int) -> int:
        """Chip 0's tensor split into band rows along the row axis."""
        ny, _ = self.grid(n_chips)
        return self._dir(self._ring_split(ny, a))

    def bcast_axis1(self, n_chips: int, a: int) -> int:
        """Each band-row head broadcasts its ``a/rows`` band along the
        kernel-channel axis; rows run in parallel."""
        ny, nx = self.grid(n_chips)
        if nx <= 1:
            return 0
        return self._dir(math.ceil(a / ny))


RING = Topology("ring")
BIRING = Topology("ring", bidirectional=True)


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """``n_chips`` identical accelerators joined by ICI links.

    Units (matching the :class:`HardwareModel` docstring above): all
    durations are accelerator cycles and all sizes are unit-less element
    counts.  ``chip`` is the per-chip platform model (its ``t_l``/``t_w``
    price HBM traffic); ``t_ici`` is the cycles to move ONE tensor element
    across one ICI link — the inter-chip counterpart of ``t_l``.  The
    duration of an ICI phase is ``bottleneck_link_elements * t_ici``
    with the bottleneck count priced by :class:`Topology` (links transfer
    in parallel — a ring halo exchange costs one boundary's elements, not
    the sum; chips do NOT overlap ICI with compute unless the planner's
    ``overlap`` discipline says so — the same conservative sequential
    accounting as the paper's Def 3).
    ``topology`` accepts ``'ring'`` (the PR-3 unidirectional default,
    bit-exact), ``'biring'``, ``'torusRxC'`` (bidirectional, v5e-style),
    or a :class:`Topology` instance; torus dims must tile ``n_chips``.
    On real hardware ``t_ici = dtype_bytes / ici_bw_per_link`` while
    ``t_l = dtype_bytes / hbm_bw``, so ``t_ici / t_l = hbm_bw /
    ici_bw_per_link`` (~16 on TPU v5e); see
    :meth:`TpuChipModel.as_cluster`.
    """

    chip: HardwareModel
    n_chips: int = 1
    t_ici: float = 0.0      # cycles to move one element across one ICI link
    topology: "Topology | str" = "ring"

    def __post_init__(self):
        if self.n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {self.n_chips}")
        if self.t_ici < 0:
            raise ValueError(f"t_ici must be >= 0, got {self.t_ici}")
        topo = Topology.parse(self.topology)
        if not topo.n_links_ok(self.n_chips):
            raise ValueError(
                f"topology {topo.describe()} does not tile "
                f"n_chips={self.n_chips}")
        object.__setattr__(self, "topology", topo)

    @property
    def topo(self) -> Topology:
        return self.topology  # normalised to a Topology in __post_init__

    def degraded(self, *, n_chips: "int | None" = None,
                 topology: "Topology | str | None" = None,
                 t_ici_factor: float = 1.0,
                 size_mem_factor: float = 1.0) -> "ClusterModel":
        """A degraded copy of this cluster (``repro.resil``): fewer chips
        on a new wiring, ``t_ici_factor``x slower links, and/or a
        per-chip budget shrunk to ``floor(size_mem * size_mem_factor)``.
        Revalidates through ``__post_init__`` — the topology must tile
        the surviving chip count."""
        if t_ici_factor < 1.0:
            raise ValueError(
                f"t_ici_factor must be >= 1 (links only degrade), "
                f"got {t_ici_factor}")
        if not 0.0 < size_mem_factor <= 1.0:
            raise ValueError(
                f"size_mem_factor must be in (0, 1], got {size_mem_factor}")
        chip = self.chip
        if size_mem_factor != 1.0:
            if chip.size_mem is None:
                raise ValueError(
                    "cannot shrink an unconstrained size_mem budget")
            new_mem = int(chip.size_mem * size_mem_factor)
            if new_mem < 1:
                raise ValueError(
                    f"size_mem_factor {size_mem_factor} leaves no memory "
                    f"(size_mem={chip.size_mem})")
            chip = dataclasses.replace(chip, size_mem=new_mem)
        return ClusterModel(
            chip=chip,
            n_chips=self.n_chips if n_chips is None else n_chips,
            t_ici=self.t_ici * t_ici_factor,
            topology=self.topology if topology is None else topology)


# ---------------------------------------------------------------------------
# TPU v5e preset — used by core.planner to drive Pallas BlockSpec choices.
# The paper's abstract units become bytes/seconds here.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TpuChipModel:
    """Roofline constants for the target chip (TPU v5e, per the brief)."""

    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s
    ici_bw_per_link: float = 50e9     # bytes/s per ICI link
    vmem_bytes: int = 128 * 1024 * 1024
    mxu_dim: int = 128                # systolic array edge; align matmul dims

    def as_hardware_model(self, dtype_bytes: int = 2) -> HardwareModel:
        """Express the chip in the paper's (t_l, t_w, t_acc, nbop) terms.

        Time unit = seconds.  ``t_acc = 1s`` window gives ``nbop_pe =
        peak_flops/2`` MACs (1 MAC = 2 FLOP); loading one element costs
        ``dtype_bytes / hbm_bw`` seconds; size_mem is VMEM in elements.
        """
        t_l = dtype_bytes / self.hbm_bw
        return HardwareModel(
            nbop_pe=int(self.peak_flops / 2.0),
            size_mem=self.vmem_bytes // dtype_bytes,
            t_l=t_l, t_w=t_l, t_acc=1.0)

    def as_cluster(self, n_chips: int, dtype_bytes: int = 2) -> ClusterModel:
        """A ring of ``n_chips`` of this chip: ``t_ici`` prices one element
        over one ICI link in the same seconds unit as ``t_l``."""
        return ClusterModel(
            chip=self.as_hardware_model(dtype_bytes),
            n_chips=n_chips,
            t_ici=dtype_bytes / self.ici_bw_per_link)


TPU_V5E = TpuChipModel()
