"""The offloading formalism (paper Sec 2.2, Defs 1-3).

An n-step computation ``S = (s_1 .. s_n)`` where each step

    s_i = (F_inp, F_ker, W, I_slice, K_sub)

is executed as the action sequence a1..a6:

    a1  Mt_inp = M_{i-1}.inp \\ F_inp        (free input parts)
    a2  Mt_ker = M_{i-1}.ker \\ F_ker        (free kernel parts)
    a3  Mt_out = M_{i-1}.out \\ W            (write results back to DRAM)
    a4  M_i.inp = Mt_inp | I_slice           (load input slice)
    a5  M_i.ker = Mt_ker | K_sub             (load kernel subset)
    a6  M_i.out = Mt_out | Out_i             (compute, result stays on-chip)

All sets are int bitmasks (see conv_spec):
  * input pixels   — spatial pixel ids over the H_in x W_in grid,
  * kernels        — kernel ids 0..N-1,
  * outputs        — output spatial positions == patch ids 0..|X|-1.

Durations follow Def 3 with the unit convention of cost_model.py.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel


@dataclasses.dataclass(frozen=True)
class MemoryState:
    """On-chip memory state M_i = [M_inp, M_ker, M_out] (bitmasks)."""

    inp: int = 0
    ker: int = 0
    out: int = 0

    @property
    def empty(self) -> bool:
        return self.inp == 0 and self.ker == 0 and self.out == 0

    def footprint_elements(self, spec: ConvSpec) -> int:
        """Tensor elements resident (channels expanded)."""
        return (self.inp.bit_count() * spec.c_in
                + self.ker.bit_count() * spec.c_in * spec.h_k * spec.w_k
                + self.out.bit_count() * spec.c_out)


@dataclasses.dataclass(frozen=True)
class Step:
    """One step s_i = (F_inp, F_ker, W, I_slice, K_sub) + its computation.

    ``out`` is Out_i — the output units computed by a6 (empty for a pure
    flush step).  ``group`` records the patch ids computed, for tracing.
    ``kernel_group`` is None for S1-family steps (Property 1: all kernels
    resident, all output channels computed); an S2-family step (paper
    Sec 9 future work, implemented in core/strategies_s2.py) names the
    kernel subset it consumes, and ``out`` ids are then
    (patch, kernel-group) units rather than patches.
    """

    f_inp: int = 0
    f_ker: int = 0
    w: int = 0
    i_slice: int = 0
    k_sub: int = 0
    out: int = 0
    group: tuple[int, ...] = ()
    kernel_group: tuple[int, ...] | None = None

    @property
    def computes(self) -> bool:
        return self.out != 0


class StepError(ValueError):
    """A step violates the semantics or an assumption of Sec 2.3."""


def apply_step(m: MemoryState, s: Step) -> MemoryState:
    """Execute actions a1..a6 of Def 2, with validity checks."""
    if s.f_inp & ~m.inp:
        raise StepError("a1: freeing input pixels not in on-chip memory")
    if s.f_ker & ~m.ker:
        raise StepError("a2: freeing kernels not in on-chip memory")
    if s.w & ~m.out:
        raise StepError("a3: writing back outputs not in on-chip memory")
    mt_inp = m.inp & ~s.f_inp
    mt_ker = m.ker & ~s.f_ker
    mt_out = m.out & ~s.w
    if s.i_slice & mt_inp:
        raise StepError("a4: re-loading pixels already resident (wasteful)")
    if s.k_sub & mt_ker:
        raise StepError("a5: re-loading kernels already resident")
    if s.out & mt_out:
        raise StepError("a6: recomputing outputs still resident")
    return MemoryState(inp=mt_inp | s.i_slice,
                       ker=mt_ker | s.k_sub,
                       out=mt_out | s.out)


def check_compute_feasible(s: Step, spec: ConvSpec, hw: HardwareModel,
                           mem_after_loads: MemoryState) -> None:
    """Assumptions of Sec 2.3 for a computing step.

    * compute fits the PE: MACs of the step <= nbop_pe;
    * loaded data is directly processed: every loaded pixel belongs to a
      patch of the step's group, every computed patch's pixels are resident.
    """
    if not s.computes:
        return
    n_ker = len(s.kernel_group) if s.kernel_group is not None \
        else spec.c_out
    macs = len(s.group) * spec.nb_op_value * n_ker
    if macs > hw.nbop_pe:
        raise StepError(
            f"step computes {macs} MACs > nbop_pe={hw.nbop_pe}")
    need = spec.group_mask(s.group)
    if s.i_slice & ~need:
        raise StepError("loaded pixels not consumed by this step's group")
    if need & ~mem_after_loads.inp:
        raise StepError("computing a patch whose pixels are not resident")
    if s.kernel_group is None:
        if mem_after_loads.ker.bit_count() != spec.n_kernels:
            # S1 (Property 1): all output channels -> all kernels resident.
            raise StepError("S1 compute requires all kernels resident")
        want_out = 0
        for pid in s.group:
            want_out |= 1 << pid
        if s.out != want_out:
            raise StepError("Out_i does not match the step's patch group")
    else:
        kmask = 0
        for kid in s.kernel_group:
            kmask |= 1 << kid
        if kmask & ~mem_after_loads.ker:
            raise StepError("S2 compute requires its kernel subset resident")


def step_duration(s: Step, spec: ConvSpec, hw: HardwareModel) -> float:
    """Def 3:  (|I_slice| + |K_sub|) * t_l + |W| * t_w + t_acc.

    I_slice and W are counted in spatial units (Example 2 convention);
    K_sub in kernel elements (a kernel is C_in*H_K*W_K elements).
    t_acc is charged only when the step computes (a terminal flush step
    performs no a6).
    """
    load = s.i_slice.bit_count() * hw.t_l
    load += s.k_sub.bit_count() * spec.c_in * spec.h_k * spec.w_k * hw.t_l
    write = s.w.bit_count() * hw.t_w
    return load + write + (hw.t_acc if s.computes else 0.0)


@dataclasses.dataclass
class RunResult:
    """Trace of executing an n-step computation."""

    states: list[MemoryState]
    durations: list[float]
    footprints: list[int]        # size_i^step of Def 3, in elements
    total_duration: float
    peak_footprint: int
    loads_per_pixel: dict[int, int]   # pixel id -> times loaded (reload bound)


def run_steps(steps: Sequence[Step], spec: ConvSpec, hw: HardwareModel,
              validate: bool = True) -> RunResult:
    """Execute the semantics over a full strategy; check global invariants:

    * memory empty after the last step, all outputs written back exactly once;
    * every patch computed exactly once;
    * reload bound (Sec 2.3): each pixel loaded at most ``nb_data_reload``
      times is *reported*, enforcement is the ILP's job.
    """
    m = MemoryState()
    states, durations, footprints = [], [], []
    loads: dict[int, int] = {}
    computed = 0
    written = 0
    for s in steps:
        # size_i^step (Def 3): footprint *during* the step, before frees of
        # the next step — union of carried data and newly loaded/computed.
        during = MemoryState(inp=(m.inp & ~s.f_inp) | s.i_slice | m.inp,
                             ker=(m.ker & ~s.f_ker) | s.k_sub | m.ker,
                             out=(m.out & ~s.w) | s.out | m.out)
        m_next = apply_step(m, s)
        if validate:
            check_compute_feasible(s, spec, hw, m_next)
        for j in spec.pixels_of_mask(s.i_slice):
            loads[j] = loads.get(j, 0) + 1
        if validate and (s.out & computed):
            raise StepError("a patch computed twice")
        computed |= s.out
        written |= s.w
        states.append(m_next)
        durations.append(step_duration(s, spec, hw))
        footprints.append(during.footprint_elements(spec))
        m = m_next
    if validate:
        full = (1 << spec.num_patches) - 1
        if computed != full:
            missing = full & ~computed
            raise StepError(
                f"strategy incomplete: {missing.bit_count()} patches never computed")
        if not m.empty:
            raise StepError("on-chip memory not empty after the last step")
        if written != full:
            raise StepError("not all outputs written back to DRAM")
    return RunResult(states=states, durations=durations,
                     footprints=footprints,
                     total_duration=sum(durations),
                     peak_footprint=max(footprints) if footprints else 0,
                     loads_per_pixel=loads)
