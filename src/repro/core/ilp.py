"""ILP formulation of the S1 optimisation problem (paper Sec 5).

Decision variables (Table 1), binary:
    P_g[i,k]        patch i assigned to group k               (eq. 2)
    pxl_g[j,k]      pixel j present in group k                (eq. 5)
    pxl_ovlp[j,k]   pixel j in groups k and k-1               (eq. 7)
and the derived  pxl_I[j,k] = pxl_g[j,k] - pxl_ovlp[j,k]      (eq. 8)
which is *eliminated by substitution*: since pxl_ovlp <= pxl_g always holds
(eq. 7 linearisation) the AND-with-negation of eq. 8 is exactly the linear
difference, so pxl_I never needs its own column.  This shrinks the model by
J*K binaries relative to the literal formulation.

Constraints:
    eq. 3   each patch in exactly one group
    eq. 4   group cardinality <= nb_patches_max_S1
    eq. 6   pxl_g = OR_i P_g  (linearised: >= each, <= sum)
    eq. 7   pxl_ovlp = AND    (linearised; only the two upper bounds are
            needed — the objective and eq. 9 both press pxl_ovlp upward)
    eq. 9   sum_k pxl_I[j,k] <= nb_data_reload
    eq. 12  on-chip memory capacity (optional; element units, see DESIGN §6)

Objective (eq. 15):  min t_l * sum_{j,k} pxl_I[j,k]  (+ K * t_acc const).

The search space is restricted to K = K_min groups as in Sec 7.1.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from scipy import sparse

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import GroupedStrategy, k_min


@dataclasses.dataclass
class IlpModel:
    """The assembled MILP in scipy (HiGHS) form."""

    spec: ConvSpec
    p: int                      # nb_patches_max_S1
    k: int                      # number of groups
    pixels: list[int]           # covered pixel ids (column order)
    c: np.ndarray               # objective vector
    a: sparse.csr_matrix        # constraint matrix
    lb: np.ndarray
    ub: np.ndarray
    n_pg: int                   # number of P_g columns
    n_px: int                   # number of pxl columns per family

    @property
    def num_vars(self) -> int:
        return len(self.c)

    def pg_col(self, i: int, k: int) -> int:
        return i * self.k + k

    def g_col(self, jx: int, k: int) -> int:
        return self.n_pg + jx * self.k + k

    def o_col(self, jx: int, k: int) -> int:
        return self.n_pg + self.n_px + jx * self.k + k

    def extract_groups(self, x: np.ndarray) -> GroupedStrategy:
        """Solution vector -> ordered patch groups."""
        groups: list[list[int]] = [[] for _ in range(self.k)]
        for i in range(self.spec.num_patches):
            for k in range(self.k):
                if x[self.pg_col(i, k)] > 0.5:
                    groups[k].append(i)
                    break
        return GroupedStrategy(
            "ilp", self.spec, tuple(tuple(g) for g in groups if g))


def build_ilp(spec: ConvSpec, p: int, k: int | None = None,
              nb_data_reload: int = 2,
              size_mem: int | None = None) -> IlpModel:
    """Assemble the Sec-5 MILP for ``spec`` with group capacity ``p``."""
    if k is None:
        k = k_min(spec, p)
    x_count = spec.num_patches
    pixels = spec.pixels_of_mask(spec.all_pixels_mask)
    jx_of = {j: jx for jx, j in enumerate(pixels)}
    j_count = len(pixels)
    n_pg = x_count * k
    n_px = j_count * k
    n_vars = n_pg + 2 * n_px

    # covering patches per pixel (from the pxl_in_P constant, Sec 5.1)
    cover: list[list[int]] = [[] for _ in range(j_count)]
    for i in range(x_count):
        for j in spec.pixels_of_mask(spec.patch_masks[i]):
            cover[jx_of[j]].append(i)

    model = IlpModel(spec=spec, p=p, k=k, pixels=pixels,
                     c=np.zeros(n_vars), a=None, lb=None, ub=None,
                     n_pg=n_pg, n_px=n_px)

    rows, cols, vals = [], [], []
    con_lb, con_ub = [], []
    r = 0

    def add(entries, lo, hi):
        nonlocal r
        for c_, v_ in entries:
            rows.append(r)
            cols.append(c_)
            vals.append(v_)
        con_lb.append(lo)
        con_ub.append(hi)
        r += 1

    # eq. 3: sum_k P_g[i,k] == 1
    for i in range(x_count):
        add([(model.pg_col(i, kk), 1.0) for kk in range(k)], 1.0, 1.0)

    # eq. 4: sum_i P_g[i,k] <= p
    for kk in range(k):
        add([(model.pg_col(i, kk), 1.0) for i in range(x_count)],
            0.0, float(p))

    # eq. 6 linearisation
    for jx in range(j_count):
        for kk in range(k):
            gcol = model.g_col(jx, kk)
            # pxl_g >= P_g[i,k]  for every covering patch i
            for i in cover[jx]:
                add([(model.pg_col(i, kk), 1.0), (gcol, -1.0)],
                    -np.inf, 0.0)
            # pxl_g <= sum_i P_g[i,k]
            add([(gcol, 1.0)] + [(model.pg_col(i, kk), -1.0)
                                 for i in cover[jx]], -np.inf, 0.0)

    # eq. 7: pxl_ovlp[j,k] <= pxl_g[j,k], <= pxl_g[j,k-1]; ovlp[j,0] == 0
    for jx in range(j_count):
        for kk in range(k):
            ocol = model.o_col(jx, kk)
            if kk == 0:
                add([(ocol, 1.0)], 0.0, 0.0)
                continue
            add([(ocol, 1.0), (model.g_col(jx, kk), -1.0)], -np.inf, 0.0)
            add([(ocol, 1.0), (model.g_col(jx, kk - 1), -1.0)], -np.inf, 0.0)

    # eq. 9: sum_k (pxl_g - pxl_ovlp) <= nb_data_reload
    for jx in range(j_count):
        add([(model.g_col(jx, kk), 1.0) for kk in range(k)]
            + [(model.o_col(jx, kk), -1.0) for kk in range(k)],
            -np.inf, float(nb_data_reload))

    # eq. 12 (optional): element-unit on-chip capacity per step
    if size_mem is not None:
        ker_elems = spec.kernel_elements
        for kk in range(k):
            add([(model.g_col(jx, kk), float(spec.c_in))
                 for jx in range(j_count)]
                + [(model.pg_col(i, kk), float(spec.c_out))
                   for i in range(x_count)],
                -np.inf, float(size_mem - ker_elems))

    # objective: min sum (pxl_g - pxl_ovlp)
    for jx in range(j_count):
        for kk in range(k):
            model.c[model.g_col(jx, kk)] = 1.0
            model.c[model.o_col(jx, kk)] = -1.0

    model.a = sparse.csr_matrix(
        (vals, (rows, cols)), shape=(r, n_vars))
    model.lb = np.asarray(con_lb)
    model.ub = np.asarray(con_ub)
    return model


def n_var_literal(spec: ConvSpec, k: int) -> int:  # lint: public-api
    """Paper's variable-count formula (Sec 7.1):
    N_var = K * (3*(H_in*W_in) + H_out*W_out)."""
    return k * (3 * spec.num_pixels + spec.num_patches)


# --------------------------------------------------------------------- #
# S2 schedule-order MILP (tiny instances)
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class S2OrderModel:
    """Exact schedule ordering of U fixed (patch-group, kernel-group)
    cells as a max-overlap Hamiltonian-path MILP.

    The S2 load cost decomposes as ``constant - sum of consecutive-cell
    overlaps`` (``|A \\ B| = |A| - |A ∩ B|``; the constant is fixed once
    the partitions are), so the order that minimises duration maximises
    the summed overlap ``W[u,v]`` along the schedule path.  Variables:
    ``x[u,t]`` (cell u at slot t, binary) and ``w[u,v,t]`` (cells u,v at
    consecutive slots t,t+1; continuous — forced to the product of the
    x's by the three linking rows).  Quadratic in U: tiny instances only.
    """

    n: int
    c: np.ndarray
    a: sparse.csr_matrix
    lb: np.ndarray
    ub: np.ndarray
    integrality: np.ndarray
    n_x: int

    @property
    def num_vars(self) -> int:
        return len(self.c)

    def x_col(self, u: int, t: int) -> int:
        return u * self.n + t

    def extract_order(self, x: np.ndarray) -> list[int]:
        order = []
        for t in range(self.n):
            for u in range(self.n):
                if x[self.x_col(u, t)] > 0.5:
                    order.append(u)
                    break
        return order


def build_s2_order_ilp(w_overlap: np.ndarray) -> S2OrderModel:
    """Assemble the order MILP for an overlap matrix ``w_overlap``
    (symmetric; forbidden adjacencies carry large negative entries)."""
    n = len(w_overlap)
    n_x = n * n
    pairs = [(u, v) for u in range(n) for v in range(n) if u != v]
    w_col = {}
    for t in range(n - 1):
        for u, v in pairs:
            w_col[(u, v, t)] = n_x + len(w_col)
    n_vars = n_x + len(w_col)

    c = np.zeros(n_vars)
    for (u, v, t), col in w_col.items():
        c[col] = -float(w_overlap[u, v])     # maximise summed overlap

    rows, cols, vals = [], [], []
    con_lb, con_ub = [], []
    r = 0

    def add(entries, lo, hi):
        nonlocal r
        for c_, v_ in entries:
            rows.append(r)
            cols.append(c_)
            vals.append(v_)
        con_lb.append(lo)
        con_ub.append(hi)
        r += 1

    def x_col(u, t):
        return u * n + t

    for u in range(n):                        # each cell in one slot
        add([(x_col(u, t), 1.0) for t in range(n)], 1.0, 1.0)
    for t in range(n):                        # each slot holds one cell
        add([(x_col(u, t), 1.0) for u in range(n)], 1.0, 1.0)
    for (u, v, t), col in w_col.items():      # w = x[u,t] AND x[v,t+1]
        add([(col, 1.0), (x_col(u, t), -1.0)], -np.inf, 0.0)
        add([(col, 1.0), (x_col(v, t + 1), -1.0)], -np.inf, 0.0)
        add([(col, 1.0), (x_col(u, t), -1.0), (x_col(v, t + 1), -1.0)],
            -1.0, np.inf)

    integrality = np.zeros(n_vars)
    integrality[:n_x] = 1                     # w relaxes to [0, 1]
    return S2OrderModel(
        n=n, c=c,
        a=sparse.csr_matrix((vals, (rows, cols)), shape=(r, n_vars)),
        lb=np.asarray(con_lb), ub=np.asarray(con_ub),
        integrality=integrality, n_x=n_x)


def solve_s2_order(w_overlap: np.ndarray, time_limit: float = 2.0,
                   ) -> tuple[list[int] | None, str]:
    """Solve the order MILP with HiGHS; returns (order|None, status)."""
    from scipy.optimize import Bounds, LinearConstraint, milp

    model = build_s2_order_ilp(np.asarray(w_overlap, dtype=float))
    res = milp(
        c=model.c,
        constraints=LinearConstraint(model.a, model.lb, model.ub),
        integrality=model.integrality,
        bounds=Bounds(0, 1),
        options={"time_limit": time_limit, "presolve": True})
    if res.x is None:
        return None, "infeasible" if res.status == 2 else "timeout"
    return model.extract_order(np.round(res.x)), (
        "optimal" if res.status == 0 else "feasible")
