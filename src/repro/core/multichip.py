"""Multi-chip sharded offloading planner (beyond-paper: ROADMAP item 1).

The paper formalises offloading ONE convolution to ONE accelerator with one
on-chip memory.  This module generalises the Def-3 duration accounting to a
:class:`~repro.core.cost_model.ClusterModel` — ``n_chips`` identical chips
on an ICI ring — by letting every layer choose a *sharding mode*:

``replicate``
    The single-chip path: the whole layer runs on chip 0 through the
    existing ``solver.solve_cached`` machinery; the other chips idle.
``row``
    Patch/row sharding: the output rows are split into contiguous bands,
    one per chip; each chip solves the halo-extended sub-convolution of
    its band (a smaller :class:`ConvSpec` through the same LRU-cached
    solver, so equal bands are solved once).  Consecutive row-sharded
    layers exchange only the halo rows over ICI (Stoutchinin et al.'s
    layer-cascade halo, arXiv:1902.01492, lifted to chip boundaries).
``channel``
    Kernel/output-channel sharding: the kernel set Λ is split across
    chips (each solves a ``n_kernels/n`` sub-convolution over the full
    map).  Every chip needs the whole input map — priced as an ICI
    all-gather — and the outputs stay channel-sharded until a consumer
    needs a different layout.  This is the regime where sharding relaxes
    the paper's eq.-12 memory bound: each chip keeps only Λ/n resident,
    so budgets that force the single-chip planner into S2 kernel-group
    swapping stay S1-feasible when sharded.
``hybrid``
    Row x kernel-channel sharding of ONE layer on a 2-D torus: the
    chips form a ``rows x cols`` grid (``Topology.grid``), the output
    rows split into ``rows`` bands along axis 0 and the kernel set into
    ``cols`` groups along axis 1; chip ``(i, j)`` solves band ``i`` of
    kernel group ``j``.  The inbound collective decomposes per axis:
    halo rows shift along the row axis, each band's input map
    all-gathers along the kernel-channel axis (rows in parallel) — the
    kernel split here is over *output* channels, so no partial sums are
    needed; ``Topology.reduce_scatter`` prices the input-channel
    variant for the follow-up.  A ``rows x 1`` grid degenerates to
    ``row`` and a ``1 x cols`` grid to ``channel`` exactly (the
    produced layout and every transition collapse to the pure mode's —
    property-tested).  Hybrid needs the full grid active, so it is
    infeasible for a layer with fewer output rows than grid rows (or
    fewer kernels than grid cols).

Duration accounting (Def 3 extended):

    layer duration = max over chips of the shard's full Def-3 duration
                     + bottleneck-link ICI elements * t_ici     (serial)
    layer duration = max(max-over-chips compute, ICI)         (overlap)

By default ICI transfers are serialised against compute (conservative,
predictable — the paper's sequential-step spirit) while the links
themselves run in parallel, so an ICI phase costs its *bottleneck link's*
element count — priced per :class:`~repro.core.cost_model.Topology`
(unidirectional ring, bidirectional ring, 2-D torus) in the direction of
Chen et al.'s communication lower bounds for convolution accelerators
(arXiv:1911.05662).  The unidirectional ring reproduces the PR-3/PR-4
numbers bit-exactly (regression-gated); bidirectional links halve every
split-tensor collective's bottleneck, so a biring plan is never slower
than the ring plan of the same network.  With
``overlap=True`` the inbound exchange of each stage is double-buffered
under compute (the Stoutchinin et al. halo-cascade discipline,
arXiv:1902.01492, and the same double-buffering our Def-3 HBM accounting
already assumes), so a stage costs ``max(compute, ICI)``; the final
gather has no compute to hide under and stays serial.  A row->row halo
exchange writes rows the consumer already holds live, so its overlap
claim is only made when sound: the DP prices it overlapped only if
every receiving band's first halo read (:func:`halo_first_use`, Def-3
timed) lands after the exchange completes — trying a zigzag-swapped
band variant that reads the halo last when the solved schedule reads
too early — and otherwise serialises that stage (per-layer
``MultiChipLayerPlan.overlap`` flags record the verdict, and
``analysis.verifier``'s ``ici/war-overlap`` rule re-proves it as a hard
ERROR).  Resharding is
charged whenever consecutive layers pick modes whose activation layouts
differ (see ``_transition_elements``); the mode sequence is chosen by a
small Viterbi-style dynamic program over (layer, mode) states, so a cheap
layer never strands the next layer in an expensive layout.

Row bands are near-even by default; ``balance_rows=True`` sizes them by
solved per-chip *duration* (``balanced_row_heights``) so the
max-over-chips term never exceeds the row-balanced one.

``same_pad=True`` asserts the specs' already-padded inputs are ``SAME``
padding (``max(0, h_k - s_h)`` zero rows split top/bottom): edge bands
then skip the first loads of the padding rows inside their halo-extended
windows — position-*dependent* band durations that make
``balanced_row_heights`` bite systematically (edge bands get more rows).
The savings are analytic (clamped to the shard strategy's first-load
traffic) and carried on each ``ShardPlan.pad_saved`` so the cluster
simulator can still reconcile measured durations exactly.

Layout approximations (documented, tested loose): band boundaries between
consecutive row-sharded layers are assumed aligned (pooling between convs
redistributes rows on-chip, as in ``core.network_planner``); pure-row
bands on a torus are laid row-major across the grid, and the wrap
boundary between grid rows is priced as one hop like every other
boundary; multi-chip inter-layer VMEM reuse stays a ROADMAP follow-up.

``plan_multichip_network`` wraps :func:`plan_network` so the 1-chip case
reproduces today's single-chip plans *exactly* (inter-layer reuse
included); for ``n_chips > 1`` the per-layer accounting is gross (no
cross-layer on-chip residency — chips' VMEM is spent on shard working
sets; co-scheduled multi-chip cascading is a ROADMAP follow-up).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core import formalism
from repro.core import solver as solver_mod
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import ClusterModel, HardwareModel
from repro.core.network_planner import (InfeasibleNetworkError, NetworkPlan,
                                        plan_network, resolve_group_size)
from repro.core.strategies import GroupedStrategy, zigzag

MODES = ("replicate", "row", "channel")
HYBRID_MODES = MODES + ("hybrid",)

# initial activation layout: the host stages the network input in every
# chip's DRAM, so layer 0 pays no ICI in any mode.
_INPUT_LAYOUT = "all"


def mode_alphabet(cluster: ClusterModel) -> tuple[str, ...]:
    """Sharding modes available on this cluster's topology: hybrid
    row x channel grids need a second torus axis to shard along."""
    if cluster.topo.kind == "torus":
        return HYBRID_MODES
    return MODES


# --------------------------------------------------------------------- #
# Shard geometry
# --------------------------------------------------------------------- #

def row_shard_specs(spec: ConvSpec, n_chips: int,
                    heights: Sequence[int] | None = None,
                    ) -> list[tuple[int, tuple[int, int], ConvSpec]]:
    """Split ``spec``'s output rows into contiguous bands, one per chip.

    Returns ``(chip, (row0, row1), shard_spec)`` triples; the shard spec
    is the halo-extended sub-convolution of the band (``(rows-1)*s_h +
    h_k`` input rows), so ``shard_spec.h_out == row1 - row0``.  Chips
    beyond ``h_out`` idle (no triple emitted).  ``heights`` overrides the
    default near-even split with explicit per-chip band heights (the
    duration-balanced partition of :func:`balanced_row_heights`)."""
    n = min(n_chips, spec.h_out)
    if heights is None:
        base, extra = divmod(spec.h_out, n)
        heights = [base + (1 if c < extra else 0) for c in range(n)]
    elif len(heights) != n or sum(heights) != spec.h_out or \
            min(heights) < 1:
        raise ValueError(
            f"band heights {list(heights)} do not tile {spec.h_out} "
            f"output rows over {n} chips")
    shards = []
    r0 = 0
    for c, rows in enumerate(heights):
        h_in_band = (rows - 1) * spec.s_h + spec.h_k
        shards.append((c, (r0, r0 + rows),
                       dataclasses.replace(spec, h_in=h_in_band)))
        r0 += rows
    return shards


def _band_solve(spec: ConvSpec, rows: int, hw,
                max_group: int | None, solve_kwargs: dict
                ) -> tuple[float, float] | None:
    """(full Def-3 duration, first-load duration) of a ``rows``-row
    band's halo-extended sub-convolution through the LRU-cached solver;
    None when no feasible strategy exists at that height."""
    sub = dataclasses.replace(spec, h_in=(rows - 1) * spec.s_h + spec.h_k)
    p = resolve_group_size(sub, hw, max_group)
    try:
        res = solver_mod.solve_cached(sub, p, hw, **solve_kwargs)
    except ValueError:
        return None
    if hw.size_mem is not None and \
            res.strategy.peak_footprint_elements() > hw.size_mem:
        return None
    return (res.strategy.full_duration(hw),
            res.strategy.first_load_duration(hw))


def band_solve_duration(spec: ConvSpec, rows: int, hw,  # lint: public-api
                        max_group: int | None,
                        solve_kwargs: dict) -> float | None:
    """Full Def-3 duration of a ``rows``-row band's halo-extended
    sub-convolution through the LRU-cached solver; None when no feasible
    strategy exists at that height."""
    info = _band_solve(spec, rows, hw, max_group, solve_kwargs)
    return None if info is None else info[0]


def same_pad_rows(spec: ConvSpec) -> tuple[int, int]:
    """(top, bottom) zero rows of a ``SAME``-padded (already-padded)
    input: ``max(0, h_k - s_h)`` total, split top-light like XLA."""
    pad = max(0, spec.h_k - spec.s_h)
    return pad // 2, pad - pad // 2


def band_pad_rows(spec: ConvSpec, r0: int, r1: int) -> int:
    """Padding rows inside band ``[r0, r1)``'s halo-extended input
    window under ``SAME`` padding — rows an edge band never needs to
    load from DRAM (they are zeros the chip can materialise)."""
    top, bot = same_pad_rows(spec)
    h0 = r0 * spec.s_h
    h1 = h0 + (r1 - r0 - 1) * spec.s_h + spec.h_k
    return max(0, top - h0) + max(0, h1 - (spec.h_in - bot))


def _band_pad_saving(spec: ConvSpec, r0: int, r1: int, hw,
                     first_load: float) -> float:
    """Analytic duration saved by not loading a band's padding rows:
    their spatial pixels' first loads, clamped to the strategy's
    measured first-load traffic (reloads stay charged — conservative)."""
    pads = band_pad_rows(spec, r0, r1)
    if not pads:
        return 0.0
    return min(pads * spec.w_in * hw.t_l, first_load)


def balanced_row_heights(spec: ConvSpec, hw, n_chips: int,
                         max_group: int | None,
                         solve_kwargs: dict,
                         same_pad: bool = False) -> list[int] | None:
    """Duration-balanced band heights: choose per-chip band heights whose
    solved max-over-chips duration is minimal, instead of balancing raw
    row counts.  The per-height duration curve ``d(rows)`` is probed
    through the shared solver LRU (a binary-search-style scan over the
    candidate heights around the even split — every band pays the same
    ``h_k - s_h`` halo rows, so heights far above ``ceil(h_out/n)`` only
    raise the max), then an exact small DP picks the partition of
    ``h_out`` rows into ``n`` bands minimising ``max d(height)``.  The
    even split is always admissible, so the result never exceeds the
    row-balanced max-over-chips duration (tests/test_multichip_overlap).
    With ``same_pad`` the duration of a band is position-dependent (edge
    bands skip their padding rows' first loads), so the DP prices band
    ``[j-r, j)`` at its actual position and the returned heights keep
    band order — the asymmetric optimum gives edge bands more rows.
    Returns None when some required height has no feasible strategy."""
    n = min(n_chips, spec.h_out)
    base, extra = divmod(spec.h_out, n)
    r_cap = min(spec.h_out, base + (1 if extra else 0) + 1)
    d: dict[int, float] = {}
    fl: dict[int, float] = {}
    for r in range(1, r_cap + 1):
        info = _band_solve(spec, r, hw, max_group, solve_kwargs)
        if info is not None:
            d[r], fl[r] = info

    def band_dur(r0: int, r: int) -> float:
        if not same_pad:
            return d[r]
        return max(0.0, d[r] - _band_pad_saving(spec, r0, r0 + r, hw,
                                                fl[r]))

    inf = float("inf")
    # best[j][k]: minimal max-duration tiling the first j rows with k bands
    best = [[inf] * (n + 1) for _ in range(spec.h_out + 1)]
    pick = [[0] * (n + 1) for _ in range(spec.h_out + 1)]
    best[0][0] = 0.0
    for j in range(1, spec.h_out + 1):
        for k in range(1, n + 1):
            for r in d:
                if r > j:
                    continue
                v = max(best[j - r][k - 1], band_dur(j - r, r))
                if v < best[j][k]:
                    best[j][k] = v
                    pick[j][k] = r
    if best[spec.h_out][n] == inf:
        return None
    heights = []
    j, k = spec.h_out, n
    while k:
        r = pick[j][k]
        heights.append(r)
        j, k = j - r, k - 1
    if same_pad:
        heights.reverse()            # positions matter: keep band order
    else:
        heights.sort(reverse=True)   # widest band on chip 0, like the
    return heights                   # near-even split's extra-row layout


def kernel_shard_specs(spec: ConvSpec, n_chips: int
                       ) -> list[tuple[int, tuple[int, int], ConvSpec]]:
    """Split ``spec``'s kernel set into near-even groups, one per chip.

    Returns ``(chip, (kid0, kid1), shard_spec)`` triples with
    ``shard_spec.n_kernels == kid1 - kid0``; chips beyond ``n_kernels``
    idle."""
    n = min(n_chips, spec.n_kernels)
    base, extra = divmod(spec.n_kernels, n)
    shards = []
    k0 = 0
    for c in range(n):
        k = base + (1 if c < extra else 0)
        shards.append((c, (k0, k0 + k),
                       dataclasses.replace(spec, n_kernels=k)))
        k0 += k
    return shards


def hybrid_shard_specs(spec: ConvSpec, rows: int, cols: int,
                       heights: Sequence[int] | None = None,
                       ) -> list[tuple[int, tuple[int, int],
                                       tuple[int, int], ConvSpec]]:
    """Carve ``spec`` into a ``rows x cols`` grid of (row band x kernel
    group) shards, chip ``i * cols + j`` taking band ``i`` of kernel
    group ``j``.  Returns ``(chip, (row0, row1), (kid0, kid1),
    shard_spec)`` quadruples.  Unlike the pure modes, the grid must be
    fully active — a layer with fewer output rows than ``rows`` (or
    fewer kernels than ``cols``) cannot be hybrid-sharded."""
    if rows > spec.h_out or cols > spec.n_kernels:
        raise ValueError(
            f"hybrid grid {rows}x{cols} does not fit layer "
            f"h_out={spec.h_out}, n_kernels={spec.n_kernels}")
    bands = row_shard_specs(spec, rows, heights)
    kgroups = kernel_shard_specs(spec, cols)
    shards = []
    for i, (_, band, bspec) in enumerate(bands):
        for j, (_, krange, _) in enumerate(kgroups):
            shards.append((i * cols + j, band, krange,
                           dataclasses.replace(
                               bspec, n_kernels=krange[1] - krange[0])))
    return shards


def halo_elements(spec: ConvSpec) -> int:
    """Elements one band boundary exchanges between consecutive
    row-sharded layers: the consumer's halo rows (``h_k - s_h`` input
    rows when the stride undershoots the kernel, else none), channel
    expanded."""
    return max(0, spec.h_k - spec.s_h) * spec.w_in * spec.c_in


def halo_pixel_mask(spec: ConvSpec) -> int:
    """Pixel mask of a band shard's inbound halo: the last
    ``max(0, h_k - s_h)`` rows of its local input window — the rows a
    row->row transition delivers from the chip below."""
    halo_rows = max(0, spec.h_k - spec.s_h)
    mask = 0
    for h in range(spec.h_in - halo_rows, spec.h_in):
        mask |= ((1 << spec.w_in) - 1) << (h * spec.w_in)
    return mask


def halo_first_use(strategy, spec: ConvSpec, hw: HardwareModel) -> float:
    """Def-3 time a shard schedule computes before its first step loads
    a halo pixel — the window an overlapped inbound halo exchange can
    stream in without a write-after-read on the live input.  ``inf``
    when the schedule never reads the halo (or there is none); ``0.0``
    for non-grouped (S2) strategies, whose kernel-swap interleaving the
    timing model does not cover — conservatively never overlap-safe."""
    mask = halo_pixel_mask(spec)
    if not mask:
        return float("inf")
    if not isinstance(strategy, GroupedStrategy):
        return 0.0
    t = 0.0
    for s in strategy.to_steps():
        if s.i_slice & mask:
            return t
        t += formalism.step_duration(s, spec, hw)
    return float("inf")


def _halo_safe_time(shards: Sequence["ShardPlan"],
                    hw: HardwareModel) -> float:
    """Earliest halo first-use across the bands that receive one (every
    band but the bottom); ``inf`` when no band ever reads its halo."""
    bands = [s for s in shards if s.out_rows is not None]
    if not bands:
        return float("inf")
    last_r1 = max(s.out_rows[1] for s in bands)
    return min((halo_first_use(s.strategy, s.spec, hw)
                for s in bands if s.out_rows[1] != last_r1),
               default=float("inf"))


# --------------------------------------------------------------------- #
# ICI pricing: activation layouts and resharding
# --------------------------------------------------------------------- #

_REQUIRED_LAYOUT = {"replicate": "single", "row": "row", "channel": "all",
                    "hybrid": "rowgrid"}


def _produced_layout(mode: str, active_chips: int,
                     grid: tuple[int, int] | None = None) -> str:
    """Layout of a layer's output map.  A single active shard owns the
    whole map, whatever the nominal mode; a hybrid grid with a trivial
    axis collapses to the pure mode's layout (the ``r x 1`` / ``1 x c``
    degeneracies)."""
    if active_chips <= 1:
        return "single"
    if mode == "hybrid":
        ny, nx = grid
        if nx == 1:
            return "row"
        if ny == 1:
            return "channel"
        return "hybrid"
    return {"replicate": "single", "row": "row", "channel": "channel"}[mode]


def _transition_elements(frm: str, mode: str, nxt: ConvSpec,
                         a_full: int, cluster: ClusterModel) -> int:
    """Bottleneck-link ICI elements to reshape an activation from layout
    ``frm`` into what ``mode`` requires for consumer ``nxt``, priced by
    the cluster's :class:`~repro.core.cost_model.Topology` collectives:

    * gather/scatter against one chip and the all-gather from any
      sharded layout funnel ``(k-1)/k`` of the tensor through a
      bottleneck link per ring axis (halved on bidirectional links);
    * a pipelined broadcast pushes the full tensor through the source's
      link, once per torus axis;
    * row->row costs only the halo (links run in parallel, so one
      boundary's rows bound the phase);
    * channel->row (and any reshard out of hybrid) is an all-to-all,
      priced at the all-gather bound;
    * the hybrid input layout (``rowgrid``: band rows along axis 0,
      replicated along axis 1) decomposes per axis — band all-gather
      along the kernel-channel rings plus the axis-0 halo shift; its
      trivial-axis cases collapse to the ``row`` / ``all`` rules, which
      is what makes ``r x 1`` / ``1 x c`` grids price exactly like the
      pure modes.

    On the unidirectional ring every rule reduces to the PR-3 formulas
    bit-exactly (``ceil(A*(n-1)/n)`` splits, ``A`` broadcast).
    """
    n_chips = cluster.n_chips
    if n_chips == 1 or frm == "all":
        return 0
    topo = cluster.topo
    ny, nx = topo.grid(n_chips)
    to = _REQUIRED_LAYOUT[mode]
    if to == "rowgrid":                    # trivial-axis degeneracies
        if nx == 1:
            to = "row"
        elif ny == 1:
            to = "all"
    if to == "single":
        return 0 if frm == "single" else topo.gather(n_chips, a_full)
    if to == "row":
        if frm == "row":
            return halo_elements(nxt)
        if frm == "single":
            return topo.scatter(n_chips, a_full)
        return topo.all_to_all(n_chips, a_full)   # channel / hybrid
    if to == "all":
        if frm == "single":
            return topo.bcast(n_chips, a_full)    # pipelined broadcast
        return topo.allgather(n_chips, a_full)
    # to == "rowgrid": every chip needs its band's rows, all channels
    if frm == "single":
        return (topo.scatter_axis0(n_chips, a_full)
                + topo.bcast_axis1(n_chips, a_full))
    if frm in ("row", "hybrid"):
        return (topo.allgather_axis1(n_chips, a_full)
                + (halo_elements(nxt) if ny > 1 else 0))
    return topo.all_to_all(n_chips, a_full)       # channel -> rowgrid


# --------------------------------------------------------------------- #
# Plan dataclasses
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """One chip's slice of one layer."""

    chip: int
    spec: ConvSpec                       # the shard's sub-convolution
    p: int
    result: solver_mod.SolveResult
    out_rows: tuple[int, int] | None     # row/hybrid: output-row band
    kernel_range: tuple[int, int] | None  # channel/hybrid: kernel ids
    gross_duration: float                # full Def-3 duration on its chip
    pad_saved: float = 0.0               # same_pad: edge-band first loads
    #   skipped (gross_duration already excludes them; the simulator
    #   reconciles measured == gross + pad_saved)

    @property
    def strategy(self):
        return self.result.strategy

    @property
    def mode(self) -> str:
        return self.result.mode          # 's1' | 's2'


@dataclasses.dataclass(frozen=True)
class MultiChipLayerPlan:
    """One layer's slot in the cluster schedule."""

    index: int
    spec: ConvSpec
    mode: str                # 'replicate' | 'row' | 'channel' | 'hybrid'
    shards: tuple[ShardPlan, ...]
    compute_duration: float              # max over chips (Def-3 gross)
    ici_elements: int                    # bottleneck-link elements, inbound
    ici_duration: float
    savings: float = 0.0                 # 1-chip path: inter-layer reuse
    overlap: bool = False                # this stage's inbound ICI is
    #   double-buffered under compute; for halo exchanges the planner
    #   only sets it after proving the bands read their halo late enough
    #   (halo_first_use), so serial-priced stages can coexist in an
    #   overlap=True plan
    grid: tuple[int, int] | None = None  # hybrid: (rows, cols) shard grid

    def __post_init__(self):
        if self.duration < -1e-9:
            raise AssertionError(
                f"layer {self.index}: negative duration {self.duration}")

    @property
    def active_chips(self) -> int:
        return len(self.shards)

    @property
    def duration(self) -> float:
        """Serialised (paper Def-3 spirit): compute + ICI.  Overlapped
        (double-buffered halo exchange, Stoutchinin-style): the inbound
        ICI hides under the stage's compute, max(compute, ICI)."""
        if self.overlap:
            return max(self.compute_duration, self.ici_duration) \
                - self.savings
        return self.compute_duration + self.ici_duration - self.savings


@dataclasses.dataclass(frozen=True)
class MultiChipPlan:
    """A solved whole-network cluster schedule."""

    name: str
    cluster: ClusterModel
    layers: tuple[MultiChipLayerPlan, ...]
    total_duration: float
    final_gather_elements: int           # last layout -> chip 0
    final_gather_duration: float
    single_chip_duration: float | None   # plan_network total (reuse incl.)
    network_plan: NetworkPlan | None     # the delegated 1-chip plan
    planning_seconds: float
    solver_calls: int
    cache_hits: int
    overlap: bool = False                # overlap requested; each layer's
    #   own flag records whether its stage actually overlapped
    balance_rows: bool = False           # duration-balanced band heights

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_sharded_layers(self) -> int:
        return sum(1 for lp in self.layers if lp.mode != "replicate")

    @property
    def mode_string(self) -> str:
        tag = {"replicate": "R", "row": "W", "channel": "K", "hybrid": "H"}
        return "".join(tag[lp.mode] for lp in self.layers)

    @property
    def ici_duration(self) -> float:
        return (sum(lp.ici_duration for lp in self.layers)
                + self.final_gather_duration)

    @property
    def ici_fraction(self) -> float:
        if self.total_duration <= 0:
            return 0.0
        return self.ici_duration / self.total_duration

    @property
    def speedup_vs_single_chip(self) -> float | None:
        if self.single_chip_duration is None or self.total_duration <= 0:
            return None
        return self.single_chip_duration / self.total_duration

    @property
    def peak_footprint(self) -> int:
        """Largest per-chip resident peak across all shards."""
        return max(s.strategy.peak_footprint_elements()
                   for lp in self.layers for s in lp.shards)

    def report(self) -> str:
        c = self.cluster
        lines = [f"multichip plan: {self.name}  "
                 f"({c.n_chips} chips, {c.topo.describe()}, "
                 f"t_ici={c.t_ici:g}, "
                 f"{self.n_layers} layers, planned in "
                 f"{self.planning_seconds:.2f}s, "
                 f"{self.cache_hits}/{self.solver_calls} cache hits)"]
        for lp in self.layers:
            per_chip = " ".join(f"c{s.chip}:{s.gross_duration:g}"
                                for s in lp.shards)
            combine = ("max overlapped ici" if lp.overlap else "+ ici")
            mode = lp.mode if lp.grid is None else \
                f"hybrid{lp.grid[0]}x{lp.grid[1]}"
            lines.append(
                f"  L{lp.index}: {mode:<9} x{lp.active_chips} "
                f"dur={lp.duration:g} (compute {lp.compute_duration:g}"
                f" {combine} {lp.ici_duration:g}"
                f"{f' - reuse {lp.savings:g}' if lp.savings else ''})"
                f"  [{per_chip}]")
        if self.final_gather_duration:
            lines.append(f"  final gather -> chip 0: "
                         f"{self.final_gather_elements} elements, "
                         f"{self.final_gather_duration:g}")
        tail = f"  total={self.total_duration:g} " \
               f"(ici {self.ici_fraction:.1%}, modes {self.mode_string})"
        if self.single_chip_duration is not None:
            tail += f"; 1-chip {self.single_chip_duration:g} " \
                    f"(speedup {self.speedup_vs_single_chip:.2f}x)"
        lines.append(tail)
        return "\n".join(lines)


# --------------------------------------------------------------------- #
# Per-layer mode evaluation
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class _ModeEval:
    mode: str
    shards: tuple[ShardPlan, ...]
    compute_duration: float
    grid: tuple[int, int] | None = None  # hybrid shard grid
    halo_safe: float = float("inf")      # earliest halo read across bands
    alt: "_ModeEval | None" = None       # zigzag-swapped overlap variant

    @property
    def layout(self) -> str:
        return _produced_layout(self.mode, len(self.shards), self.grid)


def _zigzag_swapped(shards: Sequence[ShardPlan], spec: ConvSpec,
                    hw: HardwareModel, same_pad: bool,
                    nb_data_reload: int) -> "tuple[ShardPlan, ...] | None":
    """Variant of a row eval with every halo-receiving band re-solved as
    a plain zigzag sweep: the sweep reads its input top to bottom, so
    the halo rows (the window's last rows) are read last, maximising
    the overlap-safe window.  ``None`` when nothing changes or a swap
    would break the memory budget."""
    bands = [s for s in shards if s.out_rows is not None]
    if not bands:
        return None
    last_r1 = max(s.out_rows[1] for s in bands)
    new: list[ShardPlan] = []
    changed = False
    for s in shards:
        if s.out_rows is None or s.out_rows[1] == last_r1 \
                or not isinstance(s.strategy, GroupedStrategy):
            new.append(s)
            continue
        zz = zigzag(s.spec, s.p)
        if zz.groups == s.strategy.groups:
            new.append(s)
            continue
        if hw.size_mem is not None and \
                zz.peak_footprint_elements() > hw.size_mem:
            return None
        obj = zz.objective(hw)
        res = dataclasses.replace(
            s.result, strategy=zz, objective=obj, polish_objective=obj,
            milp_status="overlap-swap", milp_objective=None,
            reload_ok=zz.max_reloads() <= nb_data_reload)
        saved = 0.0
        if same_pad:
            r0, r1 = s.out_rows
            saved = _band_pad_saving(spec, r0, r1, hw,
                                     zz.first_load_duration(hw))
        new.append(dataclasses.replace(
            s, result=res, gross_duration=zz.full_duration(hw) - saved,
            pad_saved=saved))
        changed = True
    if not changed:
        return None
    return tuple(new)


def _eval_mode(spec: ConvSpec, mode: str, cluster: ClusterModel,
               max_group: int | None, solve_kwargs: dict,
               balance_rows: bool = False,
               same_pad: bool = False,
               overlap: bool = False,
               ) -> _ModeEval | None:
    """Solve every shard of ``spec`` under ``mode`` through the LRU-cached
    solver; None when any shard fits no strategy family or the mode does
    not apply (hybrid off-torus, or a hybrid grid the layer can't fill).
    With ``overlap``, row evals also carry their halo-safety window
    (:func:`_halo_safe_time`) and, when it helps, a zigzag-swapped
    alternative whose bands read the halo later."""
    hw = cluster.chip
    grid = None
    if mode == "replicate":
        raw = [(0, None, None, spec)]
    elif mode == "row":
        heights = None
        if balance_rows:
            heights = balanced_row_heights(spec, hw, cluster.n_chips,
                                           max_group, solve_kwargs,
                                           same_pad=same_pad)
        raw = [(c, band, None, s)
               for c, band, s in row_shard_specs(spec, cluster.n_chips,
                                                 heights)]
    elif mode == "channel":
        raw = [(c, None, krange, s)
               for c, krange, s in kernel_shard_specs(spec, cluster.n_chips)]
    elif mode == "hybrid":
        if cluster.topo.kind != "torus":
            return None                  # needs a second axis to shard on
        ny, nx = cluster.topo.grid(cluster.n_chips)
        if ny > spec.h_out or nx > spec.n_kernels:
            return None                  # infeasible chip grid: the full
        grid = (ny, nx)                  # rows x cols grid must be active
        heights = None
        if balance_rows:
            # the widest kernel group's bands dominate the per-chip max
            kmax = max(k1 - k0 for _, (k0, k1), _ in
                       kernel_shard_specs(spec, nx))
            heights = balanced_row_heights(
                dataclasses.replace(spec, n_kernels=kmax), hw, ny,
                max_group, solve_kwargs, same_pad=same_pad)
        raw = hybrid_shard_specs(spec, ny, nx, heights)
    else:
        raise ValueError(f"unknown sharding mode {mode!r}")
    shards = []
    for chip, band, krange, sspec in raw:
        p = resolve_group_size(sspec, hw, max_group)
        try:
            res = solver_mod.solve_cached(sspec, p, hw, **solve_kwargs)
        except ValueError:
            return None
        if hw.size_mem is not None and \
                res.strategy.peak_footprint_elements() > hw.size_mem:
            return None
        saved = 0.0
        if same_pad:
            # every shard skips the padding rows inside its own input
            # window — replicate/channel shards span the full height, so
            # they get the whole-map credit and the mode DP stays
            # consistently priced across the alphabet
            r0, r1 = band if band is not None else (0, spec.h_out)
            saved = _band_pad_saving(
                spec, r0, r1, hw,
                res.strategy.first_load_duration(hw))
        shards.append(ShardPlan(
            chip=chip, spec=sspec, p=p, result=res,
            out_rows=band, kernel_range=krange,
            gross_duration=res.strategy.full_duration(hw) - saved,
            pad_saved=saved))
    halo_safe, alt = float("inf"), None
    if overlap and mode == "row":
        halo_safe = _halo_safe_time(shards, hw)
        swapped = _zigzag_swapped(shards, spec, hw, same_pad,
                                  solve_kwargs.get("nb_data_reload", 2))
        if swapped is not None:
            alt_safe = _halo_safe_time(swapped, hw)
            if alt_safe > halo_safe:
                alt = _ModeEval(
                    mode=mode, shards=swapped,
                    compute_duration=max(s.gross_duration
                                         for s in swapped),
                    grid=grid, halo_safe=alt_safe)
    return _ModeEval(mode=mode, shards=tuple(shards),
                     compute_duration=max(s.gross_duration for s in shards),
                     grid=grid, halo_safe=halo_safe, alt=alt)


def ici_schedule(specs: Sequence[ConvSpec], modes: Sequence[str],
                 active: Sequence[int], cluster: ClusterModel,
                 ) -> tuple[list[int], int]:
    """Re-derive the per-layer inbound ICI element counts (and the final
    gather to chip 0) from a mode sequence — the pure pricing function
    the planner charges and the simulator cross-checks."""
    if len(specs) != len(modes) or len(specs) != len(active):
        raise ValueError("specs/modes/active length mismatch")
    grid = cluster.topo.grid(cluster.n_chips)
    per_layer = []
    layout = _INPUT_LAYOUT
    for spec, mode, n_act in zip(specs, modes, active):
        per_layer.append(_transition_elements(
            layout, mode, spec, spec.num_pixels * spec.c_in, cluster))
        layout = _produced_layout(mode, n_act,
                                  grid if mode == "hybrid" else None)
    last = specs[-1]
    final = _transition_elements(
        layout, "replicate", last, last.num_patches * last.c_out, cluster)
    return per_layer, final


# --------------------------------------------------------------------- #
# Front door
# --------------------------------------------------------------------- #

def plan_multichip_network(specs: Sequence[ConvSpec], cluster: ClusterModel,
                           *,
                           name: str = "network",
                           max_group: int | None = 16,
                           nb_data_reload: int = 2,
                           polish_iters: int = 6_000,
                           polish_restarts: int = 4,
                           use_milp: bool = False,
                           time_limit: float = 10.0,
                           rng_seed: int = 0,
                           modes: Sequence[str] | None = None,
                           include_single_chip_baseline: bool = True,
                           overlap: bool = False,
                           balance_rows: bool = False,
                           same_pad: bool = False,
                           verify: bool | None = None,
                           ) -> MultiChipPlan:
    """Plan a conv network on ``cluster.n_chips`` chips wired as
    ``cluster.topology`` (unidirectional/bidirectional ring or 2-D torus).

    ``n_chips == 1`` delegates to :func:`plan_network` and reproduces its
    plan exactly (same strategies, same total duration, inter-layer reuse
    included).  Otherwise every layer's feasible sharding modes are priced
    — shards through ``solver.solve_cached`` (budget-aware S1/S2 choice,
    LRU-shared with the single-chip planner), resharding over
    topology-priced ICI collectives — and a dynamic program picks the
    mode sequence minimising total duration including a final gather of
    the last activation to chip 0.  ``modes`` defaults to the topology's
    alphabet (:func:`mode_alphabet`: hybrid row x channel grids need a
    torus).  Raises :class:`InfeasibleNetworkError` when some layer fits
    under no mode — the message names the layer, budget, chip count and
    topology.

    ``overlap=True`` prices each layer's inbound ICI as double-buffered
    against compute — per-layer duration ``max(compute, ICI)`` instead of
    ``compute + ICI`` (the halo/reshard of stage l streams while stage
    l-1's band is still computing; only the final gather stays serial).
    Halo exchanges between consecutive row-sharded layers only get the
    overlapped price when the receiving bands provably read their halo
    rows after the exchange can have delivered them (WAR-free by
    ``halo_first_use`` timing); unsound stages are re-solved with
    halo-last zigzag bands or serialised, whichever is cheaper, and each
    layer's ``overlap`` flag records what was actually priced.
    ``balance_rows=True`` sizes row bands by solved per-chip *duration*
    (:func:`balanced_row_heights`) instead of raw row counts.
    ``same_pad=True`` asserts the already-padded inputs are SAME padding,
    so edge bands skip their padding rows' first loads (position-
    dependent band durations; see the module note).  All three default
    to False, which reproduces the serialised row-balanced accounting
    bit-exactly (the paper's Def-3 spirit; the benchmark's trajectory
    baseline).

    ``verify=True`` runs the static plan verifier
    (``repro.analysis.verifier``) as a postcondition and raises
    ``PlanVerificationError`` on any error-severity diagnostic; the
    default ``None`` defers to the ``REPRO_VERIFY_PLANS`` env knob.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("empty network")
    if same_pad and cluster.n_chips == 1:
        raise ValueError(
            "same_pad models the sharded planner's band accounting; the "
            "1-chip path delegates to plan_network, which does not model "
            "padding — plan with n_chips >= 2 or drop same_pad")
    if modes is None:
        modes = mode_alphabet(cluster)
    solve_kwargs = dict(nb_data_reload=nb_data_reload,
                        time_limit=time_limit, polish_iters=polish_iters,
                        use_milp=use_milp, rng_seed=rng_seed,
                        polish_restarts=polish_restarts)
    plan_kwargs = dict(max_group=max_group, **solve_kwargs)

    from repro.analysis.verifier import assert_verified, should_verify
    do_verify = should_verify(verify)

    if cluster.n_chips == 1:
        # the delegated plan is verified through the MultiChipPlan below
        net = plan_network(specs, cluster.chip, name=name, verify=False,
                           **plan_kwargs)
        layers = tuple(
            MultiChipLayerPlan(
                index=lp.index, spec=lp.spec, mode="replicate",
                shards=(ShardPlan(
                    chip=0, spec=lp.spec, p=lp.p, result=lp.result,
                    out_rows=None, kernel_range=None,
                    gross_duration=lp.gross_duration),),
                compute_duration=lp.gross_duration,
                ici_elements=0, ici_duration=0.0,
                savings=lp.input_load_saved + lp.write_back_saved,
                overlap=overlap)
            for lp in net.layers)
        plan = MultiChipPlan(
            name=name, cluster=cluster, layers=layers,
            total_duration=net.total_duration,
            final_gather_elements=0, final_gather_duration=0.0,
            single_chip_duration=net.total_duration,
            network_plan=net,
            planning_seconds=net.planning_seconds,
            solver_calls=net.solver_calls, cache_hits=net.cache_hits,
            overlap=overlap, balance_rows=balance_rows)
        if do_verify:
            assert_verified(plan)
        return plan

    # per-stage cache attribution: deltas of a full counter snapshot, so
    # the DP window below never claims the nested single-chip baseline's
    # (or a concurrent planner's) hits
    stats0 = solver_mod.cache_stats()
    t0 = time.perf_counter()

    # 1) per-layer feasible mode evaluations
    evals: list[dict[str, _ModeEval]] = []
    for i, spec in enumerate(specs):
        layer_evals = {}
        for mode in modes:
            ev = _eval_mode(spec, mode, cluster, max_group, solve_kwargs,
                            balance_rows=balance_rows, same_pad=same_pad,
                            overlap=overlap)
            if ev is not None:
                layer_evals[mode] = ev
        if not layer_evals:
            raise InfeasibleNetworkError(
                f"layer {i} ({spec.c_in}x{spec.h_in}x{spec.w_in}"
                f"->{spec.c_out}): no sharding mode fits "
                f"size_mem={cluster.chip.size_mem} on "
                f"{cluster.n_chips} chips ({cluster.topo.describe()}; "
                f"a hybrid grid also needs rows<=h_out={spec.h_out} "
                f"and cols<=n_kernels={spec.n_kernels})")
        evals.append(layer_evals)

    # 2) Viterbi DP over (layer, mode): resharding couples neighbours
    t_ici = cluster.t_ici
    # cost[mode] = best total through layer i ending in this mode
    cost: dict[str, float] = {}
    back: list[dict[str, tuple[str | None, int, str]]] = []
    for i, layer_evals in enumerate(evals):
        nxt_cost: dict[str, float] = {}
        choices: dict[str, tuple[str | None, int, str]] = {}
        # resharding moves the consumer's (post-pooling) input map — the
        # tensor that must land in the consumer's layout.
        a_full = specs[i].num_pixels * specs[i].c_in

        def stage_price(ev: _ModeEval, elems: int,
                        prev_layout: str) -> tuple[float, str]:
            """(duration, variant) of this layer fed by ``elems`` inbound
            ICI elements.  Serial Def-3 pricing by default; with
            ``overlap``, a generic reshard hides under compute — the
            consumer cannot start before it anyway, so max(compute, ICI)
            is the pipeline bound — but a row->row *halo* exchange
            writes rows the consumer already holds live, so it may only
            overlap when every receiving band provably reads its halo
            after the exchange can have delivered it
            (:func:`halo_first_use`).  Otherwise the planner considers
            the zigzag-swapped variant ('ovl-alt': bands re-solved so
            the halo is read last) and serial pricing, picking the
            cheaper; ``ici/war-overlap`` in ``analysis.verifier``
            re-proves whichever claim is made."""
            ici = elems * t_ici
            if not overlap:
                return ev.compute_duration + ici, "serial"
            halo_like = (ev.mode == "row" and prev_layout == "row"
                         and elems == halo_elements(specs[i])
                         and elems > 0)
            if not halo_like:
                return max(ev.compute_duration, ici), "ovl"
            cands = [(ev.compute_duration + ici, "serial")]
            if ici <= ev.halo_safe + 1e-9:
                cands.append((max(ev.compute_duration, ici), "ovl"))
            elif ev.alt is not None and ici <= ev.alt.halo_safe + 1e-9:
                cands.append(
                    (max(ev.alt.compute_duration, ici), "ovl-alt"))
            return min(cands)

        for mode, ev in layer_evals.items():
            if i == 0:
                elems = _transition_elements(
                    _INPUT_LAYOUT, mode, specs[i], a_full, cluster)
                val, variant = stage_price(ev, elems, _INPUT_LAYOUT)
                nxt_cost[mode] = val
                choices[mode] = (None, elems, variant)
                continue
            best: tuple[float, str | None, int, str] = \
                (float("inf"), None, 0, "serial")
            for pmode, pcost in cost.items():
                prev_layout = evals[i - 1][pmode].layout
                elems = _transition_elements(
                    prev_layout, mode, specs[i], a_full, cluster)
                val, variant = stage_price(ev, elems, prev_layout)
                if pcost + val < best[0]:
                    best = (pcost + val, pmode, elems, variant)
            nxt_cost[mode] = best[0]
            choices[mode] = (best[1], best[2], best[3])
        cost = nxt_cost
        back.append(choices)

    # final gather of the last activation to chip 0
    last = specs[-1]
    a_last = last.num_patches * last.c_out
    best_mode, best_total, final_elems = None, float("inf"), 0
    for mode, val in cost.items():
        elems = _transition_elements(
            evals[-1][mode].layout, "replicate", last, a_last, cluster)
        if val + elems * t_ici < best_total:
            best_mode, best_total = mode, val + elems * t_ici
            final_elems = elems

    # 3) backtrack
    chosen: list[str] = [best_mode]
    in_elems: list[int] = []
    variants: list[str] = []
    for i in range(len(specs) - 1, -1, -1):
        prev_mode, elems, variant = back[i][chosen[0]]
        in_elems.insert(0, elems)
        variants.insert(0, variant)
        if i > 0:
            chosen.insert(0, prev_mode)
    planning_seconds = time.perf_counter() - t0
    # the DP's own attribution window closes BEFORE the single-chip
    # baseline runs — historically the readback after that baseline let
    # the nested plan_network claim its solves in this plan's counters
    dp_stats = solver_mod.cache_stats() - stats0
    # observability hooks (lazy import — see core.network_planner)
    from repro.obs.metrics import REGISTRY
    REGISTRY.incr("planner/multichip_calls")
    REGISTRY.incr("planner/multichip_s", planning_seconds)
    REGISTRY.incr("planner/stage/multichip/calls", dp_stats.solve_calls)
    REGISTRY.incr("planner/stage/multichip/hits", dp_stats.solve_hits)

    def _layer(i: int) -> MultiChipLayerPlan:
        ev = evals[i][chosen[i]]
        if variants[i] == "ovl-alt":
            ev = ev.alt
        return MultiChipLayerPlan(
            index=i, spec=specs[i], mode=chosen[i],
            shards=ev.shards,
            compute_duration=ev.compute_duration,
            ici_elements=in_elems[i],
            ici_duration=in_elems[i] * t_ici,
            overlap=variants[i] != "serial",
            grid=ev.grid)

    layers = tuple(_layer(i) for i in range(len(specs)))

    single = None
    if include_single_chip_baseline:
        base0 = solver_mod.cache_stats()
        try:
            # a pricing reference, not an emitted plan: skip verification
            net = plan_network(specs, cluster.chip, name=name,
                               verify=False, **plan_kwargs)
            single = net.total_duration
            if same_pad:
                # credit the baseline with the same whole-map padding
                # savings the shards get, clamped to each layer's first
                # loads NOT already covered by inter-layer reuse — so
                # speedup_vs_single_chip compares consistently-padded
                # accountings and never double-counts a saved load
                hw = cluster.chip
                for lp in net.layers:
                    whole = _band_pad_saving(
                        lp.spec, 0, lp.spec.h_out, hw,
                        lp.result.strategy.first_load_duration(hw))
                    single -= min(whole, max(
                        0.0, lp.result.strategy.first_load_duration(hw)
                        - lp.input_load_saved))
        except InfeasibleNetworkError:
            single = None               # sharding extends feasibility
        base_stats = solver_mod.cache_stats() - base0
        REGISTRY.incr("planner/stage/single_baseline/calls",
                      base_stats.solve_calls)
        REGISTRY.incr("planner/stage/single_baseline/hits",
                      base_stats.solve_hits)

    plan = MultiChipPlan(
        name=name, cluster=cluster, layers=layers,
        total_duration=best_total,
        final_gather_elements=final_elems,
        final_gather_duration=final_elems * t_ici,
        single_chip_duration=single,
        network_plan=None,
        planning_seconds=planning_seconds,
        solver_calls=dp_stats.solve_calls,
        cache_hits=dp_stats.solve_hits,
        overlap=overlap, balance_rows=balance_rows)
    if do_verify:
        assert_verified(plan)
    return plan


def replan_suffix(specs: Sequence[ConvSpec], cluster: ClusterModel, *,
                  start: int, name: str = "network",
                  **kwargs) -> MultiChipPlan:
    """Re-plan the tail ``specs[start:]`` of a network — the
    degraded-mode re-planning entry point (``repro.resil``): after a
    chip death, link degradation or budget shrink, the remaining layers
    are planned afresh on the surviving/repriced ``cluster``.  The call
    is warm-started automatically: per-layer solves go through the
    ``solver.solve_cached`` LRU shared with every other planner, so
    layers whose shard geometry survives the degradation hit the cache.

    Layer indices in the returned plan are local to the suffix (global
    layer = ``start`` + local); the engine keeps the mapping.  The first
    suffix layer is priced from the planner's usual ``_INPUT_LAYOUT``
    ("all" — every chip holds its input), which recovery pays for
    explicitly by restaging the last committed activation from the
    durable store (see ``repro.resil.engine``).
    """
    if not 0 <= start < len(specs):
        raise ValueError(
            f"suffix start {start} out of range for {len(specs)} layers")
    return plan_multichip_network(
        list(specs[start:]), cluster,
        name=f"{name}[{start}:]", **kwargs)
