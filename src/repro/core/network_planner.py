"""Network-level offloading planner (beyond-paper: whole-CNN scheduling).

The paper (and ``core.solver``) optimises ONE convolution layer.  A real
workload is a *network* — an ordered sequence of conv layers (LeNet-5,
ResNet-8, ... in ``repro.configs``).  This module plans the whole sequence:

  1. every layer is solved with the Sec-5/7 machinery (heuristic seeds +
     multi-restart parallel polish, optional MILP) through an LRU cache, so
     repeated layer shapes (ResNet stages) are solved once;
  2. layer durations use the *full* Def-3 accounting — eq. 15 plus the
     kernel load and the output write-back that the paper's single-layer
     experiments exclude — because at network level the write-back of layer
     l and the input load of layer l+1 are exactly the terms inter-layer
     scheduling can remove;
  3. when the activation between two layers fits the on-chip budget next to
     the successor's working set, the HBM round trip is skipped: layer l
     keeps its outputs resident (no write-back) and layer l+1 reads each of
     its input pixels' *first* load from on-chip memory (reloads beyond the
     first still hit DRAM).  This is the layer-cascade reuse of
     Stoutchinin et al. / Jokic et al. transplanted onto the paper's
     formalism.  Elementwise ops between convs (ReLU, pooling) are assumed
     fused on-chip and free, per the usual accelerator dataflow.

``plan_network`` returns a ``NetworkPlan`` with per-layer strategies, the
aggregate predicted duration, the per-layer-greedy baseline (no reuse, no
polish — what a layer-at-a-time compiler would emit), and a critical-path
report naming the layers that dominate the schedule.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

from repro.core import solver as solver_mod
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import GroupedStrategy, best_heuristic


def resolve_group_size(spec: ConvSpec, hw: HardwareModel,
                       max_group: int | None = 16) -> int:
    """nb_patches_max_S1 (Sec 4.2) clipped to the patch count and to an
    optional planning cap (huge PEs would otherwise allow one giant group,
    which blows up the tiled-shape enumeration without helping reuse)."""
    p = hw.nb_patches_max_s1(spec.nb_op_value, spec.c_out)
    p = min(p, spec.num_patches)
    if max_group is not None:
        p = min(p, max_group)
    return max(1, p)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's slot in the network schedule."""

    index: int
    spec: ConvSpec
    p: int
    result: solver_mod.SolveResult
    reuse_input: bool       # input arrives on-chip from the previous layer
    reuse_output: bool      # output held on-chip for the next layer
    gross_duration: float   # full Def-3 duration, no inter-layer reuse
    input_load_saved: float  # t_l saved on first loads when reuse_input
    write_back_saved: float  # t_w saved when reuse_output

    @property
    def strategy(self) -> GroupedStrategy:
        return self.result.strategy

    @property
    def duration(self) -> float:
        """Net contribution to the network schedule."""
        return self.gross_duration - self.input_load_saved \
            - self.write_back_saved


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """A solved whole-network offloading schedule."""

    name: str
    hw: HardwareModel
    layers: tuple[LayerPlan, ...]
    total_duration: float        # with inter-layer reuse
    gross_duration: float        # same strategies, no reuse
    baseline_duration: float     # per-layer greedy: best heuristic, no reuse
    planning_seconds: float
    solver_calls: int
    cache_hits: int

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def gain_vs_baseline(self) -> float:
        if self.baseline_duration == 0:
            return 0.0
        return 1.0 - self.total_duration / self.baseline_duration

    @property
    def layers_per_second(self) -> float:
        if self.planning_seconds <= 0:
            return float("inf")
        return self.n_layers / self.planning_seconds

    def critical_path(self) -> list[tuple[int, float, float]]:
        """(layer index, duration, fraction of total) sorted by duration
        descending — the layers to attack next."""
        total = self.total_duration or 1.0
        rows = [(lp.index, lp.duration, lp.duration / total)
                for lp in self.layers]
        return sorted(rows, key=lambda r: -r[1])

    def report(self) -> str:
        lines = [f"network plan: {self.name}  "
                 f"({self.n_layers} layers, planned in "
                 f"{self.planning_seconds:.2f}s, "
                 f"{self.layers_per_second:.1f} layers/s, "
                 f"{self.cache_hits}/{self.solver_calls} cache hits)"]
        for lp in self.layers:
            tags = []
            if lp.reuse_input:
                tags.append("in<-chip")
            if lp.reuse_output:
                tags.append("out->chip")
            lines.append(
                f"  L{lp.index}: {lp.spec.c_in}x{lp.spec.h_in}x{lp.spec.w_in}"
                f" -> {lp.spec.c_out}x{lp.spec.h_out}x{lp.spec.w_out}"
                f"  p={lp.p} steps={lp.strategy.n_steps}"
                f" strat={lp.strategy.name}"
                f" dur={lp.duration:g}"
                f" (gross {lp.gross_duration:g})"
                f" gap={lp.result.gap:.1%}"
                f"{('  [' + ','.join(tags) + ']') if tags else ''}")
        crit = self.critical_path()[0]
        lines.append(
            f"  total={self.total_duration:g} (gross {self.gross_duration:g},"
            f" greedy baseline {self.baseline_duration:g},"
            f" gain {self.gain_vs_baseline:.1%});"
            f" critical layer L{crit[0]} ({crit[2]:.0%} of total)")
        return "\n".join(lines)


# --------------------------------------------------------------------- #
# Inter-layer reuse feasibility
# --------------------------------------------------------------------- #

def activation_fits(prev: ConvSpec, prev_strategy: GroupedStrategy,
                    nxt: ConvSpec, nxt_strategy: GroupedStrategy,
                    hw: HardwareModel) -> bool:
    """Can layer ``prev``'s output stay resident until ``nxt`` consumed it?

    Both ends must fit: while ``prev`` executes, its accumulating output
    map (no longer drained by write-backs) coexists with prev's own
    working set; while ``nxt`` executes, the held activation (the larger
    of prev's output map and nxt's input map, since pooling/padding
    between them happens on-chip) coexists with nxt's peak working set
    (kernels + largest group's pixels + outputs).  ``size_mem=None`` is
    the paper's unconstrained Sec-7.1 setting: always fits.
    """
    if hw.size_mem is None:
        return True
    held = max(prev.num_patches * prev.c_out,
               nxt.num_pixels * nxt.c_in)
    producer_ok = (held + prev.kernel_elements
                   + prev_strategy.peak_input_footprint() * prev.c_in
                   <= hw.size_mem)
    consumer_ok = held + nxt_strategy.peak_footprint_elements() \
        <= hw.size_mem
    return producer_ok and consumer_ok


# --------------------------------------------------------------------- #
# Baselines
# --------------------------------------------------------------------- #

def greedy_network_duration(specs: Sequence[ConvSpec], hw: HardwareModel,
                            p: int | Sequence[int] | None = None,
                            max_group: int | None = 16) -> float:
    """Per-layer-greedy baseline: every layer takes the best of the paper's
    two heuristics (Row-by-Row / ZigZag), no polish, no MILP, and every
    activation makes the full HBM round trip (write-back + reload)."""
    ps = _resolve_ps(specs, hw, p, max_group)
    return sum(best_heuristic(spec, pp, hw).full_duration(hw)
               for spec, pp in zip(specs, ps))


def _resolve_ps(specs: Sequence[ConvSpec], hw: HardwareModel,
                p: int | Sequence[int] | None,
                max_group: int | None) -> list[int]:
    if p is None:
        return [resolve_group_size(s, hw, max_group) for s in specs]
    if isinstance(p, int):
        return [min(p, s.num_patches) for s in specs]
    ps = list(p)
    if len(ps) != len(specs):
        raise ValueError(f"{len(ps)} group sizes for {len(specs)} layers")
    return ps


# --------------------------------------------------------------------- #
# Front door
# --------------------------------------------------------------------- #

def plan_network(specs: Sequence[ConvSpec], hw: HardwareModel,
                 *,
                 name: str = "network",
                 p: int | Sequence[int] | None = None,
                 max_group: int | None = 16,
                 nb_data_reload: int = 2,
                 polish_iters: int = 6_000,
                 polish_restarts: int = 4,
                 use_milp: bool = False,
                 time_limit: float = 10.0,
                 rng_seed: int = 0,
                 allow_reuse: bool = True,
                 solve_fn: Callable[..., solver_mod.SolveResult] | None = None,
                 ) -> NetworkPlan:
    """Solve every layer and assemble the network schedule.

    Deterministic for fixed ``rng_seed`` (restart seeds are derived from
    it; see ``solver.polish_multi``).  ``solve_fn`` overrides the cached
    solver (tests / custom search)."""
    specs = list(specs)
    if not specs:
        raise ValueError("empty network")
    ps = _resolve_ps(specs, hw, p, max_group)
    fn = solve_fn or solver_mod.solve_cached

    hits0 = calls0 = 0
    if fn is solver_mod.solve_cached:
        info = solver_mod.solve_cached.cache_info()
        hits0, calls0 = info.hits, info.hits + info.misses

    t0 = time.perf_counter()
    results = [fn(spec, pp, hw, nb_data_reload=nb_data_reload,
                  time_limit=time_limit, polish_iters=polish_iters,
                  use_milp=use_milp, rng_seed=rng_seed,
                  polish_restarts=polish_restarts)
               for spec, pp in zip(specs, ps)]
    planning_seconds = time.perf_counter() - t0

    cache_hits = solver_calls = 0
    if fn is solver_mod.solve_cached:
        info = solver_mod.solve_cached.cache_info()
        cache_hits = info.hits - hits0
        solver_calls = (info.hits + info.misses) - calls0

    # inter-layer reuse: decide for every adjacent pair whether the
    # activation stays on-chip.
    reuse_after = []                      # reuse_after[i]: i -> i+1 held
    for i in range(len(specs) - 1):
        reuse_after.append(
            allow_reuse and activation_fits(
                specs[i], results[i].strategy,
                specs[i + 1], results[i + 1].strategy, hw))

    layers: list[LayerPlan] = []
    total = gross_total = 0.0
    for i, (spec, pp, res) in enumerate(zip(specs, ps, results)):
        strat = res.strategy
        gross = strat.full_duration(hw)
        reuse_in = i > 0 and reuse_after[i - 1]
        reuse_out = i < len(specs) - 1 and reuse_after[i]
        in_saved = (spec.all_pixels_mask.bit_count() * hw.t_l
                    if reuse_in else 0.0)
        wb_saved = strat.write_back_duration(hw) if reuse_out else 0.0
        lp = LayerPlan(index=i, spec=spec, p=pp, result=res,
                       reuse_input=reuse_in, reuse_output=reuse_out,
                       gross_duration=gross,
                       input_load_saved=in_saved,
                       write_back_saved=wb_saved)
        layers.append(lp)
        total += lp.duration
        gross_total += gross

    baseline = greedy_network_duration(specs, hw, p=p, max_group=max_group)
    return NetworkPlan(
        name=name, hw=hw, layers=tuple(layers),
        total_duration=total, gross_duration=gross_total,
        baseline_duration=baseline,
        planning_seconds=planning_seconds,
        solver_calls=solver_calls, cache_hits=cache_hits)
