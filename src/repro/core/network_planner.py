"""Network-level offloading planner (beyond-paper: whole-CNN scheduling).

The paper (and ``core.solver``) optimises ONE convolution layer.  A real
workload is a *network* — an ordered sequence of conv layers (LeNet-5,
ResNet-8, ... in ``repro.configs``).  This module plans the whole sequence:

  1. every layer is solved with the Sec-5/7 machinery (heuristic seeds +
     multi-restart parallel polish, optional MILP) through an LRU cache, so
     repeated layer shapes (ResNet stages) are solved once;
  2. layer durations use the *full* Def-3 accounting — eq. 15 plus the
     kernel load and the output write-back that the paper's single-layer
     experiments exclude — because at network level the write-back of layer
     l and the input load of layer l+1 are exactly the terms inter-layer
     scheduling can remove;
  3. when the activation between two layers fits the on-chip budget next to
     the successor's working set, the HBM round trip is skipped: layer l
     keeps its outputs resident (no write-back) and layer l+1 reads each of
     its input pixels' *first* load from on-chip memory (reloads beyond the
     first still hit DRAM).  This is the layer-cascade reuse of
     Stoutchinin et al. / Jokic et al. transplanted onto the paper's
     formalism.  Elementwise ops between convs (ReLU, pooling) are assumed
     fused on-chip and free, per the usual accelerator dataflow.

Memory feasibility — the S1/S2 selection rule
--------------------------------------------
Every planned strategy must satisfy ``peak_footprint_elements() <=
hw.size_mem``.  Per layer, ``solver.solve_cached`` applies the rule:

  * solve S1 at the largest group size ``p' <= p`` whose contiguous
    strategy fits the budget (``solver.s1_max_feasible_p``);
  * when the budget forced ``p' < p`` — or no S1 group size fits at all,
    e.g. the kernel set Λ alone exceeds ``size_mem`` — price the S2
    kernel-group-swapping alternative (``strategies_s2.best_s2``, the
    paper's Sec-9 future-work regime) with the same full Def-3 accounting
    and keep the cheaper feasible one.

Both strategy families expose one protocol (``n_steps``, ``objective``,
``full_duration``, ``write_back_duration``, ``first_load_duration``,
``peak_footprint_elements``, ``peak_working_set_elements``,
``max_group_size``), so everything downstream — reuse gating, duration
accounting, simulation, benchmarks — treats them polymorphically.
``plan_network`` raises :class:`InfeasibleNetworkError` instead of ever
returning a plan whose peak footprint exceeds the budget.

Row-window (partial) cascading
------------------------------
When the full activation does not fit next to a neighbour's working set,
the planner falls back to holding only a *row window* of the consumer's
input on-chip: ``W`` rows (``W * w_in * c_in`` elements) stay resident,
saving the first loads of exactly those rows' pixels.  The fit condition is

    W * w_in * c_in  <=  size_mem - max(producer peak working set,
                                        consumer peak footprint)

with ``W >= h_k`` (at least one halo-extended output-row window, following
Stoutchinin et al.'s layer-cascade scheduling); the producer still writes
every output back (the window is a retained copy), so only consumer-side
first loads are saved.  Savings are always clamped to the consumer
strategy's measured first-load traffic and every ``LayerPlan.duration`` is
asserted non-negative.

``plan_network`` returns a ``NetworkPlan`` with per-layer strategies, the
aggregate predicted duration, the per-layer-greedy baseline (no reuse, no
polish — what a layer-at-a-time compiler would emit, under the same
feasibility rule), and a critical-path report naming the layers that
dominate the schedule.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence, Union

from repro.core import solver as solver_mod
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import GroupedStrategy, row_by_row, zigzag
from repro.core.strategies_s2 import S2Strategy

Strategy = Union[GroupedStrategy, S2Strategy]


class InfeasibleNetworkError(ValueError):
    """No strategy family fits a layer under ``hw.size_mem``."""


def resolve_group_size(spec: ConvSpec, hw: HardwareModel,
                       max_group: int | None = 16) -> int:
    """nb_patches_max_S1 (Sec 4.2) clipped to the patch count and to an
    optional planning cap (huge PEs would otherwise allow one giant group,
    which blows up the tiled-shape enumeration without helping reuse).
    Returns 1 when the PE cannot take one full S1 patch row — the solver
    then falls back to S2 kernel-group swapping."""
    try:
        p = hw.nb_patches_max_s1(spec.nb_op_value, spec.c_out)
    except ValueError:
        return 1
    p = min(p, spec.num_patches)
    if max_group is not None:
        p = min(p, max_group)
    return max(1, p)


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's slot in the network schedule."""

    index: int
    spec: ConvSpec
    p: int
    result: solver_mod.SolveResult
    reuse_input: bool       # ALL first loads arrive from the previous layer
    reuse_output: bool      # output held on-chip for the next layer (no wb)
    window_rows: int        # >0: only this many input rows held (partial)
    gross_duration: float   # full Def-3 duration, no inter-layer reuse
    input_load_saved: float  # t_l saved on first loads (full or window)
    write_back_saved: float  # t_w saved when reuse_output

    def __post_init__(self):
        if self.duration < -1e-9:
            raise AssertionError(
                f"layer {self.index}: negative net duration "
                f"{self.duration} (gross {self.gross_duration}, "
                f"in_saved {self.input_load_saved}, "
                f"wb_saved {self.write_back_saved})")

    @property
    def strategy(self) -> Strategy:
        return self.result.strategy

    @property
    def mode(self) -> str:
        """'s1' or 's2' (kernel-group swapping fallback)."""
        return self.result.mode

    @property
    def duration(self) -> float:
        """Net contribution to the network schedule."""
        return self.gross_duration - self.input_load_saved \
            - self.write_back_saved


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """A solved whole-network offloading schedule."""

    name: str
    hw: HardwareModel
    layers: tuple[LayerPlan, ...]
    total_duration: float        # with inter-layer reuse
    gross_duration: float        # same strategies, no reuse
    baseline_duration: float     # per-layer greedy: best heuristic, no reuse
    planning_seconds: float
    solver_calls: int
    cache_hits: int

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_s2_layers(self) -> int:
        return sum(1 for lp in self.layers if lp.mode == "s2")

    @property
    def peak_footprint(self) -> int:
        return max(lp.strategy.peak_footprint_elements() for lp in self.layers)

    @property
    def gain_vs_baseline(self) -> float:
        if self.baseline_duration == 0:
            return 0.0
        return 1.0 - self.total_duration / self.baseline_duration

    @property
    def layers_per_second(self) -> float:
        if self.planning_seconds <= 0:
            return float("inf")
        return self.n_layers / self.planning_seconds

    def critical_path(self) -> list[tuple[int, float, float]]:
        """(layer index, duration, fraction of total) sorted by duration
        descending — the layers to attack next."""
        total = self.total_duration or 1.0
        rows = [(lp.index, lp.duration, lp.duration / total)
                for lp in self.layers]
        return sorted(rows, key=lambda r: -r[1])

    def report(self) -> str:
        lines = [f"network plan: {self.name}  "
                 f"({self.n_layers} layers, planned in "
                 f"{self.planning_seconds:.2f}s, "
                 f"{self.layers_per_second:.1f} layers/s, "
                 f"{self.cache_hits}/{self.solver_calls} cache hits)"]
        for lp in self.layers:
            tags = []
            if lp.reuse_input:
                tags.append("in<-chip")
            elif lp.window_rows:
                tags.append(f"win{lp.window_rows}<-chip")
            if lp.reuse_output:
                tags.append("out->chip")
            lines.append(
                f"  L{lp.index}: {lp.spec.c_in}x{lp.spec.h_in}x{lp.spec.w_in}"
                f" -> {lp.spec.c_out}x{lp.spec.h_out}x{lp.spec.w_out}"
                f"  p={lp.p} steps={lp.strategy.n_steps}"
                f" strat={lp.strategy.name}"
                f" dur={lp.duration:g}"
                f" (gross {lp.gross_duration:g})"
                f" gap={lp.result.gap:.1%}"
                f"{('  [' + ','.join(tags) + ']') if tags else ''}")
        crit = self.critical_path()[0]
        lines.append(
            f"  total={self.total_duration:g} (gross {self.gross_duration:g},"
            f" greedy baseline {self.baseline_duration:g},"
            f" gain {self.gain_vs_baseline:.1%});"
            f" critical layer L{crit[0]} ({crit[2]:.0%} of total)")
        return "\n".join(lines)


# --------------------------------------------------------------------- #
# Inter-layer reuse feasibility
# --------------------------------------------------------------------- #

def _held_elements(prev: ConvSpec, nxt: ConvSpec) -> int:
    """Resident elements of a fully held activation: the larger of prev's
    output map and nxt's input map (pooling/padding between them happens
    on-chip)."""
    return max(prev.num_patches * prev.c_out, nxt.num_pixels * nxt.c_in)


def activation_fits(prev: ConvSpec, prev_strategy: Strategy,
                    nxt: ConvSpec, nxt_strategy: Strategy,
                    hw: HardwareModel,
                    producer_extra_held: int = 0) -> bool:
    """Can layer ``prev``'s output stay fully resident until ``nxt``
    consumed it?

    Both ends must fit, using the unified strategy-protocol accounting:
    while ``prev`` executes, the accumulating held map (no longer drained
    by write-backs) coexists with prev's peak *working set* — for S2
    producers that is the largest (input pixels + swapped kernel group) of
    any step, so S2 layers keep producer-side residency only when the held
    map fits next to the swapped kernel groups; while ``nxt`` executes,
    the held activation coexists with nxt's peak footprint.

    ``producer_extra_held`` counts elements already resident while
    ``prev`` executes — its own held *input* map when the previous pair
    also reuses (a middle layer holds both maps at once).
    ``size_mem=None`` is the paper's unconstrained Sec-7.1 setting:
    always fits.
    """
    if hw.size_mem is None:
        return True
    held = _held_elements(prev, nxt)
    producer_ok = (held + producer_extra_held
                   + prev_strategy.peak_working_set_elements()
                   <= hw.size_mem)
    consumer_ok = held + nxt_strategy.peak_footprint_elements() \
        <= hw.size_mem
    return producer_ok and consumer_ok


def row_window_rows(prev: ConvSpec, prev_strategy: Strategy,
                    nxt: ConvSpec, nxt_strategy: Strategy,
                    hw: HardwareModel,
                    producer_extra_held: int = 0) -> int:
    """Partial (row-window) cascading: how many of the consumer's input
    rows can stay resident when the full activation does not fit.

    The window (``W * w_in * c_in`` elements) must coexist with the
    producer's peak *footprint* while the producer finishes (in the window
    regime the producer still drains outputs through write-backs, so its
    output buffers stay resident — unlike full residency where they
    accumulate into the held map) AND with the consumer's peak footprint
    while it is consumed; it must cover at least one halo-extended
    output-row window (``h_k`` input rows).  ``producer_extra_held`` is
    the producer's own held input map, as in :func:`activation_fits`.
    Returns 0 when no admissible window exists."""
    if hw.size_mem is None:
        return 0                      # full residency always fits
    per_row = nxt.w_in * nxt.c_in
    spare = hw.size_mem - max(
        prev_strategy.peak_footprint_elements() + producer_extra_held,
        nxt_strategy.peak_footprint_elements())
    if spare < per_row:
        return 0
    rows = min(spare // per_row, nxt.h_in)
    return rows if rows >= nxt.h_k else 0


def _window_load_saved(nxt: ConvSpec, rows: int, hw: HardwareModel) -> float:
    """t_l saved by serving the first ``rows`` input rows' first loads
    from the held window (only pixels some patch actually needs count)."""
    mask = (1 << (rows * nxt.w_in)) - 1
    return (mask & nxt.all_pixels_mask).bit_count() * hw.t_l


# --------------------------------------------------------------------- #
# Baselines
# --------------------------------------------------------------------- #

def greedy_feasible_strategy(spec: ConvSpec, p: int,
                             hw: HardwareModel) -> Strategy:
    """Per-layer-greedy choice under the memory-feasibility rule: best of
    the paper's two heuristics (Row-by-Row / ZigZag) at the largest
    budget-feasible group size, else the S2 kernel-group-swapping
    fallback.  Raises :class:`InfeasibleNetworkError` when nothing fits."""
    p_fit = solver_mod.s1_max_feasible_p(spec, p, hw)
    if p_fit is not None:
        cands = [row_by_row(spec, p_fit), zigzag(spec, p_fit)]
        if hw.size_mem is not None:
            cands = [s for s in cands
                     if s.peak_footprint_elements() <= hw.size_mem]
        if cands:
            return min(cands, key=lambda s: s.objective(hw))
    try:
        res = solver_mod.best_s2_cached(spec, hw)
        # the baseline is polish-free by definition: use the enumeration
        # winner, not the polished/MILP-certified strategy
        return res.seed_strategy if res.seed_strategy is not None \
            else res.strategy
    except ValueError as e:
        raise InfeasibleNetworkError(
            f"no S1 or S2 strategy fits size_mem={hw.size_mem} "
            f"for layer {spec}") from e


def greedy_network_duration(specs: Sequence[ConvSpec], hw: HardwareModel,
                            p: int | Sequence[int] | None = None,
                            max_group: int | None = 16) -> float:
    """Per-layer-greedy baseline: every layer takes the best *feasible*
    heuristic (Row-by-Row / ZigZag, shrunk to fit the budget, or the S2
    fallback), no polish, no MILP, and every activation makes the full HBM
    round trip (write-back + reload).  Raises
    :class:`InfeasibleNetworkError` instead of pricing an infeasible
    schedule."""
    ps = _resolve_ps(specs, hw, p, max_group)
    return sum(greedy_feasible_strategy(spec, pp, hw).full_duration(hw)
               for spec, pp in zip(specs, ps))


def _resolve_ps(specs: Sequence[ConvSpec], hw: HardwareModel,
                p: int | Sequence[int] | None,
                max_group: int | None) -> list[int]:
    if p is None:
        return [resolve_group_size(s, hw, max_group) for s in specs]
    if isinstance(p, int):
        return [min(p, s.num_patches) for s in specs]
    ps = list(p)
    if len(ps) != len(specs):
        raise ValueError(f"{len(ps)} group sizes for {len(specs)} layers")
    return ps


# --------------------------------------------------------------------- #
# Plan assembly (strategies -> reuse decisions -> layer schedule)
# --------------------------------------------------------------------- #

def _assemble_layers(specs: Sequence[ConvSpec], ps: Sequence[int],
                     results: Sequence[solver_mod.SolveResult],
                     hw: HardwareModel, allow_reuse: bool,
                     ) -> tuple[list[LayerPlan], float, float]:
    """Fixed per-layer strategies -> (layers, total, gross total): the
    inter-layer reuse pass and duration accounting, shared between the
    first assembly and the reuse-aware refinement candidates.

    Reuse decision per adjacent pair: hold the full activation on-chip if
    it fits, else the largest admissible row window.  The decision is
    sequential: a middle layer holding its input map (from the previous
    pair) has less room for an accumulating output map, so the
    producer-side check carries that already-held amount forward."""
    # reuse_after[i]: ("full", 0) | ("window", rows) | None   for i -> i+1
    reuse_after: list[tuple[str, int] | None] = []
    for i in range(len(specs) - 1):
        held_in = 0                  # resident while layer i executes
        if i > 0 and reuse_after[i - 1] is not None:
            kind, rows = reuse_after[i - 1]
            held_in = (_held_elements(specs[i - 1], specs[i])
                       if kind == "full"
                       else rows * specs[i].w_in * specs[i].c_in)
        choice: tuple[str, int] | None = None
        if allow_reuse:
            if activation_fits(specs[i], results[i].strategy,
                               specs[i + 1], results[i + 1].strategy, hw,
                               producer_extra_held=held_in):
                choice = ("full", 0)
            else:
                rows = row_window_rows(
                    specs[i], results[i].strategy,
                    specs[i + 1], results[i + 1].strategy, hw,
                    producer_extra_held=held_in)
                if rows:
                    choice = ("window", rows)
        reuse_after.append(choice)

    layers: list[LayerPlan] = []
    total = gross_total = 0.0
    for i, (spec, pp, res) in enumerate(zip(specs, ps, results)):
        strat = res.strategy
        gross = strat.full_duration(hw)
        mode_in = reuse_after[i - 1] if i > 0 else None
        mode_out = reuse_after[i] if i < len(specs) - 1 else None
        reuse_in = mode_in is not None and mode_in[0] == "full"
        window_rows = mode_in[1] if mode_in and mode_in[0] == "window" else 0
        # savings never exceed the strategy's measured first-load DRAM
        # traffic: full residency saves exactly that; a window saves its
        # rows' needed pixels, clamped for strategies that load fewer.
        if reuse_in:
            in_saved = strat.first_load_duration(hw)
        elif window_rows:
            in_saved = min(_window_load_saved(spec, window_rows, hw),
                           strat.first_load_duration(hw))
        else:
            in_saved = 0.0
        reuse_out = mode_out is not None and mode_out[0] == "full"
        wb_saved = strat.write_back_duration(hw) if reuse_out else 0.0
        lp = LayerPlan(index=i, spec=spec, p=pp, result=res,
                       reuse_input=reuse_in, reuse_output=reuse_out,
                       window_rows=window_rows,
                       gross_duration=gross,
                       input_load_saved=in_saved,
                       write_back_saved=wb_saved)
        layers.append(lp)
        total += lp.duration
        gross_total += gross
    return layers, total, gross_total


# --------------------------------------------------------------------- #
# Front door
# --------------------------------------------------------------------- #

def plan_network(specs: Sequence[ConvSpec], hw: HardwareModel,
                 *,
                 name: str = "network",
                 p: int | Sequence[int] | None = None,
                 max_group: int | None = 16,
                 nb_data_reload: int = 2,
                 polish_iters: int = 6_000,
                 polish_restarts: int = 4,
                 use_milp: bool = False,
                 time_limit: float = 10.0,
                 rng_seed: int = 0,
                 allow_reuse: bool = True,
                 solve_fn: Callable[..., solver_mod.SolveResult] | None = None,
                 verify: bool | None = None,
                 ) -> NetworkPlan:
    """Solve every layer and assemble the network schedule.

    Every returned strategy is feasible under ``hw.size_mem`` (S1, shrunk
    S1, or the S2 kernel-group-swapping fallback — see the module note);
    :class:`InfeasibleNetworkError` is raised when a layer fits no family.
    Deterministic for fixed ``rng_seed`` (restart seeds are derived from
    it; see ``solver.polish_multi``).  ``solve_fn`` overrides the cached
    solver (tests / custom search).

    ``verify=True`` runs the static plan verifier
    (``repro.analysis.verifier``) as a postcondition and raises
    ``PlanVerificationError`` on any error-severity diagnostic; the
    default ``None`` defers to the ``REPRO_VERIFY_PLANS`` env knob."""
    specs = list(specs)
    if not specs:
        raise ValueError("empty network")
    ps = _resolve_ps(specs, hw, p, max_group)
    fn = solve_fn or solver_mod.solve_cached

    # per-stage cache attribution: snapshot counters around each stage
    # and report deltas, so interleaved stages (this solve loop, the
    # refine pass below, a concurrent multichip DP or resil re-plan)
    # never claim each other's hits
    track = fn is solver_mod.solve_cached
    stats0 = solver_mod.cache_stats() if track else None

    t0 = time.perf_counter()
    results = []
    for i, (spec, pp) in enumerate(zip(specs, ps)):
        try:
            results.append(
                fn(spec, pp, hw, nb_data_reload=nb_data_reload,
                   time_limit=time_limit, polish_iters=polish_iters,
                   use_milp=use_milp, rng_seed=rng_seed,
                   polish_restarts=polish_restarts))
        except ValueError as e:
            raise InfeasibleNetworkError(
                f"layer {i} ({spec.c_in}x{spec.h_in}x{spec.w_in}"
                f"->{spec.c_out}): no strategy fits "
                f"size_mem={hw.size_mem}") from e
    t_solved = time.perf_counter()
    solve_stats = (solver_mod.cache_stats() - stats0) if track else None
    # feasibility validation: never emit a plan whose peak exceeds the
    # budget (regression guard for custom solve_fn paths too).
    if hw.size_mem is not None:
        for i, res in enumerate(results):
            peak = res.strategy.peak_footprint_elements()
            if peak > hw.size_mem:
                raise InfeasibleNetworkError(
                    f"layer {i}: strategy {res.strategy.name} peak "
                    f"footprint {peak} exceeds size_mem={hw.size_mem}")

    layers, total, gross_total = _assemble_layers(
        specs, ps, results, hw, allow_reuse)

    # reuse-aware refinement: the per-layer joint (p, strategy) search can
    # pick a cheaper-gross strategy whose larger footprint blocks an
    # inter-layer reuse worth more than the layer-level gain.  For every
    # pair that got no full residency, re-solve the consumer under a
    # budget tightened to leave room for (a) the full held input map and
    # (b) one minimal halo window, and keep whichever full assembly is
    # cheaper (each capped solve hits the same LRU).
    refine0 = solver_mod.cache_stats() if track else None
    if allow_reuse and hw.size_mem is not None and fn is \
            solver_mod.solve_cached:
        for i in range(1, len(specs)):
            if layers[i].reuse_input:
                continue
            caps = []
            if not layers[i].window_rows:
                caps.append(hw.size_mem
                            - specs[i].h_k * specs[i].w_in * specs[i].c_in)
            caps.append(hw.size_mem - _held_elements(specs[i - 1],
                                                     specs[i]))
            peak_i = results[i].strategy.peak_footprint_elements()
            for cap in sorted(set(caps), reverse=True):
                if cap <= 0 or peak_i <= cap:
                    continue
                capped_hw = dataclasses.replace(hw, size_mem=cap)
                try:
                    alt = fn(specs[i], ps[i], capped_hw,
                             nb_data_reload=nb_data_reload,
                             time_limit=time_limit,
                             polish_iters=polish_iters,
                             use_milp=use_milp, rng_seed=rng_seed,
                             polish_restarts=polish_restarts)
                except ValueError:
                    continue
                alt_results = list(results)
                alt_results[i] = alt
                alt_layers, alt_total, alt_gross = _assemble_layers(
                    specs, ps, alt_results, hw, allow_reuse)
                if alt_total < total:
                    results, layers = alt_results, alt_layers
                    total, gross_total = alt_total, alt_gross
    planning_seconds = time.perf_counter() - t0

    cache_hits = solver_calls = 0
    if track:
        refine_stats = solver_mod.cache_stats() - refine0
        cache_hits = solve_stats.solve_hits + refine_stats.solve_hits
        solver_calls = solve_stats.solve_calls + refine_stats.solve_calls

    # observability hooks: per-stage wall-clocks accumulate in the
    # process-wide metrics registry (lazy import — repro.obs depends on
    # repro.core, never the reverse at module level)
    from repro.obs.metrics import REGISTRY
    REGISTRY.incr("planner/plan_network_calls")
    REGISTRY.incr("planner/solve_s", t_solved - t0)
    REGISTRY.incr("planner/refine_s", planning_seconds - (t_solved - t0))
    REGISTRY.incr("planner/solver_calls", solver_calls)
    REGISTRY.incr("planner/cache_hits", cache_hits)
    if track:
        REGISTRY.incr("planner/stage/solve/calls", solve_stats.solve_calls)
        REGISTRY.incr("planner/stage/solve/hits", solve_stats.solve_hits)
        REGISTRY.incr("planner/stage/refine/calls",
                      refine_stats.solve_calls)
        REGISTRY.incr("planner/stage/refine/hits", refine_stats.solve_hits)

    base0 = solver_mod.cache_stats()
    with REGISTRY.timer("planner/baseline_s"):
        baseline = greedy_network_duration(specs, hw, p=p,
                                           max_group=max_group)
    # the greedy baseline prices layers through best_s2_cached — its own
    # attribution window, so it never pollutes the solve/refine hit rates
    base_stats = solver_mod.cache_stats() - base0
    REGISTRY.incr("planner/stage/baseline/s2_calls", base_stats.s2_calls)
    REGISTRY.incr("planner/stage/baseline/s2_hits", base_stats.s2_hits)
    plan = NetworkPlan(
        name=name, hw=hw, layers=tuple(layers),
        total_duration=total, gross_duration=gross_total,
        baseline_duration=baseline,
        planning_seconds=planning_seconds,
        solver_calls=solver_calls, cache_hits=cache_hits)
    # lazy import: repro.analysis depends on this module
    from repro.analysis.verifier import assert_verified, should_verify
    if should_verify(verify):
        assert_verified(plan)
    return plan
