"""Offloading-schedule planner: the paper's formalism applied to TPU tiling.

This is the beyond-paper generalization described in DESIGN.md §2/§4.  The
paper's strategy model — steps that (free, write-back, load I_slice/K_sub,
compute) against an on-chip memory of size ``size_MEM`` with a PE of
``nbop_PE`` — maps onto Pallas kernels as:

    on-chip memory  = VMEM budget
    a step          = one grid iteration
    I_slice/K_sub   = BlockSpec-driven (or explicit-DMA) HBM->VMEM fetches
    kept-for-later  = block revisiting (index_map unchanged between steps)
    delta (eq. 15)  = HBM bytes moved / bandwidth + step overheads

For every perf-critical operator the planner enumerates candidate
*rectangular* strategies (tile shapes x loop orders), prices each with the
paper's duration model, and returns the argmin.  Both the paper-faithful
additive duration (no compute/copy overlap) and the overlapped duration
(max of roofline terms — what a double-buffered TPU kernel achieves) are
reported; optimisation uses the overlapped one by default.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import TPU_V5E, TpuChipModel
from repro.core.strategies import tiled as tiled_strategy


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, m: int) -> int:
    return _ceil_div(a, m) * m


@dataclasses.dataclass(frozen=True)
class Plan:
    """A chosen offloading schedule for one operator instance."""

    kind: str
    tiles: dict
    order: str
    steps: int
    hbm_bytes: int              # sum of I_slice/K_sub/W over all steps
    flops: int
    vmem_bytes: int             # peak on-chip footprint (eq. 12 analogue)
    duration_additive: float    # paper Def 3: loads + writes + compute
    duration_overlapped: float  # max(mem, compute) — double-buffered kernel

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1, self.hbm_bytes)


# --------------------------------------------------------------------- #
# Block GeMM (paper Sec 1.3: TMMA/VTA adaptation — "we need to slightly
# adapt our ILP problem").  Strategies = loop orders x tile shapes.
# --------------------------------------------------------------------- #

_ORDERS = ("mnk", "mkn", "nmk", "nkm", "kmn", "knm")   # outer->inner


def _gemm_bytes(m_t: int, n_t: int, k_t: int, bm: int, bn: int, bk: int,
                mm: int, nn: int, kk: int, order: str,
                dtype_bytes: int, acc_bytes: int) -> int:
    """HBM bytes for C[M,N] += A[M,K] B[K,N] under a loop order, counting
    Pallas revisiting: a block is re-fetched only when its index changes
    between consecutive steps (the formalism's I_slice).

    A blocks are indexed by (m,k), B by (k,n), C by (m,n).  For an operand
    whose indices are all *outside* the innermost varying loops, consecutive
    steps revisit the same block -> loaded once per distinct index tuple of
    the loops it depends on, in loop order."""
    inner = order[2]            # fastest-varying grid dim
    a_bytes = bm * bk * dtype_bytes
    b_bytes = bk * bn * dtype_bytes
    c_bytes = bm * bn * dtype_bytes

    def loads(dep: set[str]) -> int:
        """Distinct consecutive index changes for an operand depending on
        ``dep`` ⊆ {m,n,k}: product of trip counts of all loops at or outside
        the innermost loop the operand depends on."""
        trips = {"m": m_t, "n": n_t, "k": k_t}
        # position of the innermost loop this operand depends on:
        deepest = max(order.index(d) for d in dep)
        total = 1
        for pos in range(deepest + 1):
            total *= trips[order[pos]]
        return total

    total = loads({"m", "k"}) * a_bytes + loads({"k", "n"}) * b_bytes
    if order.index("k") < 2:
        # k is not innermost -> C block leaves/re-enters VMEM while partial:
        # read-modify-write per visit (except first read / last write).
        visits = loads({"m", "n"})
        total += (2 * visits - 2 * m_t * n_t) * c_bytes + \
            m_t * n_t * c_bytes          # final writes
    else:
        # output-stationary: C written once per (m,n)
        total += m_t * n_t * c_bytes
    return total


def plan_matmul(m: int, n: int, k: int, dtype_bytes: int = 2,
                chip: TpuChipModel = TPU_V5E,
                vmem_fraction: float = 0.7) -> Plan:
    """Choose (bm, bn, bk, loop order) minimising the paper's duration."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    flops = 2 * m * n * k
    cands: list[Plan] = []
    sizes = [128, 256, 512, 1024, 2048]
    for bm, bn, bk in itertools.product(sizes, repeat=3):
        bm_, bn_, bk_ = min(bm, _round_up(m, 8)), min(bn, _round_up(n, 128)), \
            min(bk, _round_up(k, 128))
        # VMEM: A + B blocks (dtype) + C accumulator (f32), double-buffered
        vmem = (2 * (bm_ * bk_ + bk_ * bn_) * dtype_bytes
                + bm_ * bn_ * 4)
        if vmem > budget:
            continue
        m_t, n_t, k_t = _ceil_div(m, bm_), _ceil_div(n, bn_), _ceil_div(k, bk_)
        for order in _ORDERS:
            hbm = _gemm_bytes(m_t, n_t, k_t, bm_, bn_, bk_, m, n, k,
                              order, dtype_bytes, dtype_bytes)
            t_mem = hbm / chip.hbm_bw
            t_cmp = flops / chip.peak_flops
            cands.append(Plan(
                kind="matmul", tiles={"bm": bm_, "bn": bn_, "bk": bk_},
                order=order, steps=m_t * n_t * k_t, hbm_bytes=hbm,
                flops=flops, vmem_bytes=vmem,
                duration_additive=t_mem + t_cmp,
                duration_overlapped=max(t_mem, t_cmp)))
    if not cands:
        raise ValueError("no tile fits VMEM")
    return min(cands, key=lambda p: (p.duration_overlapped,
                                     p.duration_additive, p.steps))


# --------------------------------------------------------------------- #
# Decode attention: S1 with roles swapped — Q is the resident "kernel set",
# KV blocks are the patches (disjoint, stride == block -> no halo).
# --------------------------------------------------------------------- #

def plan_decode_attention(seq_len: int, head_dim: int, q_rows: int,
                          dtype_bytes: int = 2,
                          chip: TpuChipModel = TPU_V5E,
                          vmem_fraction: float = 0.7) -> Plan:
    budget = int(chip.vmem_bytes * vmem_fraction)
    flops = 4 * q_rows * seq_len * head_dim      # QK^T + PV
    best: Plan | None = None
    bkv = 128
    while bkv <= max(128, min(seq_len, 8192)):
        # resident: q, acc, m, l; streamed: K,V double-buffered
        vmem = (q_rows * head_dim * dtype_bytes
                + q_rows * head_dim * 4 + 2 * q_rows * 4
                + 2 * 2 * bkv * head_dim * dtype_bytes)
        if vmem <= budget and seq_len % bkv == 0:
            steps = seq_len // bkv
            hbm = 2 * seq_len * head_dim * dtype_bytes \
                + 2 * q_rows * head_dim * dtype_bytes
            t_mem = hbm / chip.hbm_bw
            t_cmp = flops / chip.peak_flops
            cand = Plan(kind="decode_attention", tiles={"bkv": bkv},
                        order="kv", steps=steps, hbm_bytes=hbm, flops=flops,
                        vmem_bytes=vmem,
                        duration_additive=t_mem + t_cmp,
                        duration_overlapped=max(t_mem, t_cmp))
            # bytes are block-size independent here; prefer fewer steps
            # (lower per-step overhead = fewer t_acc terms in paper units)
            if best is None or cand.steps < best.steps:
                best = cand
        bkv *= 2
    if best is None:
        raise ValueError("no KV block fits VMEM")
    return best


# --------------------------------------------------------------------- #
# Convolution (the paper's own operator): rectangular S1 strategies.
# --------------------------------------------------------------------- #

def plan_conv(spec: ConvSpec, dtype_bytes: int = 2,
              chip: TpuChipModel = TPU_V5E,
              vmem_fraction: float = 0.7,
              max_run: int = 64) -> Plan:
    """Pick the row-run length T for the Pallas conv kernel: each grid step
    computes a (1 x T) run of output columns for all C_out channels, with
    all kernels VMEM-resident (S1).  Cost = paper eq. 15 with halo-aware
    I_slice; evaluated exactly via the strategy bitmasks."""
    budget = int(chip.vmem_bytes * vmem_fraction)
    flops = 2 * spec.macs_total
    best: Plan | None = None
    for t in range(1, min(max_run, spec.w_out) + 1):
        t_in = (t - 1) * spec.s_w + spec.w_k
        vmem = (spec.kernel_elements * dtype_bytes          # resident Λ
                + 2 * spec.c_in * spec.h_k * t_in * dtype_bytes
                + spec.c_out * t * 4)
        if vmem > budget:
            continue
        strat = tiled_strategy(spec, t, tile=(1, t))
        pixels = strat.pixels_loaded()
        hbm = (pixels * spec.c_in + spec.kernel_elements
               + spec.num_patches * spec.c_out) * dtype_bytes
        steps = strat.n_steps
        t_mem = hbm / chip.hbm_bw
        t_cmp = flops / chip.peak_flops
        cand = Plan(kind="conv2d", tiles={"t": t}, order="zigzag",
                    steps=steps, hbm_bytes=hbm, flops=flops, vmem_bytes=vmem,
                    duration_additive=t_mem + t_cmp,
                    duration_overlapped=max(t_mem, t_cmp))
        if best is None or (cand.duration_overlapped, cand.steps) < \
                (best.duration_overlapped, best.steps):
            best = cand
    if best is None:
        raise ValueError("conv does not fit VMEM at any run length")
    return best
