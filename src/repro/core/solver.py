"""Strategy optimisation (paper Sec 5 + Sec 7.1 solver setup).

The paper solves the MILP with CPLEX, warm-started from the best of
ZigZag/Row-by-Row ("MIP Start") and switched to "Solution Polishing" after
60 s.  CPLEX is unavailable offline, so we reproduce the *method*:

  1. heuristic seeds: Row-by-Row, ZigZag (paper) + Tiled, Hilbert (ours);
  2. a polishing local search over ordered patch partitions — simulated
     annealing with bitmask-incremental cost evaluation (this plays the role
     of CPLEX's genetic polishing, seeded exactly like their MIP start);
  3. the exact MILP (Sec 5) via HiGHS (`scipy.optimize.milp`) with a time
     limit, when the model is small enough;
  4. the analytic lower bound, so optimality gaps are always reported.

The search space is restricted to K = K_min groups (Sec 7.1).
"""
from __future__ import annotations

import atexit
import concurrent.futures
import dataclasses
import functools
import multiprocessing
import os
import random
import sys
from typing import Sequence

import numpy as np

from repro.core import ilp as ilp_mod
from repro.core import strategies_s2 as s2_mod
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import (
    GroupedStrategy, best_heuristic, hilbert, k_min, lower_bound,
    row_by_row, tiled, zigzag)


@dataclasses.dataclass
class SolveResult:
    strategy: GroupedStrategy | s2_mod.S2Strategy
    objective: float            # eq. 15 (S1) / full-load objective (S2)
    lower_bound: float
    seed_objective: float       # best heuristic (the MIP start)
    milp_status: str            # "optimal" | "feasible" | "skipped" | "infeasible" | "s2_fallback"
    milp_objective: float | None
    polish_objective: float
    reload_ok: bool             # satisfies nb_data_reload
    mode: str = "s1"            # "s1" | "s2" (kernel-group swapping)

    @property
    def gap(self) -> float:
        if self.lower_bound <= 0:
            return 0.0
        return self.objective / self.lower_bound - 1.0

    @property
    def gain_vs_seed(self) -> float:
        """Paper Fig 13 metric: relative gain over best heuristic."""
        if self.seed_objective == 0:
            return 0.0
        return 1.0 - self.objective / self.seed_objective


# --------------------------------------------------------------------- #
# Polishing local search
# --------------------------------------------------------------------- #

_RELOAD_PENALTY = 10_000.0

_EMPTY_IDX = np.empty(0, dtype=np.int64)


def _mask_to_indices(mask: int, num_pixels: int) -> np.ndarray:
    """Vectorised bitmask -> sorted pixel-index array (the polish hot path:
    one unpackbits instead of a Python loop over set bits)."""
    if mask == 0:
        return _EMPTY_IDX
    buf = mask.to_bytes((num_pixels + 7) // 8, "little")
    bits = np.unpackbits(np.frombuffer(buf, dtype=np.uint8),
                         bitorder="little")
    return np.flatnonzero(bits[:num_pixels])


class _SearchState:
    """Ordered partition with O(affected-groups) incremental cost."""

    def __init__(self, spec: ConvSpec, groups: Sequence[Sequence[int]],
                 p: int, nb_data_reload: int):
        self.spec = spec
        self.p = p
        self.r = nb_data_reload
        self.groups: list[list[int]] = [list(g) for g in groups]
        self.k = len(self.groups)
        self.gmask = [spec.group_mask(g) for g in self.groups]
        self.loads = np.zeros(spec.num_pixels, dtype=np.int32)
        self.total_load = 0
        for kk in range(self.k):
            isl = self._islice(kk)
            self.total_load += isl.bit_count()
            self.loads[_mask_to_indices(isl, spec.num_pixels)] += 1
        self.violations = int(np.maximum(self.loads - self.r, 0).sum())

    def _islice(self, kk: int) -> int:
        prev = self.gmask[kk - 1] if kk > 0 else 0
        return self.gmask[kk] & ~prev

    def cost(self) -> float:
        return self.total_load + _RELOAD_PENALTY * self.violations

    # -- incremental update of steps' I_slices after group masks change --
    def _refresh_islices(self, ks: Sequence[int], old_islices: dict[int, int]):
        npix = self.spec.num_pixels
        for kk in ks:
            old = old_islices[kk]
            new = self._islice(kk)
            if old == new:
                continue
            gone, came = old & ~new, new & ~old
            self.total_load += came.bit_count() - gone.bit_count()
            gi = _mask_to_indices(gone, npix)
            if gi.size:
                self.violations -= int((self.loads[gi] > self.r).sum())
                self.loads[gi] -= 1
            ci = _mask_to_indices(came, npix)
            if ci.size:
                self.loads[ci] += 1
                self.violations += int((self.loads[ci] > self.r).sum())

    def _affected(self, ks: Sequence[int]) -> list[int]:
        out = set()
        for kk in ks:
            out.add(kk)
            if kk + 1 < self.k:
                out.add(kk + 1)
        return sorted(out)

    def _snapshot(self, ks: Sequence[int]) -> dict[int, int]:
        return {kk: self._islice(kk) for kk in ks}

    # -- moves: each returns an undo closure ------------------------------
    def move_swap_patches(self, a: int, ia: int, b: int, ib: int):
        ks = self._affected([a, b])
        snap = self._snapshot(ks)
        ga, gb = self.groups[a], self.groups[b]
        ga[ia], gb[ib] = gb[ib], ga[ia]
        self.gmask[a] = self.spec.group_mask(ga)
        self.gmask[b] = self.spec.group_mask(gb)
        self._refresh_islices(ks, snap)

        def undo():
            snap2 = self._snapshot(ks)
            ga[ia], gb[ib] = gb[ib], ga[ia]
            self.gmask[a] = self.spec.group_mask(ga)
            self.gmask[b] = self.spec.group_mask(gb)
            self._refresh_islices(ks, snap2)
        return undo

    def move_relocate(self, a: int, ia: int, b: int):
        """Move one patch from group a (|a|>1) to group b (|b|<p)."""
        ks = self._affected([a, b])
        snap = self._snapshot(ks)
        pid = self.groups[a].pop(ia)
        self.groups[b].append(pid)
        self.gmask[a] = self.spec.group_mask(self.groups[a])
        self.gmask[b] = self.spec.group_mask(self.groups[b])
        self._refresh_islices(ks, snap)

        def undo():
            snap2 = self._snapshot(ks)
            self.groups[b].pop()
            self.groups[a].insert(ia, pid)
            self.gmask[a] = self.spec.group_mask(self.groups[a])
            self.gmask[b] = self.spec.group_mask(self.groups[b])
            self._refresh_islices(ks, snap2)
        return undo

    def move_reverse(self, a: int, b: int):
        """2-opt on the group order: reverse segment [a, b]."""
        ks = self._affected(range(a, b + 1))
        snap = self._snapshot(ks)
        self.groups[a:b + 1] = self.groups[a:b + 1][::-1]
        self.gmask[a:b + 1] = self.gmask[a:b + 1][::-1]
        self._refresh_islices(ks, snap)

        def undo():
            snap2 = self._snapshot(ks)
            self.groups[a:b + 1] = self.groups[a:b + 1][::-1]
            self.gmask[a:b + 1] = self.gmask[a:b + 1][::-1]
            self._refresh_islices(ks, snap2)
        return undo

    def strategy(self, name: str = "polished") -> GroupedStrategy:
        return GroupedStrategy(
            name, self.spec, tuple(tuple(g) for g in self.groups if g))


def polish(seed: GroupedStrategy, p: int, hw: HardwareModel,
           nb_data_reload: int = 2, iters: int = 30_000,
           rng_seed: int = 0) -> GroupedStrategy:
    """Simulated-annealing polish of a seed strategy (our stand-in for
    CPLEX solution polishing).  Keeps K fixed (= len(seed.groups))."""
    spec = seed.spec
    st = _SearchState(spec, seed.groups, p, nb_data_reload)
    rng = random.Random(rng_seed)
    best_cost = st.cost()
    best = st.strategy()
    cur = best_cost
    t0, t1 = max(2.0, best_cost * 0.02), 0.05
    for it in range(iters):
        temp = t0 * (t1 / t0) ** (it / max(1, iters - 1))
        kind = rng.random()
        if st.k < 2:
            break
        if kind < 0.45:
            a, b = rng.sample(range(st.k), 2)
            if not st.groups[a] or not st.groups[b]:
                continue
            undo = st.move_swap_patches(
                a, rng.randrange(len(st.groups[a])),
                b, rng.randrange(len(st.groups[b])))
        elif kind < 0.70:
            a, b = rng.sample(range(st.k), 2)
            if len(st.groups[a]) <= 1 or len(st.groups[b]) >= p:
                continue
            undo = st.move_relocate(a, rng.randrange(len(st.groups[a])), b)
        else:
            a = rng.randrange(st.k)
            b = min(st.k - 1, a + rng.randint(1, 6))
            if a >= b:
                continue
            undo = st.move_reverse(a, b)
        new_cost = st.cost()
        if new_cost <= cur or rng.random() < np.exp(-(new_cost - cur) / temp):
            cur = new_cost
            if cur < best_cost:
                best_cost = cur
                best = st.strategy()
        else:
            undo()
    return best


def _polish_task(args) -> GroupedStrategy:
    seed, p, hw, nb_data_reload, iters, rng_seed = args
    return polish(seed, p, hw, nb_data_reload, iters=iters,
                  rng_seed=rng_seed)


_POOLS: dict[tuple[str, int], concurrent.futures.ProcessPoolExecutor] = {}
_POOLS_FINAL = False    # set by the atexit shutdown — bars resurrection


def shutdown_pools(final: bool = False) -> None:
    """Shut down the long-lived polish pools.  Registered with ``atexit``
    (so pytest / benchmark runs exit promptly instead of joining idle
    workers) and exposed as a test hook.  ``final=True`` (the atexit
    path) additionally bars later ``polish_multi`` calls from
    resurrecting a pool mid-interpreter-teardown — they run serially."""
    global _POOLS_FINAL
    if final:
        _POOLS_FINAL = True
    for pool in _POOLS.values():
        pool.shutdown(wait=False, cancel_futures=True)
    _POOLS.clear()


atexit.register(shutdown_pools, final=True)


def _pool_key(max_workers: int) -> tuple[str, int]:
    """Pool registry key: (start method, size).  Forking a process that
    already initialised jax's thread pools can deadlock, so spawn is used
    once jax is loaded — its higher startup cost is exactly what pool
    reuse amortises.  Computed once per ``polish_multi`` call so a retry
    after eviction rebuilds the same pool it evicted."""
    return ("spawn" if "jax" in sys.modules else "fork", max_workers)


def _polish_pool(key: tuple[str, int],
                 ) -> concurrent.futures.ProcessPoolExecutor:
    """Long-lived process pool for ``key`` — re-used across solve calls so
    a network plan pays worker startup once, not once per layer
    (concurrent.futures joins the workers at exit)."""
    pool = _POOLS.get(key)
    if pool is None:
        pool = concurrent.futures.ProcessPoolExecutor(
            key[1], mp_context=multiprocessing.get_context(key[0]))
        _POOLS[key] = pool
    return pool


def _evict_pool(key: tuple[str, int]) -> None:
    """Retire ONE broken pool: shut it down and drop it from the registry
    so the next request builds a fresh replacement.  Sibling pools (other
    sizes / start methods) keep their healthy workers."""
    pool = _POOLS.pop(key, None)
    if pool is not None:
        pool.shutdown(wait=False, cancel_futures=True)


def polish_multi(seed: GroupedStrategy, p: int, hw: HardwareModel,
                 nb_data_reload: int = 2, iters: int = 30_000,
                 restarts: int = 4, rng_seed: int = 0,
                 workers: int | None = None) -> GroupedStrategy:
    """Best of ``restarts`` independent polish runs from distinct rng
    streams, fanned out over a process pool (the multi-restart analogue of
    CPLEX running its polishing heuristics in parallel).  Deterministic for
    a fixed ``rng_seed``: the restart seeds are derived from it and the
    argmin over their results does not depend on scheduling order.

    A pool that dies mid-run (``BrokenProcessPool``) is evicted and
    rebuilt once; a second failure falls back to running the same tasks
    serially, so the returned strategy is identical either way."""
    if restarts <= 1:
        return polish(seed, p, hw, nb_data_reload, iters=iters,
                      rng_seed=rng_seed)
    tasks = [(seed, p, hw, nb_data_reload, iters, rng_seed + 1_000_003 * i)
             for i in range(restarts)]
    results = None
    if not _POOLS_FINAL:
        key = _pool_key(workers or min(restarts, os.cpu_count() or 1))
        for _attempt in range(2):
            try:
                results = list(_polish_pool(key).map(_polish_task, tasks))
                break
            except (OSError, concurrent.futures.process.BrokenProcessPool,
                    RuntimeError):
                _evict_pool(key)
    if results is None:
        # sandboxed / fork-restricted environments, a twice-broken pool,
        # or post-atexit: same seeds, serially
        results = [_polish_task(t) for t in tasks]
    return min(results, key=lambda s: (s.objective(hw), s.max_reloads()))


# --------------------------------------------------------------------- #
# HiGHS backend
# --------------------------------------------------------------------- #

def solve_milp(model: ilp_mod.IlpModel, time_limit: float = 60.0):
    """Solve the Sec-5 MILP with HiGHS.  Returns (strategy|None, status,
    objective|None)."""
    from scipy.optimize import Bounds, LinearConstraint, milp

    res = milp(
        c=model.c,
        constraints=LinearConstraint(model.a, model.lb, model.ub),
        integrality=np.ones(model.num_vars),
        bounds=Bounds(0, 1),
        options={"time_limit": time_limit, "presolve": True})
    if res.x is None:
        status = "infeasible" if res.status == 2 else "timeout"
        return None, status, None
    strat = model.extract_groups(np.round(res.x))
    status = "optimal" if res.status == 0 else "feasible"
    return strat, status, float(res.fun)


# --------------------------------------------------------------------- #
# Front door
# --------------------------------------------------------------------- #

def solve(spec: ConvSpec, p: int, hw: HardwareModel,
          nb_data_reload: int = 2,
          size_mem: int | None = None,
          time_limit: float = 30.0,
          polish_iters: int = 30_000,
          milp_var_limit: int = 60_000,
          use_milp: bool = True,
          rng_seed: int = 0,
          polish_restarts: int = 1,
          polish_workers: int | None = None) -> SolveResult:
    """Find the best S1 strategy for ``spec`` on ``hw`` with group size p.

    ``size_mem`` defaults to ``hw.size_mem`` (historically it was only
    forwarded to the MILP when passed explicitly, so heuristic/polished
    incumbents could silently exceed the budget): candidates whose peak
    footprint exceeds the budget are rejected, and ValueError is raised
    when no seed fits at all — shrink ``p`` (``s1_max_feasible_p``) or
    fall back to S2 (``solve_cached`` does both automatically).
    """
    if size_mem is None:
        size_mem = hw.size_mem

    def fits(s: GroupedStrategy) -> bool:
        return size_mem is None or s.peak_footprint_elements() <= size_mem

    k = k_min(spec, p)
    seeds = [row_by_row(spec, p), zigzag(spec, p),
             tiled(spec, p), hilbert(spec, p)]
    mip_start = min(seeds[:2], key=lambda s: s.objective(hw))  # paper's seed
    feasible_seeds = [s for s in seeds if fits(s)]
    if not feasible_seeds:
        raise ValueError(
            f"no S1 strategy with group size {p} fits size_mem={size_mem}")
    incumbent = min(feasible_seeds, key=lambda s: s.objective(hw))

    polished = polish_multi(incumbent, p, hw, nb_data_reload,
                            iters=polish_iters, restarts=polish_restarts,
                            rng_seed=rng_seed, workers=polish_workers)
    if polished.objective(hw) < incumbent.objective(hw) and \
            polished.max_reloads() <= max(nb_data_reload,
                                          incumbent.max_reloads()) and \
            fits(polished):
        incumbent = polished

    milp_status, milp_obj = "skipped", None
    if use_milp:
        model = ilp_mod.build_ilp(spec, p, k=k,
                                  nb_data_reload=nb_data_reload,
                                  size_mem=size_mem)
        if model.num_vars <= milp_var_limit:
            strat, milp_status, raw = solve_milp(model, time_limit)
            if strat is not None:
                milp_obj = strat.objective(hw)
                if milp_obj < incumbent.objective(hw) and fits(strat):
                    incumbent = strat
        else:
            milp_status = "skipped_too_large"

    return SolveResult(
        strategy=incumbent,
        objective=incumbent.objective(hw),
        lower_bound=lower_bound(spec, p, hw),
        seed_objective=mip_start.objective(hw),
        milp_status=milp_status,
        milp_objective=milp_obj,
        polish_objective=polished.objective(hw),
        reload_ok=incumbent.max_reloads() <= nb_data_reload)


# --------------------------------------------------------------------- #
# Memory-feasible solving: S1 with group shrinking, S2 kernel-group
# swapping as the fallback when no S1 group size fits the budget.
# --------------------------------------------------------------------- #

def s1_max_feasible_p(spec: ConvSpec, p: int, hw: HardwareModel) -> int | None:
    """Largest group size ``p' <= p`` whose contiguous (zigzag) S1 strategy
    fits ``hw.size_mem``, or None when S1 is infeasible outright — the
    kernel set Λ plus one patch exceeds the budget, or the PE cannot take
    one full patch row (S1 computes all C_out channels per step)."""
    try:
        hw.nb_patches_max_s1(spec.nb_op_value, spec.c_out)
    except ValueError:
        return None
    if hw.size_mem is None:
        return p
    for cand in range(p, 0, -1):
        if zigzag(spec, cand).peak_footprint_elements() <= hw.size_mem:
            return cand
    return None


def _plan_store():
    """(store, codec) when the persistent plan cache is configured via
    ``REPRO_PLAN_CACHE``, else (None, None).  Lazy on both the env check
    and the import: ``repro.core`` never pulls ``repro.plancache`` (or,
    transitively, ``repro.obs``) unless the layer is actually on."""
    if not os.environ.get("REPRO_PLAN_CACHE"):
        return None, None
    from repro.plancache import codec
    from repro.plancache import store as store_mod
    store = store_mod.active_store()
    if store is None:
        return None, None
    return store, codec


def _neighbor_rank(key: dict, p: int, hw: HardwareModel) -> tuple:
    """Scenario distance of a same-family cached key: budget gap first
    (the axis sweeps vary fastest), then group-size gap."""
    mem = key["hw"]["size_mem"]
    d_mem = abs(mem - hw.size_mem) if (
        mem is not None and hw.size_mem is not None) else float("inf")
    return (d_mem, abs(key.get("p", p) - p))


def _warm_s2(res: s2_mod.S2Result, spec: ConvSpec, hw: HardwareModel,
             store, codec, key: dict, fam: str) -> s2_mod.S2Result:
    """Reprice the nearest same-family cached S2 scenarios (same spec,
    neighbouring budget) as warm seeds for the annealing polish; adopt
    only a candidate that is feasible AND strictly cheaper, so the warm
    start can never make a solve worse."""
    if hw.size_mem is None:
        return res
    from repro.plancache.store import CacheCorruptionError
    ranked = sorted(store.neighbors("s2", fam, exclude_key=key),
                    key=lambda kr: _neighbor_rank(kr[0], 0, hw))
    best = res
    for _nkey, raw in ranked[:2]:
        try:
            seed = codec.s2_result_from_json(raw).strategy
        except CacheCorruptionError:
            continue
        if seed.spec != spec:
            continue
        store.warm_considered += 1
        cand = s2_mod.polish_s2(seed, hw, size_mem=hw.size_mem)
        peak = cand.peak_memory_elements()
        if peak > hw.size_mem:
            continue
        obj = cand.objective(hw)
        if obj < best.objective - 1e-9:
            best = dataclasses.replace(
                best, strategy=cand, objective=obj, peak_memory=peak,
                milp_status="warm_start")
            store.warm_adopted += 1
    return best


def _best_s2_impl(spec: ConvSpec, hw: HardwareModel) -> s2_mod.S2Result:
    """``best_s2`` behind the two cache layers (the in-memory LRU is the
    ``best_s2_cached`` binding at the bottom of this module) — the
    planner and the greedy baseline share one S2 search (seed enumeration
    + joint polish + tiny-grid order MILP) per (spec, hw).  On an LRU
    miss the persistent store is consulted; on a store miss the nearest
    cached scenario warm-starts the polish.  Raises ValueError when even
    S2 cannot fit ``hw.size_mem`` (not cached, matching lru_cache)."""
    store, codec = _plan_store()
    if store is None:
        return s2_mod.best_s2(spec, hw)
    key, fam = codec.s2_key(spec, hw)
    hit = store.get("s2", key, fam, codec.s2_result_from_json)
    if hit is not None:
        return hit
    res = _warm_s2(s2_mod.best_s2(spec, hw), spec, hw, store, codec,
                   key, fam)
    store.put("s2", key, fam, codec.s2_result_to_json(res))
    return res


def _s2_fallback_result(spec: ConvSpec, hw: HardwareModel) -> SolveResult:
    res = best_s2_cached(spec, hw)
    return SolveResult(
        strategy=res.strategy,
        objective=res.objective,
        lower_bound=s2_mod.s2_lower_bound(spec, hw),
        seed_objective=(res.seed_objective if res.seed_objective is not None
                        else res.objective),
        milp_status="s2_fallback",
        milp_objective=res.milp_objective,
        polish_objective=res.objective,
        reload_ok=True,
        mode="s2")


# --------------------------------------------------------------------- #
# Solve cache — repeated layers (ResNet stages) are solved once.
# All key components are frozen dataclasses, hence hashable.
# --------------------------------------------------------------------- #

def _s1_seed_full_duration(spec: ConvSpec, q: int, hw: HardwareModel,
                           ) -> float:
    """Cheapest budget-feasible heuristic seed at group size ``q`` under
    full Def-3 accounting (inf when none fits) — the O(num_patches)
    probe the joint (p, strategy) search scans before paying a solve."""
    best = float("inf")
    for builder in (zigzag, row_by_row):
        cand = builder(spec, q)
        if hw.size_mem is not None and \
                cand.peak_footprint_elements() > hw.size_mem:
            continue
        best = min(best, cand.full_duration(hw))
    return best


def _s2_can_beat(spec: ConvSpec, hw: HardwareModel, target: float) -> bool:
    """Analytic precheck: can ANY S2 strategy undercut ``target`` under
    full Def-3 accounting?  S2 writes back (patch, kernel) cells, so its
    duration is bounded below by ``s2_lower_bound`` plus the cell-granular
    write-back — skipping the search when the bound already loses keeps
    the joint search free on layers where S1 dominates."""
    wb = spec.num_patches * spec.c_out * hw.t_w
    return s2_mod.s2_lower_bound(spec, hw) + wb < target


def _solve_fresh(spec: ConvSpec, p: int, hw: HardwareModel,
                 nb_data_reload: int = 2,
                 time_limit: float = 30.0,
                 polish_iters: int = 30_000,
                 use_milp: bool = True,
                 rng_seed: int = 0,
                 polish_restarts: int = 1) -> SolveResult:
    """The cold joint (p, strategy) search — ``solve_cached`` with every
    cache layer peeled off (see ``_solve_cached_impl`` for layering)."""
    p_fit = s1_max_feasible_p(spec, p, hw)
    if p_fit is None:
        return _s2_fallback_result(spec, hw)
    res = solve(spec, p_fit, hw, nb_data_reload=nb_data_reload,
                time_limit=time_limit, polish_iters=polish_iters,
                use_milp=use_milp, rng_seed=rng_seed,
                polish_restarts=polish_restarts)
    if hw.size_mem is None:
        return res
    if res.strategy.peak_footprint_elements() > hw.size_mem:
        return _s2_fallback_result(spec, hw)

    best = res
    best_full = res.strategy.full_duration(hw)

    # (p) dimension: probe smaller group sizes with heuristic seeds; only
    # a probe that already beats the solved incumbent earns a full solve.
    probes = sorted({q for q in (p_fit // 2, p_fit // 4, 1)
                     if 1 <= q < p_fit})
    for q in probes:
        if _s1_seed_full_duration(spec, q, hw) >= best_full:
            continue
        cand = solve(spec, q, hw, nb_data_reload=nb_data_reload,
                     time_limit=time_limit, polish_iters=polish_iters,
                     use_milp=use_milp, rng_seed=rng_seed,
                     polish_restarts=polish_restarts)
        cand_full = cand.strategy.full_duration(hw)
        if cand.strategy.peak_footprint_elements() <= hw.size_mem and \
                cand_full < best_full:
            best, best_full = cand, cand_full

    # (strategy) dimension: the S2 alternative, searched whenever its
    # analytic bound could undercut the incumbent (always when the budget
    # shrank the S1 group — the historical comparison point).
    if p_fit < p or _s2_can_beat(spec, hw, best_full):
        try:
            s2_res = _s2_fallback_result(spec, hw)
        except ValueError:
            return best
        if s2_res.strategy.full_duration(hw) < best_full:
            best = s2_res
    return best


def _warm_solve_result(strat, spec: ConvSpec, hw: HardwareModel,
                       seed_objective: float) -> SolveResult:
    """Wrap an adopted warm-start strategy as a ``SolveResult`` (the
    bound/objective fields re-derived for the *current* scenario)."""
    if isinstance(strat, GroupedStrategy):
        return SolveResult(
            strategy=strat,
            objective=strat.objective(hw),
            lower_bound=lower_bound(spec, strat.max_group_size(), hw),
            seed_objective=seed_objective,
            milp_status="warm_start",
            milp_objective=None,
            polish_objective=strat.objective(hw),
            reload_ok=True,
            mode="s1")
    return SolveResult(
        strategy=strat,
        objective=strat.objective(hw),
        lower_bound=s2_mod.s2_lower_bound(spec, hw),
        seed_objective=seed_objective,
        milp_status="warm_start",
        milp_objective=None,
        polish_objective=strat.objective(hw),
        reload_ok=True,
        mode="s2")


def _adopt_warm_neighbors(best: SolveResult, spec: ConvSpec, p: int,
                          hw: HardwareModel, nb_data_reload: int,
                          polish_iters: int, rng_seed: int,
                          store, codec, key: dict, fam: str) -> SolveResult:
    """Delta re-planning: reprice the nearest same-family cached
    scenarios (same spec + knobs, neighbouring budget / group size) as
    warm seeds — a short polish from the cached strategy instead of a
    full search.  A candidate is adopted only when it is budget- and
    reload-feasible AND strictly cheaper under full Def-3 accounting, so
    warm starts preserve the never-worse property of the cold search."""
    if hw.size_mem is None:
        return best
    from repro.plancache.store import CacheCorruptionError
    ranked = sorted(store.neighbors("solve", fam, exclude_key=key),
                    key=lambda kr: _neighbor_rank(kr[0], p, hw))
    best_full = best.strategy.full_duration(hw)
    for _nkey, raw in ranked[:4]:
        try:
            seed = codec.solve_result_from_json(raw).strategy
        except CacheCorruptionError:
            continue
        if seed.spec != spec:
            continue
        store.warm_considered += 1
        if isinstance(seed, GroupedStrategy):
            if seed.max_group_size() > p:
                continue
            cand = polish(seed, seed.max_group_size(), hw, nb_data_reload,
                          iters=min(polish_iters, 2_000), rng_seed=rng_seed)
            if cand.peak_footprint_elements() > hw.size_mem or \
                    cand.max_reloads() > nb_data_reload:
                continue
        else:
            cand = s2_mod.polish_s2(seed, hw, size_mem=hw.size_mem,
                                    rng_seed=rng_seed)
            if cand.peak_memory_elements() > hw.size_mem:
                continue
        cand_full = cand.full_duration(hw)
        if cand_full < best_full - 1e-9:
            best = _warm_solve_result(cand, spec, hw, best.seed_objective)
            best_full = cand_full
            store.warm_adopted += 1
    return best


def _solve_cached_impl(spec: ConvSpec, p: int, hw: HardwareModel,
                       nb_data_reload: int = 2,
                       time_limit: float = 30.0,
                       polish_iters: int = 30_000,
                       use_milp: bool = True,
                       rng_seed: int = 0,
                       polish_restarts: int = 1) -> SolveResult:
    """Cached memory-feasible solve keyed on (spec, p, hw, ...) — the
    S1/S2 choice is part of the cached entry, so repeated layers resolve
    their fallback once.  ``hw.size_mem`` participates in the key via the
    frozen ``HardwareModel``.

    Two cache layers.  The in-memory LRU (the ``solve_cached`` binding at
    the bottom of this module; maxsize from ``REPRO_SOLVE_CACHE_SIZE``,
    default 256) preserves the historical ``cache_info()`` /
    ``cache_clear()`` semantics.  On an LRU miss, the persistent
    content-hashed store (``repro.plancache``, enabled by
    ``REPRO_PLAN_CACHE``) is consulted: an exact-key hit is returned
    bit-identically; a miss runs the cold search below, then tries the
    nearest same-family cached scenario as a warm seed
    (``_adopt_warm_neighbors``) and persists the winner.

    Selection rule — the joint (p, strategy) search under eq. 12: the
    largest S1 group size that fits the budget is solved; smaller group
    sizes are probed with cheap heuristic seeds and re-solved only when a
    probe undercuts the incumbent; and the S2 kernel-group-swapping
    alternative (seed + polish + tiny-grid MILP) is priced with the same
    full Def-3 accounting whenever its analytic lower bound could win.
    The cheapest feasible candidate is returned, so the result never
    loses to either single-endpoint policy (S1-at-max-p or S2-only) —
    see tests/test_s2_polish.py.  With ``size_mem=None`` (the paper's
    Sec-7.1 setting) the behaviour is unchanged: S1 at the requested
    group size.  ``solve_cached.cache_info()`` exposes the hit counters
    the network planner reports; ``cache_stats()`` snapshots every layer
    at once for per-stage delta attribution."""
    store, codec = _plan_store()
    if store is None:
        return _solve_fresh(spec, p, hw, nb_data_reload=nb_data_reload,
                            time_limit=time_limit,
                            polish_iters=polish_iters, use_milp=use_milp,
                            rng_seed=rng_seed,
                            polish_restarts=polish_restarts)
    key, fam = codec.solve_key(
        spec, p, hw, nb_data_reload=nb_data_reload, time_limit=time_limit,
        polish_iters=polish_iters, use_milp=use_milp, rng_seed=rng_seed,
        polish_restarts=polish_restarts)
    hit = store.get("solve", key, fam, codec.solve_result_from_json)
    if hit is not None:
        return hit
    best = _solve_fresh(spec, p, hw, nb_data_reload=nb_data_reload,
                        time_limit=time_limit, polish_iters=polish_iters,
                        use_milp=use_milp, rng_seed=rng_seed,
                        polish_restarts=polish_restarts)
    best = _adopt_warm_neighbors(best, spec, p, hw, nb_data_reload,
                                 polish_iters, rng_seed, store, codec,
                                 key, fam)
    store.put("solve", key, fam, codec.solve_result_to_json(best))
    return best


# --------------------------------------------------------------------- #
# Cache bindings and observability
# --------------------------------------------------------------------- #

def _resolve_cache_size() -> int | None:
    """LRU maxsize from ``REPRO_SOLVE_CACHE_SIZE`` (default 256; a value
    <= 0 means unbounded).  Sweeps that visit more than maxsize distinct
    (spec, p, hw) keys silently thrash the LRU — the eviction counts in
    the benchmark's ``--profile`` output make that visible, and this knob
    is the fix."""
    raw = os.environ.get("REPRO_SOLVE_CACHE_SIZE", "").strip()
    if not raw:
        return 256
    try:
        size = int(raw)
    except ValueError:
        return 256
    return None if size <= 0 else size


def reconfigure_caches() -> None:    # lint: public-api
    """Rebind ``solve_cached`` / ``best_s2_cached`` with the LRU size
    currently in ``REPRO_SOLVE_CACHE_SIZE``.  Both in-memory caches are
    dropped; the persistent store is untouched.  Callers that captured
    the old binding keep a working (stale-sized) cache — everything that
    resolves ``solver.solve_cached`` as an attribute sees the new one."""
    global solve_cached, best_s2_cached
    size = _resolve_cache_size()
    solve_cached = functools.lru_cache(maxsize=size)(_solve_cached_impl)
    best_s2_cached = functools.lru_cache(maxsize=size)(_best_s2_impl)


solve_cached = functools.lru_cache(maxsize=_resolve_cache_size())(
    _solve_cached_impl)
best_s2_cached = functools.lru_cache(maxsize=_resolve_cache_size())(
    _best_s2_impl)


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Point-in-time snapshot of every planner cache counter, closed
    under subtraction: ``after - before`` is the per-stage delta, which
    is how interleaved stages (solve loop, refine pass, multichip DP,
    resil re-plan) attribute hits without claiming each other's."""
    solve_hits: int = 0
    solve_misses: int = 0
    s2_hits: int = 0
    s2_misses: int = 0
    store_hits: int = 0
    store_misses: int = 0

    def __sub__(self, other: "CacheStats") -> "CacheStats":
        return CacheStats(*(a - b for a, b in
                            zip(dataclasses.astuple(self),
                                dataclasses.astuple(other))))

    @property
    def solve_calls(self) -> int:
        return self.solve_hits + self.solve_misses

    @property
    def s2_calls(self) -> int:
        return self.s2_hits + self.s2_misses


def cache_stats() -> CacheStats:
    """Current counters across both LRUs and the persistent store (zeros
    when the store is disabled).  Snapshot before a stage, subtract
    after."""
    si = solve_cached.cache_info()
    s2i = best_s2_cached.cache_info()
    store, _codec = _plan_store()
    return CacheStats(
        solve_hits=si.hits, solve_misses=si.misses,
        s2_hits=s2i.hits, s2_misses=s2i.misses,
        store_hits=store.hits if store is not None else 0,
        store_misses=store.misses if store is not None else 0)
