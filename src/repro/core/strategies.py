"""Strategies (paper Sec 4): S1-baseline, S1 grouped, Row-by-Row, ZigZag —
plus two beyond-paper group builders (Tiled, Hilbert).

A *grouped strategy* (Def 16) is an ordered partition of the patch set X into
groups ``g_1..g_n`` with ``|g_k| <= nb_patches_max_S1``.  Executing group
``g_k`` as step ``s_k`` gives, with the eager-free policy of Def 16:

    M_k.inp   = pixels(g_k)                       (exactly)
    I_slice_k = pixels(g_k) \\ pixels(g_{k-1})
    F_inp_k   = M_{k-1}.inp \\ pixels(g_k)

so the S1 objective (eq. 15) reduces to

    delta = t_l * sum_k |pixels(g_k) \\ pixels(g_{k-1})| + n * t_acc .

Outputs are written back at the *next* step (Sec 7.1 assumption), which
forces a terminal flush step s_{n+1} that frees the kernels (F^ker_n = Λ of
Def 16) and writes back the last group's outputs, leaving memory empty.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import Step


Groups = list[tuple[int, ...]]


@dataclasses.dataclass(frozen=True)
class GridMeta:
    """A grouped strategy recognised as a Pallas grid sweep.

    The S1 conv kernels iterate a ``(h_out, w_out // t_run)`` grid, one
    row-run of ``t_run`` output columns per step, rows top-to-bottom and
    column tiles in ``order`` ("zigzag" alternates direction per row,
    "row" restarts at the left edge).  When
    :meth:`GroupedStrategy.as_grid` returns this, the strategy's step
    sequence is *exactly* the kernel's grid order and
    ``kernels.emit.emit_layer_kernel`` can execute the plan.
    """

    order: str                  # "zigzag" | "row"
    t_run: int
    h_out: int
    w_out_tiles: int

    @property
    def grid(self) -> tuple[int, int]:
        return (self.h_out, self.w_out_tiles)


@dataclasses.dataclass(frozen=True)
class GroupedStrategy:
    """An ordered partition of patches into compute groups."""

    name: str
    spec: ConvSpec
    groups: tuple[tuple[int, ...], ...]

    def __post_init__(self):
        seen: set[int] = set()
        for g in self.groups:
            if not g:
                raise ValueError("empty group")
            for pid in g:
                if pid in seen:
                    raise ValueError(f"patch {pid} in two groups")
                seen.add(pid)
        if len(seen) != self.spec.num_patches:
            raise ValueError(
                f"{self.name}: groups cover {len(seen)} of "
                f"{self.spec.num_patches} patches")

    @property
    def n_steps(self) -> int:
        return len(self.groups)

    def max_group_size(self) -> int:
        return max(len(g) for g in self.groups)

    def as_grid(self) -> GridMeta | None:
        """Recognise this strategy as a kernel grid sweep, if it is one.

        Requires every group to be one row-run of a uniform ``t_run``
        dividing ``w_out``, with the runs visited in zigzag or row order
        (zigzag preferred when both match, e.g. ``h_out == 1``).
        Within-group patch order is irrelevant — steps are built from
        group *masks* — so groups are compared as sets.  Returns None
        for anything else (tiled/hilbert groups, ragged runs), which the
        emitter reports as a non-emitable plan.
        """
        spec = self.spec
        t = len(self.groups[0])
        if any(len(g) != t for g in self.groups):
            return None
        if spec.w_out % t != 0:
            return None
        tiles = spec.w_out // t
        if len(self.groups) != spec.h_out * tiles:
            return None
        got = [tuple(sorted(g)) for g in self.groups]
        for order in ("zigzag", "row"):
            want = []
            for i in range(spec.h_out):
                cols = range(tiles)
                if order == "zigzag" and i % 2 == 1:
                    cols = reversed(cols)
                for jt in cols:
                    want.append(tuple(spec.patch_id(i, jt * t + u)
                                      for u in range(t)))
            if got == want:
                return GridMeta(order=order, t_run=t, h_out=spec.h_out,
                                w_out_tiles=tiles)
        return None

    # ------------------------------------------------------------------ #
    def to_steps(self) -> list[Step]:
        """Materialise Def 16 into the Def 1/2 step sequence."""
        spec = self.spec
        all_kernels = (1 << spec.n_kernels) - 1
        steps: list[Step] = []
        prev_pix = 0
        prev_out = 0
        for k, g in enumerate(self.groups):
            need = spec.group_mask(g)
            out = 0
            for pid in g:
                out |= 1 << pid
            steps.append(Step(
                f_inp=prev_pix & ~need,
                f_ker=0,
                w=prev_out,                    # write-back at next step
                i_slice=need & ~prev_pix,
                k_sub=all_kernels if k == 0 else 0,
                out=out,
                group=tuple(g)))
            prev_pix, prev_out = need, out
        # terminal flush: empty the memory, write the last outputs back.
        steps.append(Step(f_inp=prev_pix, f_ker=all_kernels, w=prev_out))
        return steps

    # ------------------------------------------------------------------ #
    def objective(self, hw: HardwareModel) -> float:
        """Eq. 15: t_l * sum|I_slice| + n * t_acc (kernel load + writes
        excluded, as in the paper's Sec 5.4/7.1 experiments)."""
        return (hw.t_l * self.pixels_loaded()
                + self.n_steps * hw.t_acc)

    def pixels_loaded(self) -> int:
        """sum_k |pixels(g_k) \\ pixels(g_{k-1})| (spatial units)."""
        total, prev = 0, 0
        for g in self.groups:
            cur = self.spec.group_mask(g)
            total += (cur & ~prev).bit_count()
            prev = cur
        return total

    def loads_per_pixel(self) -> dict[int, int]:
        loads: dict[int, int] = {}
        prev = 0
        for g in self.groups:
            cur = self.spec.group_mask(g)
            new = cur & ~prev
            for j in self.spec.pixels_of_mask(new):
                loads[j] = loads.get(j, 0) + 1
            prev = cur
        return loads

    def max_reloads(self) -> int:
        return max(self.loads_per_pixel().values())

    def peak_input_footprint(self) -> int:
        """max_k |pixels(g_k)| in spatial units."""
        return max(self.spec.group_mask(g).bit_count() for g in self.groups)

    def peak_footprint_elements(self) -> int:
        """Upper bound on resident tensor elements during any step: the
        kernel set Λ, the largest group's input pixels (channel-expanded),
        and two groups' outputs (write-back happens at the *next* step, so
        the previous group's outputs coexist with the current one's)."""
        return (self.spec.kernel_elements
                + self.peak_input_footprint() * self.spec.c_in
                + 2 * self.max_group_size() * self.spec.c_out)

    def peak_working_set_elements(self) -> int:
        """Peak resident elements excluding output buffers — what must fit
        next to a held activation when the outputs accumulate into that
        held map instead of draining through write-backs (the producer-side
        term of the network planner's reuse fit condition)."""
        return (self.spec.kernel_elements
                + self.peak_input_footprint() * self.spec.c_in)

    def first_load_duration(self, hw: HardwareModel) -> float:
        """t_l traffic of first-time input-pixel loads — the most an
        upstream on-chip activation can ever save this strategy."""
        covered = 0
        for g in self.groups:
            covered |= self.spec.group_mask(g)
        return covered.bit_count() * hw.t_l

    # -- full Def-3 accounting (network-level planning) ----------------- #
    def kernel_load_duration(self, hw: HardwareModel) -> float:
        """t_l cost of loading Λ once (K_sub of step 1, element units)."""
        return self.spec.kernel_elements * hw.t_l

    def write_back_duration(self, hw: HardwareModel) -> float:
        """t_w cost of writing every output column back (spatial units)."""
        return self.spec.num_patches * hw.t_w

    def full_duration(self, hw: HardwareModel) -> float:
        """Def-3 duration of the materialised ``to_steps()`` sequence:
        eq. 15 plus the kernel load and output write-back that the paper's
        Sec 5.4/7.1 experiments exclude.  Matches the Sec-6 simulator
        exactly (see tests/test_network_planner.py)."""
        return (self.objective(hw) + self.kernel_load_duration(hw)
                + self.write_back_duration(hw))


# ---------------------------------------------------------------------- #
# Group builders
# ---------------------------------------------------------------------- #

def _chunks(order: Sequence[int], p: int) -> Groups:
    return [tuple(order[i:i + p]) for i in range(0, len(order), p)]


def row_by_row(spec: ConvSpec, p: int) -> GroupedStrategy:
    """Sec 7.2: group p patches sequentially, every row left->right."""
    order = list(range(spec.num_patches))           # row-major patch ids
    return GroupedStrategy("row_by_row", spec, tuple(_chunks(order, p)))


def zigzag(spec: ConvSpec, p: int) -> GroupedStrategy:
    """Sec 7.2: even rows left->right, odd rows right->left."""
    order: list[int] = []
    for i in range(spec.h_out):
        row = [spec.patch_id(i, j) for j in range(spec.w_out)]
        order.extend(row if i % 2 == 0 else row[::-1])
    return GroupedStrategy("zigzag", spec, tuple(_chunks(order, p)))


def s1_baseline(spec: ConvSpec) -> GroupedStrategy:
    """Def 12: one patch per step (order unspecified in [23]; row-major)."""
    order = list(range(spec.num_patches))
    return GroupedStrategy("s1_baseline", spec, tuple(_chunks(order, 1)))


def tiled(spec: ConvSpec, p: int,
          tile: tuple[int, int] | None = None) -> GroupedStrategy:
    """Beyond-paper: rectangular th x tw patch tiles (halo-minimizing).

    A fresh tile loads ``(th*s_h + h_k - s_h) * (tw*s_w + w_k - s_w)``
    pixels; square-ish tiles minimise the halo perimeter.  Tiles are visited
    in zigzag order over the tile grid so vertically/horizontally adjacent
    tiles share a halo.  If ``tile`` is None, all factor pairs with
    ``th*tw <= p`` are evaluated *exactly* (bitmask cost) and the best kept.
    """
    if tile is not None:
        cands = [tile]
    else:
        cands = [(th, tw) for th in range(1, p + 1)
                 for tw in range(1, p + 1) if th * tw <= p]
    best: GroupedStrategy | None = None
    for th, tw in cands:
        groups: Groups = []
        n_tile_rows = -(-spec.h_out // th)
        n_tile_cols = -(-spec.w_out // tw)
        for tr in range(n_tile_rows):
            cols = range(n_tile_cols)
            if tr % 2 == 1:
                cols = reversed(cols)
            for tc in cols:
                g = [spec.patch_id(i, j)
                     for i in range(tr * th, min((tr + 1) * th, spec.h_out))
                     for j in range(tc * tw, min((tc + 1) * tw, spec.w_out))]
                groups.append(tuple(g))
        cand = GroupedStrategy(f"tiled_{th}x{tw}", spec, tuple(groups))
        if best is None or cand.pixels_loaded() + cand.n_steps < \
                best.pixels_loaded() + best.n_steps:
            best = cand
    if best is None:
        raise ValueError(f"tiled: no tile shape admits p={p} patches")
    return best


def _hilbert_d2xy(order: int, d: int) -> tuple[int, int]:
    """Hilbert curve index -> (x, y) on a 2**order square grid."""
    rx = ry = 0
    x = y = 0
    t = d
    s = 1
    while s < (1 << order):
        rx = 1 & (t // 2)
        ry = 1 & (t ^ rx)
        if ry == 0:
            if rx == 1:
                x, y = s - 1 - x, s - 1 - y
            x, y = y, x
        x += s * rx
        y += s * ry
        t //= 4
        s *= 2
    return x, y


def hilbert(spec: ConvSpec, p: int) -> GroupedStrategy:
    """Beyond-paper: patches ordered along a Hilbert space-filling curve."""
    side = max(spec.h_out, spec.w_out)
    order_bits = max(1, (side - 1).bit_length())
    n = 1 << order_bits
    order: list[int] = []
    for d in range(n * n):
        x, y = _hilbert_d2xy(order_bits, d)
        if y < spec.h_out and x < spec.w_out:
            order.append(spec.patch_id(y, x))
    return GroupedStrategy("hilbert", spec, tuple(_chunks(order, p)))


HEURISTICS: dict[str, Callable[[ConvSpec, int], GroupedStrategy]] = {
    "row_by_row": row_by_row,
    "zigzag": zigzag,
    "tiled": tiled,
    "hilbert": hilbert,
}


def best_heuristic(spec: ConvSpec, p: int, hw: HardwareModel,
                   names: Iterable[str] = ("row_by_row", "zigzag"),
                   ) -> GroupedStrategy:
    """Best of the named heuristics under eq. 15 (the paper's MIP start)."""
    cands = [HEURISTICS[n](spec, p) for n in names]
    return min(cands, key=lambda s: s.objective(hw))


def nb_patches_max_s1(spec: ConvSpec, hw: HardwareModel) -> int:
    return hw.nb_patches_max_s1(spec.nb_op_value, spec.c_out)


def k_min(spec: ConvSpec, p: int) -> int:
    """Def 14."""
    return -(-spec.num_patches // p)


def k_max(spec: ConvSpec) -> int:  # lint: public-api
    """Def 15."""
    return spec.num_patches


def lower_bound(spec: ConvSpec, p: int, hw: HardwareModel) -> float:
    """Analytic lower bound on eq. 15 (beyond-paper reporting):
    every needed pixel is loaded at least once and there are at least
    K_min steps."""
    return (hw.t_l * spec.all_pixels_mask.bit_count()
            + k_min(spec, p) * hw.t_acc)
