"""S2: offloading strategies that do NOT keep all kernels on-chip — the
paper's stated future work (Sec 9: "strategies that operate at a finer
granularity than patches and do not assume that all kernels are stored in
on-chip memory during computation"), expressed in the same Def 1/2
formalism.

A step computes a (patch group, kernel group) pair: output *units* are
(patch, kernel-group) cells, ``out`` ids = pid * G + g for G kernel groups.
Two canonical orders trade input reloads against kernel reloads — exactly
the weight-stationary / output-stationary dataflow choice of the GeMM
planner:

  * ``kernel_major`` (weight-stationary): for each kernel group, sweep all
    patch groups — kernels loaded once each, input reloaded G times;
  * ``patch_major`` (input-stationary): for each patch group, cycle the
    kernel groups — input loaded once (plus halos), kernels reloaded
    n_patch_groups times.

Why S2 matters: S1 *requires* size_MEM ≥ all kernels + a patch + outputs;
S2 runs under arbitrarily small kernel budgets.  ``best_s2`` searches
(kernel-group size × order) under a memory cap and the PE budget —
a concrete optimizer for the paper's future-work regime.

The search runs in three stages (mirroring ``core.solver`` for S1):

  1. *seed enumeration* — every kernel-group size 1..N (ragged final
     group allowed) × both canonical orders × a few patch-group sizes,
     priced with closed-form formulas (no schedule materialised), so the
     enumeration is O(candidates) instead of O(candidates × cells);
  2. *polish* — a simulated-annealing search over the joint space of
     schedule order × patch partition × ragged kernel partition
     (``polish_s2``), the Sec-5 polishing discipline ported to S2.  The
     cost is maintained through a symmetric consecutive-overlap matrix
     (load cost = constant − overlaps), so order moves are O(1) and
     partition moves are one vectorised numpy rebuild;
  3. an exact schedule-*order* MILP for tiny grids (``ilp.build_s2_order_ilp``
     via HiGHS), so optimality gaps stay reported on small instances.
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import Iterable, Sequence

import numpy as np

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import Step
from repro.core.strategies import zigzag

# Polish budget for the S2 annealing search; ``tests/conftest.py`` caps it
# and REPRO_S2_POLISH_ITERS overrides (the S2 analogue of REPRO_FULL_POLISH).
DEFAULT_POLISH_ITERS = int(os.environ.get("REPRO_S2_POLISH_ITERS", "3000"))

# grids with at most this many (patch-group, kernel-group) cells get the
# exact schedule-order MILP on top of the polish
S2_MILP_MAX_CELLS = 9


def _chunks(seq, n):
    return [tuple(seq[i:i + n]) for i in range(0, len(seq), n)]


@dataclasses.dataclass(frozen=True)
class S2Strategy:
    """Ordered (patch-group, kernel-group-index) schedule."""

    name: str
    spec: ConvSpec
    kernel_groups: tuple[tuple[int, ...], ...]
    schedule: tuple[tuple[tuple[int, ...], int], ...]   # ((patch ids), kg)

    def __post_init__(self):
        seen: set[tuple[int, int]] = set()
        for g, kg in self.schedule:
            for pid in g:
                for kid in self.kernel_groups[kg]:
                    cell = (pid, kid)
                    if cell in seen:
                        raise ValueError(f"{cell} computed twice")
                    seen.add(cell)
        want = self.spec.num_patches * self.spec.n_kernels
        if len(seen) != want:
            raise ValueError(
                f"{self.name}: covers {len(seen)} of {want} cells")

    @property
    def n_steps(self) -> int:
        return len(self.schedule)

    @property
    def n_kernel_groups(self) -> int:
        return len(self.kernel_groups)

    def out_unit(self, pid: int, kg: int) -> int:
        return pid * self.n_kernel_groups + kg

    # ------------------------------------------------------------------ #
    def to_steps(self) -> list[Step]:
        """Def-16-style eager-free semantics for BOTH inputs and kernels;
        outputs written back at the next step."""
        spec = self.spec
        steps: list[Step] = []
        res_pix = 0
        res_ker = 0
        prev_out = 0
        for g, kg in self.schedule:
            need_pix = spec.group_mask(g)
            need_ker = 0
            for kid in self.kernel_groups[kg]:
                need_ker |= 1 << kid
            out = 0
            for pid in g:
                out |= 1 << self.out_unit(pid, kg)
            steps.append(Step(
                f_inp=res_pix & ~need_pix,
                f_ker=res_ker & ~need_ker,
                w=prev_out,
                i_slice=need_pix & ~res_pix,
                k_sub=need_ker & ~res_ker,
                out=out,
                group=tuple(g),
                kernel_group=self.kernel_groups[kg]))
            res_pix, res_ker, prev_out = need_pix, need_ker, out
        steps.append(Step(f_inp=res_pix, f_ker=res_ker, w=prev_out))
        return steps

    # ------------------------------------------------------------------ #
    def objective(self, hw: HardwareModel) -> float:
        """Full Def-3 duration: unlike S1 (eq. 15), kernel loads COUNT —
        trading them against input reloads is the whole point of S2."""
        spec = self.spec
        total = 0.0
        res_pix = res_ker = 0
        kelem = spec.c_in * spec.h_k * spec.w_k
        for g, kg in self.schedule:
            need_pix = spec.group_mask(g)
            need_ker = 0
            for kid in self.kernel_groups[kg]:
                need_ker |= 1 << kid
            total += (need_pix & ~res_pix).bit_count() * hw.t_l
            total += (need_ker & ~res_ker).bit_count() * kelem * hw.t_l
            total += hw.t_acc
            res_pix, res_ker = need_pix, need_ker
        return total

    def peak_memory_elements(self) -> int:
        """Max on-chip elements during any step (inputs + kernels + the
        step's output cells + the previous step's not-yet-written cells)."""
        spec = self.spec
        kelem = spec.c_in * spec.h_k * spec.w_k
        peak = 0
        prev_out_elems = 0
        for g, kg in self.schedule:
            pix = spec.group_mask(g).bit_count() * spec.c_in
            ker = len(self.kernel_groups[kg]) * kelem
            out = len(g) * len(self.kernel_groups[kg])
            peak = max(peak, pix + ker + out + prev_out_elems)
            prev_out_elems = out
        return peak

    # -- strategy protocol (shared with strategies.GroupedStrategy) ------ #
    def max_group_size(self) -> int:
        return max(len(g) for g, _ in self.schedule)

    def peak_footprint_elements(self) -> int:
        """Protocol alias: peak resident elements during any step."""
        return self.peak_memory_elements()

    def peak_working_set_elements(self) -> int:
        """Peak resident elements excluding output buffers: the largest
        (input pixels + swapped kernel group) of any step — what must fit
        next to a held activation on the producer side."""
        spec = self.spec
        kelem = spec.c_in * spec.h_k * spec.w_k
        return max(spec.group_mask(g).bit_count() * spec.c_in
                   + len(self.kernel_groups[kg]) * kelem
                   for g, kg in self.schedule)

    def write_back_duration(self, hw: HardwareModel) -> float:
        """t_w cost of writing every (patch, kernel) output cell back —
        S2 drains outputs at cell granularity (cf. sim.s2.run_s2)."""
        return self.spec.num_patches * self.spec.c_out * hw.t_w

    def full_duration(self, hw: HardwareModel) -> float:
        """Def-3 duration of the materialised schedule.  The S2 objective
        already includes kernel (re)loads, so only write-backs are added;
        matches ``sim.s2.run_s2`` exactly (tests/test_s2_sim.py)."""
        return self.objective(hw) + self.write_back_duration(hw)

    def first_load_duration(self, hw: HardwareModel) -> float:
        """t_l traffic of first-time input-pixel loads (reloads beyond the
        first still hit DRAM even under inter-layer reuse)."""
        covered = 0
        for g, _ in self.schedule:
            covered |= self.spec.group_mask(g)
        return covered.bit_count() * hw.t_l


# --------------------------------------------------------------------- #
# Builders
# --------------------------------------------------------------------- #

def _kernel_groups(spec: ConvSpec, kg_size: int):
    return tuple(_chunks(list(range(spec.n_kernels)), kg_size))


def kernel_major(spec: ConvSpec, p: int, kg_size: int) -> S2Strategy:
    """Weight-stationary: kernels loaded once each; input swept per group."""
    kgs = _kernel_groups(spec, kg_size)
    patch_groups = [tuple(g) for g in zigzag(spec, p).groups]
    sched = [(g, kg) for kg in range(len(kgs)) for g in patch_groups]
    return S2Strategy(f"s2_kernel_major_kg{kg_size}", spec, kgs,
                      tuple(sched))


def patch_major(spec: ConvSpec, p: int, kg_size: int) -> S2Strategy:
    """Input-stationary: each patch group stays while kernel groups cycle."""
    kgs = _kernel_groups(spec, kg_size)
    patch_groups = [tuple(g) for g in zigzag(spec, p).groups]
    sched = [(g, kg) for g in patch_groups for kg in range(len(kgs))]
    return S2Strategy(f"s2_patch_major_kg{kg_size}", spec, kgs,
                      tuple(sched))


def nb_patches_max_s2(spec: ConvSpec, hw: HardwareModel,  # lint: public-api
                      kg_size: int) -> int:
    """PE budget per step with only kg_size output channels computed."""
    cap = hw.nbop_pe // (spec.nb_op_value * kg_size)
    if cap < 1:
        raise ValueError("PE cannot fit one patch x kernel-group step")
    return cap


def s2_lower_bound(spec: ConvSpec, hw: HardwareModel) -> float:
    """Analytic lower bound on the S2 objective: every needed pixel and
    every kernel element loaded at least once, and at least enough steps to
    push all (patch, kernel) cells through the PE."""
    cells = spec.num_patches * spec.n_kernels
    cells_per_step = max(1, hw.nbop_pe // spec.nb_op_value)
    min_steps = -(-cells // cells_per_step)
    return (hw.t_l * (spec.all_pixels_mask.bit_count() + spec.kernel_elements)
            + min_steps * hw.t_acc)


@dataclasses.dataclass
class S2Result:
    strategy: S2Strategy
    objective: float
    peak_memory: int
    feasible_s1: bool        # could S1 have run under this memory cap?
    seed_strategy: S2Strategy | None = None   # best enumerated, no polish
    seed_objective: float | None = None
    milp_status: str = "skipped"              # exact order MILP (tiny grids)
    milp_objective: float | None = None

    @property
    def gain_vs_seed(self) -> float:
        """Polish + MILP gain over the enumeration winner (Fig-13 style)."""
        if not self.seed_objective:
            return 0.0
        return 1.0 - self.objective / self.seed_objective


# --------------------------------------------------------------------- #
# Seed enumeration: closed-form pricing of the canonical orders
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class _ZigProfile:
    """Per-p reusable terms of the zigzag patch-group sequence."""

    p: int
    cnt: tuple[int, ...]        # |pixels(g_i)|
    glen: tuple[int, ...]       # |g_i|
    zig_loads: int              # pixels loaded sweeping g_0..g_{m-1} once
    cross: int                  # |pixels(g_0) \ pixels(g_{m-1})|


def _zig_profile(spec: ConvSpec, p: int) -> _ZigProfile:
    groups = zigzag(spec, p).groups
    masks = [spec.group_mask(g) for g in groups]
    cnt = tuple(m.bit_count() for m in masks)
    glen = tuple(len(g) for g in groups)
    loads = cnt[0] + sum((masks[i] & ~masks[i - 1]).bit_count()
                         for i in range(1, len(masks)))
    cross = (masks[0] & ~masks[-1]).bit_count()
    return _ZigProfile(p, cnt, glen, loads, cross)


def _kg_lens(n_kernels: int, kg_size: int) -> np.ndarray:
    """Kernel-group sizes for a ragged chunking (final group may be short)."""
    full, rest = divmod(n_kernels, kg_size)
    lens = [kg_size] * full + ([rest] if rest else [])
    return np.asarray(lens, dtype=np.int64)


def _price_candidate(spec: ConvSpec, hw: HardwareModel, prof: _ZigProfile,
                     ks: np.ndarray, order: str) -> tuple[float, int]:
    """(objective, peak_elements) of ``kernel_major``/``patch_major`` at
    patch-group size ``prof.p`` and kernel-group sizes ``ks`` — closed
    form, no schedule materialised (verified against the built strategies
    in tests/test_s2_polish.py)."""
    kelem = spec.c_in * spec.h_k * spec.w_k
    m, g_count = len(prof.cnt), len(ks)
    cnt = np.asarray(prof.cnt, dtype=np.int64)
    glen = np.asarray(prof.glen, dtype=np.int64)
    steps = m * g_count
    out = glen[:, None] * ks[None, :]                     # (m, G)
    base = cnt[:, None] * spec.c_in + ks[None, :] * kelem
    prev = np.zeros_like(out)
    if order == "kernel_major":
        # every sweep reloads its kernel group once; the input is re-swept
        # per sweep (first sweep pays the full zigzag loads, later sweeps
        # pay the wrap-around transition plus the zigzag interior)
        pix = prof.zig_loads + (g_count - 1) * (
            prof.cross + prof.zig_loads - prof.cnt[0])
        ker_ids = spec.n_kernels
        prev[1:, :] = out[:-1, :]
        prev[0, 1:] = glen[-1] * ks[:-1]
    else:
        # input loaded once along the zigzag; kernels recycle per patch
        # group (unless there is a single kernel group, which stays put)
        pix = prof.zig_loads
        ker_ids = spec.n_kernels if g_count == 1 else m * spec.n_kernels
        prev[:, 1:] = glen[:, None] * ks[None, :-1]
        prev[1:, 0] = glen[:-1] * ks[-1]
    obj = hw.t_l * pix + hw.t_l * kelem * ker_ids + steps * hw.t_acc
    peak = int((base + out + prev).max())
    return obj, peak


def _s1_min_mem(spec: ConvSpec) -> int:
    return (spec.kernel_elements
            + spec.patch_masks[0].bit_count() * spec.c_in + spec.c_out)


def enumerate_s2_seed(spec: ConvSpec, hw: HardwareModel,
                      size_mem: int | None,
                      kg_sizes: Iterable[int] | None = None,
                      ) -> tuple[S2Strategy, float, int] | None:
    """Best (builder, p, kernel-group size) under the caps, priced closed
    form; only the winner is materialised.  Ragged final kernel groups are
    included — every kg size 1..N is admissible, not just divisors."""
    if kg_sizes is None:
        kg_sizes = range(1, spec.n_kernels + 1)
    profiles: dict[int, _ZigProfile] = {}
    best = None            # (obj, order, p, kg, peak)
    for kg in kg_sizes:
        if not 1 <= kg <= spec.n_kernels:
            continue
        cap = hw.nbop_pe // (spec.nb_op_value * kg)
        if cap < 1:
            continue       # PE cannot take one (patch x kernel-group) step
        p_max = min(cap, spec.num_patches)
        ks = _kg_lens(spec.n_kernels, kg)
        for p in sorted({p_max, max(1, p_max // 2), max(1, p_max // 4),
                         4, 2, 1}):
            if p > p_max:
                continue
            prof = profiles.get(p)
            if prof is None:
                prof = profiles[p] = _zig_profile(spec, p)
            for order in ("kernel_major", "patch_major"):
                obj, peak = _price_candidate(spec, hw, prof, ks, order)
                if size_mem is not None and peak > size_mem:
                    continue
                if best is None or obj < best[0]:
                    best = (obj, order, p, kg, peak)
    if best is None:
        return None
    obj, order, p, kg, peak = best
    builder = kernel_major if order == "kernel_major" else patch_major
    strat = builder(spec, p, kg)
    return strat, strat.objective(hw), strat.peak_memory_elements()


def best_s2(spec: ConvSpec, hw: HardwareModel,
            size_mem: int | None = None,
            kg_sizes: Iterable[int] | None = None,
            polish_iters: int | None = None,
            rng_seed: int = 0,
            use_milp: bool = True,
            milp_time_limit: float = 2.0) -> S2Result:
    """Search (kernel-group size x order x patch-group size) under the
    memory cap, then polish the winner over the joint schedule space and,
    on tiny grids, certify the order with an exact MILP.  The S1
    comparison records whether the cap even admits an S1 strategy."""
    size_mem = size_mem if size_mem is not None else hw.size_mem
    seed = enumerate_s2_seed(spec, hw, size_mem, kg_sizes)
    if seed is None:
        raise ValueError(f"no S2 strategy fits size_mem={size_mem}")
    seed_strat, seed_obj, seed_peak = seed
    feasible_s1 = size_mem is None or _s1_min_mem(spec) <= size_mem

    if polish_iters is None:
        polish_iters = DEFAULT_POLISH_ITERS
    best_strat, best_obj, best_peak = seed_strat, seed_obj, seed_peak
    if polish_iters > 0:
        pol = polish_s2(seed_strat, hw, size_mem=size_mem,
                        iters=polish_iters, rng_seed=rng_seed)
        pol_obj = pol.objective(hw)
        pol_peak = pol.peak_memory_elements()
        if pol_obj < best_obj and (size_mem is None or pol_peak <= size_mem):
            best_strat, best_obj, best_peak = pol, pol_obj, pol_peak

    milp_status, milp_obj = "skipped", None
    if use_milp and best_strat.n_steps <= S2_MILP_MAX_CELLS:
        milp_strat, milp_status = milp_order_s2(
            best_strat, hw, size_mem=size_mem, time_limit=milp_time_limit)
        if milp_strat is not None:
            milp_obj = milp_strat.objective(hw)
            if milp_obj < best_obj and (
                    size_mem is None
                    or milp_strat.peak_memory_elements() <= size_mem):
                best_strat, best_obj = milp_strat, milp_obj
                best_peak = milp_strat.peak_memory_elements()

    return S2Result(best_strat, best_obj, best_peak,
                    feasible_s1=feasible_s1,
                    seed_strategy=seed_strat, seed_objective=seed_obj,
                    milp_status=milp_status, milp_objective=milp_obj)


# --------------------------------------------------------------------- #
# Polishing search over the joint S2 schedule space
# --------------------------------------------------------------------- #

_S2_PENALTY = 1e12


class _S2Grid:
    """Mutable (patch partition x ragged kernel partition x schedule
    order) state with vectorised cost bookkeeping.

    The schedule is a full grid: every (patch group i, kernel group j)
    pair appears exactly once, so any order permutation, any movement of
    patches between patch groups, and any movement of kernels between
    kernel groups preserves the computes-every-cell-once invariant.

    Cost identity: total load duration equals the (partition-dependent)
    constant ``sum over cells of (pixels + kernel elements)`` minus the
    sum of *consecutive-cell overlaps*, which is SYMMETRIC —
    ``|A \\ B| = |A| - |A ∩ B|`` — so 2-opt order reversals are exact
    O(1) delta evaluations against the overlap matrix ``W``.
    """

    def __init__(self, spec: ConvSpec, hw: HardwareModel,
                 patch_groups: Sequence[Sequence[int]],
                 kernel_groups: Sequence[Sequence[int]],
                 order: Sequence[tuple[int, int]],
                 size_mem: int | None):
        self.spec = spec
        self.hw = hw
        self.size_mem = size_mem
        self.kelem = spec.c_in * spec.h_k * spec.w_k
        self.pg: list[list[int]] = [list(g) for g in patch_groups]
        self.kg: list[list[int]] = [list(g) for g in kernel_groups]
        self.m = len(self.pg)
        self.g = len(self.kg)
        self.order: list[int] = [i * self.g + j for i, j in order]
        self.pmask = [spec.group_mask(g) for g in self.pg]
        self._rebuild_partition_arrays()

    # -- partition-dependent arrays ------------------------------------- #
    def _rebuild_partition_arrays(self) -> None:
        m, g = self.m, self.g
        self.pcnt = np.array([pm.bit_count() for pm in self.pmask],
                             dtype=np.int64)
        self.glen = np.array([len(gr) for gr in self.pg], dtype=np.int64)
        self.klen = np.array([len(gr) for gr in self.kg], dtype=np.int64)
        self.P = np.array(
            [[(a & b).bit_count() for b in self.pmask] for a in self.pmask],
            dtype=np.int64)
        t_l = self.hw.t_l
        # W[c, c'] = overlap(load sets of cells c, c') in duration units
        self.W = t_l * np.kron(self.P, np.ones((g, g))) \
            + t_l * self.kelem * np.kron(np.ones((m, m)), np.diag(self.klen))
        out = (self.glen[:, None] * self.klen[None, :]).ravel()
        succ = (self.pcnt[:, None] * self.spec.c_in
                + self.klen[None, :] * self.kelem).ravel()
        self.cell_peak = succ + out               # single-cell peak
        if self.size_mem is not None:
            # pair[c', c]: peak when cell c executes right after c' (the
            # outputs of c' are still pending write-back) — asymmetric.
            # ``bad_dir`` is the exact feasibility matrix; the annealing's
            # symmetric 2-opt deltas use the conservative union (a
            # transition is avoided if either direction overflows), the
            # directed MILP uses the exact directed penalties.
            pair = succ[None, :] + out[None, :] + out[:, None]
            self.bad_dir = pair > self.size_mem
            self.W_dir = np.where(self.bad_dir, self.W - _S2_PENALTY,
                                  self.W)
            self.W = np.where(self.bad_dir | self.bad_dir.T,
                              self.W - _S2_PENALTY, self.W)
        else:
            self.bad_dir = None
            self.W_dir = self.W
        self.load_const = t_l * (self.g * int(self.pcnt.sum())
                                 + self.m * self.kelem
                                 * int(self.klen.sum()))

    # -- cost ----------------------------------------------------------- #
    def consec_overlap(self) -> float:
        o = np.asarray(self.order)
        return float(self.W[o[:-1], o[1:]].sum())

    def cost(self) -> float:
        return (self.load_const - self.consec_overlap()
                + len(self.order) * self.hw.t_acc)

    def feasible(self) -> bool:
        if self.size_mem is None:
            return True
        if (self.cell_peak > self.size_mem).any():
            return False
        o = np.asarray(self.order)
        return not bool(self.bad_dir[o[:-1], o[1:]].any())

    # -- order moves (O(1) delta) --------------------------------------- #
    def reverse_delta(self, a: int, b: int) -> float:
        """Cost delta of reversing order[a..b] (inclusive)."""
        o = self.order
        gain = 0.0
        if a > 0:
            gain += self.W[o[a - 1], o[b]] - self.W[o[a - 1], o[a]]
        if b + 1 < len(o):
            gain += self.W[o[a], o[b + 1]] - self.W[o[b], o[b + 1]]
        return -gain

    def apply_reverse(self, a: int, b: int) -> None:
        self.order[a:b + 1] = self.order[a:b + 1][::-1]

    # -- partition moves (vectorised rebuild) --------------------------- #
    def max_cell_macs(self) -> int:
        return int(self.glen.max()) * self.spec.nb_op_value \
            * int(self.klen.max())

    def move_patch(self, a: int, ia: int, b: int) -> None:
        pid = self.pg[a].pop(ia)
        self.pg[b].append(pid)
        self.pmask[a] = self.spec.group_mask(self.pg[a])
        self.pmask[b] = self.spec.group_mask(self.pg[b])
        self._rebuild_partition_arrays()

    def swap_patches(self, a: int, ia: int, b: int, ib: int) -> None:
        self.pg[a][ia], self.pg[b][ib] = self.pg[b][ib], self.pg[a][ia]
        self.pmask[a] = self.spec.group_mask(self.pg[a])
        self.pmask[b] = self.spec.group_mask(self.pg[b])
        self._rebuild_partition_arrays()

    def move_kernel(self, a: int, b: int) -> None:
        self.kg[b].append(self.kg[a].pop())
        self._rebuild_partition_arrays()

    # -- materialise ---------------------------------------------------- #
    def snapshot(self):
        return ([list(g) for g in self.pg], [list(g) for g in self.kg],
                list(self.order))

    def restore(self, snap) -> None:
        pg, kg, order = snap
        self.pg = [list(g) for g in pg]
        self.kg = [list(g) for g in kg]
        self.order = list(order)
        self.pmask = [self.spec.group_mask(g) for g in self.pg]
        self._rebuild_partition_arrays()

    def strategy(self, name: str) -> S2Strategy:
        kgs = tuple(tuple(g) for g in self.kg)
        sched = tuple((tuple(self.pg[c // self.g]), c % self.g)
                      for c in self.order)
        return S2Strategy(name, self.spec, kgs, sched)


def _grid_of(strategy: S2Strategy) -> tuple[list[tuple[int, ...]],
                                            list[tuple[int, int]]] | None:
    """Recover the (patch groups, cell order) grid behind a schedule, or
    None when the schedule is not a full patch-group x kernel-group grid
    (polish requires the grid invariant for partition moves)."""
    pgroups: list[tuple[int, ...]] = []
    index: dict[tuple[int, ...], int] = {}
    cells: list[tuple[int, int]] = []
    for g, kg in strategy.schedule:
        i = index.get(g)
        if i is None:
            i = index[g] = len(pgroups)
            pgroups.append(g)
        cells.append((i, kg))
    want = len(pgroups) * strategy.n_kernel_groups
    if len(cells) != want or len(set(cells)) != want:
        return None
    return pgroups, cells


def polish_s2(seed: S2Strategy, hw: HardwareModel,
              size_mem: int | None = None,
              iters: int | None = None,
              rng_seed: int = 0) -> S2Strategy:
    """Simulated-annealing polish of an S2 strategy over the JOINT space:
    schedule order (2-opt / relocation, O(1) bitmask-overlap deltas),
    patch moves between patch groups, and kernel moves between ragged
    kernel groups — the Sec-5 polishing discipline ported to S2.
    Returns the best feasible strategy found (the seed if none better)."""
    if iters is None:
        iters = DEFAULT_POLISH_ITERS
    grid = _grid_of(seed)
    if grid is None or seed.n_steps < 2:
        return seed
    pgroups, cells = grid
    spec = seed.spec
    st = _S2Grid(spec, hw, pgroups, seed.kernel_groups, cells, size_mem)
    if not st.feasible():
        return seed
    rng = random.Random(rng_seed)
    n = len(st.order)
    cur = st.cost()
    best_cost, best_snap = cur, st.snapshot()
    t0, t1 = max(2.0, cur * 0.02), 0.05
    for it in range(iters):
        temp = t0 * (t1 / t0) ** (it / max(1, iters - 1))
        kind = rng.random()
        if kind < 0.55:                       # 2-opt order reversal
            a = rng.randrange(n - 1)
            b = min(n - 1, a + rng.randint(1, max(1, n // 4)))
            delta = st.reverse_delta(a, b)
            if delta <= 0 or rng.random() < np.exp(-delta / temp):
                st.apply_reverse(a, b)
                cur += delta
            else:
                continue
        elif kind < 0.75 and st.m >= 2:       # patch swap / relocation
            a, b = rng.sample(range(st.m), 2)
            if not st.pg[a]:
                continue
            snap = st.snapshot()
            if rng.random() < 0.5 and st.pg[b]:
                st.swap_patches(a, rng.randrange(len(st.pg[a])),
                                b, rng.randrange(len(st.pg[b])))
            else:
                if len(st.pg[a]) <= 1:
                    continue
                st.move_patch(a, rng.randrange(len(st.pg[a])), b)
            if st.max_cell_macs() > hw.nbop_pe:
                st.restore(snap)
                continue
            new = st.cost()
            if new <= cur or rng.random() < np.exp(-(new - cur) / temp):
                cur = new
            else:
                st.restore(snap)
                continue
        elif st.g >= 2:                       # kernel move (ragged groups)
            a, b = rng.sample(range(st.g), 2)
            if len(st.kg[a]) <= 1:
                continue
            snap = st.snapshot()
            st.move_kernel(a, b)
            if st.max_cell_macs() > hw.nbop_pe:
                st.restore(snap)
                continue
            new = st.cost()
            if new <= cur or rng.random() < np.exp(-(new - cur) / temp):
                cur = new
            else:
                st.restore(snap)
                continue
        else:
            continue
        if cur < best_cost - 1e-9 and st.feasible():
            best_cost, best_snap = cur, st.snapshot()
    st.restore(best_snap)
    polished = st.strategy(f"{seed.name}+polish")
    if polished.objective(hw) < seed.objective(hw):
        return polished
    return seed


def milp_order_s2(strategy: S2Strategy, hw: HardwareModel,
                  size_mem: int | None = None,
                  time_limit: float = 2.0) -> tuple[S2Strategy | None, str]:
    """Exact schedule-order optimisation of ``strategy``'s grid via the
    Sec-5-style MILP in ``ilp.build_s2_order_ilp`` (tiny instances only:
    the model is quadratic in the cell count).  Partitions stay fixed —
    this certifies the *order* dimension of the polish."""
    grid = _grid_of(strategy)
    if grid is None:
        return None, "skipped_not_grid"
    pgroups, cells = grid
    st = _S2Grid(strategy.spec, hw, pgroups, strategy.kernel_groups,
                 cells, size_mem)
    from repro.core import ilp as ilp_mod
    order, status = ilp_mod.solve_s2_order(st.W_dir, time_limit=time_limit)
    if order is None:
        return None, status
    st.order = list(order)
    cand = st.strategy(f"{strategy.name}+milp")
    if size_mem is not None and not st.feasible():
        return None, "infeasible_order"
    return cand, status
