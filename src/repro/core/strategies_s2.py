"""S2: offloading strategies that do NOT keep all kernels on-chip — the
paper's stated future work (Sec 9: "strategies that operate at a finer
granularity than patches and do not assume that all kernels are stored in
on-chip memory during computation"), expressed in the same Def 1/2
formalism.

A step computes a (patch group, kernel group) pair: output *units* are
(patch, kernel-group) cells, ``out`` ids = pid * G + g for G kernel groups.
Two canonical orders trade input reloads against kernel reloads — exactly
the weight-stationary / output-stationary dataflow choice of the GeMM
planner:

  * ``kernel_major`` (weight-stationary): for each kernel group, sweep all
    patch groups — kernels loaded once each, input reloaded G times;
  * ``patch_major`` (input-stationary): for each patch group, cycle the
    kernel groups — input loaded once (plus halos), kernels reloaded
    n_patch_groups times.

Why S2 matters: S1 *requires* size_MEM ≥ all kernels + a patch + outputs;
S2 runs under arbitrarily small kernel budgets.  ``best_s2`` searches
(kernel-group size × order) under a memory cap and the PE budget —
a concrete optimizer for the paper's future-work regime.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import Step
from repro.core.strategies import zigzag


def _chunks(seq, n):
    return [tuple(seq[i:i + n]) for i in range(0, len(seq), n)]


@dataclasses.dataclass(frozen=True)
class S2Strategy:
    """Ordered (patch-group, kernel-group-index) schedule."""

    name: str
    spec: ConvSpec
    kernel_groups: tuple[tuple[int, ...], ...]
    schedule: tuple[tuple[tuple[int, ...], int], ...]   # ((patch ids), kg)

    def __post_init__(self):
        seen: set[tuple[int, int]] = set()
        for g, kg in self.schedule:
            for pid in g:
                for kid in self.kernel_groups[kg]:
                    cell = (pid, kid)
                    if cell in seen:
                        raise ValueError(f"{cell} computed twice")
                    seen.add(cell)
        want = self.spec.num_patches * self.spec.n_kernels
        if len(seen) != want:
            raise ValueError(
                f"{self.name}: covers {len(seen)} of {want} cells")

    @property
    def n_steps(self) -> int:
        return len(self.schedule)

    @property
    def n_kernel_groups(self) -> int:
        return len(self.kernel_groups)

    def out_unit(self, pid: int, kg: int) -> int:
        return pid * self.n_kernel_groups + kg

    # ------------------------------------------------------------------ #
    def to_steps(self) -> list[Step]:
        """Def-16-style eager-free semantics for BOTH inputs and kernels;
        outputs written back at the next step."""
        spec = self.spec
        steps: list[Step] = []
        res_pix = 0
        res_ker = 0
        prev_out = 0
        for g, kg in self.schedule:
            need_pix = spec.group_mask(g)
            need_ker = 0
            for kid in self.kernel_groups[kg]:
                need_ker |= 1 << kid
            out = 0
            for pid in g:
                out |= 1 << self.out_unit(pid, kg)
            steps.append(Step(
                f_inp=res_pix & ~need_pix,
                f_ker=res_ker & ~need_ker,
                w=prev_out,
                i_slice=need_pix & ~res_pix,
                k_sub=need_ker & ~res_ker,
                out=out,
                group=tuple(g),
                kernel_group=self.kernel_groups[kg]))
            res_pix, res_ker, prev_out = need_pix, need_ker, out
        steps.append(Step(f_inp=res_pix, f_ker=res_ker, w=prev_out))
        return steps

    # ------------------------------------------------------------------ #
    def objective(self, hw: HardwareModel) -> float:
        """Full Def-3 duration: unlike S1 (eq. 15), kernel loads COUNT —
        trading them against input reloads is the whole point of S2."""
        spec = self.spec
        total = 0.0
        res_pix = res_ker = 0
        kelem = spec.c_in * spec.h_k * spec.w_k
        for g, kg in self.schedule:
            need_pix = spec.group_mask(g)
            need_ker = 0
            for kid in self.kernel_groups[kg]:
                need_ker |= 1 << kid
            total += (need_pix & ~res_pix).bit_count() * hw.t_l
            total += (need_ker & ~res_ker).bit_count() * kelem * hw.t_l
            total += hw.t_acc
            res_pix, res_ker = need_pix, need_ker
        return total

    def peak_memory_elements(self) -> int:
        """Max on-chip elements during any step (inputs + kernels + the
        step's output cells + the previous step's not-yet-written cells)."""
        spec = self.spec
        kelem = spec.c_in * spec.h_k * spec.w_k
        peak = 0
        prev_out_elems = 0
        for g, kg in self.schedule:
            pix = spec.group_mask(g).bit_count() * spec.c_in
            ker = len(self.kernel_groups[kg]) * kelem
            out = len(g) * len(self.kernel_groups[kg])
            peak = max(peak, pix + ker + out + prev_out_elems)
            prev_out_elems = out
        return peak

    # -- strategy protocol (shared with strategies.GroupedStrategy) ------ #
    def max_group_size(self) -> int:
        return max(len(g) for g, _ in self.schedule)

    def peak_footprint_elements(self) -> int:
        """Protocol alias: peak resident elements during any step."""
        return self.peak_memory_elements()

    def peak_working_set_elements(self) -> int:
        """Peak resident elements excluding output buffers: the largest
        (input pixels + swapped kernel group) of any step — what must fit
        next to a held activation on the producer side."""
        spec = self.spec
        kelem = spec.c_in * spec.h_k * spec.w_k
        return max(spec.group_mask(g).bit_count() * spec.c_in
                   + len(self.kernel_groups[kg]) * kelem
                   for g, kg in self.schedule)

    def write_back_duration(self, hw: HardwareModel) -> float:
        """t_w cost of writing every (patch, kernel) output cell back —
        S2 drains outputs at cell granularity (cf. sim.s2.run_s2)."""
        return self.spec.num_patches * self.spec.c_out * hw.t_w

    def full_duration(self, hw: HardwareModel) -> float:
        """Def-3 duration of the materialised schedule.  The S2 objective
        already includes kernel (re)loads, so only write-backs are added;
        matches ``sim.s2.run_s2`` exactly (tests/test_s2_sim.py)."""
        return self.objective(hw) + self.write_back_duration(hw)

    def first_load_duration(self, hw: HardwareModel) -> float:
        """t_l traffic of first-time input-pixel loads (reloads beyond the
        first still hit DRAM even under inter-layer reuse)."""
        covered = 0
        for g, _ in self.schedule:
            covered |= self.spec.group_mask(g)
        return covered.bit_count() * hw.t_l


# --------------------------------------------------------------------- #
# Builders
# --------------------------------------------------------------------- #

def _kernel_groups(spec: ConvSpec, kg_size: int):
    return tuple(_chunks(list(range(spec.n_kernels)), kg_size))


def kernel_major(spec: ConvSpec, p: int, kg_size: int) -> S2Strategy:
    """Weight-stationary: kernels loaded once each; input swept per group."""
    kgs = _kernel_groups(spec, kg_size)
    patch_groups = [tuple(g) for g in zigzag(spec, p).groups]
    sched = [(g, kg) for kg in range(len(kgs)) for g in patch_groups]
    return S2Strategy(f"s2_kernel_major_kg{kg_size}", spec, kgs,
                      tuple(sched))


def patch_major(spec: ConvSpec, p: int, kg_size: int) -> S2Strategy:
    """Input-stationary: each patch group stays while kernel groups cycle."""
    kgs = _kernel_groups(spec, kg_size)
    patch_groups = [tuple(g) for g in zigzag(spec, p).groups]
    sched = [(g, kg) for g in patch_groups for kg in range(len(kgs))]
    return S2Strategy(f"s2_patch_major_kg{kg_size}", spec, kgs,
                      tuple(sched))


def nb_patches_max_s2(spec: ConvSpec, hw: HardwareModel,
                      kg_size: int) -> int:
    """PE budget per step with only kg_size output channels computed."""
    cap = hw.nbop_pe // (spec.nb_op_value * kg_size)
    if cap < 1:
        raise ValueError("PE cannot fit one patch x kernel-group step")
    return cap


def s2_lower_bound(spec: ConvSpec, hw: HardwareModel) -> float:
    """Analytic lower bound on the S2 objective: every needed pixel and
    every kernel element loaded at least once, and at least enough steps to
    push all (patch, kernel) cells through the PE."""
    cells = spec.num_patches * spec.n_kernels
    cells_per_step = max(1, hw.nbop_pe // spec.nb_op_value)
    min_steps = -(-cells // cells_per_step)
    return (hw.t_l * (spec.all_pixels_mask.bit_count() + spec.kernel_elements)
            + min_steps * hw.t_acc)


@dataclasses.dataclass
class S2Result:
    strategy: S2Strategy
    objective: float
    peak_memory: int
    feasible_s1: bool        # could S1 have run under this memory cap?


def best_s2(spec: ConvSpec, hw: HardwareModel,
            size_mem: int | None = None,
            kg_sizes: Iterable[int] | None = None) -> S2Result:
    """Search (kernel-group size x order) under the memory cap; the S1
    comparison records whether the cap even admits an S1 strategy."""
    size_mem = size_mem if size_mem is not None else hw.size_mem
    if kg_sizes is None:
        kg_sizes = [k for k in range(1, spec.n_kernels + 1)
                    if spec.n_kernels % k == 0]
    best: S2Result | None = None
    for kg in kg_sizes:
        p_max = max(1, min(nb_patches_max_s2(spec, hw, kg),
                           spec.num_patches))
        # under a tight memory cap the patch group must shrink too
        p_cands = sorted({p_max, max(1, p_max // 2), max(1, p_max // 4),
                          4, 2, 1})
        for p in p_cands:
            if p > p_max:
                continue
            for builder in (kernel_major, patch_major):
                cand = builder(spec, p, kg)
                peak = cand.peak_memory_elements()
                if size_mem is not None and peak > size_mem:
                    continue
                obj = cand.objective(hw)
                if best is None or obj < best.objective:
                    s1_min_mem = (spec.kernel_elements
                                  + spec.patch_masks[0].bit_count()
                                  * spec.c_in + spec.c_out)
                    best = S2Result(cand, obj, peak,
                                    feasible_s1=(size_mem is None
                                                 or s1_min_mem <= size_mem))
    if best is None:
        raise ValueError(f"no S2 strategy fits size_mem={size_mem}")
    return best
