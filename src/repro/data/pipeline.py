"""Deterministic, shardable synthetic token pipeline.

Properties needed at 1000+ nodes (DESIGN.md §5):
  * **deterministic indexing** — batch content is a pure function of
    (step, host_index), so restarts and elastic rescales never double-feed
    or skip data: after restoring step S from a checkpoint, every host
    regenerates exactly the batch it would have seen;
  * **host-local generation** — each host materialises only its shard of
    the global batch (global_batch // data_shards rows);
  * **resumable iterator state** — the state is just the integer step.

The "dataset" is a seeded PRNG token stream (documents of geometric length
with BOS/EOS framing) — the framework's real-data entry point is
``TokenSource``, which any tokenised corpus can implement.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Protocol

import numpy as np


class TokenSource(Protocol):
    def batch(self, step: int, shard: int, nshards: int,
              batch_size: int, seq_len: int) -> np.ndarray: ...


@dataclasses.dataclass(frozen=True)
class SyntheticLM(TokenSource):
    """Seeded synthetic documents; vocabulary ``vocab``."""

    vocab: int
    seed: int = 0
    bos: int = 1
    eos: int = 2
    mean_doc_len: int = 512

    def batch(self, step: int, shard: int, nshards: int,
              batch_size: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch_size, seq_len + 1), np.int32)
        for row in range(batch_size):
            # deterministic per (step, global_row): elastic-rescale safe
            global_row = step * batch_size * nshards + shard * batch_size \
                + row
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, global_row]))
            toks: list[int] = []
            while len(toks) < seq_len + 1:
                n = int(rng.geometric(1.0 / self.mean_doc_len))
                toks.append(self.bos)
                toks.extend(rng.integers(3, self.vocab,
                                         size=min(n, seq_len + 1)).tolist())
                toks.append(self.eos)
            out[row] = toks[:seq_len + 1]
        return out


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    data_shards: int = 1


class Pipeline:
    """Per-host iterator yielding {'tokens', 'labels'} numpy batches."""

    def __init__(self, source: TokenSource, cfg: DataConfig, shard: int = 0,
                 start_step: int = 0):
        if cfg.global_batch % cfg.data_shards:
            raise ValueError("global_batch must divide by data_shards")
        self.source = source
        self.cfg = cfg
        self.shard = shard
        self.step = start_step

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.data_shards

    def next(self) -> dict[str, np.ndarray]:
        seq = self.source.batch(self.step, self.shard,
                                self.cfg.data_shards, self.local_batch,
                                self.cfg.seq_len)
        self.step += 1
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next()

    # -- state for checkpointing -----------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "shard": self.shard}

    def restore(self, state: dict, new_shard: int | None = None,
                new_nshards: int | None = None) -> None:
        """Resume; optionally re-shard for elastic rescale.  Determinism of
        ``batch(step, shard, nshards, ...)`` guarantees exactly-once
        consumption across the reshard boundary."""
        self.step = int(state["step"])
        if new_shard is not None:
            self.shard = new_shard
        if new_nshards is not None:
            self.cfg = dataclasses.replace(self.cfg,
                                           data_shards=new_nshards)
