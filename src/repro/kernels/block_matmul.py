"""Pallas TPU kernel: strategy-driven block GeMM (paper Sec 1.3 adaptation).

The paper notes its formalism applies to GeMM-based accelerators (TMMA/VTA)
with "slightly adapted" strategies: tiles of A/B/C play the role of patches
and kernels, and the loop order decides which operand is revisited (kept in
on-chip memory) between consecutive steps.  ``core.planner.plan_matmul``
enumerates tile shapes x loop orders under the paper's duration model and
this kernel executes the chosen plan:

  * order "...k" (k innermost)  — output-stationary: the C block is the
    resident set, A/B stream (S1 with C in the Λ role);
  * order "..m" / "..n" inner   — the A (resp. B) block is revisited across
    the inner sweep, C is read-modified-written.

Blocks are plain BlockSpecs (non-overlapping — no halo in GeMM), grid
dimension semantics mark k as "arbitrary" for TPU so the compiler may
software-pipeline the parallel dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import KernelShapeError


def matmul_grid(m: int, n: int, k: int, *, bm: int, bn: int, bk: int,
                order: str):
    """Grid + BlockSpec index_maps for a given loop order.

    Shared by :func:`block_matmul` and the static checker
    (:mod:`repro.analysis.kerncheck`), which evaluates the maps on
    concrete grid indices.  Returns ``(grid, amap, bmap, cmap, axis)``.
    """
    if sorted(order) != ["k", "m", "n"]:
        raise KernelShapeError(f"order {order!r} must permute 'mnk'")
    if k <= 0 or m % bm or n % bn or k % bk:
        raise KernelShapeError(
            f"tiles ({bm},{bn},{bk}) must divide dims ({m},{n},{k}) "
            f"(ops.matmul pads)")
    trip = {"m": m // bm, "n": n // bn, "k": k // bk}
    grid = tuple(trip[d] for d in order)
    axis = {d: i for i, d in enumerate(order)}

    def amap(*ids):
        return (ids[axis["m"]], ids[axis["k"]])

    def bmap(*ids):
        return (ids[axis["k"]], ids[axis["n"]])

    def cmap(*ids):
        return (ids[axis["m"]], ids[axis["n"]])

    return grid, amap, bmap, cmap, axis


def _mm_kernel_osta(a_ref, b_ref, o_ref, acc_ref, *, k_axis: int,
                    k_tiles: int):
    """Output-stationary (k innermost): f32 VMEM accumulator, flushed when
    the k sweep of this C block completes."""
    kk = pl.program_id(k_axis)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(kk == k_tiles - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mm_kernel_rmw(a_ref, b_ref, o_ref, *, k_axis: int, k_tiles: int):
    """k not innermost: the C block leaves VMEM while partial, so partial
    sums are read-modified-written through the output ref — exactly the
    extra W/I_slice traffic the planner charges such orders for."""
    kk = pl.program_id(k_axis)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32
                          ).astype(o_ref.dtype)


def block_matmul(a: jax.Array, b: jax.Array, *,
                 bm: int = 128, bn: int = 128, bk: int = 128,
                 order: str = "mnk",
                 interpret: bool = True) -> jax.Array:
    """C = A @ B with planner-chosen tiles and loop order.

    ``order`` is outer->inner over the grid axes, e.g. "mnk" iterates k
    fastest (output-stationary).  Dims must divide by the tiles
    (``ops.matmul`` pads).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise KernelShapeError(f"A has k={k} but B has k={k2}")
    grid, amap, bmap, cmap, axis = matmul_grid(
        m, n, k, bm=bm, bn=bn, bk=bk, order=order)
    k_t = k // bk
    dim_sem = tuple("arbitrary" if d == "k" else "parallel" for d in order)
    k_inner = order[2] == "k"
    if k_inner:
        kernel = functools.partial(_mm_kernel_osta, k_axis=axis["k"],
                                   k_tiles=k_t)
        scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
        out_dtype = a.dtype
    else:
        kernel = functools.partial(_mm_kernel_rmw, k_axis=axis["k"],
                                   k_tiles=k_t)
        scratch = []
        out_dtype = jnp.float32     # RMW partials accumulate in f32
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), amap),
                  pl.BlockSpec((bk, bn), bmap)],
        out_specs=pl.BlockSpec((bm, bn), cmap),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        compiler_params=pltpu.CompilerParams(dimension_semantics=dim_sem)
        if not interpret else None,
        interpret=interpret,
    )(a, b)
    return out.astype(a.dtype)
