"""Pallas TPU kernel: S1 convolution offloading (paper Sec 4 on TPU).

Strategy S1, faithfully mapped to the TPU memory hierarchy:

  * **K_sub / kernel residency** — all kernels Λ are fetched once and stay
    in VMEM for the whole sweep.  Expressed with a BlockSpec whose index_map
    is constant, so Pallas revisits (never re-fetches) the block: exactly
    "loaded during the first step and never freed until the last step"
    (Def 16).
  * **I_slice** — the input lives in HBM (the paper's DRAM,
    ``memory_space=pl.ANY``).  Each grid step DMAs the patch-group window
    into a VMEM scratch buffer with ``pltpu.make_async_copy`` — action a4.
  * **patch groups** — one step computes a row-run of T output columns for
    *all* C_out channels (Property 1).  T comes from
    ``core.planner.plan_conv`` (the nb_patches_max analogue under the VMEM
    budget).  Grid order is zigzag (paper Sec 7.2) or row-by-row.
  * **W / write-back** — the step's (C_out, 1, T) output block leaves VMEM
    when the grid moves on — action a3.

The MAC loop is an im2col-in-VMEM followed by one MXU ``jnp.dot``:
(T, C_in*H_K*W_K) x (C_in*H_K*W_K, C_out).  On real hardware T and C_out
should be padded to MXU lanes (multiples of 128); ``ops.conv2d`` handles
padding.  Validated with ``interpret=True`` on CPU against ``ref.conv2d``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_hbm, w_ref, o_ref, win_buf, sem, *,
                 t_run: int, s_h: int, s_w: int, h_k: int, w_k: int,
                 w_out_tiles: int, zigzag: bool):
    """One S1 step: DMA the input window, im2col in VMEM, one MXU dot."""
    i = pl.program_id(0)            # output row
    jt = pl.program_id(1)           # column-run index (possibly zigzagged)
    if zigzag:
        jt = jnp.where(i % 2 == 1, w_out_tiles - 1 - jt, jt)
    t_in = (t_run - 1) * s_w + w_k

    # a4: load I_slice — the (C_in, H_K, t_in) window — into VMEM.
    cp = pltpu.make_async_copy(
        x_hbm.at[:, pl.ds(i * s_h, h_k), pl.ds(jt * t_run * s_w, t_in)],
        win_buf, sem)
    cp.start()
    cp.wait()

    # im2col in VMEM: (T, C_in*H_K*W_K)
    win = win_buf[...]
    cols = [win[:, :, t * s_w:t * s_w + w_k].reshape(-1) for t in range(t_run)]
    patches = jnp.stack(cols, axis=0)

    # a6: one MXU matmul against the resident kernels (C_in*Hk*Wk, C_out).
    # (f32 upcast: XLA:CPU interpret mode lacks a bf16 dot thunk; on TPU the
    # MXU consumes bf16 directly and this cast fuses away.)
    out = jnp.dot(patches.astype(jnp.float32),
                  w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    # (T, C_out) -> output block (C_out, 1, T)
    o_ref[...] = out.T[:, None, :].astype(o_ref.dtype)


def conv2d_offload(x: jax.Array, w: jax.Array, *,
                   t_run: int, s_h: int = 1, s_w: int = 1,
                   order: str = "zigzag",
                   interpret: bool = True) -> jax.Array:
    """S1 Pallas convolution.

    Args:
      x: input (C_in, H_in, W_in) — already padded (paper Remark 2).
      w: kernels (N, C_in, H_K, W_K).
      t_run: patches per step (row-run length); ``W_out % t_run == 0``
        (``ops.conv2d`` pads/chooses for you).
      order: "zigzag" (paper Sec 7.2) or "row" grid sweep.
    """
    c_in, h_in, w_in = x.shape
    n, c_in2, h_k, w_k = w.shape
    assert c_in == c_in2
    h_out = (h_in - h_k) // s_h + 1
    w_out = (w_in - w_k) // s_w + 1
    assert w_out % t_run == 0, (w_out, t_run)
    w_out_tiles = w_out // t_run
    t_in = (t_run - 1) * s_w + w_k
    w_mat = w.reshape(n, -1).T          # (C_in*Hk*Wk, N)

    if order == "zigzag":
        def out_index(i, jt):
            return (0, i, jnp.where(i % 2 == 1, w_out_tiles - 1 - jt, jt))
    else:
        def out_index(i, jt):
            return (0, i, jt)

    kernel = functools.partial(
        _conv_kernel, t_run=t_run, s_h=s_h, s_w=s_w, h_k=h_k, w_k=w_k,
        w_out_tiles=w_out_tiles, zigzag=(order == "zigzag"))
    return pl.pallas_call(
        kernel,
        grid=(h_out, w_out_tiles),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),               # x stays in HBM
            pl.BlockSpec((c_in * h_k * w_k, n), lambda i, jt: (0, 0)),  # Λ resident
        ],
        out_specs=pl.BlockSpec((n, 1, t_run), out_index),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((c_in, h_k, t_in), x.dtype),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(x, w_mat)
