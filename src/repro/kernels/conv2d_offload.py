"""Pallas TPU kernels: S1 convolution offloading (paper Sec 4 on TPU).

Strategy S1, faithfully mapped to the TPU memory hierarchy:

  * **K_sub / kernel residency** — all kernels Λ are fetched once and stay
    in VMEM for the whole sweep.  Expressed with a BlockSpec whose index_map
    is constant, so Pallas revisits (never re-fetches) the block: exactly
    "loaded during the first step and never freed until the last step"
    (Def 16).
  * **I_slice** — the input lives in HBM (the paper's DRAM,
    ``memory_space=pl.ANY``).  Each grid step DMAs the patch-group window
    into a VMEM scratch buffer with ``pltpu.make_async_copy`` — action a4.
  * **patch groups** — one step computes a row-run of T output columns for
    *all* C_out channels (Property 1).  T comes from
    ``core.planner.plan_conv`` (the nb_patches_max analogue under the VMEM
    budget).  Grid order is zigzag (paper Sec 7.2) or row-by-row.
  * **W / write-back** — the step's (C_out, 1, T) output block leaves VMEM
    when the grid moves on — action a3.

Two variants share the geometry helpers below (which
``repro.analysis.kerncheck`` also evaluates on concrete grid indices to
derive each kernel's static access trace):

* :func:`conv2d_offload` — the simple seed kernel: every step DMAs its
  *full* ``(C_in, H_K, t_in)`` window and blocks on the copy.  Correct,
  but it re-fetches the ``w_k - s_w`` columns (and, across rows, the
  ``h_k - s_h`` rows) shared with the previous step — traffic the plan's
  Def-3 ``I_slice`` accounting does *not* charge.
* :func:`conv2d_offload_planned` — the plan-shaped kernel
  ``kernels.emit`` maps ``LayerPlan``s onto: the window stays resident in
  VMEM and each step DMAs only its **I_slice delta** (new columns within
  a row, new rows at a zigzag row turn), *prefetched* one step ahead into
  a separate delta buffer so the copy overlaps the previous step's MXU
  work.  Double-buffering is exactly the part that is easy to get subtly
  wrong (a dropped wait, a prefetch aimed at the live window), which is
  why ``kerncheck`` proves its DMA trace hazard-free and its per-step
  regions equal to the plan's I_slices before the kernel is trusted.

The MAC loop is an im2col-in-VMEM followed by one MXU ``jnp.dot``:
(T, C_in*H_K*W_K) x (C_in*H_K*W_K, C_out).  On real hardware T and C_out
should be padded to MXU lanes (multiples of 128); ``ops.conv2d`` handles
padding.  Validated with ``interpret=True`` on CPU against ``ref.conv2d``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import KernelShapeError

# Step cases of the planned kernel (shared with the static checker).
CASE_FULL = "full"          # DMA the whole window (first step / no overlap)
CASE_ROW = "row-delta"      # zigzag row turn: fetch the s_h new rows
CASE_COL = "col-delta"      # within-row move: fetch the t_run*s_w new cols

# Semaphore slots of the planned kernel's DMA semaphore array.
SEM_FULL, SEM_ROW, SEM_COL = 0, 1, 2


# --------------------------------------------------------------------- #
# Shared grid geometry (evaluated on tracers in-kernel, on ints by the
# static checker — keep everything branch-free arithmetic over i/jt).
# --------------------------------------------------------------------- #

def t_in_cols(t_run: int, s_w: int, w_k: int) -> int:
    """Input columns covered by a ``t_run``-patch row-run."""
    return (t_run - 1) * s_w + w_k


def eff_tile(i, jt, w_out_tiles: int, zigzag: bool):
    """Physical column-tile index of grid step ``(i, jt)``.

    Zigzag reverses odd rows; the arithmetic form works for both Python
    ints (checker) and traced values (kernel)."""
    if not zigzag:
        return jt
    return jt + (i % 2) * (w_out_tiles - 1 - 2 * jt)


def moving_right(i, zigzag: bool):
    """Whether within-row steps of row ``i`` advance left-to-right."""
    if not zigzag:
        return True
    return i % 2 == 0


def grid_sequence(h_out: int, w_out_tiles: int):
    """The Pallas grid's sequential step order: last axis fastest."""
    return [(i, jt) for i in range(h_out) for jt in range(w_out_tiles)]


def step_case(i: int, jt: int, *, t_run: int, s_h: int, s_w: int,
              h_k: int, w_k: int, w_out_tiles: int, order: str) -> str:
    """Which I_slice the planned kernel fetches at grid step ``(i, jt)``.

    Concrete-index form of the kernel's ``pl.when`` structure: the first
    step and any step whose window is disjoint from its predecessor's
    fetch the full window; a zigzag row turn (same column window, one
    stride down) fetches only the new rows; a within-row move fetches
    only the new columns.  Row order with more than one column tile jumps
    back to the row's left edge at each turn — a (mostly) disjoint
    window, fetched in full."""
    zig = order == "zigzag"
    if i == 0 and jt == 0:
        return CASE_FULL
    if jt == 0:                                   # row turn
        if (zig or w_out_tiles == 1) and h_k > s_h:
            return CASE_ROW
        return CASE_FULL
    if t_in_cols(t_run, s_w, w_k) > t_run * s_w:  # windows share columns
        return CASE_COL
    return CASE_FULL


# --------------------------------------------------------------------- #
# Seed kernel: full window DMA every step
# --------------------------------------------------------------------- #

def _conv_kernel(x_hbm, w_ref, o_ref, win_buf, sem, *,
                 t_run: int, s_h: int, s_w: int, h_k: int, w_k: int,
                 w_out_tiles: int, zigzag: bool):
    """One S1 step: DMA the input window, im2col in VMEM, one MXU dot."""
    i = pl.program_id(0)            # output row
    jt = eff_tile(i, pl.program_id(1), w_out_tiles, zigzag)
    t_in = t_in_cols(t_run, s_w, w_k)

    # a4: load I_slice — the (C_in, H_K, t_in) window — into VMEM.
    cp = pltpu.make_async_copy(
        x_hbm.at[:, pl.ds(i * s_h, h_k), pl.ds(jt * t_run * s_w, t_in)],
        win_buf, sem)
    cp.start()
    cp.wait()

    _im2col_dot(win_buf, w_ref, o_ref, t_run=t_run, s_w=s_w, w_k=w_k)


def _im2col_dot(win_buf, w_ref, o_ref, *, t_run: int, s_w: int, w_k: int):
    """im2col in VMEM then one MXU matmul against the resident kernels.

    (f32 upcast: XLA:CPU interpret mode lacks a bf16 dot thunk; on TPU the
    MXU consumes bf16 directly and this cast fuses away.)"""
    win = win_buf[...]
    cols = [win[:, :, t * s_w:t * s_w + w_k].reshape(-1)
            for t in range(t_run)]
    patches = jnp.stack(cols, axis=0)            # (T, C_in*Hk*Wk)
    out = jnp.dot(patches.astype(jnp.float32),
                  w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    # (T, C_out) -> output block (C_out, 1, T)
    o_ref[...] = out.T[:, None, :].astype(o_ref.dtype)


def _conv_geometry(x: jax.Array, w: jax.Array, t_run: int,
                   s_h: int, s_w: int) -> tuple[int, int, int, int, int]:
    """Validate shapes; return (n, h_k, w_k, h_out, w_out_tiles)."""
    c_in, h_in, w_in = x.shape
    n, c_in2, h_k, w_k = w.shape
    if c_in != c_in2:
        raise KernelShapeError(
            f"input has {c_in} channels but kernels expect {c_in2}")
    h_out = (h_in - h_k) // s_h + 1
    w_out = (w_in - w_k) // s_w + 1
    if h_out <= 0 or w_out <= 0:
        raise KernelShapeError(
            f"kernel {h_k}x{w_k} does not fit input {h_in}x{w_in}")
    if t_run <= 0 or w_out % t_run != 0:
        raise KernelShapeError(
            f"t_run={t_run} must divide w_out={w_out} "
            f"(ops.conv2d pads/chooses for you)")
    return n, h_k, w_k, h_out, w_out // t_run


def _out_index_map(w_out_tiles: int, zigzag: bool):
    def out_index(i, jt):
        return (0, i, eff_tile(i, jt, w_out_tiles, zigzag))
    return out_index


def conv2d_offload(x: jax.Array, w: jax.Array, *,
                   t_run: int, s_h: int = 1, s_w: int = 1,
                   order: str = "zigzag",
                   interpret: bool = True) -> jax.Array:
    """S1 Pallas convolution (full-window DMA per step).

    Args:
      x: input (C_in, H_in, W_in) — already padded (paper Remark 2).
      w: kernels (N, C_in, H_K, W_K).
      t_run: patches per step (row-run length); ``W_out % t_run == 0``
        (``ops.conv2d`` pads/chooses for you).
      order: "zigzag" (paper Sec 7.2) or "row" grid sweep.
    """
    c_in = x.shape[0]
    n, h_k, w_k, h_out, w_out_tiles = _conv_geometry(x, w, t_run, s_h, s_w)
    t_in = t_in_cols(t_run, s_w, w_k)
    w_mat = w.reshape(n, -1).T          # (C_in*Hk*Wk, N)

    kernel = functools.partial(
        _conv_kernel, t_run=t_run, s_h=s_h, s_w=s_w, h_k=h_k, w_k=w_k,
        w_out_tiles=w_out_tiles, zigzag=(order == "zigzag"))
    return pl.pallas_call(
        kernel,
        grid=(h_out, w_out_tiles),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),               # x stays in HBM
            pl.BlockSpec((c_in * h_k * w_k, n), lambda i, jt: (0, 0)),  # Λ
        ],
        out_specs=pl.BlockSpec((n, 1, t_run),
                               _out_index_map(w_out_tiles,
                                              order == "zigzag")),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out_tiles * t_run),
                                       x.dtype),
        scratch_shapes=[pltpu.VMEM((c_in, h_k, t_in), x.dtype),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(x, w_mat)


# --------------------------------------------------------------------- #
# Planned kernel: resident window + prefetched I_slice deltas
# --------------------------------------------------------------------- #

def _conv_planned_kernel(x_hbm, w_ref, o_ref, win_buf, col_buf, row_buf,
                         sems, *,
                         t_run: int, s_h: int, s_w: int, h_k: int,
                         w_k: int, h_out: int, w_out_tiles: int,
                         zigzag: bool):
    """One plan step: retire the prefetched delta, update the resident
    window, prefetch the next step's delta, then im2col + MXU dot."""
    i = pl.program_id(0)
    jt_raw = pl.program_id(1)
    tiles = w_out_tiles
    jt = eff_tile(i, jt_raw, tiles, zigzag)
    t_in = t_in_cols(t_run, s_w, w_k)
    nw = t_run * s_w                    # new columns per within-row move
    ov_w = t_in - nw                    # columns shared with the neighbour
    keep_rows = h_k - s_h               # rows shared across a row turn
    row_delta = (zigzag or tiles == 1) and keep_rows > 0
    col_delta = ov_w > 0

    h0 = i * s_h
    w0 = jt * nw
    first = (i == 0) & (jt_raw == 0)
    rowchg = (jt_raw == 0) & (i > 0)
    within = jt_raw > 0

    full_cond = first
    if not row_delta:
        full_cond = full_cond | rowchg
    if not col_delta:
        full_cond = full_cond | within

    @pl.when(full_cond)
    def _full():
        # No usable overlap with the previous window: synchronous fetch
        # of the whole (C_in, H_K, t_in) box.
        cp = pltpu.make_async_copy(
            x_hbm.at[:, pl.ds(h0, h_k), pl.ds(w0, t_in)],
            win_buf, sems.at[SEM_FULL])
        cp.start()
        cp.wait()

    if row_delta:
        @pl.when(rowchg)
        def _row():
            # Retire the row prefetch issued one step ago, shift the kept
            # rows up, splice the s_h new rows in at the bottom.
            pltpu.make_async_copy(
                x_hbm.at[:, pl.ds(h0 + keep_rows, s_h), pl.ds(w0, t_in)],
                row_buf, sems.at[SEM_ROW]).wait()
            kept = win_buf[:, s_h:, :]
            win_buf[:, :keep_rows, :] = kept
            win_buf[:, keep_rows:, :] = row_buf[...]

    if col_delta:
        @pl.when(within)
        def _col():
            # Retire the column prefetch, slide the kept ov_w columns to
            # their position in the new window, splice the delta in.
            right = moving_right(i, zigzag)
            delta_off = ov_w * right        # right: [ov_w, t_in); left: [0, nw)
            pltpu.make_async_copy(
                x_hbm.at[:, pl.ds(h0, h_k), pl.ds(w0 + delta_off, nw)],
                col_buf, sems.at[SEM_COL]).wait()
            kept = win_buf[:, :, pl.ds(nw * right, ov_w)]
            win_buf[:, :, pl.ds(nw * (1 - right), ov_w)] = kept
            win_buf[:, :, pl.ds(delta_off, nw)] = col_buf[...]

    # Prefetch the NEXT step's delta while this step computes — the
    # double-buffering whose soundness kerncheck proves (the copy writes
    # col_buf/row_buf, never the win_buf this step still reads).
    is_last = (i == h_out - 1) & (jt_raw == tiles - 1)
    nxt_turn = jt_raw == tiles - 1
    i_n = i + nxt_turn
    jt_n = eff_tile(i_n, (jt_raw + 1) * (1 - nxt_turn), tiles, zigzag)
    h0_n = i_n * s_h
    w0_n = jt_n * nw

    if row_delta:
        @pl.when((~is_last) & nxt_turn)
        def _prefetch_row():
            pltpu.make_async_copy(
                x_hbm.at[:, pl.ds(h0_n + keep_rows, s_h),
                         pl.ds(w0_n, t_in)],
                row_buf, sems.at[SEM_ROW]).start()

    if col_delta:
        @pl.when((~is_last) & (~nxt_turn))
        def _prefetch_col():
            delta_off_n = ov_w * moving_right(i_n, zigzag)
            pltpu.make_async_copy(
                x_hbm.at[:, pl.ds(h0_n, h_k), pl.ds(w0_n + delta_off_n, nw)],
                col_buf, sems.at[SEM_COL]).start()

    _im2col_dot(win_buf, w_ref, o_ref, t_run=t_run, s_w=s_w, w_k=w_k)


def conv2d_offload_planned(x: jax.Array, w: jax.Array, *,
                           t_run: int, s_h: int = 1, s_w: int = 1,
                           order: str = "zigzag",
                           interpret: bool = True) -> jax.Array:
    """Plan-shaped S1 Pallas convolution: per-step DMA == plan I_slice.

    Same arguments and result as :func:`conv2d_offload`; the difference
    is the traffic contract — each grid step fetches exactly the pixels
    the corresponding ``GroupedStrategy`` step charges to ``t_l`` (the
    window overlap with the previous step stays resident in VMEM), and
    the fetch is prefetched one step ahead.  ``kernels.emit`` maps
    ``LayerPlan``s here; ``repro.analysis.kerncheck`` proves the
    equivalence statically.
    """
    if order not in ("zigzag", "row"):
        raise KernelShapeError(f"unknown grid order {order!r}")
    c_in = x.shape[0]
    n, h_k, w_k, h_out, w_out_tiles = _conv_geometry(x, w, t_run, s_h, s_w)
    t_in = t_in_cols(t_run, s_w, w_k)
    nw = t_run * s_w
    w_mat = w.reshape(n, -1).T

    kernel = functools.partial(
        _conv_planned_kernel, t_run=t_run, s_h=s_h, s_w=s_w, h_k=h_k,
        w_k=w_k, h_out=h_out, w_out_tiles=w_out_tiles,
        zigzag=(order == "zigzag"))
    return pl.pallas_call(
        kernel,
        grid=(h_out, w_out_tiles),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),               # x stays in HBM
            pl.BlockSpec((c_in * h_k * w_k, n), lambda i, jt: (0, 0)),  # Λ
        ],
        out_specs=pl.BlockSpec((n, 1, t_run),
                               _out_index_map(w_out_tiles,
                                              order == "zigzag")),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out_tiles * t_run),
                                       x.dtype),
        scratch_shapes=[
            pltpu.VMEM((c_in, h_k, t_in), x.dtype),          # resident window
            pltpu.VMEM((c_in, h_k, nw), x.dtype),            # column delta
            pltpu.VMEM((c_in, max(1, min(s_h, h_k)), t_in), x.dtype),
            pltpu.SemaphoreType.DMA((3,)),
        ],
        interpret=interpret,
    )(x, w_mat)
