"""Emit Pallas kernels from solved offloading plans.

The bridge between the planning stack and the kernels:
:func:`emit_layer_kernel` maps an S1 :class:`~repro.core.network_planner.
LayerPlan` onto :func:`~repro.kernels.conv2d_offload.
conv2d_offload_planned` — grid, ``t_run`` and sweep order are read off
the solved strategy via :meth:`GroupedStrategy.as_grid`, so the kernel's
grid steps are, by construction, the plan's Def-3 steps in order.

"By construction" is the claim; :mod:`repro.analysis.kerncheck` is the
proof: it statically re-derives the emitted kernel's per-step DMA
regions and checks them against the plan's I_slices (traffic
conservation), its VMEM occupancy against the budget the plan was
solved under, and its DMA pipeline for hazards.  ``emit`` therefore
refuses anything it cannot map *exactly*:

* S2 plans (kernel-group swapping — no kernel implements swapping yet);
* strategies that are not a uniform grid sweep (tiled/hilbert groups);
* "row"-order sweeps whose windows overlap across rows: at a row turn
  the kernel would re-fetch the full window, charging more traffic than
  the plan's eager-free I_slice accounting.

The emitted kernel implements the layer's *gross* schedule (every input
pixel from HBM, every output written back); inter-layer reuse savings
are a schedule-level accounting on top and do not change the kernel.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import LayerPlan, NetworkPlan, plan_network
from repro.core.solver import SolveResult
from repro.core.strategies import (
    GridMeta, GroupedStrategy, lower_bound, zigzag)
from repro.kernels import KernelShapeError
from repro.kernels.conv2d_offload import conv2d_offload_planned, t_in_cols


class KernelEmitError(ValueError):
    """The plan cannot be mapped onto an implemented kernel."""


def kernel_vmem_elements(spec: ConvSpec, t_run: int) -> int:
    """VMEM elements the emitted kernel actually occupies.

    The checker's kern/vmem convention: the resident Λ block (constant
    index_map — Pallas keeps one copy), the window/delta scratch buffers
    exactly as ``conv2d_offload_planned`` allocates them, and two output
    blocks (Pallas double-buffers blocks whose index_map moves).
    """
    t_in = t_in_cols(t_run, spec.s_w, spec.w_k)
    nw = t_run * spec.s_w
    lam = spec.kernel_elements
    win = spec.c_in * spec.h_k * t_in
    col = spec.c_in * spec.h_k * nw
    row = spec.c_in * max(1, min(spec.s_h, spec.h_k)) * t_in
    out2 = 2 * spec.c_out * t_run
    return lam + win + col + row + out2


@dataclasses.dataclass(frozen=True)
class EmittedConv:
    """A LayerPlan compiled to a concrete Pallas kernel invocation."""

    spec: ConvSpec
    grid_meta: GridMeta
    layer_index: int
    vmem_elements: int

    @property
    def t_run(self) -> int:
        return self.grid_meta.t_run

    @property
    def order(self) -> str:
        return self.grid_meta.order

    def run(self, x: jax.Array, w: jax.Array, *,
            interpret: bool = True) -> jax.Array:
        """Execute the plan: x (C_in, H_in, W_in), w (N, C_in, Hk, Wk)."""
        spec = self.spec
        if x.shape != (spec.c_in, spec.h_in, spec.w_in):
            raise KernelShapeError(
                f"layer {self.layer_index}: input {x.shape} != plan spec "
                f"({spec.c_in}, {spec.h_in}, {spec.w_in})")
        if w.shape != (spec.c_out, spec.c_in, spec.h_k, spec.w_k):
            raise KernelShapeError(
                f"layer {self.layer_index}: kernels {w.shape} != plan "
                f"spec ({spec.c_out}, {spec.c_in}, {spec.h_k}, {spec.w_k})")
        return conv2d_offload_planned(
            x, w, t_run=self.t_run, s_h=spec.s_h, s_w=spec.s_w,
            order=self.order, interpret=interpret)


def emit_layer_kernel(lp: LayerPlan) -> EmittedConv:
    """Map an S1 LayerPlan onto ``conv2d_offload_planned``.

    Raises :class:`KernelEmitError` for plans no implemented kernel
    realises exactly (see module docstring).  The result's grid,
    ``t_run`` and order come from the solved strategy, so
    ``repro.analysis.kerncheck`` can verify contract equivalence
    statically before the kernel is ever run.
    """
    if lp.mode != "s1":
        raise KernelEmitError(
            f"layer {lp.index}: mode {lp.mode!r} (kernel-group swapping) "
            f"has no emitted kernel")
    strat = lp.strategy
    if not isinstance(strat, GroupedStrategy):
        raise KernelEmitError(
            f"layer {lp.index}: {type(strat).__name__} is not a grouped "
            f"S1 strategy")
    meta = strat.as_grid()
    if meta is None:
        raise KernelEmitError(
            f"layer {lp.index}: strategy {strat.name!r} is not a uniform "
            f"grid sweep — no kernel grid realises its group order")
    spec = lp.spec
    if meta.order == "row" and meta.w_out_tiles > 1 \
            and spec.h_k > spec.s_h:
        raise KernelEmitError(
            f"layer {lp.index}: row-order sweep with overlapping rows "
            f"(h_k={spec.h_k} > s_h={spec.s_h}) re-fetches the full "
            f"window at every row turn — kernel traffic would exceed "
            f"the plan's I_slice charge; solve with zigzag instead")
    return EmittedConv(spec=spec, grid_meta=meta, layer_index=lp.index,
                       vmem_elements=kernel_vmem_elements(spec,
                                                          meta.t_run))


# --------------------------------------------------------------------- #
# Emitable planning: restrict the solver to kernel-realisable strategies
# --------------------------------------------------------------------- #

def grid_solve(spec: ConvSpec, p: int, hw: HardwareModel, *,
               nb_data_reload: int = 2, time_limit: float = 10.0,
               polish_iters: int = 0, use_milp: bool = False,
               rng_seed: int = 0, polish_restarts: int = 0) -> SolveResult:
    """``plan_network`` solve_fn over *emitable* strategies only.

    Candidates are zigzag sweeps with every run length ``t`` dividing
    ``w_out`` and ``t <= p``; feasibility is the emitted kernel's actual
    VMEM occupancy (:func:`kernel_vmem_elements`), which upper-bounds
    the plan-level ``peak_footprint_elements``.  Polishing knobs are
    accepted (the shared solve_fn signature) and ignored — the candidate
    set is tiny and enumerated exactly.
    """
    del time_limit, polish_iters, use_milp, rng_seed, polish_restarts
    best: GroupedStrategy | None = None
    for t in range(1, min(p, spec.w_out) + 1):
        if spec.w_out % t:
            continue
        if hw.size_mem is not None and \
                kernel_vmem_elements(spec, t) > hw.size_mem:
            continue
        cand = zigzag(spec, t)
        if best is None or cand.objective(hw) < best.objective(hw):
            best = cand
    if best is None:
        raise ValueError(
            f"no emitable zigzag strategy fits size_mem={hw.size_mem} "
            f"for layer {spec.c_in}x{spec.h_in}x{spec.w_in}"
            f"->{spec.c_out}")
    obj = best.objective(hw)
    return SolveResult(
        strategy=best, objective=obj,
        lower_bound=lower_bound(spec, best.max_group_size(), hw),
        seed_objective=obj, milp_status="skipped", milp_objective=None,
        polish_objective=obj,
        reload_ok=best.max_reloads() <= nb_data_reload)


def plan_emitable_network(specs, hw: HardwareModel, *, name: str,
                          **kwargs) -> NetworkPlan:
    """``plan_network`` restricted to plans every layer of which
    ``emit_layer_kernel`` accepts.  Inter-layer reuse is disabled: the
    emitted kernels implement gross layer schedules, and the checker's
    traffic-conservation rule compares against exactly that."""
    return plan_network(specs, hw, name=name, allow_reuse=False,
                        solve_fn=grid_solve, **kwargs)
