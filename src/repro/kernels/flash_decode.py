"""Pallas TPU kernel: decode attention as an S1 offloading schedule.

One decoded token attends to a long KV cache.  In the paper's terms
(DESIGN.md §4): the query block is the *kernel set* Λ — loaded once, resident
for every step (constant index_map -> Pallas revisiting); the KV cache is the
input tensor, cut into disjoint ``bkv``-sized *patch groups* (stride == block
size, so no halo); each grid step loads one KV block (I_slice, action a4),
computes (a6) with an online-softmax accumulator held on-chip, and the single
output block is written back once at the end (W at the last step, as Def 2
requires).  ``core.planner.plan_decode_attention`` chooses ``bkv`` under the
VMEM budget.

Layout: q (G, D) — the G = H_q/H_kv grouped query heads of one KV head;
k/v (S, D).  Batch and KV heads are vmapped in ``ops.decode_attention``.
A padded cache is handled with a length scalar: positions >= length are
masked before the softmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import KernelShapeError

_NEG_INF = -1e30


def decode_specs(g: int, d: int, s: int, bkv: int):
    """Grid + index_maps of the decode schedule, shared with the static
    checker: q and the output block are resident (constant maps), K/V
    stream one disjoint ``bkv`` block per step."""
    if d <= 0 or s <= 0 or bkv <= 0 or s % bkv:
        raise KernelShapeError(
            f"KV length {s} must be a positive multiple of bkv={bkv} "
            f"(ops.decode_attention pads)")
    kv_tiles = s // bkv
    grid = (kv_tiles,)

    def qmap(i, *_):
        return (0, 0)

    def kvmap(i, *_):
        return (i, 0)

    def omap(i, *_):
        return (0, 0)

    return grid, qmap, kvmap, omap


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, bkv: int, kv_tiles: int,
                   scale: float):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)              # (G, D) resident
    k = k_ref[...].astype(jnp.float32)              # (bkv, D) streamed
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = step * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, _NEG_INF)

    m_prev = m_ref[...]                             # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                          # (G, bkv)
    alpha = jnp.exp(m_prev - m_new)                 # (G, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(step == kv_tiles - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: jax.Array | int | None = None, *,
                     bkv: int = 512, interpret: bool = True) -> jax.Array:
    """q (G, D), k/v (S, D), optional valid ``length`` -> (G, D)."""
    g, d = q.shape
    s, d2 = k.shape
    if d != d2:
        raise KernelShapeError(f"q has head dim {d} but k has {d2}")
    grid, qmap, kvmap, omap = decode_specs(g, d, s, bkv)
    kv_tiles = s // bkv
    if length is None:
        length = s
    length = jnp.asarray(length, jnp.int32).reshape(1)
    kernel = functools.partial(
        _decode_kernel, bkv=bkv, kv_tiles=kv_tiles,
        scale=1.0 / (d ** 0.5))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((g, d), qmap),      # q resident (Λ)
            pl.BlockSpec((bkv, d), kvmap),   # K patch group
            pl.BlockSpec((bkv, d), kvmap),   # V patch group
        ],
        out_specs=pl.BlockSpec((g, d), omap),
        scratch_shapes=[pltpu.VMEM((g, d), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32)])
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), q.dtype),
        interpret=interpret,
    )(length, q, k, v)
