"""Jit'd public wrappers around the Pallas kernels.

Each wrapper: pads to kernel-friendly shapes, consults ``core.planner`` for
the offloading schedule when the caller does not pin one, dispatches to the
Pallas kernel (interpret=True on CPU — the TPU path flips the flag), and
unpads.  ``ref.py`` holds the oracles; tests sweep shapes/dtypes and
assert_allclose kernel vs oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import planner
from repro.kernels import KernelShapeError
from repro.kernels import block_matmul as _bm
from repro.kernels import conv2d_offload as _conv
from repro.kernels import flash_decode as _fd

_INTERPRET = True          # CPU container; TPU deployments set False.


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("t_run", "s_h", "s_w", "order"))
def conv2d(x: jax.Array, w: jax.Array, *, t_run: int | None = None,
           s_h: int = 1, s_w: int = 1, order: str = "zigzag") -> jax.Array:
    """S1 Pallas convolution; ``t_run=None`` asks the planner."""
    c_in, h_in, w_in = x.shape
    n, _, h_k, w_k = w.shape
    w_out = (w_in - w_k) // s_w + 1
    if t_run is None:
        from repro.core.conv_spec import ConvSpec
        spec = ConvSpec(c_in, h_in, w_in, n, h_k, w_k, s_h, s_w)
        t_run = planner.plan_conv(spec, dtype_bytes=x.dtype.itemsize
                                  ).tiles["t"]
    # pad W_in so W_out divides by t_run (extra columns discarded after)
    pad_cols = ((-w_out) % t_run) * s_w
    if pad_cols:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_cols)))
    out = _conv.conv2d_offload(x, w, t_run=t_run, s_h=s_h, s_w=s_w,
                               order=order, interpret=_INTERPRET)
    return out[:, :, :w_out]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "order", "plan"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int | None = None,
           bn: int | None = None, bk: int | None = None,
           order: str | None = None, plan: bool = True) -> jax.Array:
    """Planner-scheduled block GeMM."""
    m, k = a.shape
    _, n = b.shape
    if bm is None or bn is None or bk is None or order is None:
        p = planner.plan_matmul(m, n, k, dtype_bytes=a.dtype.itemsize)
        bm = bm or min(p.tiles["bm"], 1 << (max(m, 8) - 1).bit_length())
        bn = bn or min(p.tiles["bn"], 1 << (max(n, 8) - 1).bit_length())
        bk = bk or min(p.tiles["bk"], 1 << (max(k, 8) - 1).bit_length())
        order = order or p.order
    a = _pad_to(_pad_to(a, 0, bm), 1, bk)
    b = _pad_to(_pad_to(b, 0, bk), 1, bn)
    out = _bm.block_matmul(a, b, bm=bm, bn=bn, bk=bk, order=order,
                           interpret=_INTERPRET)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bkv",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array | None = None, *,
                     bkv: int | None = None) -> jax.Array:
    """Batched GQA decode attention over a (padded) KV cache.

    q: (B, H_q, D); k/v: (B, S, H_kv, D); lengths: (B,) valid cache lengths.
    Returns (B, H_q, D).
    """
    b, h_q, d = q.shape
    _, s, h_kv, _ = k.shape
    if h_q % h_kv != 0:
        raise KernelShapeError(
            f"GQA needs h_q={h_q} divisible by h_kv={h_kv}")
    g = h_q // h_kv
    if bkv is None:
        p = planner.plan_decode_attention(s, d, g, q.dtype.itemsize)
        bkv = min(p.tiles["bkv"], s)
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)

    qg = q.reshape(b, h_kv, g, d)
    kg = jnp.moveaxis(k, 2, 1)           # (B, H_kv, S, D)
    vg = jnp.moveaxis(v, 2, 1)

    single = functools.partial(_fd.decode_attention, bkv=bkv,
                               interpret=_INTERPRET)
    per_head = jax.vmap(single, in_axes=(0, 0, 0, None))     # over H_kv
    per_batch = jax.vmap(per_head, in_axes=(0, 0, 0, 0))     # over B
    out = per_batch(qg, kg, vg, lengths)
    return out.reshape(b, h_q, d)
