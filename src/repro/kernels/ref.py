"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def conv2d(x: jax.Array, w: jax.Array, s_h: int = 1, s_w: int = 1
           ) -> jax.Array:
    """(C_in, H_in, W_in) x (N, C_in, Hk, Wk) -> (N, H_out, W_out)."""
    out = lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(s_h, s_w), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out[0].astype(x.dtype)


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(a.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: int | None = None) -> jax.Array:
    """Single-position attention: q (G, D), k/v (S, D) -> (G, D).

    ``length`` masks positions >= length (padded KV cache)."""
    scores = jnp.einsum("gd,sd->gs", q.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    if length is not None:
        pos = jnp.arange(k.shape[0])
        scores = jnp.where(pos[None, :] < length, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("gs,sd->gd", p, v.astype(jnp.float32)).astype(q.dtype)
