import os
# 512 placeholder devices for the production meshes; LICM disabled because
# XLA:CPU legalizes bf16 dots by f32-upcasting operands and then hoists the
# loop-invariant converts OUT of the layer scans — materialising f32 copies
# of entire weight/cache stacks (observed +13 GB/device on decode cells).
# TPU executes bf16 dots natively, so those converts do not exist there;
# disabling the hoist makes the memory analysis reflect the target.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline raw terms.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices (smoke tests and
benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--out benchmarks/results/dryrun]

Per cell this produces a JSON with:
  * memory_analysis (bytes/device: args, outputs, temps, generated code)
  * cost_analysis flops + bytes accessed (per-device SPMD program)
  * per-collective byte totals parsed from the optimized HLO
which EXPERIMENTS.md §Dry-run / §Roofline consume.
"""
import argparse
import json
import re
import sys
import time

import jax

from repro.launch import steps
from repro.launch.mesh import enter_mesh, make_production_mesh
from repro.models import registry
from repro.models.common import SHAPES, Axes, cell_applicable

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[4,1024]' -> bytes.  Tuple shapes handled by summing parts."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO
    (per-device program -> per-device bytes moved)."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # result-defining lines look like: '%name = TYPE op-name(' or
        # 'name.N = TYPE fusion(' — find ' = <shape> <op>(' patterns.
        for coll in _COLLECTIVES:
            if f" {coll}(" not in s and f" {coll}-start(" not in s and \
                    f" {coll}-done(" not in s:
                continue
            if f"{coll}-done(" in s:
                continue                      # counted at -start
            eq = s.find(" = ")
            if eq < 0:
                continue
            rhs = s[eq + 3:]
            op_pos = rhs.find(coll)
            shape_str = rhs[:op_pos]
            out[coll] += _shape_bytes(shape_str)
            out["count"] += 1
            break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def lower_cell(arch: str, shape: str, multi_pod: bool):
    api = registry.get(arch)
    cell = SHAPES[shape]
    ok, why = cell_applicable(api.cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = Axes.for_mesh(mesh)
    with enter_mesh(mesh):
        t0 = time.time()
        if cell.kind == "train":
            jitted = steps.jit_train_step(api, axes, cell)
            args = steps.abstract_train_args(api, cell, axes)
        elif cell.kind == "prefill":
            jitted = steps.jit_prefill_step(api, axes, cell)
            args = steps.abstract_serve_args(api, cell, axes)
        else:
            jitted = steps.jit_decode_step(api, axes, cell)
            args = steps.abstract_serve_args(api, cell, axes)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    from repro.launch import hlo_stats
    cost = hlo_stats.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)          # raw text scan (bodies once)
    stats = hlo_stats.analyze(hlo)         # trip-count-corrected roll-up

    result = {
        "arch": arch, "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "multi_pod": multi_pod,
        "status": "ok",
        "kind": cell.kind,
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": (mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
        },
        "cost": {
            # raw XLA numbers: while bodies counted ONCE (undercount for
            # scanned models) — kept for reference/debugging.
            "flops_per_device_raw": cost.get("flops", 0.0),
            "bytes_accessed_per_device_raw": cost.get("bytes accessed", 0.0),
        },
        # trip-count-corrected structural analysis (launch/hlo_stats.py):
        # the numbers §Roofline uses.
        "analyzed": {
            "matmul_flops_per_device": stats.flops,
            "bytes_accessed_per_device": stats.bytes_accessed,
            "collective_bytes_per_device": stats.collective_bytes,
            "collective_bytes_total": stats.collective_total,
            "collective_count": stats.collective_count,
            "unknown_trip_loops": stats.unknown_trip_loops,
        },
        "collectives_per_device_bytes_raw": colls,
        "hlo_bytes": len(hlo),
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args(argv)

    result = lower_cell(args.arch, args.shape, args.multi_pod)
    mesh_tag = "pod" if args.multi_pod else "single"
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(
        args.out, f"{args.arch}_{args.shape}_{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(json.dumps({k: v for k, v in result.items()
                      if k not in ("memory", "cost")}, indent=1))
    if result["status"] == "ok":
        print("memory_analysis:", json.dumps(result["memory"]))
        print("cost_analysis:", json.dumps(result["cost"]))
    print("saved ->", path)
    return 0 if result["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
