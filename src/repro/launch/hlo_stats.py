"""Structural analysis of optimized (post-SPMD) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts every while-loop
body ONCE — a 60-layer scanned model reports ~1 layer of FLOPs.  This module
parses the optimized HLO, recovers the call graph (while bodies, fusions,
calls) and the loop trip counts, and rolls up

  * matmul FLOPs (dot ops, 2*prod(out)*prod(contract) convention),
  * bytes accessed (operands + outputs per surface op; fusion internals
    excluded, matching XLA's one-kernel fusion model),
  * per-collective bytes (result shape of each all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, -start counted once),

each multiplied by the enclosing loops' trip counts.  Elementwise FLOPs are
not counted (MXU roofline wants matmul FLOPs; documented in EXPERIMENTS).

Validated in tests/test_hlo_stats.py against cost_analysis on loop-free
programs and against analytic counts on scanned programs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CALLEE_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# surface ops that do not move data
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota"}


def cost_analysis_dict(compiled) -> dict:
    """Normalise ``Compiled.cost_analysis()`` across jax versions.

    Old jax returns a one-element list of per-program dicts; newer jax
    returns the dict directly.  Either way callers get a plain dict
    (possibly empty when the backend reports nothing).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str           # text after the opening paren (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]
    ops: list[Op]
    is_entry: bool = False

    def shape_of(self, name: str) -> str | None:
        if name in self.params:
            return self.params[name]
        for op in self.ops:
            if op.name == name:
                return op.shape
        return None


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m and ("->" in line):
            params = {}
            for p in re.finditer(
                    r"([\w\.\-]+)\s*:\s*("
                    r"\([^)]*\)"                                # tuple type
                    r"|[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?"    # array type
                    r"|[a-z0-9]+\[\]"                           # scalar
                    r")", m.group(3)):
                params[p.group(1)] = p.group(2)
            cur = Computation(name=m.group(2), params=params, ops=[],
                              is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            cur.ops.append(Op(name=om.group(1), shape=om.group(2).strip(),
                              kind=om.group(3), rest=om.group(4)))
    return comps


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    collective_count: float = 0.0
    unknown_trip_loops: int = 0

    def add(self, other: "Stats", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for c in _COLLECTIVES:
            self.collective_bytes[c] += other.collective_bytes[c] * mult
        self.collective_count += other.collective_count * mult
        self.unknown_trip_loops += other.unknown_trip_loops

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for _, dims in _shape_dims(op.shape):
        for d in dims:
            out_elems *= d
    cm = _CONTRACT_RE.search(op.rest)
    contract = 1
    operands = _OPERAND_RE.findall(op.rest.split(", lhs_contracting")[0])
    if cm and operands:
        lhs_shape = comp.shape_of(operands[0])
        if lhs_shape:
            shapes = _shape_dims(lhs_shape)
            if shapes:
                dims = shapes[0][1]
                for idx in (int(i) for i in cm.group(1).split(",") if i):
                    if idx < len(dims):
                        contract *= dims[idx]
    return 2.0 * out_elems * contract


def _op_bytes(op: Op, comp: Computation) -> float:
    """Operands + output logical bytes for a surface op."""
    total = float(_shape_bytes(op.shape))
    # operands appear before the first attribute (comma-separated attrs all
    # contain '='); just scan names and look them up.
    head = op.rest.split("=")[0] if "=" in op.rest else op.rest
    for name in _OPERAND_RE.findall(head):
        s = comp.shape_of(name)
        if s:
            total += _shape_bytes(s)
    return total


def _trip_count(cond: Computation) -> int | None:
    """Largest s32 scalar constant in the loop condition (counter LT bound).
    jax-emitted scans always look like this; None if no constant found."""
    best = None
    for op in cond.ops:
        m = _CONST_RE.search(f"= {op.shape} {op.kind}({op.rest}")
        if op.kind == "constant":
            mm = re.match(r"s32\[\]", op.shape)
            if mm:
                cm = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
                if cm:
                    v = int(cm.group(1))
                    best = v if best is None else max(best, v)
    return best


def analyze(hlo: str) -> Stats:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    memo: dict[str, Stats] = {}
    visiting: set[str] = set()

    def total(name: str) -> Stats:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return Stats()
        visiting.add(name)
        comp = comps[name]
        st = Stats()
        for op in comp.ops:
            if op.kind == "dot":
                st.flops += _dot_flops(op, comp)
                st.bytes_accessed += _op_bytes(op, comp)
            elif op.kind == "while":
                body = cond = None
                m = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                if m:
                    cond = m.group(1)
                m = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if m:
                    body = m.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else None
                if trips is None:
                    trips = 1
                    st.unknown_trip_loops += 1
                if body:
                    st.add(total(body), trips)
                if cond:
                    st.add(total(cond), trips)
            elif op.kind in ("fusion", "call", "custom-call",
                             "conditional", "map", "reduce",
                             "reduce-window", "sort", "scatter", "select-and-scatter"):
                st.bytes_accessed += _op_bytes(op, comp)
                for callee in _CALLEE_RE.findall(op.rest):
                    sub = total(callee)
                    # fusion internals: count flops (a dot may hide inside)
                    # but not bytes (one-kernel model).
                    st.flops += sub.flops
                    for c in _COLLECTIVES:
                        st.collective_bytes[c] += sub.collective_bytes[c]
                    st.collective_count += sub.collective_count
            elif any(op.kind == c or op.kind == c + "-start"
                     for c in _COLLECTIVES):
                kind = op.kind.replace("-start", "")
                b = float(_shape_bytes(op.shape))
                st.collective_bytes[kind] += b
                st.collective_count += 1
                st.bytes_accessed += _op_bytes(op, comp)
            elif op.kind.endswith("-done"):
                pass
            elif op.kind in _FREE_OPS:
                pass
            else:
                st.bytes_accessed += _op_bytes(op, comp)
        visiting.discard(name)
        memo[name] = st
        return st

    return total(entry.name)
