"""Production mesh builders (multi-pod dry-run contract).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: a leading pure-DP "pod" axis (2 pods = 512 chips) — the lowest
ICI-pressure placement for the slower inter-pod links (DESIGN.md §5)."""
from __future__ import annotations

import contextlib

import jax


_ACTIVE_MESH = None      # legacy-path bookkeeping for as_shardings()


def supports_ambient_partition_specs() -> bool:
    """True when this jax lets jit in/out_shardings be bare PartitionSpecs
    resolved against the ambient mesh (the set_mesh / use_mesh era)."""
    return hasattr(jax, "set_mesh") or hasattr(jax.sharding, "use_mesh")


@contextlib.contextmanager
def _legacy_mesh_ctx(mesh):
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        with mesh:               # 0.4.x: Mesh is the resource-env manager
            yield mesh
    finally:
        _ACTIVE_MESH = prev


def enter_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh across jax versions.

    * jax >= 0.5: ``jax.sharding.use_mesh`` (a real context manager that
      restores the previous mesh — preferred over ``jax.set_mesh``, whose
      bare-setter form on some versions cannot be undone);
    * jax >= 0.6 without use_mesh: ``jax.set_mesh``;
    * jax 0.4.x (this container): the ``Mesh`` object itself is the
      resource-env context manager, and the mesh is recorded so
      ``as_shardings`` can build concrete NamedShardings.
    """
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        cm = set_mesh(mesh)
        return cm if hasattr(cm, "__enter__") else \
            contextlib.nullcontext(mesh)
    return _legacy_mesh_ctx(mesh)


def as_shardings(tree):
    """Adapt a PartitionSpec pytree to what this jax's jit accepts.

    New jax (ambient-mesh era): specs pass through untouched.  jax 0.4.x:
    every PartitionSpec leaf is wrapped into a NamedSharding over the mesh
    entered via ``enter_mesh`` (jit there rejects bare specs)."""
    if tree is None or supports_ambient_partition_specs():
        return tree
    mesh = _ACTIVE_MESH
    if mesh is None:
        return tree
    is_spec = lambda s: isinstance(s, jax.sharding.PartitionSpec)
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s) if is_spec(s) else s,
        tree, is_leaf=is_spec)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests)."""
    n = devices or len(jax.devices())
    d = max(1, n // 2) if n > 1 else 1
    m = n // d
    try:
        from jax.sharding import AxisType
        return jax.make_mesh((d, m), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    except (ImportError, TypeError):
        return jax.make_mesh((d, m), ("data", "model"))
