"""Production mesh builders (multi-pod dry-run contract).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: a leading pure-DP "pod" axis (2 pods = 512 chips) — the lowest
ICI-pressure placement for the slower inter-pod links (DESIGN.md §5)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests)."""
    n = devices or len(jax.devices())
    d = max(1, n // 2) if n > 1 else 1
    m = n // d
    try:
        from jax.sharding import AxisType
        return jax.make_mesh((d, m), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    except (ImportError, TypeError):
        return jax.make_mesh((d, m), ("data", "model"))
