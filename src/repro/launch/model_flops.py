"""Analytic MODEL_FLOPS per (arch x cell) — the "useful work" reference for
the §Roofline ratio MODEL_FLOPS / HLO_FLOPs.

Conventions (documented in EXPERIMENTS.md):
  * parameter flops: 6·N·D for training (fwd 2 + bwd 4; remat recompute is
    deliberately NOT included — it is waste the ratio should expose),
    2·N·D for forward-only (prefill/decode);
  * N counts matmul-visible parameters (embedding gather excluded, LM head
    included, MoE experts counted at top_k + shared activation);
  * attention flops: 4·S²·H·dh per layer per sequence (QK^T + PV, full
    square — our flash computes the full square), x3 for training;
  * SSD flops: intra-chunk quadratic + state terms per the ssm.py einsums.
"""
from __future__ import annotations

from repro.models.common import ArchConfig, ShapeCell
from repro.models.registry import ModelApi


def _dense_layer_params(cfg: ArchConfig) -> int:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.family in ("ssm", "hybrid"):
        # backbone layers are pure SSD mixers (zamba's attention/MLP live
        # only in the shared block, added separately)
        di, n, hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        return d * (2 * di + 2 * n + hs) + di * d
    if cfg.mla:
        qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        attn = (cfg.d_model * cfg.q_lora_rank
                + cfg.q_lora_rank * h * qk
                + cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                + cfg.kv_lora_rank * h * (cfg.qk_nope_head_dim
                                          + cfg.v_head_dim)
                + h * cfg.v_head_dim * d)
    elif h:
        attn = d * h * dh + 2 * d * hk * dh + h * dh * d
    else:
        attn = 0
    if cfg.n_experts:
        ffn_active = 3 * d * cfg.d_ff * (cfg.top_k + cfg.n_shared_experts)
    elif cfg.d_ff:
        mult = 2 if cfg.family == "audio" else 3      # gelu vs swiglu
        ffn_active = mult * d * cfg.d_ff
    else:
        ffn_active = 0
    return attn + ffn_active


def active_param_flops_per_token(cfg: ArchConfig) -> int:
    """2·N_active: matmul params touched per token, times 2."""
    per_layer = _dense_layer_params(cfg)
    n = cfg.n_layers * per_layer
    if cfg.family == "audio":
        # decoder layers add cross-attention (q + o over d, k/v over d)
        n += (cfg.dec_layers or cfg.n_layers) * (
            _dense_layer_params(cfg)
            + 4 * cfg.d_model * cfg.n_heads * cfg.head_dim)
    if cfg.family == "hybrid":
        d = cfg.d_model
        shared = (2 * d * d + d * cfg.n_heads * cfg.head_dim
                  + 2 * d * cfg.n_kv_heads * cfg.head_dim
                  + cfg.n_heads * cfg.head_dim * d + 3 * d * cfg.d_ff)
        n += (cfg.n_layers // cfg.attn_every) * shared
    n += cfg.d_model * cfg.padded_vocab          # lm head
    return 2 * n


def _attn_flops_fwd(cfg: ArchConfig, s: int, kv_len: int | None = None
                    ) -> int:
    """Per sequence, all layers: QK^T + PV (full square / full cache)."""
    kv_len = kv_len or s
    if cfg.family == "ssm":
        # SSD: scores 2·nc·Q²·N + intra 2·nc·Q²·H·P + states/out terms
        q = cfg.ssm_chunk
        nc = max(1, s // q)
        n, h, p = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        per_layer = nc * (2 * q * q * n + 2 * q * q * h * p
                          + 4 * q * h * p * n)
        return cfg.n_layers * per_layer
    total = 0
    if cfg.n_heads:
        dh_qk = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) if cfg.mla \
            else cfg.head_dim
        dh_v = cfg.v_head_dim if cfg.mla else cfg.head_dim
        per_layer = 2 * s * kv_len * cfg.n_heads * (dh_qk + dh_v)
        if cfg.family == "hybrid":
            total += (cfg.n_layers // cfg.attn_every) * per_layer
            # plus the SSD backbone
            ssm_cfg = cfg
            q = cfg.ssm_chunk
            nc = max(1, s // q)
            n, h, p = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
            total += cfg.n_layers * nc * (2 * q * q * n + 2 * q * q * h * p
                                          + 4 * q * h * p * n)
        elif cfg.family == "audio":
            total += cfg.n_layers * per_layer                    # encoder
            dec = cfg.dec_layers or cfg.n_layers
            t = cfg.dec_seq
            total += dec * 2 * t * t * cfg.n_heads * 2 * cfg.head_dim
            total += dec * 2 * t * kv_len * cfg.n_heads * 2 * cfg.head_dim
        else:
            total += cfg.n_layers * per_layer
    return total


def _audio_parts(cfg: ArchConfig):
    enc_params = cfg.n_layers * _dense_layer_params(cfg)
    dec_l = cfg.dec_layers or cfg.n_layers
    dec_params = dec_l * (_dense_layer_params(cfg)
                          + 4 * cfg.d_model * cfg.n_heads * cfg.head_dim) \
        + cfg.d_model * cfg.padded_vocab
    return enc_params, dec_params, dec_l


def model_flops(api: ModelApi, cell: ShapeCell) -> float:
    """Useful FLOPs per executed step, whole job (all devices)."""
    cfg = api.cfg
    b, s = cell.global_batch, cell.seq_len
    pf = active_param_flops_per_token(cfg)
    hdh = cfg.n_heads * (cfg.head_dim or 0)
    if cfg.family == "audio":
        enc_p, dec_p, dec_l = _audio_parts(cfg)
        t = cfg.dec_seq
        enc_fwd = (2 * enc_p * s + cfg.n_layers * 4 * s * s * hdh) * b
        if cell.kind == "train":
            dec_fwd = (2 * dec_p * t
                       + dec_l * (4 * t * t * hdh + 4 * t * s * hdh)) * b
            return 3 * (enc_fwd + dec_fwd)
        if cell.kind == "prefill":     # encode + 1 BOS decoder token
            return enc_fwd + (2 * dec_p + dec_l * 4 * s * hdh) * b
        # decode: 1 token, self cache dec_seq + cross cache s
        return (2 * dec_p + dec_l * (4 * t * hdh + 4 * s * hdh)) * b
    if cell.kind == "train":
        return 3 * pf * b * s + 3 * _attn_flops_fwd(cfg, s) * b
    if cell.kind == "prefill":
        return pf * b * s + _attn_flops_fwd(cfg, s) * b
    # decode: one token, cache length s
    if cfg.family in ("ssm", "hybrid"):
        n, h, p = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
        rec = cfg.n_layers * (4 * h * p * n)
        attn = 0
        if cfg.family == "hybrid":
            attn = (cfg.n_layers // cfg.attn_every) * 4 * s * hdh
        return (pf + rec + attn) * b
    return pf * b + _attn_flops_fwd(cfg, 1, kv_len=s) * b
