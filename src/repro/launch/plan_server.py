"""Planner-as-a-service: sweep-query plan serving over the persistent cache.

``python -m repro.launch.plan_server`` answers full
budget x topology x chip-count sweep queries for the registered networks.
Every per-layer solve goes through ``solver.solve_cached``'s two cache
layers — the in-memory LRU and, when a cache directory is given (the
``--cache-dir`` flag or the ``REPRO_PLAN_CACHE`` env var), the
content-hashed on-disk store from ``repro.plancache`` — so a warm server
answers a full sweep in seconds where a cold planner takes minutes, and
bit-identically: an exact-key store hit replays the recorded strategy,
and near-miss scenarios (same layers, neighbouring budget) warm-start
the polish instead of searching from scratch.

Every served plan is re-checked against the ``repro.analysis`` verifier
postconditions (``verify=False`` only skips the planner's *internal*
check; the service always runs its own unless constructed with
``verify=False``), and every row carries its cache attribution
(solver calls / LRU hits / store hits) plus a ``plan_fingerprint`` so
callers can prove warm answers identical to cold ones.

CLI::

    PYTHONPATH=src python -m repro.launch.plan_server \
        --network tight4 --budgets auto --topologies ring torus2x2 \
        --chips 1 4 --cache-dir /tmp/plancache --out sweep.json

Exit code 0 iff at least one scenario is feasible and every feasible
plan passed the verifier.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Sequence

from repro.configs.clusters import make_cluster, torus_dims
from repro.configs.networks import NETWORKS
from repro.configs.tight import budget_points
from repro.core import solver as solver_mod
from repro.core.cost_model import Topology
from repro.core.multichip import plan_multichip_network
from repro.core.network_planner import InfeasibleNetworkError
from repro.obs.metrics import REGISTRY
from repro.plancache import codec as codec_mod
from repro.plancache import store as store_mod


@dataclasses.dataclass(frozen=True)
class PlanQuery:
    """One scenario: a network on a concrete cluster under a budget."""

    network: str
    size_mem: int | None = None
    topology: str = "ring"
    n_chips: int = 1
    nbop_pe: int = 10 ** 9
    polish_iters: int = 600
    polish_restarts: int = 1
    rng_seed: int = 0


def resolve_topology(topology: str, n_chips: int) -> str | None:
    """Concrete topology label for a sweep point, or None when the
    combination does not exist (a torus needs a 2-D grid of exactly
    ``n_chips``; ``torus`` auto-picks the squarest).  One chip has no
    links, so every wiring resolves to the same ``ring`` point there
    (deduped by :meth:`PlanService.sweep`)."""
    if n_chips == 1:
        return "ring"
    if topology in ("ring", "biring"):
        return topology
    if topology == "torus":
        dims = torus_dims(n_chips)
        return None if dims is None else f"torus{dims[0]}x{dims[1]}"
    ny, nx = Topology.parse(topology).dims
    return topology if ny * nx == n_chips else None


class PlanService:
    """The query API behind the CLI — importable for in-process use
    (tests, the benchmark's cold/warm canary)."""

    def __init__(self, cache_dir: "str | None" = None, *,
                 verify: bool = True) -> None:
        if cache_dir is not None:
            store_mod.configure(cache_dir)
        self.verify = verify

    def query(self, q: PlanQuery) -> dict[str, Any]:
        """Plan one scenario and return a serializable row: the plan's
        headline numbers, a content fingerprint of its decisions, and
        this query's own cache-attribution window."""
        if q.network not in NETWORKS:
            raise KeyError(f"unknown network {q.network!r}; "
                           f"registered: {sorted(NETWORKS)}")
        REGISTRY.incr("plan_server/queries")
        stats0 = solver_mod.cache_stats()
        t0 = time.perf_counter()
        cluster = make_cluster(q.n_chips, nbop_pe=q.nbop_pe,
                               size_mem=q.size_mem, topology=q.topology)
        base: dict[str, Any] = {
            "network": q.network, "size_mem": q.size_mem,
            "topology": q.topology, "n_chips": q.n_chips,
        }
        try:
            plan = plan_multichip_network(
                NETWORKS[q.network], cluster, name=q.network,
                polish_iters=q.polish_iters,
                polish_restarts=q.polish_restarts, rng_seed=q.rng_seed,
                include_single_chip_baseline=False, verify=False)
        except InfeasibleNetworkError as e:
            delta = solver_mod.cache_stats() - stats0
            return {**base, "feasible": False, "error": str(e),
                    "verified": False,
                    "planning_seconds": round(time.perf_counter() - t0, 4),
                    "solver_calls": delta.solve_calls,
                    "cache_hits": delta.solve_hits,
                    "store_hits": delta.store_hits,
                    "store_misses": delta.store_misses}
        verified = False
        if self.verify:
            from repro.analysis.verifier import assert_verified
            assert_verified(plan)
            verified = True
        delta = solver_mod.cache_stats() - stats0
        return {
            **base,
            "feasible": True,
            "verified": verified,
            "total_duration": plan.total_duration,
            "layer_modes": [lp.mode for lp in plan.layers],
            "mode_string": plan.mode_string,
            "fingerprint": codec_mod.plan_fingerprint(plan),
            "planning_seconds": round(time.perf_counter() - t0, 4),
            "solver_calls": delta.solve_calls,
            "cache_hits": delta.solve_hits,
            "store_hits": delta.store_hits,
            "store_misses": delta.store_misses,
        }

    def sweep(self, network: str, *,
              budgets: Sequence[int],
              topologies: Sequence[str] = ("ring",),
              chip_counts: Sequence[int] = (1,),
              nbop_pe: int = 10 ** 9,
              polish_iters: int = 600,
              polish_restarts: int = 1,
              rng_seed: int = 0) -> list[dict[str, Any]]:
        """The full budget x topology x chips grid for ``network``.
        Non-existent (topology, n_chips) combinations are skipped and
        duplicate resolutions (every wiring at 1 chip is ``ring``) are
        answered once."""
        rows: list[dict[str, Any]] = []
        for n_chips in chip_counts:
            seen: set[str] = set()
            for topo in topologies:
                label = resolve_topology(topo, n_chips)
                if label is None or label in seen:
                    continue
                seen.add(label)
                for size_mem in budgets:
                    rows.append(self.query(PlanQuery(
                        network=network, size_mem=size_mem,
                        topology=label, n_chips=n_chips,
                        nbop_pe=nbop_pe, polish_iters=polish_iters,
                        polish_restarts=polish_restarts,
                        rng_seed=rng_seed)))
                    REGISTRY.incr("plan_server/scenarios")
        return rows

    def cache_stats(self) -> dict[str, Any]:
        """Both layers' counters: the LRUs plus the persistent store
        (``store: None`` when no cache directory is configured)."""
        info = solver_mod.solve_cached.cache_info()
        s2 = solver_mod.best_s2_cached.cache_info()
        store = store_mod.active_store()
        return {
            "lru": {
                "solve_cached": {"hits": info.hits, "misses": info.misses,
                                 "currsize": info.currsize},
                "best_s2_cached": {"hits": s2.hits, "misses": s2.misses,
                                   "currsize": s2.currsize},
            },
            "store": store.stats() if store is not None else None,
        }


def _parse_budgets(raw: "list[str]", network: str) -> list[int]:
    if raw == ["auto"]:
        return budget_points(NETWORKS[network])
    return [int(v) for v in raw]


def main(argv: "Sequence[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.plan_server",
        description="Answer plan sweep queries from the persistent "
                    "plan cache (repro.plancache).")
    ap.add_argument("--network", nargs="*", default=sorted(NETWORKS),
                    help="networks to sweep (default: all registered)")
    ap.add_argument("--budgets", nargs="+", default=["auto"],
                    help="'auto' (the tight budget_points grid) or "
                         "explicit size_mem values")
    ap.add_argument("--topologies", nargs="+", default=["ring"],
                    help="ring | biring | torusRxC | torus (auto-dims)")
    ap.add_argument("--chips", nargs="+", type=int, default=[1],
                    help="chip counts for the sweep grid")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent plan-cache directory (defaults to "
                         "the REPRO_PLAN_CACHE env var; omit both for "
                         "in-memory caching only)")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--restarts", type=int, default=1)
    ap.add_argument("--rng-seed", type=int, default=0)
    ap.add_argument("--nbop-pe", type=int, default=10 ** 9)
    ap.add_argument("--out", default=None, help="write the sweep JSON here")
    args = ap.parse_args(argv)

    service = PlanService(args.cache_dir)
    t0 = time.perf_counter()
    sweeps: list[dict[str, Any]] = []
    for network in args.network:
        rows = service.sweep(
            network, budgets=_parse_budgets(args.budgets, network),
            topologies=args.topologies, chip_counts=args.chips,
            nbop_pe=args.nbop_pe, polish_iters=args.iters,
            polish_restarts=args.restarts, rng_seed=args.rng_seed)
        sweeps.append({"network": network, "rows": rows})
        feas = [r for r in rows if r["feasible"]]
        hits = sum(r["cache_hits"] + r["store_hits"] for r in rows)
        calls = sum(r["solver_calls"] for r in rows)
        print(f"[plan_server] {network}: {len(feas)}/{len(rows)} "
              f"scenarios feasible, {calls} solver calls, "
              f"{hits} cache hits (LRU + store)")

    result: dict[str, Any] = {
        "sweeps": sweeps,
        "wall_seconds": round(time.perf_counter() - t0, 4),
        "cache": service.cache_stats(),
    }
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        print(f"[plan_server] wrote {args.out}")

    all_rows = [r for s in sweeps for r in s["rows"]]
    feasible = [r for r in all_rows if r["feasible"]]
    ok = bool(feasible) and all(r["verified"] for r in feasible)
    print(f"[plan_server] {len(feasible)}/{len(all_rows)} feasible, "
          f"all verified: {ok}, wall {result['wall_seconds']}s")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
