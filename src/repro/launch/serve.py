"""Serving launcher: batched prefill + decode loop.

The decode step is the S1 offloading schedule of DESIGN.md §4: resident
queries stream the KV cache block by block (the Pallas flash_decode kernel
on TPU; the sharded jnp path under pjit).  Smoke mode runs a real batched
generation on CPU with the reduced config."""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_mod
from repro.launch.mesh import enter_mesh, make_production_mesh, \
    make_smoke_mesh
from repro.models import registry
from repro.models.common import Axes


class ServeConfigError(ValueError):
    """A serving config that cannot run (non-positive batch/lengths) —
    caught at the entry point instead of surfacing as a shape error deep
    inside jit tracing (or, for ``gen_len=0``, an empty ``np.stack``)."""


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen_len: int = 16,
          multi_pod: bool = False, greedy: bool = True):
    if batch < 1 or prompt_len < 1 or gen_len < 1:
        raise ServeConfigError(
            f"batch, prompt_len and gen_len must all be >= 1, got "
            f"batch={batch} prompt_len={prompt_len} gen_len={gen_len}")
    with contextlib.ExitStack() as mesh_ctx:
        if smoke:
            api = registry.get_reduced(arch)
            axes = None
        else:
            api = registry.get(arch)
            mesh = make_production_mesh(multi_pod=multi_pod)
            mesh_ctx.enter_context(enter_mesh(mesh))
            axes = Axes.for_mesh(mesh)
        return _serve_loop(api, axes, batch=batch, prompt_len=prompt_len,
                           gen_len=gen_len)


def _serve_loop(api, axes, *, batch, prompt_len, gen_len):
    cfg = api.cfg
    max_len = prompt_len + gen_len

    params = api.init_params(jax.random.key(0), axes)
    rng = np.random.default_rng(0)
    prompts = rng.integers(3, cfg.vocab, size=(batch, prompt_len))

    prefill = jax.jit(lambda p, b: api.prefill_fn(p, b, axes,
                                                  max_len=max_len))
    decode = jax.jit(steps_mod.make_decode_step(api, axes))

    t0 = time.time()
    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)),
            jnp.bfloat16)
        logits, cache = prefill(params, {"frames": frames})
        start_pos = 1
    else:
        logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
        start_pos = prompt_len
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(gen_len):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok, jnp.int32(start_pos + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t_decode = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"[serve] batch={batch} prefill {t_prefill:.2f}s, "
          f"{gen_len} decode steps {t_decode:.2f}s "
          f"({t_decode / gen_len * 1e3:.0f} ms/step on CPU)")
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args(argv)
    gen = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len,
                multi_pod=args.multi_pod)
    print("[serve] generated token matrix shape:", gen.shape)


if __name__ == "__main__":
    main()
