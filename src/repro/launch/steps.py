"""Distributed step functions: train_step / serve_prefill / serve_step.

These are the functions the dry-run lowers and the real launcher runs.
All sharding is explicit: params and optimizer state carry the ParamDef
PartitionSpecs, inputs the cell's batch specs; GSPMD materialises the
collective schedule that EXPERIMENTS.md §Roofline audits."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import as_shardings
from repro.models.common import Axes, ShapeCell
from repro.models.registry import ModelApi
from repro.optim import adamw


def make_train_step(api: ModelApi, axes: Axes | None,
                    opt_cfg: adamw.AdamWConfig | None = None,
                    num_microbatches: int = 8):
    """Training step with microbatched gradient accumulation.

    The global batch is split into ``num_microbatches`` slices scanned
    sequentially: only one microbatch's remat stack is live at a time (the
    activation-memory lever) and gradients accumulate into a pytree pinned
    to the parameter sharding (ZeRO-style: no replicated f32 grads)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    pspecs = api.param_specs(axes) if axes else None
    # grads/accumulators take the full ZeRO-1 sharding (data x model,
    # pod-extended on the multi-pod mesh) so reductions are reduce-scatters
    # — even when the weights themselves are data-replicated (small archs).
    gspecs = adamw.state_specs(api.zero1_specs(axes), axes)["m"] \
        if axes else None

    def _pin(grads):
        if gspecs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, gspecs)

    def _n_batch_shards():
        if axes is None:
            return 1
        try:
            mesh = jax.sharding.get_abstract_mesh()
            if mesh is None or mesh.empty:
                return 1
            shape = dict(mesh.shape)
            n = shape.get(axes.data, 1)
            if axes.pod:
                n *= shape.get(axes.pod, 1)
            return n
        except Exception:
            return 1

    def train_step(params, opt_state, batch):
        b = jax.tree.leaves(batch)[0].shape[0]
        nshards = _n_batch_shards()
        # microbatch rows must stay divisible by the batch shards, or GSPMD
        # replicates the microbatch (observed on the multi-pod MoE cells).
        m = num_microbatches
        while m > 1 and (b % m != 0 or (b // m) % nshards != 0):
            m //= 2
        # strided split (row r -> microbatch r % m): every data shard
        # contributes rows to every microbatch, so the batch sharding is
        # preserved inside the accumulation scan.
        micro = jax.tree.map(
            lambda x: jnp.swapaxes(
                x.reshape((b // m, m) + x.shape[1:]), 0, 1), batch)
        if axes is not None:
            micro = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P(*((None, axes.batch) + (None,) * (x.ndim - 2)))),
                micro)

        def accum(carry, mb):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(
                lambda p: api.loss_fn(p, mb, axes))(params)
            gsum = _pin(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return (gsum, lsum + loss), None

        gzero = _pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (gsum, lsum), _ = jax.lax.scan(
            accum, (gzero, jnp.float32(0)), micro)
        grads = jax.tree.map(lambda g: g / m, gsum)
        loss = lsum / m
        params, opt_state, gnorm = adamw.update(params, grads, opt_state,
                                                opt_cfg)
        return loss, gnorm, params, opt_state

    return train_step


def make_prefill_step(api: ModelApi, axes: Axes | None,
                      max_len: int | None = None):
    def serve_prefill(params, batch):
        return api.prefill_fn(params, batch, axes, max_len=max_len)

    return serve_prefill


def make_decode_step(api: ModelApi, axes: Axes | None):
    def serve_step(params, cache, tokens, pos):
        return api.decode_fn(params, cache, tokens, pos, axes)

    return serve_step


def jit_train_step(api: ModelApi, axes: Axes, cell: ShapeCell):
    """jit with explicit in/out shardings for the dry-run / launcher."""
    pspecs = api.param_specs(axes)
    ospecs = adamw.state_specs(api.zero1_specs(axes), axes)
    _, bspecs = api.input_specs(cell, axes)
    # MoE transients scale with tokens/microbatch: slice finer for them.
    micro = 16 if api.cfg.n_experts else 8
    fn = make_train_step(api, axes, num_microbatches=micro)
    return jax.jit(
        fn,
        in_shardings=as_shardings((pspecs, ospecs, bspecs)),
        out_shardings=as_shardings((P(), P(), pspecs, ospecs)),
        donate_argnums=(0, 1))


def jit_prefill_step(api: ModelApi, axes: Axes, cell: ShapeCell):
    from jax.sharding import PartitionSpec as P
    from repro.models.common import param_specs as _pspecs_of
    pspecs = api.param_specs(axes)
    _, bspecs = api.input_specs(cell, axes)
    fn = make_prefill_step(api, axes, max_len=cell.seq_len)
    # pin the returned cache to the decode-cell cache sharding — without
    # this the prefill output cache lands batch-sharded only (observed
    # 12 GB/device of unsharded MLA cache on deepseek prefill_32k).
    cache_specs = _pspecs_of(api.cache_defs(cell.global_batch, cell.seq_len,
                                            axes))
    logits_spec = P(axes.batch if cell.global_batch > 1 else None, None)
    return jax.jit(fn, in_shardings=as_shardings((pspecs, bspecs)),
                   out_shardings=as_shardings((logits_spec, cache_specs)))


def jit_decode_step(api: ModelApi, axes: Axes, cell: ShapeCell):
    pspecs = api.param_specs(axes, layout="decode")
    inputs, ispecs = api.input_specs(cell, axes)
    fn = make_decode_step(api, axes)
    return jax.jit(
        fn,
        in_shardings=as_shardings((pspecs, ispecs["cache"],
                                   ispecs["tokens"], ispecs["pos"])),
        donate_argnums=(1,))


def abstract_train_args(api: ModelApi, cell: ShapeCell,
                        axes: Axes | None = None):
    params = api.abstract_params(axes)
    opt = adamw.abstract_state(params)
    inputs, _ = api.input_specs(cell, axes)
    return params, opt, inputs


def abstract_serve_args(api: ModelApi, cell: ShapeCell,
                        axes: Axes | None = None):
    params = api.abstract_params(axes)
    inputs, _ = api.input_specs(cell, axes)
    if cell.kind == "prefill":
        return params, inputs
    return params, inputs["cache"], inputs["tokens"], inputs["pos"]
