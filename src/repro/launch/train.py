"""Training launcher: mesh + data pipeline + checkpoint/restart loop.

Production path (TPU pods): ``--mesh single|pod`` builds the 256/512-chip
mesh of launch/mesh.py and every step runs the jit'd train_step with the
full sharding contract (same code the dry-run compiles).

Smoke path (this CPU container): ``--smoke`` uses the reduced config on a
1-device mesh and actually trains — the end-to-end driver for
examples/train_lm.py.

Fault tolerance: checkpoints every --checkpoint-every steps via the atomic
CheckpointManager; on restart the latest committed step is restored and the
deterministic pipeline resumes from it (exactly-once).
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline, SyntheticLM
from repro.launch import steps as steps_mod
from repro.launch.mesh import enter_mesh, make_production_mesh, \
    make_smoke_mesh
from repro.models import registry
from repro.models.common import Axes, ShapeCell
from repro.optim import adamw


def train(arch: str, *, smoke: bool = True, steps: int = 10,
          batch: int = 2, seq_len: int = 128, ckpt_dir: str | None = None,
          checkpoint_every: int = 50, lr: float = 3e-4,
          log_every: int = 10, multi_pod: bool = False,
          num_microbatches: int = 1):
    with contextlib.ExitStack() as mesh_ctx:
        if smoke:
            api = registry.get_reduced(arch)
            mesh = make_smoke_mesh()
            axes = None                  # un-meshed fast path on 1 device
        else:
            api = registry.get(arch)
            mesh = make_production_mesh(multi_pod=multi_pod)
            mesh_ctx.enter_context(enter_mesh(mesh))
            axes = Axes.for_mesh(mesh)
        return _train_loop(api, axes, steps=steps, batch=batch,
                           seq_len=seq_len, ckpt_dir=ckpt_dir,
                           checkpoint_every=checkpoint_every, lr=lr,
                           log_every=log_every,
                           num_microbatches=num_microbatches)


def _train_loop(api, axes, *, steps, batch, seq_len, ckpt_dir,
                checkpoint_every, lr, log_every, num_microbatches):
    cfg = api.cfg

    pipe = Pipeline(SyntheticLM(vocab=cfg.vocab, seed=0),
                    DataConfig(global_batch=batch, seq_len=seq_len))
    params = api.init_params(jax.random.key(0), axes)
    opt_state = adamw.init(params)
    opt_cfg = adamw.AdamWConfig(lr=lr)

    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    start_step = 0
    if mgr and mgr.latest_step() is not None:
        (state, meta) = mgr.restore_latest({"params": params,
                                            "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = meta["step"]
        pipe.restore({"step": start_step, "shard": 0})
        print(f"[train] restored step {start_step}")

    step_fn = jax.jit(steps_mod.make_train_step(
        api, axes, opt_cfg, num_microbatches=num_microbatches))

    losses = []
    t_start = time.time()
    for step in range(start_step, steps):
        batch_np = pipe.next()
        loss, gnorm, params, opt_state = step_fn(params, opt_state,
                                                 batch_np)
        losses.append(float(loss))
        if (step + 1) % log_every == 0 or step == steps - 1:
            dt = time.time() - t_start
            print(f"[train] step {step + 1}/{steps} "
                  f"loss={float(loss):.4f} gnorm={float(gnorm):.2f} "
                  f"({dt / max(1, step + 1 - start_step):.2f}s/step)")
        if mgr and (step + 1) % checkpoint_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt_state}, block=True)
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full config on the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    args = ap.parse_args(argv)
    losses = train(args.arch, smoke=args.smoke, steps=args.steps,
                   batch=args.batch, seq_len=args.seq_len, lr=args.lr,
                   ckpt_dir=args.ckpt_dir,
                   checkpoint_every=args.checkpoint_every,
                   multi_pod=args.multi_pod)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
