"""Model substrate: parameter definitions with sharding, config dataclass.

Parameters are defined once as a tree of ``ParamDef`` (shape + PartitionSpec
+ init kind); the same tree materialises as random weights (smoke tests /
real training), as ShapeDtypeStructs (dry-run lowering — no allocation), or
as a PartitionSpec tree (pjit in_shardings).

Sharding vocabulary (DESIGN.md §5): mesh axes are ("data", "model") within a
pod, with an optional leading "pod" axis for multi-pod (pure DP).  The
``Axes`` helper abstracts whether "pod" exists.  Rules:

  * TP dims (heads, d_ff, vocab, experts)          -> "model"
  * FSDP/ZeRO storage dim (largest non-TP dim)     -> "data"
  * batch / tokens                                  -> ("pod", "data")
  * sequence-parallel activations (policy B)        -> "model" on seq
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------- #
# Mesh axes abstraction
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Axes:
    """Names of the mesh axes; ``pod`` is None on a single pod."""

    pod: str | None = None
    data: str = "data"
    model: str = "model"

    @property
    def batch(self) -> tuple[str, ...] | str:
        return (self.pod, self.data) if self.pod else self.data

    @classmethod
    def for_mesh(cls, mesh) -> "Axes":
        return cls(pod="pod" if "pod" in mesh.axis_names else None)


# --------------------------------------------------------------------- #
# Parameter definitions
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: P
    init: str = "normal"        # normal | zeros | ones
    scale: float | None = None  # None -> 1/sqrt(fan_in)
    dtype: Any = jnp.bfloat16


def pd(shape, spec=P(), init="normal", scale=None, dtype=jnp.bfloat16):
    return ParamDef(tuple(shape), spec, init, scale, dtype)


def is_param_def(x) -> bool:
    return isinstance(x, ParamDef)


def abstract_params(defs):
    """ParamDef tree -> ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
        is_leaf=is_param_def)


def param_specs(defs):
    """ParamDef tree -> PartitionSpec tree."""
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_param_def)


def init_params(defs, key: jax.Array):
    """ParamDef tree -> initialised weights (host-side, for smoke tests)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_param_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = d.scale if d.scale is not None else fan_in ** -0.5
            out.append(
                (jax.random.normal(k, d.shape, jnp.float32) * scale
                 ).astype(d.dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_param_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


# --------------------------------------------------------------------- #
# Architecture config
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact numbers from the public pool)."""

    name: str
    family: str                 # dense | moe | ssm | vlm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2)
    attn_every: int = 0         # shared attention block period
    # enc-dec (whisper)
    dec_layers: int = 0
    dec_seq: int = 448
    causal: bool = True
    # sharding policy: "tp" or "spfsdp" (see DESIGN.md §5)
    policy: str = "tp"
    # which shape cells run (long_500k only for sub-quadratic archs)
    supports_long: bool = False
    has_decoder: bool = True

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads
                               if self.n_heads else 0)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the 'model' axis (16) divides it (DESIGN.md §6)."""
        return -(-self.vocab // 16) * 16

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **over) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16 if self.n_heads else None,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            kv_lora_rank=32 if self.mla else 0,
            q_lora_rank=48 if self.mla else 0,
            qk_rope_head_dim=8 if self.mla else 64,
            qk_nope_head_dim=16 if self.mla else 128,
            v_head_dim=16 if self.mla else 128,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            attn_every=2 if self.attn_every else 0,
            dec_layers=2 if self.dec_layers else 0,
            dec_seq=16 if self.dec_layers else 448,
        )
        small.update(over)
        return dataclasses.replace(self, **small)


# --------------------------------------------------------------------- #
# Shape cells (the assigned input-shape set)
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    """Whether (arch x shape) runs; reason recorded in EXPERIMENTS.md."""
    if cell.name == "long_500k" and not cfg.supports_long:
        return False, "SKIP: pure full-attention arch at 524k (sub-quadratic required)"
    if cell.kind == "decode" and not cfg.has_decoder:
        return False, "SKIP: encoder-only arch has no decode step"
    return True, "ok"
