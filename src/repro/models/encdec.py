"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the brief: ``input_specs`` provides
precomputed frame embeddings (B, S_frames, d_model) directly.  Shapes map
as: ``train_4k``/``prefill_32k`` put seq_len on the *encoder* frames with a
short decoder (dec_seq tokens for train, 1 BOS for prefill); ``decode_32k``
decodes one token with self-cache (dec_seq) + cross-attention to seq_len
encoder states (see DESIGN.md §4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, Axes, pd
from repro.models.layers import (decode_attention_jnp, embed,
                                 flash_attention, gelu_mlp, layernorm,
                                 repeat_kv, shard, sinusoidal_positions)
from repro.models.transformer import _stack_defs, chunked_loss


def _attn_defs(cfg: ArchConfig, axes: Axes, kv: bool = True):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    defs = {
        "wq": pd((d, h * dh), P(axes.data, axes.model)),
        "bq": pd((h * dh,), P(axes.model), init="zeros"),
        "wo": pd((h * dh, d), P(axes.model, axes.data)),
        "bo": pd((d,), P(None), init="zeros"),
    }
    if kv:
        defs.update({
            "wk": pd((d, h * dh), P(axes.data, axes.model)),
            "wv": pd((d, h * dh), P(axes.data, axes.model)),
            "bv": pd((h * dh,), P(axes.model), init="zeros"),
        })
    return defs


def _ln(cfg, name=""):
    return {"w": pd((cfg.d_model,), P(None), init="ones"),
            "b": pd((cfg.d_model,), P(None), init="zeros")}


def _mlp_defs(cfg: ArchConfig, axes: Axes):
    return {
        "w1": pd((cfg.d_model, cfg.d_ff), P(axes.data, axes.model)),
        "b1": pd((cfg.d_ff,), P(axes.model), init="zeros"),
        "w2": pd((cfg.d_ff, cfg.d_model), P(axes.model, axes.data)),
        "b2": pd((cfg.d_model,), P(None), init="zeros"),
    }


def param_defs(cfg: ArchConfig, axes: Axes | None = None):
    ax = axes or Axes()
    enc_layer = {"ln1": _ln(cfg), "attn": _attn_defs(cfg, ax),
                 "ln2": _ln(cfg), "mlp": _mlp_defs(cfg, ax)}
    dec_layer = {"ln1": _ln(cfg), "self_attn": _attn_defs(cfg, ax),
                 "ln2": _ln(cfg), "cross_attn": _attn_defs(cfg, ax),
                 "ln3": _ln(cfg), "mlp": _mlp_defs(cfg, ax)}
    return {
        "enc_layers": _stack_defs(enc_layer, cfg.n_layers),
        "enc_ln_post": _ln(cfg),
        "embed": pd((cfg.padded_vocab, cfg.d_model), P(None, ax.model),
                    scale=1.0),
        "dec_layers": _stack_defs(dec_layer, cfg.dec_layers or cfg.n_layers),
        "dec_ln_f": _ln(cfg),
        "lm_head": pd((cfg.d_model, cfg.padded_vocab), P(ax.data, ax.model)),
    }


def _mha(x, kv_src, p, cfg: ArchConfig, axes: Axes | None, causal: bool):
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"] + p["bq"]).reshape(b, s, h, dh)
    k = (kv_src @ p["wk"]).reshape(b, kv_src.shape[1], h, dh)
    v = (kv_src @ p["wv"] + p["bv"]).reshape(b, kv_src.shape[1], h, dh)
    if axes:
        hspec = P(axes.batch if b > 1 else None, None, axes.model, None)
        q, k, v = shard(q, hspec), shard(k, hspec), shard(v, hspec)
    out = flash_attention(q, k, v, causal=causal)
    return out.reshape(b, s, h * dh) @ p["wo"] + p["bo"], (k, v)


def encode(params, frames, cfg: ArchConfig, axes: Axes | None,
           remat: bool = True):
    """frames (B, S, d) stub embeddings -> encoder states."""
    s = frames.shape[1]
    x = frames + sinusoidal_positions(s, cfg.d_model)[None].astype(
        frames.dtype)
    if axes:
        x = shard(x, P(axes.batch, None, None))

    def layer(x, lp):
        a, _ = _mha(layernorm(x, lp["ln1"]["w"], lp["ln1"]["b"]),
                    layernorm(x, lp["ln1"]["w"], lp["ln1"]["b"]),
                    lp["attn"], cfg, axes, causal=False)
        x = x + a
        x = x + gelu_mlp(layernorm(x, lp["ln2"]["w"], lp["ln2"]["b"]),
                         lp["mlp"]["w1"], lp["mlp"]["b1"],
                         lp["mlp"]["w2"], lp["mlp"]["b2"])
        return x

    if remat:
        layer = jax.checkpoint(layer)

    def body(x, lp):
        return layer(x, lp), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layernorm(x, params["enc_ln_post"]["w"], params["enc_ln_post"]["b"])


def decode_train(params, enc_out, tokens, cfg: ArchConfig,
                 axes: Axes | None, remat: bool = True):
    """Teacher-forced decoder forward -> hidden states."""
    b, t = tokens.shape
    x = embed(tokens, params["embed"]) \
        + sinusoidal_positions(t, cfg.d_model)[None].astype(jnp.bfloat16)

    def layer(x, lp):
        a, _ = _mha(layernorm(x, lp["ln1"]["w"], lp["ln1"]["b"]),
                    layernorm(x, lp["ln1"]["w"], lp["ln1"]["b"]),
                    lp["self_attn"], cfg, axes, causal=True)
        x = x + a
        c, _ = _mha(layernorm(x, lp["ln2"]["w"], lp["ln2"]["b"]), enc_out,
                    lp["cross_attn"], cfg, axes, causal=False)
        x = x + c
        x = x + gelu_mlp(layernorm(x, lp["ln3"]["w"], lp["ln3"]["b"]),
                         lp["mlp"]["w1"], lp["mlp"]["b1"],
                         lp["mlp"]["w2"], lp["mlp"]["b2"])
        return x

    if remat:
        layer = jax.checkpoint(layer)

    def body(x, lp):
        return layer(x, lp), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return layernorm(x, params["dec_ln_f"]["w"], params["dec_ln_f"]["b"])


def loss_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None):
    enc_out = encode(params, batch["frames"], cfg, axes)
    hidden = decode_train(params, enc_out, batch["tokens"], cfg, axes)
    return chunked_loss(hidden, params["lm_head"], batch["labels"])


def cache_defs(cfg: ArchConfig, batch: int, enc_len: int,
               axes: Axes | None):
    """Cross K/V over encoder states + self K/V over dec_seq."""
    ax = axes or Axes()
    h, dh = cfg.n_heads, cfg.head_dim
    batch_axis = ax.batch if axes else None
    model_axis = ax.model if axes else None
    one = {
        "cross_k": pd((batch, enc_len, h, dh),
                      P(batch_axis, None, model_axis, None), init="zeros"),
        "cross_v": pd((batch, enc_len, h, dh),
                      P(batch_axis, None, model_axis, None), init="zeros"),
        "self_k": pd((batch, cfg.dec_seq, h, dh),
                     P(batch_axis, None, model_axis, None), init="zeros"),
        "self_v": pd((batch, cfg.dec_seq, h, dh),
                     P(batch_axis, None, model_axis, None), init="zeros"),
    }
    return _stack_defs(one, cfg.dec_layers or cfg.n_layers)


def prefill_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None,
               max_len: int | None = None):
    """Encode the audio; prime the decoder with one BOS token."""
    enc_out = encode(params, batch["frames"], cfg, axes)
    b = enc_out.shape[0]
    bos = jnp.zeros((b, 1), jnp.int32)
    x = embed(bos, params["embed"]) \
        + sinusoidal_positions(1, cfg.d_model)[None].astype(jnp.bfloat16)

    def body(x, lp):
        h, dh = cfg.n_heads, cfg.head_dim
        xin = layernorm(x, lp["ln1"]["w"], lp["ln1"]["b"])
        a, (sk, sv) = _mha(xin, xin, lp["self_attn"], cfg, axes, causal=True)
        x = x + a
        c, (ck, cv) = _mha(layernorm(x, lp["ln2"]["w"], lp["ln2"]["b"]),
                           enc_out, lp["cross_attn"], cfg, axes,
                           causal=False)
        x = x + c
        x = x + gelu_mlp(layernorm(x, lp["ln3"]["w"], lp["ln3"]["b"]),
                         lp["mlp"]["w1"], lp["mlp"]["b1"],
                         lp["mlp"]["w2"], lp["mlp"]["b2"])
        pad = cfg.dec_seq - 1
        cache = {
            "cross_k": ck.astype(jnp.bfloat16),
            "cross_v": cv.astype(jnp.bfloat16),
            "self_k": jnp.pad(sk, ((0, 0), (0, pad), (0, 0), (0, 0))
                              ).astype(jnp.bfloat16),
            "self_v": jnp.pad(sv, ((0, 0), (0, pad), (0, 0), (0, 0))
                              ).astype(jnp.bfloat16),
        }
        return x, cache

    x, cache = jax.lax.scan(body, x, params["dec_layers"])
    x = layernorm(x, params["dec_ln_f"]["w"], params["dec_ln_f"]["b"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache


def decode_fn(params, cache, tokens, pos, cfg: ArchConfig,
              axes: Axes | None = None):
    """One decoder token; cross-attends the cached encoder K/V."""
    b = tokens.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    pos_emb = jnp.take(sinusoidal_positions(cfg.dec_seq, cfg.d_model),
                       pos, axis=0)
    x = embed(tokens, params["embed"]) + pos_emb[None, None].astype(
        jnp.bfloat16)

    def body(x, lc):
        lp, c = lc
        xin = layernorm(x, lp["ln1"]["w"], lp["ln1"]["b"])
        q = (xin @ lp["self_attn"]["wq"]
             + lp["self_attn"]["bq"]).reshape(b, 1, h, dh)
        k = (xin @ lp["self_attn"]["wk"]).reshape(b, 1, h, dh)
        v = (xin @ lp["self_attn"]["wv"]
             + lp["self_attn"]["bv"]).reshape(b, 1, h, dh)
        sk = jax.lax.dynamic_update_slice_in_dim(
            c["self_k"], k.astype(c["self_k"].dtype), pos, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(
            c["self_v"], v.astype(c["self_v"].dtype), pos, axis=1)
        a = decode_attention_jnp(q[:, 0], sk, sv, pos + 1)
        x = x + (a.reshape(b, 1, h * dh) @ lp["self_attn"]["wo"]
                 + lp["self_attn"]["bo"])
        # cross attention against the fixed encoder cache
        xin2 = layernorm(x, lp["ln2"]["w"], lp["ln2"]["b"])
        q2 = (xin2 @ lp["cross_attn"]["wq"]
              + lp["cross_attn"]["bq"]).reshape(b, 1, h, dh)
        ca = decode_attention_jnp(q2[:, 0], c["cross_k"], c["cross_v"],
                                  c["cross_k"].shape[1])
        x = x + (ca.reshape(b, 1, h * dh) @ lp["cross_attn"]["wo"]
                 + lp["cross_attn"]["bo"])
        x = x + gelu_mlp(layernorm(x, lp["ln3"]["w"], lp["ln3"]["b"]),
                         lp["mlp"]["w1"], lp["mlp"]["b1"],
                         lp["mlp"]["w2"], lp["mlp"]["b2"])
        return x, {"cross_k": c["cross_k"], "cross_v": c["cross_v"],
                   "self_k": sk, "self_v": sv}

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = layernorm(x, params["dec_ln_f"]["w"], params["dec_ln_f"]["b"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, new_cache
