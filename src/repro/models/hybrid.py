"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba-2 backbone with a single
*shared* attention+MLP block applied every ``attn_every`` layers.  The
shared block's input is concat(hidden, initial embedding) projected back to
d_model (the paper adds per-invocation LoRA deltas on the shared weights —
omitted here; recorded in DESIGN.md §6).

Structure: n_layers mamba blocks in ``n_layers // attn_every`` scanned
segments; after each segment the one shared block runs.  Each *application*
of the shared block needs its own KV cache (same weights, different
activations)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import ssm
from repro.models.common import ArchConfig, Axes, pd
from repro.models.layers import (decode_attention_jnp, embed,
                                 flash_attention, repeat_kv, rmsnorm, shard,
                                 swiglu, apply_rope)
from repro.models.transformer import _stack_defs, chunked_loss


def _n_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def shared_block_defs(cfg: ArchConfig, axes: Axes):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "w_in": pd((2 * d, d), P(axes.data, axes.model)),
        "ln_attn": pd((d,), P(None), init="ones"),
        "wq": pd((d, h * dh), P(axes.data, axes.model)),
        "wk": pd((d, cfg.n_kv_heads * dh), P(axes.data, axes.model)),
        "wv": pd((d, cfg.n_kv_heads * dh), P(axes.data, axes.model)),
        "wo": pd((h * dh, d), P(axes.model, axes.data)),
        "ln_mlp": pd((d,), P(None), init="ones"),
        "w_gate": pd((d, cfg.d_ff), P(axes.data, axes.model)),
        "w_up": pd((d, cfg.d_ff), P(axes.data, axes.model)),
        "w_down": pd((cfg.d_ff, d), P(axes.model, axes.data)),
    }


def param_defs(cfg: ArchConfig, axes: Axes | None = None):
    ax = axes or Axes()
    mamba_layer = {
        "ln": pd((cfg.d_model,), P(None), init="ones"),
        "mixer": ssm.ssm_param_defs(cfg, ax),
    }
    return {
        "embed": pd((cfg.padded_vocab, cfg.d_model), P(None, ax.model),
                    scale=1.0),
        "mamba": _stack_defs(mamba_layer, cfg.n_layers),
        "shared": shared_block_defs(cfg, ax),
        "ln_f": pd((cfg.d_model,), P(None), init="ones"),
        "lm_head": pd((cfg.d_model, cfg.padded_vocab), P(ax.data, ax.model)),
    }


def _qkv(x, p, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, hk, dh)
    v = (x @ p["wv"]).reshape(b, s, hk, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def shared_block(x, x0, p, cfg: ArchConfig, axes: Axes | None, positions):
    """Full-sequence form.  Returns (out, (k, v) for caching)."""
    xin = jnp.concatenate([x, x0], axis=-1) @ p["w_in"]
    a_in = rmsnorm(xin, p["ln_attn"])
    q, k, v = _qkv(a_in, p, cfg, positions)
    if axes:
        hspec = P(axes.batch if x.shape[0] > 1 else None, None,
                  axes.model, None)
        q, k, v = shard(q, hspec), shard(k, hspec), shard(v, hspec)
    rep = cfg.n_heads // cfg.n_kv_heads
    out = flash_attention(q, repeat_kv(k, rep), repeat_kv(v, rep),
                          causal=True)
    b, s = x.shape[:2]
    xin = xin + out.reshape(b, s, -1) @ p["wo"]
    xin = xin + swiglu(rmsnorm(xin, p["ln_mlp"]), p["w_gate"], p["w_up"],
                       p["w_down"])
    return x + xin, (k, v)


def shared_block_decode(x, x0, p, cfg: ArchConfig, axes: Axes | None,
                        cache, pos):
    b = x.shape[0]
    xin = jnp.concatenate([x, x0], axis=-1) @ p["w_in"]
    a_in = rmsnorm(xin, p["ln_attn"])
    positions = jnp.full((b, 1), pos)
    q, k, v = _qkv(a_in, p, cfg, positions)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    rep = cfg.n_heads // cfg.n_kv_heads
    out = decode_attention_jnp(q[:, 0], repeat_kv(kc, rep),
                               repeat_kv(vc, rep), pos + 1)
    xin = xin + out.reshape(b, 1, -1) @ p["wo"]
    xin = xin + swiglu(rmsnorm(xin, p["ln_mlp"]), p["w_gate"], p["w_up"],
                       p["w_down"])
    return x + xin, {"k": kc, "v": vc}


def cache_defs(cfg: ArchConfig, batch: int, max_len: int,
               axes: Axes | None):
    ax = axes or Axes()
    batch_axis = ax.batch if (axes and batch > 1) else None
    seq_axis = ax.data if (axes and batch == 1) else None   # long_500k
    from repro.models import mamba_lm
    kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    attn_one = {"k": pd(kv, P(batch_axis, seq_axis,
                              ax.model if axes else None, None),
                        init="zeros"),
                "v": pd(kv, P(batch_axis, seq_axis,
                              ax.model if axes else None, None),
                        init="zeros")}
    return {
        "mamba": mamba_lm.cache_defs(cfg, batch, max_len, axes),
        "attn": _stack_defs(attn_one, _n_apps(cfg)),
    }


def _segments(params_mamba, cfg: ArchConfig):
    """Static per-segment slices of the stacked mamba params."""
    n_apps = _n_apps(cfg)
    per = cfg.attn_every
    return [jax.tree.map(lambda a: a[i * per:(i + 1) * per], params_mamba)
            for i in range(n_apps)]


def _run_segment(x, seg_params, cfg, axes, remat=True):
    def layer(x, lp):
        return x + ssm.ssd_forward(rmsnorm(x, lp["ln"]), lp["mixer"], cfg,
                                   axes)
    if remat:
        layer = jax.checkpoint(layer)

    def body(x, lp):
        return layer(x, lp), None

    x, _ = jax.lax.scan(body, x, seg_params)
    return x


def backbone(params, tokens, cfg: ArchConfig, axes: Axes | None,
             remat: bool = True):
    tokens_p, s0 = _pad(tokens, cfg.ssm_chunk)
    x = embed(tokens_p, params["embed"])
    x0 = x
    b, s = tokens_p.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for seg in _segments(params["mamba"], cfg):
        x = _run_segment(x, seg, cfg, axes, remat)
        x, _ = shared_block(x, x0, params["shared"], cfg, axes, positions)
    return rmsnorm(x, params["ln_f"])[:, :s0]


def _pad(tokens, chunk):
    s = tokens.shape[1]
    pad = (-s) % chunk
    if pad:
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)))
    return tokens, s


def loss_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None):
    hidden = backbone(params, batch["tokens"], cfg, axes)
    return chunked_loss(hidden, params["lm_head"], batch["labels"])


def prefill_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None,
               max_len: int | None = None):
    tokens, s0 = _pad(batch["tokens"], cfg.ssm_chunk)
    b, s = tokens.shape
    max_len = max(max_len or s0, s)
    x = embed(tokens, params["embed"])
    x0 = x
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    seq_mask = (jnp.arange(s)[None] < s0)
    mamba_caches, attn_caches = [], []
    for seg in _segments(params["mamba"], cfg):
        def body(x, lp):
            y, c = ssm.ssd_forward(rmsnorm(x, lp["ln"]), lp["mixer"], cfg,
                                   axes, return_cache=True,
                                   seq_mask=seq_mask)
            return x + y, c
        x, mc = jax.lax.scan(body, x, seg)
        mamba_caches.append(mc)
        x, (k, v) = shared_block(x, x0, params["shared"], cfg, axes,
                                 positions)
        pad = max_len - s
        attn_caches.append({
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))
                         ).astype(jnp.bfloat16),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))
                         ).astype(jnp.bfloat16)})
    cache = {
        "mamba": _concat_trees(mamba_caches),
        "attn": _stack_trees(attn_caches),
    }
    h = rmsnorm(x[:, s0 - 1:s0], params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", h[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache


def _concat_trees(trees):
    """Concat per-segment (per_seg, ...) stacked caches -> (L, ...)."""
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *trees)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def decode_fn(params, cache, tokens, pos, cfg: ArchConfig,
              axes: Axes | None = None):
    b = tokens.shape[0]
    x = embed(tokens, params["embed"])
    x0 = x
    per = cfg.attn_every
    new_mamba, new_attn = [], []
    for i, seg in enumerate(_segments(params["mamba"], cfg)):
        seg_cache = jax.tree.map(lambda a: a[i * per:(i + 1) * per],
                                 cache["mamba"])

        def body(x, lc):
            lp, c = lc
            y, c2 = ssm.ssd_decode(rmsnorm(x, lp["ln"]), lp["mixer"], cfg,
                                   axes, c)
            return x + y, c2

        x, mc = jax.lax.scan(body, x, (seg, seg_cache))
        new_mamba.append(mc)
        ac = jax.tree.map(lambda a: a[i], cache["attn"])
        x, ac2 = shared_block_decode(x, x0, params["shared"], cfg, axes,
                                     ac, pos)
        new_attn.append(ac2)
    x = rmsnorm(x, params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, {"mamba": _concat_trees(new_mamba),
                    "attn": _stack_trees(new_attn)}
