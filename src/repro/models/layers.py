"""Neural building blocks (pure JAX): norms, RoPE, memory-safe flash
attention, GQA, MLPs, embeddings.  All functions take explicit param dicts
(built from ParamDef trees in the model files)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def shard(x: jax.Array, spec: P | None) -> jax.Array:
    """with_sharding_constraint that no-ops when no mesh is active (smoke
    tests run un-meshed; the dry-run sets a mesh via jax.set_mesh)."""
    if spec is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
    except Exception:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ----------------------------- norms ---------------------------------- #

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# ----------------------------- RoPE ------------------------------------ #

def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10_000.0) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, dim, 2, jnp.float32) / dim))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------- flash attention ----------------------------- #

def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, H_kv, D) -> (B, S, H_kv*n_rep, D)."""
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=2)


_NEG = -1e30


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    q_offset: int | jax.Array = 0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    qr_spec: P | None = None,
                    kv_spec: P | None = None) -> jax.Array:
    """Memory-safe attention: outer scan over query chunks, inner scan over
    KV chunks with online softmax (the S1 schedule of DESIGN.md §4 in pure
    jnp, so it lowers on any backend; the Pallas `flash_decode` kernel is
    the single-query TPU version).

    q: (B, Sq, H, D); k/v: (B, Skv, H, D) (already GQA-repeated).
    ``q_offset``: absolute position of q[0] (prefill continuation).
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    dv = v.shape[-1]                    # may differ from d (MLA: qk 192, v 128)
    skv = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    # pad to multiples
    pq = (-sq) % qc
    pk = (-skv) % kc
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (sq + pq) // qc, (skv + pk) // kc
    scale = d ** -0.5

    qr = q.reshape(b, nq, qc, h, d).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qc,D)
    kr = k.reshape(b, nk, kc, h, d).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nk, kc, h, dv).transpose(1, 0, 3, 2, 4)
    # qr_spec shards *within* each scanned query chunk (e.g. rows of qc on
    # the model axis for odd-head-count archs): scan iterations are
    # sequential, so intra-chunk sharding is the only way the model axis
    # can divide attention compute when heads cannot.  kv_spec pins the
    # scanned K/V stacks (left ambiguous, the partitioner was observed to
    # all-gather the FULL stack inside the inner scan body every
    # iteration — 939 MB x nq x nk x L on qwen2-7b prefill).
    qr = shard(qr, qr_spec)
    kr = shard(kr, kv_spec)
    vr = shard(vr, kv_spec)

    def q_block(carry, qi_q):
        qi, qb = qi_q                                   # qb: (B,H,qc,D)
        qpos = q_offset + qi * qc + jnp.arange(qc)

        def kv_block(state, ki_kv):
            m, l, acc = state
            ki, kb, vb = ki_kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            kpos = ki * kc + jnp.arange(kc)
            mask = kpos[None, :] < skv                  # padding
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            s = jnp.where(mask[None, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, qc, 1), _NEG, jnp.float32)
        l0 = jnp.zeros((b, h, qc, 1), jnp.float32)
        a0 = jnp.zeros((b, h, qc, dv), jnp.float32)
        # checkpoint each KV block: backward recomputes the (qc, kc) score
        # tile instead of saving every probability matrix — the flash
        # memory property under plain jax AD.
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_block), (m0, l0, a0),
            (jnp.arange(nk), kr, vr))
        out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)  # (B,H,qc,Dv)
        return carry, out

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qr))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * qc, h, dv)
    return out[:, :sq]


def decode_attention_jnp(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length: jax.Array,
                         ) -> jax.Array:
    """Single-token attention over a padded cache (pure jnp path used by the
    distributed serve_step; cache S may be sharded — softmax reductions
    become collectives under GSPMD).

    q: (B, H, D); caches: (B, S, H, D) GQA-repeated; length: (B,) or scalar.
    """
    b, h, d = q.shape
    s = k_cache.shape[1]
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * (d ** -0.5)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(length, (-1, 1))
    scores = jnp.where(valid[:, None, :], scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------ MLPs ----------------------------------- #

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, ff_spec: P | None = None) -> jax.Array:
    g = shard(x @ w_gate, ff_spec)
    u = shard(x @ w_up, ff_spec)
    return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) @ w_down


def gelu_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
             b2: jax.Array, ff_spec: P | None = None) -> jax.Array:
    h = shard(x @ w1 + b1, ff_spec)
    return jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype) @ w2 + b2


# --------------------------- embeddings -------------------------------- #

def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Logits in f32 (loss numerics)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean next-token CE over valid labels; logits (..., V) f32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].clip(0),
                               axis=-1)[..., 0]
    nll = logz - gold
    valid = (labels != ignore_id).astype(jnp.float32)
    return (nll * valid).sum() / jnp.maximum(valid.sum(), 1.0)
