"""Pure Mamba-2 LM (mamba2-2.7b): embed -> scanned SSD layers -> head.

Attention-free: the serve cache is the (state, conv-tail) pair per layer —
O(1) in sequence length, which is why this arch (and the zamba2 hybrid)
carries the long_500k cell."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import ssm
from repro.models.common import ArchConfig, Axes, pd
from repro.models.layers import embed, rmsnorm, shard
from repro.models.transformer import _stack_defs, chunked_loss


def param_defs(cfg: ArchConfig, axes: Axes | None = None):
    ax = axes or Axes()
    layer = {
        "ln": pd((cfg.d_model,), P(None), init="ones"),
        "mixer": ssm.ssm_param_defs(cfg, ax),
    }
    return {
        "embed": pd((cfg.padded_vocab, cfg.d_model), P(None, ax.model),
                    scale=1.0),
        "layers": _stack_defs(layer, cfg.n_layers),
        "ln_f": pd((cfg.d_model,), P(None), init="ones"),
        "lm_head": pd((cfg.d_model, cfg.padded_vocab),
                      P(ax.data, ax.model)),
    }


def cache_defs(cfg: ArchConfig, batch: int, max_len: int,
               axes: Axes | None):
    ax = axes or Axes()
    batch_axis = ax.batch if (axes and batch > 1) else None
    one = {
        "h": pd((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                P(batch_axis, ax.model if axes else None, None, None),
                init="zeros", dtype=jnp.float32),
        "conv": pd((batch, cfg.ssm_conv_width - 1,
                    cfg.d_inner + 2 * cfg.ssm_state),
                   P(batch_axis, None, ax.model if axes else None),
                   init="zeros"),
    }
    return _stack_defs(one, cfg.n_layers)


def _pad_seq(x, chunk):
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x, s


def backbone(params, tokens, cfg: ArchConfig, axes: Axes | None,
             remat: bool = True):
    tokens, s0 = _pad_seq(tokens, cfg.ssm_chunk)
    x = embed(tokens, params["embed"])
    if axes:
        x = shard(x, P(axes.batch, None, None))

    def layer(x, lp):
        return x + ssm.ssd_forward(rmsnorm(x, lp["ln"]), lp["mixer"], cfg,
                                   axes)

    if remat:
        layer = jax.checkpoint(layer)

    def body(x, lp):
        y = layer(x, lp)
        if axes:
            y = shard(y, P(axes.batch, None, None))
        return y, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return rmsnorm(x, params["ln_f"])[:, :s0]


def loss_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None):
    hidden = backbone(params, batch["tokens"], cfg, axes)
    return chunked_loss(hidden, params["lm_head"], batch["labels"])


def prefill_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None,
               max_len: int | None = None):
    tokens, s0 = _pad_seq(batch["tokens"], cfg.ssm_chunk)
    x = embed(tokens, params["embed"])
    if axes:
        x = shard(x, P(axes.batch, None, None))
    seq_mask = (jnp.arange(tokens.shape[1])[None] < s0)

    def body(x, lp):
        y, cache = ssm.ssd_forward(rmsnorm(x, lp["ln"]), lp["mixer"], cfg,
                                   axes, return_cache=True,
                                   seq_mask=seq_mask)
        return x + y, cache

    x, cache = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x[:, s0 - 1:s0], params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache


def decode_fn(params, cache, tokens, pos, cfg: ArchConfig,
              axes: Axes | None = None):
    del pos                                     # stateless in position
    x = embed(tokens, params["embed"])

    def body(x, lc):
        lp, c = lc
        y, c2 = ssm.ssd_decode(rmsnorm(x, lp["ln"]), lp["mixer"], cfg,
                               axes, c)
        return x + y, c2

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(x, params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, new_cache
