"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Two execution forms:
  * train/prefill — decompress the latent to full per-head K/V and run flash
    attention (compute-optimal when S tokens amortise the decompression);
  * decode — *absorbed* form: queries are pulled into the latent space
    (q_nope @ W_UK), attention runs directly against the compressed cache
    c_kv (kv_lora_rank + rope dims per token), and the context is expanded
    back with W_UV.  The KV cache is therefore 576 B/token instead of
    ~40 KiB/token — this is what makes decode_32k x batch 128 fit at all.

In the paper's terms the compressed cache is the input tensor in DRAM; the
absorbed decode streams it once per step (S1 with Q resident), which is also
exactly what `kernels/flash_decode` implements on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, Axes, pd
from repro.models.layers import apply_rope, flash_attention, rmsnorm, shard

_NEG = -1e30


def mla_param_defs(cfg: ArchConfig, axes: Axes):
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wq_a": pd((d, cfg.q_lora_rank), P(axes.data, None)),
        "q_norm": pd((cfg.q_lora_rank,), P(None), init="ones"),
        "wq_b": pd((cfg.q_lora_rank, h * qk), P(axes.data, axes.model)),
        "wkv_a": pd((d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
                    P(axes.data, None)),
        "kv_norm": pd((cfg.kv_lora_rank,), P(None), init="ones"),
        "wkv_b": pd((cfg.kv_lora_rank,
                     h * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
                    P(axes.data, axes.model)),
        "wo": pd((h * cfg.v_head_dim, d), P(axes.model, axes.data)),
    }


def _project_q(x, p, cfg: ArchConfig, positions):
    """x (B,S,d) -> q_nope (B,S,H,nope), q_pe (B,S,H,rope)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(
        b, s, h, cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_nope, q_pe = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def mla_attention(x: jax.Array, p, cfg: ArchConfig, axes: Axes | None,
                  positions: jax.Array) -> jax.Array:
    """Train/prefill form (decompressed K/V + flash attention)."""
    b, s, d = x.shape
    h = cfg.n_heads
    q_nope, q_pe = _project_q(x, p, cfg, positions)

    kv_a = x @ p["wkv_a"]                                  # (B,S,lora+rope)
    c_kv, k_pe = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)
    kv = rmsnorm(c_kv, p["kv_norm"]) @ p["wkv_b"]
    if axes:
        # pin head-sharding on the flat (H * (nope+v)) dim BEFORE the
        # reshape — the decompressed K/V is the big MLA prefill tensor.
        kv = shard(kv, P(axes.batch, None, axes.model))
    kv = kv.reshape(b, s, h, cfg.qk_nope_head_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_head_dim], axis=-1)

    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (b, s, h, cfg.qk_rope_head_dim))],
        axis=-1)
    if axes:
        hspec = P(axes.batch, None, axes.model, None)
        q, k, v = shard(q, hspec), shard(k, hspec), shard(v, hspec)
    out = flash_attention(q, k, v, causal=True)            # (B,S,H,v_dim)
    return out.reshape(b, s, h * cfg.v_head_dim) @ p["wo"]


def mla_init_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    """Compressed cache: c_kv (B,S,lora) + roped k_pe (B,S,rope)."""
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_cache_specs(cfg: ArchConfig, axes: Axes, shard_seq: bool):
    seq = axes.model if shard_seq else None
    return {"c_kv": P(axes.batch if not shard_seq else None, seq, None),
            "k_pe": P(axes.batch if not shard_seq else None, seq, None)}


def mla_prefill_cache(x, p, cfg: ArchConfig, positions, max_len: int):
    """Compute the compressed cache entries for a prompt."""
    kv_a = x @ p["wkv_a"]
    c_kv, k_pe = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    k_pe = apply_rope(k_pe[:, :, None, :], positions,
                      cfg.rope_theta)[:, :, 0, :]
    pad = max_len - x.shape[1]
    return {
        "c_kv": jnp.pad(rmsnorm(c_kv, p["kv_norm"]),
                        ((0, 0), (0, pad), (0, 0))).astype(jnp.bfloat16),
        "k_pe": jnp.pad(k_pe, ((0, 0), (0, pad), (0, 0))
                        ).astype(jnp.bfloat16),
    }


def mla_decode(x: jax.Array, p, cfg: ArchConfig, axes: Axes | None,
               cache: dict, pos: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed single-token decode against the compressed cache.

    x: (B, 1, d); cache c_kv (B, S, lora), k_pe (B, S, rope); pos: scalar
    current position.  Returns (out (B,1,d), updated cache).
    """
    b, _, d = x.shape
    h = cfg.n_heads
    nope, rope, dv = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                      cfg.v_head_dim)
    positions = jnp.full((b, 1), pos)
    q_nope, q_pe = _project_q(x, p, cfg, positions)        # (B,1,H,*)
    q_nope, q_pe = q_nope[:, 0], q_pe[:, 0]                # (B,H,*)

    # new cache entry
    kv_a = x[:, 0] @ p["wkv_a"]                            # (B, lora+rope)
    c_new, kpe_new = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    c_new = rmsnorm(c_new, p["kv_norm"])
    kpe_new = apply_rope(kpe_new[:, None, None, :], positions,
                         cfg.rope_theta)[:, 0, 0]
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new[:, None].astype(cache["c_kv"].dtype), pos,
            axis=1),
        "k_pe": jax.lax.dynamic_update_slice_in_dim(
            cache["k_pe"], kpe_new[:, None].astype(cache["k_pe"].dtype),
            pos, axis=1),
    }

    # absorb: q into latent space (per head)
    w_kv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, h, nope + dv)
    w_uk = w_kv_b[:, :, :nope]                             # (lora, H, nope)
    w_uv = w_kv_b[:, :, nope:]                             # (lora, H, dv)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))           # (B,H,lora)

    scale = (nope + rope) ** -0.5
    s_lat = jnp.einsum("bhl,bsl->bhs", q_lat,
                       cache["c_kv"].astype(jnp.float32))
    s_pe = jnp.einsum("bhr,bsr->bhs", q_pe.astype(jnp.float32),
                      cache["k_pe"].astype(jnp.float32))
    scores = (s_lat + s_pe) * scale                        # (B,H,S)
    valid = jnp.arange(scores.shape[-1])[None, None, :] <= pos
    scores = jnp.where(valid, scores, _NEG)
    pr = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsl->bhl", pr,
                         cache["c_kv"].astype(jnp.float32))
    ctx = jnp.einsum("bhl,lhv->bhv", ctx_lat, w_uv.astype(jnp.float32))
    out = ctx.reshape(b, 1 * h * dv).astype(x.dtype)[:, None, :]
    return out.reshape(b, 1, h * dv) @ p["wo"], cache
