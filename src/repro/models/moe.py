"""Mixture-of-Experts with sort-based (dropped-token) dispatch.

Distribution design (DESIGN.md §5): the routing is *block-local by
construction* — tokens are reshaped to (n_blocks, T_loc, d) where n_blocks
equals the number of (pod x data) shards and the leading dim is sharded
over those axes.  Every argsort / capacity / gather / scatter then carries
the block dim as a batch dim, so GSPMD partitions them along dim 0 without
any cross-shard index traffic (the global formulation made it replicate
12.9 GB/device cotangent buffers; an explicit shard_map formulation crashed
the XLA:CPU partitioner).  Expert weights stay sharded over "model" (EP):
the block-diagonal einsum (n, E, C, d) x (E, d, f) is 2-D partitioned
(blocks x experts) — real expert parallelism with shard-local capacity.

FLOP accounting: dispatch is gather-based, so compiled HLO FLOPs ≈ active
expert FLOPs (the MODEL_FLOPS/HLO_FLOPs roofline ratio stays meaningful).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, Axes, pd
from repro.models.layers import shard


def moe_param_defs(cfg: ArchConfig, axes: Axes):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    defs = {
        "router": pd((d, e), P(None, axes.model), dtype=jnp.float32),
        "w_gate": pd((e, d, f), P(axes.model, axes.data, None)),
        "w_up": pd((e, d, f), P(axes.model, axes.data, None)),
        "w_down": pd((e, f, d), P(axes.model, axes.data, None)),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * cfg.d_ff
        defs["shared"] = {
            "w_gate": pd((d, fs), P(axes.data, axes.model)),
            "w_up": pd((d, fs), P(axes.data, axes.model)),
            "w_down": pd((fs, d), P(axes.model, axes.data)),
        }
    return defs


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    c = int(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _n_blocks(axes: Axes | None, t: int) -> int:
    """Number of (pod x data) shards, if the mesh is known and divides t."""
    if axes is None:
        return 1
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return 1
        shape = dict(zip(mesh.axis_names, mesh.shape.values())) \
            if hasattr(mesh.shape, "values") else dict(mesh.shape)
        nb = shape.get(axes.data, 1)
        if axes.pod:
            nb *= shape.get(axes.pod, 1)
        return nb if t % nb == 0 else 1
    except Exception:
        return 1


def moe_ffn(x: jax.Array, p, cfg: ArchConfig, axes: Axes | None
            ) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  Block-local top-k routing, gather
    dispatch, EP expert compute, weighted combine + shared experts."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    nb = _n_blocks(axes, t)
    tl = t // nb                                   # tokens per block
    c = _capacity(tl, cfg)
    blk = (axes.pod, axes.data) if (axes and axes.pod) else \
        (axes.data if axes else None)

    xf = x.reshape(nb, tl, d)
    if axes:
        xf = shard(xf, P(blk, None, None))
    logits = (xf.astype(jnp.float32) @ p["router"])          # (nb, Tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                   # (nb, Tl, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(nb, tl * k)
    sort_idx = jnp.argsort(flat_e, axis=-1)                  # (nb, Tl*k)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e),
                                                 side="left"))(sorted_e)
    pos_in_e = jnp.arange(tl * k)[None] - jnp.take_along_axis(
        first, sorted_e, axis=-1)
    keep = pos_in_e < c
    token_of = sort_idx // k                                 # (nb, Tl*k)
    dest = jnp.where(keep, sorted_e * c + pos_in_e, e * c)

    # per-block int32 index maps (batched scatters along dim 0)
    src_token = jnp.full((nb, e * c + 1), tl, jnp.int32)
    src_token = jax.vmap(lambda st, de, to: st.at[de].set(
        to.astype(jnp.int32), mode="drop"))(src_token, dest, token_of)
    inv_sort = jax.vmap(lambda si: jnp.zeros((tl * k,), jnp.int32)
                        .at[si].set(jnp.arange(tl * k, dtype=jnp.int32))
                        )(sort_idx)
    slot_of_pair = jnp.take_along_axis(
        jnp.where(keep, dest, e * c), inv_sort, axis=-1)     # (nb, Tl*k)
    # inverse map: slot s holds sorted pair j = dest^-1(s) whose token-major
    # index is sort_idx[j]; unused slots point past the end (masked later).
    pair_of_slot = jax.vmap(
        lambda de, si: jnp.full((e * c,), tl * k, jnp.int32)
        .at[de].set(si.astype(jnp.int32), mode="drop"))(dest, sort_idx)

    # dispatch (a4): batched gather — block dim sharded over (pod, data).
    # clamp+mask instead of a +1 pad row (the pad makes an extra full copy
    # of the token block and breaks divisibility for GSPMD).
    slot_used = (src_token[:, :e * c] < tl)
    xb = jnp.take_along_axis(
        xf, jnp.minimum(src_token[:, :e * c], tl - 1)[:, :, None], axis=1)
    xb = xb * slot_used[:, :, None].astype(x.dtype)
    if axes and (e * c) % 16 == 0:
        # pin expert-major sharding before the reshape: the flat gather
        # output is the big MoE prefill transient.
        xb = shard(xb, P(blk, axes.model, None))
    xb = xb.reshape(nb, e, c, d)
    if axes:
        xb = shard(xb, P(blk, axes.model, None, None))

    # expert FFN (a6): (blocks x experts) 2-D partitioned grouped matmul.
    g = jnp.einsum("necd,edf->necf", xb, p["w_gate"])
    u = jnp.einsum("necd,edf->necf", xb, p["w_up"])
    y = jnp.einsum("necf,efd->necd",
                   jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
                   p["w_down"])
    y = y.reshape(nb, e * c, d)
    if axes and (e * c) % 16 == 0:
        # keep the slot dim expert-major-sharded through the combine: any
        # pad/gather that breaks the 16-divisibility forces GSPMD to
        # materialise the full (E*C, d) buffer per device.
        y = shard(y, P(blk, axes.model, None))

    # combine (a3): weight each slot by its router prob (slot-sharded), then
    # k separate clamp+mask gathers back to token order — no +1 pad row
    # (padding breaks the even sharding), peak transient is one (nb, Tl, d).
    w_flat = top_w.reshape(nb, tl * k)
    w_slot = jnp.take_along_axis(
        w_flat, jnp.minimum(pair_of_slot, tl * k - 1), axis=1) \
        * (pair_of_slot < tl * k)
    y_w = y * w_slot[..., None].astype(y.dtype)
    sop = slot_of_pair.reshape(nb, tl, k)
    out = jnp.zeros((nb, tl, d), x.dtype)
    for kk in range(k):
        idx = sop[:, :, kk]
        valid = (idx < e * c)[..., None].astype(y.dtype)
        out = out + jnp.take_along_axis(
            y_w, jnp.minimum(idx, e * c - 1)[:, :, None], axis=1) * valid

    if cfg.n_shared_experts:
        sp = p["shared"]
        gs = xf @ sp["w_gate"]
        us = xf @ sp["w_up"]
        out = out + (jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype)
                     * us) @ sp["w_down"]
    out = out.reshape(b, s, d)
    if axes:
        out = shard(out, P(blk, None, None) if b % nb == 0
                    else P(None, None, None))
    return out


def aux_load_balance_loss(logits: jax.Array, top_e: jax.Array,
                          n_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    onehot = jax.nn.one_hot(top_e[..., 0], n_experts)
    ce = onehot.mean(axis=tuple(range(onehot.ndim - 1)))
    return n_experts * jnp.sum(me * ce)
