"""Architecture registry: ``--arch <id>`` -> config + model API + input
specs for every shape cell."""
from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import encdec, hybrid, mamba_lm, transformer
from repro.models.common import (ArchConfig, Axes, ShapeCell, SHAPES,
                                 abstract_params, cell_applicable,
                                 init_params, param_specs)

_ARCH_MODULES = {
    "deepseek-v2-236b": ("repro.configs.deepseek_v2_236b", transformer),
    "dbrx-132b": ("repro.configs.dbrx_132b", transformer),
    "qwen2.5-32b": ("repro.configs.qwen2_5_32b", transformer),
    "tinyllama-1.1b": ("repro.configs.tinyllama_1_1b", transformer),
    "qwen2-7b": ("repro.configs.qwen2_7b", transformer),
    "qwen2.5-14b": ("repro.configs.qwen2_5_14b", transformer),
    "mamba2-2.7b": ("repro.configs.mamba2_2_7b", mamba_lm),
    "chameleon-34b": ("repro.configs.chameleon_34b", transformer),
    "zamba2-2.7b": ("repro.configs.zamba2_2_7b", hybrid),
    "whisper-medium": ("repro.configs.whisper_medium", encdec),
}

ARCH_IDS = tuple(_ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class ModelApi:
    """Uniform handle over one architecture."""

    cfg: ArchConfig
    module: Any

    # ---- parameters ----------------------------------------------------
    def param_defs(self, axes: Axes | None = None):
        return self.module.param_defs(self.cfg, axes)

    def abstract_params(self, axes: Axes | None = None):
        return abstract_params(self.param_defs(axes))

    # NOTE (§Perf iteration 10, refuted): replicating small archs' weights
    # over the data axis to remove FSDP gathers was tried — measured only
    # 3–5% off the collective term (the dominant weight traffic is
    # all-gathers over the *model* axis: sequence-parallel shards each need
    # the full weights, independent of storage sharding) at +1 GB peak.
    # Reverted; storage stays (data x model).

    def param_specs(self, axes: Axes, layout: str = "train"):
        """PartitionSpec tree.  layout="decode" for spfsdp archs swaps every
        2-D weight to P(model-on-contraction, None): row-parallel decode —
        per-token weight reads are shard-local instead of FSDP-gathered
        (EXPERIMENTS.md §Perf iteration 3)."""
        specs = param_specs(self.param_defs(axes))
        if layout != "decode" or self.cfg.policy != "spfsdp":
            return specs
        from jax.sharding import PartitionSpec as P
        defs = self.param_defs(axes)
        import jax
        from repro.models.common import is_param_def

        def flip(d):
            nd = len(d.shape)
            if nd >= 2 and d.shape[-1] > 1 and d.shape[-2] > 256:
                # 2-D weight (possibly layer-stacked): model on the
                # contraction (second-to-last) dim, replicated elsewhere.
                return P(*((None,) * (nd - 2)), axes.model, None)
            return P(*((None,) * nd))

        flipped = jax.tree.map(flip, defs, is_leaf=is_param_def)
        # keep the embedding gather layout (vocab lookups, not matmul)
        if isinstance(flipped, dict) and "embed" in flipped:
            flipped["embed"] = param_specs(defs)["embed"] \
                if not isinstance(defs["embed"], dict) else flipped["embed"]
        return flipped

    def zero1_specs(self, axes: Axes):
        """Full (data x model) storage specs for optimizer state / grad
        accumulators — independent of the small-arch weight replication."""
        return param_specs(self.param_defs(axes))

    def init_params(self, key, axes: Axes | None = None):
        return init_params(self.param_defs(axes), key)

    # ---- step functions -------------------------------------------------
    def loss_fn(self, params, batch, axes: Axes | None = None):
        return self.module.loss_fn(params, batch, self.cfg, axes)

    def prefill_fn(self, params, batch, axes: Axes | None = None,
                   max_len: int | None = None):
        return self.module.prefill_fn(params, batch, self.cfg, axes,
                                      max_len=max_len)

    def decode_fn(self, params, cache, tokens, pos,
                  axes: Axes | None = None):
        return self.module.decode_fn(params, cache, tokens, pos, self.cfg,
                                     axes)

    # ---- caches ----------------------------------------------------------
    def cache_defs(self, batch: int, max_len: int, axes: Axes | None):
        return self.module.cache_defs(self.cfg, batch, max_len, axes)

    # ---- dry-run inputs ---------------------------------------------------
    def input_specs(self, cell: ShapeCell, axes: Axes | None = None):
        """ShapeDtypeStruct stand-ins + PartitionSpecs for one shape cell.

        Returns (abstract_inputs: dict, partition_specs: dict).  Decode
        cells include the abstract cache under key "cache"."""
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        batch_axis = (axes.batch if axes and b > 1 else None)
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        tok_spec = P(batch_axis, None)

        if cell.kind == "train":
            if cfg.family == "audio":
                inputs = {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((b, cfg.dec_seq),
                                                   jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, cfg.dec_seq),
                                                   jnp.int32),
                }
                specs = {"frames": P(batch_axis, None, None),
                         "tokens": tok_spec, "labels": tok_spec}
            else:
                inputs = {"tokens": tok, "labels": tok}
                specs = {"tokens": tok_spec, "labels": tok_spec}
            return inputs, specs

        if cell.kind == "prefill":
            if cfg.family == "audio":
                inputs = {"frames": jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), jnp.bfloat16)}
                specs = {"frames": P(batch_axis, None, None)}
            else:
                inputs = {"tokens": tok}
                specs = {"tokens": tok_spec}
            return inputs, specs

        # decode: one new token against a seq_len cache
        cache_d = self.cache_defs(b, s, axes)
        inputs = {
            "cache": abstract_params(cache_d),
            "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        specs = {
            "cache": param_specs(cache_d),
            "tokens": P(batch_axis, None),
            "pos": P(),
        }
        return inputs, specs

    def applicable_cells(self):
        out = []
        for cell in SHAPES.values():
            ok, why = cell_applicable(self.cfg, cell)
            out.append((cell, ok, why))
        return out


@functools.lru_cache(maxsize=None)
def get(arch_id: str) -> ModelApi:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; have {ARCH_IDS}")
    cfg_mod, model_mod = _ARCH_MODULES[arch_id]
    cfg = importlib.import_module(cfg_mod).CONFIG
    return ModelApi(cfg=cfg, module=model_mod)


def get_reduced(arch_id: str, **over) -> ModelApi:
    """Reduced same-family config for CPU smoke tests."""
    api = get(arch_id)
    return ModelApi(cfg=api.cfg.reduced(**over), module=api.module)
