"""Mamba-2 SSD layer (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: the sequence is cut into
chunks of Q tokens; within a chunk the computation is a masked quadratic
form (runs on the MXU), across chunks a small state (H, P, N) is carried by
an associative scan.  Note the paper-mapping (DESIGN.md §4): the chunk size
is a *step size* in the offloading formalism — each chunk's inputs are one
I_slice, the carried state is the "kept in on-chip memory" set, and
``core.planner`` reasoning applies to choosing Q.

Decode is the O(1) recurrent form: h <- exp(dt A) h + dt B x, carried in the
serve cache together with the causal-conv tail window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig, Axes, pd
from repro.models.layers import rmsnorm, shard


def ssm_param_defs(cfg: ArchConfig, axes: Axes):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n                     # x, B, C convolved jointly
    proj_out = 2 * di + 2 * n + h             # z, x, B, C, dt
    return {
        "in_proj": pd((d, proj_out), P(axes.data, axes.model)),
        "conv_w": pd((cfg.ssm_conv_width, conv_dim), P(None, axes.model),
                     scale=0.5),
        "conv_b": pd((conv_dim,), P(axes.model), init="zeros"),
        "a_log": pd((h,), P(axes.model), init="ones", dtype=jnp.float32),
        "d_skip": pd((h,), P(axes.model), init="ones", dtype=jnp.float32),
        "dt_bias": pd((h,), P(axes.model), init="zeros", dtype=jnp.float32),
        "norm_w": pd((di,), P(axes.model), init="ones"),
        "out_proj": pd((di, d), P(axes.model, axes.data)),
    }


def _split_proj(zxbcdt, cfg: ArchConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along S.  xbc (B, S, C); w (W, C).
    Returns (out, new_state) where state is the trailing W-1 window."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)            # (B, S+W-1, C)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i][None, None]
              for i in range(width))
    out = jax.nn.silu((out + b[None, None]).astype(jnp.float32)
                      ).astype(xbc.dtype)
    new_state = full[:, -(width - 1):] if width > 1 else pad
    return out, new_state


def ssd_forward(x: jax.Array, p, cfg: ArchConfig, axes: Axes | None,
                cache: dict | None = None, return_cache: bool = False,
                seq_mask: jax.Array | None = None):
    """Chunked SSD.  x (B, S, d) -> (B, S, d) [, final cache].
    S % chunk == 0 (launch layer pads).  ``cache`` streams a previous
    segment's final state in (prefill continuation).  ``seq_mask`` (B, S)
    zeroes dt at pad positions so they do not disturb the carried state."""
    b, s, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                  cache["conv"] if cache else None)
    xi = xbc[..., :di].reshape(b, s, h, pdim)
    bmat = xbc[..., di:di + n]                              # (B,S,N) 1 group
    cmat = xbc[..., di + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])        # (B,S,H)
    if seq_mask is not None:
        dt = dt * seq_mask[:, :, None].astype(jnp.float32)
    a = -jnp.exp(p["a_log"])                                # (H,)
    da = dt * a[None, None]                                 # (B,S,H)

    # chunk
    xi = xi.reshape(b, nc, q, h, pdim)
    bm = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cm = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dt_c = dt.reshape(b, nc, q, h)
    da_c = da.reshape(b, nc, q, h)
    da_cs = jnp.cumsum(da_c, axis=2)                        # (B,nc,Q,H)

    if axes:
        xi = shard(xi, P(axes.batch, None, None, axes.model, None))

    # --- intra-chunk (quadratic, causal-masked) -------------------------
    # decay L[q1, q2] = exp(da_cs[q1] - da_cs[q2]) for q1 >= q2
    ldec = jnp.exp(da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((q, q), bool))
    ldec = jnp.where(causal[None, None, :, :, None], ldec, 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", cm, bm)          # (B,nc,Q,Q)
    w = scores[..., None] * ldec * dt_c[:, :, None, :, :]   # (B,nc,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", w,
                         xi.astype(jnp.float32))

    # --- chunk states + inter-chunk scan --------------------------------
    seg_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)          # decay to chunk end
    states = jnp.einsum("bckn,bckh,bckhp->bchpn",
                        bm, (dt_c * seg_end).astype(jnp.float32),
                        xi.astype(jnp.float32))             # (B,nc,H,P,N)
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])               # (B,nc,H)

    def scan_fn(hprev, inp):
        st, dec = inp                                       # (B,H,P,N),(B,H)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = cache["h"] if cache else jnp.zeros((b, h, pdim, n), jnp.float32)
    h_final, h_before = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)            # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cm, h_before,
                         jnp.exp(da_cs))
    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    y = y + xi.reshape(b, s, h, pdim).astype(jnp.float32) \
        * p["d_skip"][None, None, :, None]

    # gated RMSNorm + out projection
    y = y.reshape(b, s, di).astype(x.dtype)
    z = jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y * z, p["norm_w"])
    if axes:
        y = shard(y, P(axes.batch, None, axes.model))
    out = y @ p["out_proj"]
    if return_cache:
        return out, {"h": h_final, "conv": conv_tail.astype(jnp.bfloat16)}
    return out


def ssm_init_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def ssm_cache_specs(cfg: ArchConfig, axes: Axes):
    return {"h": P(axes.batch, axes.model, None, None),
            "conv": P(axes.batch, None, axes.model)}


def ssd_decode(x: jax.Array, p, cfg: ArchConfig, axes: Axes | None,
               cache: dict) -> tuple[jax.Array, dict]:
    """Recurrent single-token step.  x (B, 1, d)."""
    b = x.shape[0]
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    zxbcdt = x[:, 0] @ p["in_proj"]                         # (B, proj)
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)

    # conv update with cached tail window
    win = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)
    conv_out = (win * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_conv = win[:, 1:]

    xi = xbc[:, :di].reshape(b, h, pdim)
    bm = xbc[:, di:di + n].astype(jnp.float32)              # (B,N)
    cm = xbc[:, di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None])
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a[None])                             # (B,H)

    hstate = cache["h"] * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xi.astype(jnp.float32), bm)
    y = jnp.einsum("bn,bhpn->bhp", cm, hstate) \
        + xi.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    z = jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y * z, p["norm_w"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"h": hstate, "conv": new_conv}
