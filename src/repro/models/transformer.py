"""Decoder-only LM covering the dense / moe / mla / vlm-backbone families.

Layout & distribution (DESIGN.md §5):
  * every 2-D weight is stored P(data, model) — "model" carries the TP dim
    (flattened head dim, d_ff, vocab, experts), "data" is ZeRO/FSDP storage
    sharding that GSPMD gathers at use inside the layer scan;
  * activations get with_sharding_constraint steering per policy:
      - policy "tp":      batch on ("pod","data"), heads/d_ff on "model";
      - policy "spfsdp":  sequence on "model" (odd head counts — Qwen), see
        DESIGN.md §5;
  * layers are stacked and scanned (jax.lax.scan) with per-layer remat —
    one layer of HLO regardless of depth (compile-time at 512 devices, and
    the right call at 1000+ nodes too);
  * the LM loss is computed in sequence chunks so the (B,S,V) logits tensor
    never materialises.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.common import (ArchConfig, Axes, ParamDef, abstract_params,
                                 init_params, is_param_def, param_specs, pd)
from repro.models.layers import (apply_rope, cross_entropy,
                                 decode_attention_jnp, embed, flash_attention,
                                 repeat_kv, rmsnorm, shard, swiglu)


# --------------------------------------------------------------------- #
# Parameter definitions
# --------------------------------------------------------------------- #

def attn_param_defs(cfg: ArchConfig, axes: Axes):
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": pd((d, h * dh), P(axes.data, axes.model)),
        "wk": pd((d, hk * dh), P(axes.data, axes.model)),
        "wv": pd((d, hk * dh), P(axes.data, axes.model)),
        "wo": pd((h * dh, d), P(axes.model, axes.data)),
    }
    if cfg.qkv_bias:
        defs.update({
            "bq": pd((h * dh,), P(axes.model), init="zeros"),
            "bk": pd((hk * dh,), P(axes.model), init="zeros"),
            "bv": pd((hk * dh,), P(axes.model), init="zeros"),
        })
    if cfg.qk_norm:
        defs.update({
            "q_norm": pd((dh,), P(None), init="ones"),
            "k_norm": pd((dh,), P(None), init="ones"),
        })
    return defs


def mlp_param_defs(cfg: ArchConfig, axes: Axes):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": pd((d, f), P(axes.data, axes.model)),
        "w_up": pd((d, f), P(axes.data, axes.model)),
        "w_down": pd((f, d), P(axes.model, axes.data)),
    }


def layer_param_defs(cfg: ArchConfig, axes: Axes):
    defs: dict[str, Any] = {
        "ln_attn": pd((cfg.d_model,), P(None), init="ones"),
        "ln_mlp": pd((cfg.d_model,), P(None), init="ones"),
    }
    defs["attn"] = (mla_mod.mla_param_defs(cfg, axes) if cfg.mla
                    else attn_param_defs(cfg, axes))
    defs["ffn"] = (moe_mod.moe_param_defs(cfg, axes) if cfg.n_experts
                   else mlp_param_defs(cfg, axes))
    return defs


def _stack_defs(defs, n: int):
    return jax.tree.map(
        lambda d: dataclasses.replace(d, shape=(n,) + d.shape,
                                      spec=P(None, *d.spec)),
        defs, is_leaf=is_param_def)


def param_defs(cfg: ArchConfig, axes: Axes | None = None):
    ax = axes or Axes()
    v, d = cfg.padded_vocab, cfg.d_model
    return {
        "embed": pd((v, d), P(None, ax.model), scale=1.0),
        "layers": _stack_defs(layer_param_defs(cfg, ax), cfg.n_layers),
        "ln_f": pd((d,), P(None), init="ones"),
        "lm_head": pd((d, v), P(ax.data, ax.model)),
    }


# --------------------------------------------------------------------- #
# Blocks
# --------------------------------------------------------------------- #

def gqa_attention(x, p, cfg: ArchConfig, axes: Axes | None, positions,
                  q_offset=0):
    """Full-sequence GQA attention (train / prefill)."""
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"] + (p.get("bq", 0))
    k = x @ p["wk"] + (p.get("bk", 0))
    v = x @ p["wv"] + (p.get("bv", 0))
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hk, dh)
    v = v.reshape(b, s, hk, dh)
    if cfg.qk_norm:
        q, k = rmsnorm(q, p["q_norm"]), rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k_raw, v_raw = k, v                          # pre-repeat (cache layout)
    k, v = repeat_kv(k, h // hk), repeat_kv(v, h // hk)
    qr_spec = kv_spec = None
    if axes and cfg.policy == "tp":
        hspec = P(axes.batch, None, axes.model, None)
        q, k, v = shard(q, hspec), shard(k, hspec), shard(v, hspec)
    elif axes:                                   # spfsdp: sequence parallel
        sspec = P(axes.batch, axes.model, None, None)
        q = shard(q, sspec)
        # odd head counts: divide the model axis within each query chunk;
        # K/V stacks stay batch-sharded, replicated over model.
        qr_spec = P(None, axes.batch, None, axes.model, None)
        kv_spec = P(None, axes.batch, None, None, None)
    out = flash_attention(q, k, v, causal=cfg.causal, q_offset=q_offset,
                          qr_spec=qr_spec, kv_spec=kv_spec)
    return out.reshape(b, s, h * dh) @ p["wo"], (k_raw, v_raw)


def gqa_decode(x, p, cfg: ArchConfig, axes: Axes | None, cache, pos):
    """One-token GQA attention against the cache.  x (B,1,d)."""
    b = x.shape[0]
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((b, 1), pos)
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, 1, h, dh)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(b, 1, hk, dh)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(b, 1, hk, dh)
    if cfg.qk_norm:
        q, k = rmsnorm(q, p["q_norm"]), rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
    k_full = repeat_kv(kc, h // hk)
    v_full = repeat_kv(vc, h // hk)
    out = decode_attention_jnp(q[:, 0], k_full, v_full, pos + 1)
    return (out.reshape(b, 1, h * dh) @ p["wo"]), {"k": kc, "v": vc}


def ffn_block(x, p, cfg: ArchConfig, axes: Axes | None):
    if cfg.n_experts:
        return moe_mod.moe_ffn(x, p, cfg, axes)
    if axes is None:
        ff_spec = None
    elif cfg.policy == "tp":
        ff_spec = P(axes.batch, None, axes.model)      # d_ff on model
    else:                                              # spfsdp: seq on model
        ff_spec = P(axes.batch, axes.model, None)
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"], ff_spec)


def decoder_layer(x, p, cfg: ArchConfig, axes: Axes | None, positions):
    xspec = _x_spec(cfg, axes)
    if cfg.mla:
        a = mla_mod.mla_attention(rmsnorm(x, p["ln_attn"]), p["attn"], cfg,
                                  axes, positions)
    else:
        a, _ = gqa_attention(rmsnorm(x, p["ln_attn"]), p["attn"], cfg, axes,
                             positions)
    # keep the residual stream pinned (spfsdp: sequence on "model" — without
    # this the FFN/attention compute replicates 16x across the model axis).
    x = shard(x + a, xspec)
    x = shard(x + ffn_block(rmsnorm(x, p["ln_mlp"]), p["ffn"], cfg, axes),
              xspec)
    return x


# --------------------------------------------------------------------- #
# Forward passes
# --------------------------------------------------------------------- #

def _x_spec(cfg: ArchConfig, axes: Axes | None):
    if axes is None:
        return None
    if cfg.policy == "spfsdp":
        return P(axes.batch, axes.model, None)
    return P(axes.batch, None, None)


def _best_group(n: int) -> int:
    """Divisor G of n minimising G + n/G (sqrt-L two-level remat)."""
    best = 1
    for g in range(1, n + 1):
        if n % g == 0 and g + n // g < best + n // best:
            best = g
    return best


def two_level_scan(layer_fn, x, stacked_params, n_layers: int,
                   constrain=None):
    """sqrt(L) activation checkpointing: outer remat over G groups, inner
    remat per layer.  Remat-saved layer inputs drop from L to G + L/G at
    the price of one extra forward recompute in the backward pass
    (EXPERIMENTS.md §Perf discusses the trade)."""
    g = _best_group(n_layers)
    per = n_layers // g
    params2 = jax.tree.map(
        lambda a: a.reshape((g, per) + a.shape[1:]), stacked_params)
    inner_layer = jax.checkpoint(layer_fn)

    def group(x, gp):
        def body(x, lp):
            y = inner_layer(x, lp)
            if constrain is not None:
                y = constrain(y)
            return y, None
        y, _ = jax.lax.scan(body, x, gp)
        return y

    group = jax.checkpoint(group)

    def outer(x, gp):
        return group(x, gp), None

    y, _ = jax.lax.scan(outer, x, params2)
    return y


def backbone(params, tokens, cfg: ArchConfig, axes: Axes | None,
             remat: bool = True):
    """tokens (B, S) -> hidden (B, S, d), after final norm."""
    b, s = tokens.shape
    x = embed(tokens, params["embed"])
    x = shard(x, _x_spec(cfg, axes))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    layer = functools.partial(decoder_layer, cfg=cfg, axes=axes,
                              positions=positions)
    if remat:
        x = two_level_scan(layer, x, params["layers"], cfg.n_layers,
                           constrain=lambda y: shard(y, _x_spec(cfg, axes)))
    else:
        def body(x, lp):
            return shard(layer(x, lp), _x_spec(cfg, axes)), None
        x, _ = jax.lax.scan(body, x, params["layers"])
    return rmsnorm(x, params["ln_f"])


def chunked_loss(hidden, lm_head, labels, chunk: int = 512):
    """CE without materialising (B, S, V): scan over sequence chunks."""
    b, s, d = hidden.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // c
    hs = hidden.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, c).transpose(1, 0, 2)

    def body(acc, inp):
        h, lab = inp
        logits = jnp.einsum("bcd,dv->bcv", h.astype(jnp.float32),
                            lm_head.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None].clip(0),
                                   axis=-1)[..., 0]
        valid = (lab != -1).astype(jnp.float32)
        return (acc[0] + ((logz - gold) * valid).sum(),
                acc[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None):
    hidden = backbone(params, batch["tokens"], cfg, axes)
    return chunked_loss(hidden, params["lm_head"], batch["labels"])


# --------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------- #

def cache_defs(cfg: ArchConfig, batch: int, max_len: int, axes: Axes | None):
    """Per-layer cache as ParamDef tree (stacked over layers).

    Sharding: batch over ("pod","data"); the second cache dim over "model"
    — heads when the KV head count divides the axis, otherwise the cache
    *sequence* (GQA kv=4/8 archs; decode attention then runs a distributed
    softmax over the sequence shards).  batch==1 (long_500k) shards the
    sequence over "data" instead."""
    ax = axes or Axes()
    seq_axis = None
    batch_axis = ax.batch if axes else None
    head_axis = None
    if axes:
        if batch == 1:                # long_500k: no batch to shard
            batch_axis, seq_axis = None, ax.data
        elif cfg.n_kv_heads and cfg.n_kv_heads % 16 == 0:
            head_axis = ax.model
        else:
            seq_axis = ax.model
    if cfg.mla:
        # compressed latent has no head dim to shard: put the sequence on
        # "model" (batch>1) — 290 GB of c_kv at decode_32k x batch 128 needs
        # the full 256-way (batch x seq) sharding.
        mla_seq = seq_axis if seq_axis else (ax.model if axes else None)
        one = {
            "c_kv": pd((batch, max_len, cfg.kv_lora_rank),
                       P(batch_axis, mla_seq, None), init="zeros"),
            "k_pe": pd((batch, max_len, cfg.qk_rope_head_dim),
                       P(batch_axis, mla_seq, None), init="zeros"),
        }
    else:
        kv_shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        spec = P(batch_axis, seq_axis, head_axis, None)
        one = {"k": pd(kv_shape, spec, init="zeros"),
               "v": pd(kv_shape, spec, init="zeros")}
    return _stack_defs(one, cfg.n_layers)


def prefill_fn(params, batch, cfg: ArchConfig, axes: Axes | None = None,
               max_len: int | None = None):
    """Prompt forward.  Returns (last-position logits (B, V), cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    x = embed(tokens, params["embed"])
    x = shard(x, _x_spec(cfg, axes))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pad = max_len - s
    # per-layer cache sharding (strip the stacked-layer leading dim of the
    # cache_defs specs): keeps the scan's cache stack sharded — without it
    # the MLA prefill stack materialised 12 GB/device unsharded.
    from repro.models.common import param_specs as _ps
    layer_cache_spec = jax.tree.map(
        lambda spec: P(*spec[1:]),
        _ps(cache_defs(cfg, b, max_len, axes)),
        is_leaf=lambda x: isinstance(x, P))

    def _pin(cache):
        return jax.tree.map(lambda a, sp: shard(a, sp), cache,
                            layer_cache_spec)

    def body(x, lp):
        xin = rmsnorm(x, lp["ln_attn"])
        if cfg.mla:
            a = mla_mod.mla_attention(xin, lp["attn"], cfg, axes, positions)
            cache = mla_mod.mla_prefill_cache(xin, lp["attn"], cfg,
                                              positions, max_len)
        else:
            a, (k, v) = gqa_attention(xin, lp["attn"], cfg, axes, positions)
            cache = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))
                             ).astype(jnp.bfloat16),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))
                             ).astype(jnp.bfloat16),
            }
        x = x + a
        x = x + ffn_block(rmsnorm(x, lp["ln_mlp"]), lp["ffn"], cfg, axes)
        x = shard(x, _x_spec(cfg, axes))
        return x, _pin(cache)

    x, cache = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x[:, -1:], params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, cache


def decode_fn(params, cache, tokens, pos, cfg: ArchConfig,
              axes: Axes | None = None):
    """One decode step.  tokens (B, 1); pos scalar int32.
    Returns (logits (B, V), new cache)."""
    x = embed(tokens, params["embed"])

    def body(x, lc):
        lp, c = lc
        xin = rmsnorm(x, lp["ln_attn"])
        if cfg.mla:
            a, c2 = mla_mod.mla_decode(xin, lp["attn"], cfg, axes, c, pos)
        else:
            a, c2 = gqa_decode(xin, lp["attn"], cfg, axes, c, pos)
        x = x + a
        x = x + ffn_block(rmsnorm(x, lp["ln_mlp"]), lp["ffn"], cfg, axes)
        return x, c2

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(x, params["ln_f"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                        params["lm_head"].astype(jnp.float32))
    return logits, new_cache
