"""Unified offload timeline: structured trace events, Perfetto export,
and predicted-vs-simulated drift attribution.

Every producer of durations in this repo — the planner's Def-3 step
ledgers (``core.network_planner`` / ``core.multichip``), the functional
simulators (``sim.system`` / ``sim.s2`` / ``sim.multichip``), and the
statically-traced Pallas kernels (``analysis.kerncheck``) — is adapted
onto ONE shared event model (:mod:`repro.obs.events`): spans on four
lanes per chip (``dma_in`` / ``compute`` / ``write_back`` / ``ici``),
counters (VMEM occupancy, cumulative DRAM traffic), and structured
attributes keyed to Def-3 steps.  From there:

* :mod:`repro.obs.chrome`  — Chrome-trace / Perfetto JSON export with a
  pinned schema and validator;
* :mod:`repro.obs.adapters` — plan / simulator / kernel-trace builders;
* :mod:`repro.obs.metrics` — the planner metrics registry (absorbs the
  ad-hoc ``--profile`` perf_counter keys of ``benchmarks.network_plan``);
* :mod:`repro.obs.report`  — ``python -m repro.obs.report``: walks the
  predicted, simulated and kernel-traced timelines of one network and
  attributes any divergence to a specific (layer, chip, lane, step).

Only the dependency-light leaves are imported eagerly here; adapters and
the report pull in ``sim``/``analysis`` and must be imported explicitly
(``core`` imports :mod:`repro.obs.metrics` lazily, so the package root
must never import anything that imports ``core``'s dependents).
"""
from repro.obs.events import (CounterSample, LANES, Span, StepLanes,
                              Timeline, decompose_step)
from repro.obs.metrics import MetricsRegistry, REGISTRY

__all__ = [
    "CounterSample", "LANES", "MetricsRegistry", "REGISTRY", "Span",
    "StepLanes", "Timeline", "decompose_step",
]
