"""Timeline builders: plans, simulator runs, and kernel traces onto the
shared event model.

Three producers, one vocabulary:

* **predicted** — a plan's Def-3 step ledger, decomposed per step into
  lane spans (``obs.events.decompose_step``; write-back weights mirror
  ``analysis.verifier._out_weights``), plus VMEM-occupancy and
  cumulative-traffic counters from a symbolic step walk;
* **simulated** — what the functional simulators *measured*
  (``sim.system`` / ``sim.s2`` step traces carry their own lane
  durations and DRAM element counts, not recomputed from the plan);
* **kernel** — the static grid walk of the emitted Pallas kernels
  (``analysis.kerncheck``), with DMA'd regions and output blocks per
  grid step.

Network timelines lay layers back to back at their *gross* durations
(both predicted and simulated model the reuse-free schedule the
simulator executes; inter-layer reuse savings are analytic in
``sim.network`` and cancel in the drift comparison).  Multichip
timelines follow the plan's stage discipline — a layer's inbound ICI
spans open the stage on every active chip, shard spans start after them
(serial) or alongside them (``overlap``), and the stage cursor advances
by the plan's layer duration, so the predicted cluster timeline ends at
``plan.total_duration`` minus the analytic savings already folded in.

Kernel timelines cover the *compute* steps of an emitable plan: the
kernel writes each output block during its own grid step, one step
earlier than the plan's a3 write-back (which drains at the *next* step)
— per-step ``dma_in`` spans reconcile exactly; ``write_back`` reconciles
at layer granularity.
"""
from __future__ import annotations

from repro.analysis import kerncheck
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import MemoryState, apply_step
from repro.core.multichip import MultiChipPlan
from repro.core.network_planner import NetworkPlan
from repro.obs.events import Timeline
from repro.sim.multichip import MultiChipSimReport
from repro.sim.network import NetworkSimReport
from repro.sim.trace import StepTrace


def _kernel_groups_of(strategy):
    """S2 strategies carry ``kernel_groups``; S1 strategies do not."""
    return getattr(strategy, "kernel_groups", None)


def _footprint_elements(m: MemoryState, spec: ConvSpec,
                        kernel_groups) -> int:
    """Resident elements of a formal state, with S2 cell weighting
    (mirrors ``analysis.verifier``'s occupancy ledger)."""
    kelem = spec.c_in * spec.h_k * spec.w_k
    base = m.inp.bit_count() * spec.c_in + m.ker.bit_count() * kelem
    if kernel_groups is None:
        return base + m.out.bit_count() * spec.c_out
    g_count = len(kernel_groups)
    cells = 0
    mask = m.out
    while mask:
        low = mask & -mask
        cells += len(kernel_groups[(low.bit_length() - 1) % g_count])
        mask ^= low
    return base + cells


def add_plan_layer(tl: Timeline, strategy, spec: ConvSpec,
                   hw: HardwareModel, *, chip: int, layer: int,
                   t0: float, cum_read: int = 0) -> tuple[float, int]:
    """Emit one layer's predicted step ledger onto ``tl`` starting at
    ``t0``; returns (end time, cumulative DRAM-read elements)."""
    kernel_groups = _kernel_groups_of(strategy)
    m = MemoryState()
    t = t0
    for idx, s in enumerate(strategy.to_steps()):
        t = tl.add_step(s, spec, hw, chip=chip, layer=layer, index=idx,
                        t0=t, kernel_groups=kernel_groups)
        m = apply_step(m, s)
        tl.add_counter("vmem_elements", chip, t,
                       _footprint_elements(m, spec, kernel_groups))
        cum_read += s.i_slice.bit_count() * spec.c_in \
            + s.k_sub.bit_count() * spec.c_in * spec.h_k * spec.w_k
        tl.add_counter("dram_read_elements", chip, t, cum_read)
    return t, cum_read


def add_sim_layer(tl: Timeline, traces: "list[StepTrace]",
                  hw: HardwareModel, *, chip: int, layer: int,
                  t0: float, cum_read: int = 0) -> tuple[float, int]:
    """Emit one layer's *measured* step traces onto ``tl``."""
    t = t0
    for tr in traces:
        tl.add_span(f"L{layer} s{tr.index} wb", "write_back", chip, t,
                    tr.write_duration, layer=layer, step=tr.index,
                    elements=tr.written_elements, w=tr.step.w)
        t += tr.write_duration
        tl.add_span(f"L{layer} s{tr.index} dma", "dma_in", chip, t,
                    tr.load_duration, layer=layer, step=tr.index,
                    elements=tr.read_elements, i_slice=tr.step.i_slice,
                    k_sub=tr.step.k_sub)
        t += tr.load_duration
        tl.add_span(f"L{layer} s{tr.index} acc", "compute", chip, t,
                    tr.compute_duration, layer=layer, step=tr.index,
                    group=tr.step.group)
        t += tr.compute_duration
        retry_dur = getattr(tr, "retry_duration", 0.0)
        if retry_dur:
            # injected DMA transients (repro.resil): the re-issued loads
            # + backoff surface on the fault lane, keeping the invariant
            # wb + dma + acc + retry == tr.duration
            tl.add_span(f"L{layer} s{tr.index} dma-retry", "fault", chip,
                        t, retry_dur, layer=layer, step=tr.index,
                        elements=getattr(tr, "retry_elements", 0),
                        retries=getattr(tr, "retries", 0))
            t += retry_dur
        tl.add_counter("vmem_elements", chip, t, tr.mem_elements)
        cum_read += tr.read_elements
        tl.add_counter("dram_read_elements", chip, t, cum_read)
    return t, cum_read


# --------------------------------------------------------------------- #
# Single-chip network timelines
# --------------------------------------------------------------------- #

def network_predicted_timeline(plan: NetworkPlan,
                               label: str = "predicted") -> Timeline:
    tl = Timeline(label)
    t = 0.0
    cum = 0
    for lp in plan.layers:
        t, cum = add_plan_layer(tl, lp.strategy, lp.spec, plan.hw,
                                chip=0, layer=lp.index, t0=t,
                                cum_read=cum)
    return tl


def network_simulated_timeline(sim: NetworkSimReport,
                               label: str = "simulated") -> Timeline:
    tl = Timeline(label)
    t = 0.0
    cum = 0
    for lp, rep in zip(sim.plan.layers, sim.layer_reports):
        t, cum = add_sim_layer(tl, rep.traces, sim.plan.hw, chip=0,
                               layer=lp.index, t0=t, cum_read=cum)
    return tl


# --------------------------------------------------------------------- #
# Multichip timelines
# --------------------------------------------------------------------- #

def _add_stage_ici(tl: Timeline, lp, t0: float) -> None:
    if lp.ici_duration <= 0:
        return
    for shard in lp.shards:
        tl.add_span(f"L{lp.index} ici {lp.mode}", "ici", shard.chip, t0,
                    lp.ici_duration, layer=lp.index,
                    elements=lp.ici_elements, mode=lp.mode,
                    overlap=lp.overlap)


def _add_final_gather(tl: Timeline, plan: MultiChipPlan,
                      t0: float) -> None:
    if plan.final_gather_duration <= 0:
        return
    for shard in plan.layers[-1].shards:
        tl.add_span("final gather", "ici", shard.chip, t0,
                    plan.final_gather_duration,
                    elements=plan.final_gather_elements)


def multichip_predicted_timeline(plan: MultiChipPlan,
                                 label: str = "predicted") -> Timeline:
    tl = Timeline(label)
    t = 0.0
    for lp in plan.layers:
        _add_stage_ici(tl, lp, t)
        start = t if lp.overlap else t + lp.ici_duration
        for shard in lp.shards:
            add_plan_layer(tl, shard.strategy, shard.spec,
                           plan.cluster.chip, chip=shard.chip,
                           layer=lp.index, t0=start)
        t += lp.duration
    _add_final_gather(tl, plan, t)
    return tl


def multichip_simulated_timeline(sim: MultiChipSimReport,
                                 label: str = "simulated") -> Timeline:
    """Measured shard runs placed under the plan's stage discipline (the
    ICI transfers themselves are analytic — see ``sim.multichip``)."""
    plan = sim.plan
    tl = Timeline(label)
    t = 0.0
    for lp, reps in zip(plan.layers, sim.shard_reports):
        _add_stage_ici(tl, lp, t)
        start = t if lp.overlap else t + lp.ici_duration
        for shard, rep in zip(lp.shards, reps):
            add_sim_layer(tl, rep.traces, plan.cluster.chip,
                          chip=shard.chip, layer=lp.index, t0=start)
        t += lp.duration
    _add_final_gather(tl, plan, t)
    return tl


# --------------------------------------------------------------------- #
# Fault-injected timelines (repro.resil)
# --------------------------------------------------------------------- #

def faulted_timeline(report, label: str = "faulted") -> Timeline:
    """Timeline of a fault-injected run (``repro.resil.engine``).

    Committed attempts place their measured shard traces under the
    stage discipline exactly like :func:`multichip_simulated_timeline`
    (chips are the attempt's *physical* ids, so a post-recovery plan's
    slot 0 lands on the surviving chip's track); wasted attempts become
    ``fault`` spans on every chip of the doomed attempt plus the
    heartbeat-detection window on the dead chip; every re-plan becomes
    ``recovery`` spans (re-plan latency, then the recovery-point restage
    for chip deaths).  Duck-typed over ``FaultSimReport`` so
    ``repro.obs`` stays below ``repro.resil`` in the layering.
    """
    plan0 = report.plans[0]
    hw = plan0.cluster.chip
    tl = Timeline(label)
    for att in report.attempts:
        if att.wasted:
            for c in att.phys_chips:
                tl.add_span(f"L{att.layer} wasted attempt", "fault", c,
                            att.t0, att.duration, layer=att.layer,
                            cause="chip_death", dead_chip=att.dead_chip)
            tl.add_span(f"L{att.layer} detection", "fault",
                        att.dead_chip, att.t0 + att.duration,
                        att.detection, layer=att.layer,
                        cause="heartbeat_timeout")
            continue
        lp = att.lp
        if lp.ici_duration > 0:
            for shard in lp.shards:
                tl.add_span(f"L{att.layer} ici {lp.mode}", "ici",
                            att.phys_chips[shard.chip], att.t0,
                            lp.ici_duration, layer=att.layer,
                            elements=lp.ici_elements, mode=lp.mode,
                            overlap=lp.overlap)
        start = att.t0 if lp.overlap else att.t0 + lp.ici_duration
        for shard, rep in zip(lp.shards, att.reports):
            add_sim_layer(tl, rep.traces, hw,
                          chip=att.phys_chips[shard.chip],
                          layer=att.layer, t0=start)
    for rec in report.recoveries:
        tl.add_span(f"L{rec.layer} replan {rec.kind}", "recovery", 0,
                    rec.t0, rec.replan_cycles, layer=rec.layer,
                    kind=rec.kind, n_chips=rec.n_chips,
                    topology=rec.new_topology, verified=rec.verified)
        if rec.restage_cycles > 0:
            tl.add_span(f"L{rec.layer} restage", "recovery", 0,
                        rec.t0 + rec.replan_cycles, rec.restage_cycles,
                        layer=rec.layer, kind=rec.kind,
                        elements=rec.restage_elements)
    last = report.plans[-1]
    if last.final_gather_duration > 0 and report.attempts:
        t0 = report.faulted_duration - last.final_gather_duration
        for c in report.attempts[-1].phys_chips:
            tl.add_span("final gather", "ici", c, t0,
                        last.final_gather_duration,
                        elements=last.final_gather_elements)
    return tl


# --------------------------------------------------------------------- #
# Kernel-trace timelines (static Pallas grid walk)
# --------------------------------------------------------------------- #

def kernel_timeline(plan: NetworkPlan, label: str = "kernel") -> Timeline:
    """Timeline of the emitted kernels' *traced* access sets, one grid
    step per plan compute step (see the module note on write-back skew).
    ``plan`` must be emitable (``kernels.emit.plan_emitable_network``)."""
    from repro.kernels.emit import emit_layer_kernel
    hw = plan.hw
    tl = Timeline(label)
    t = 0.0
    for lp in plan.layers:
        spec = lp.spec
        trace = kerncheck.build_conv_trace(emit_layer_kernel(lp))
        for st in trace.steps:
            pix = kerncheck._box_pixmask(spec, st.x_load)
            n_pix = pix.bit_count()
            load_dur = (n_pix + st.lam_elements) * hw.t_l
            tl.add_span(f"L{lp.index} g{st.index} dma", "dma_in", 0, t,
                        load_dur, layer=lp.index, step=st.index,
                        elements=st.x_load.elements + st.lam_elements,
                        i_slice=pix, region=st.x_load.describe())
            t += load_dur
            tl.add_span(f"L{lp.index} g{st.index} acc", "compute", 0, t,
                        hw.t_acc, layer=lp.index, step=st.index)
            t += hw.t_acc
            out_mask = kerncheck._out_patchmask(spec, st.out)
            n_out = out_mask.bit_count()
            tl.add_span(f"L{lp.index} g{st.index} wb", "write_back", 0,
                        t, n_out * hw.t_w, layer=lp.index, step=st.index,
                        elements=n_out * spec.c_out, w=out_mask)
            t += n_out * hw.t_w
        tl.add_counter("vmem_elements", 0, t, trace.vmem_elements)
    return tl
