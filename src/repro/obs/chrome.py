"""Chrome-trace / Perfetto JSON export with a pinned schema.

``to_chrome_trace`` maps timelines onto the Trace Event Format that both
``chrome://tracing`` and https://ui.perfetto.dev load directly:

* process (``pid``)  = one (timeline label, chip) pair, named via ``M``
  (metadata) events — e.g. ``predicted · chip0``;
* thread  (``tid``)  = one lane per process, in ``LANES`` order;
* ``X`` (complete) events = spans, with Def-3 step attribution in
  ``args`` (layer, step, elements);
* ``C`` (counter) events = counters (VMEM occupancy, cumulative traffic).

Timestamps are emitted in microseconds-as-cycles: one Def-3 cycle is one
``ts`` unit, so Perfetto's time axis reads directly in model cycles.

``TRACE_SCHEMA`` is the *pinned* contract for the exported document —
tests validate every export against it, and ``validate_chrome_trace``
additionally enforces the per-phase requirements a generic JSON-schema
walk cannot express (``X`` needs ``ts``/``dur``/``tid``, ``C`` needs
``args``, ``M`` names must be known metadata keys).  The validator is
hand-rolled (subset of JSON Schema: ``type`` / ``required`` /
``properties`` / ``items`` / ``enum`` / ``minimum``) because the repo
deliberately carries no jsonschema dependency.
"""
from __future__ import annotations

import json
from typing import Any, Sequence

from repro.obs.events import LANES, Timeline

#: Pinned JSON-schema subset for the exported trace document.
TRACE_SCHEMA: dict = {
    "type": "object",
    "required": ["traceEvents", "displayTimeUnit", "otherData"],
    "properties": {
        "displayTimeUnit": {"type": "string", "enum": ["ms", "ns"]},
        "otherData": {
            "type": "object",
            "required": ["generator", "cycle_unit"],
            "properties": {
                "generator": {"type": "string"},
                "cycle_unit": {"type": "string"},
            },
        },
        "traceEvents": {
            "type": "array",
            "items": {
                "type": "object",
                "required": ["ph", "pid", "name"],
                "properties": {
                    "ph": {"type": "string", "enum": ["X", "C", "M"]},
                    "name": {"type": "string"},
                    "cat": {"type": "string"},
                    "pid": {"type": "integer", "minimum": 0},
                    "tid": {"type": "integer", "minimum": 0},
                    "ts": {"type": "number", "minimum": 0},
                    "dur": {"type": "number", "minimum": 0},
                    "args": {"type": "object"},
                },
            },
        },
    },
}

_METADATA_NAMES = ("process_name", "process_sort_index", "thread_name",
                   "thread_sort_index")
_COUNTER_TID = len(LANES)


def _jsonable(value: Any) -> Any:
    """Span attrs may carry bitmask ints, tuples, etc. — keep JSON tame
    (huge masks become bit counts; tuples become lists)."""
    if isinstance(value, bool) or value is None:
        return value
    if isinstance(value, int):
        return value if value.bit_length() <= 53 else \
            {"bit_count": value.bit_count()}
    if isinstance(value, (list, tuple)):
        return [_jsonable(v) for v in value]
    if isinstance(value, float):
        return value
    return str(value)


def to_chrome_trace(timelines: Sequence[Timeline]) -> dict:
    """Export timelines to one Chrome-trace document (see module note)."""
    events: list[dict] = []
    pids: dict[tuple[str, int], int] = {}
    for tl in timelines:
        for chip in tl.chips():
            pid = pids.setdefault((tl.label, chip), len(pids) + 1)
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "args": {"name": f"{tl.label} · chip{chip}"}})
            events.append({"ph": "M", "name": "process_sort_index",
                           "pid": pid, "args": {"sort_index": pid}})
            for tid, lane in enumerate(LANES):
                events.append({"ph": "M", "name": "thread_name",
                               "pid": pid, "tid": tid,
                               "args": {"name": lane}})
                events.append({"ph": "M", "name": "thread_sort_index",
                               "pid": pid, "tid": tid,
                               "args": {"sort_index": tid}})
    for tl in timelines:
        for s in tl.spans:
            pid = pids[(tl.label, s.chip)]
            args: dict[str, Any] = {}
            if s.layer is not None:
                args["layer"] = s.layer
            if s.step is not None:
                args["step"] = s.step
            if s.elements:
                args["elements"] = s.elements
            for k, v in s.attrs.items():
                args[k] = _jsonable(v)
            events.append({"ph": "X", "name": s.name, "cat": s.lane,
                           "pid": pid, "tid": LANES.index(s.lane),
                           "ts": s.t0, "dur": s.dur, "args": args})
        for c in tl.counters:
            pid = pids[(tl.label, c.chip)]
            events.append({"ph": "C", "name": c.name, "pid": pid,
                           "tid": _COUNTER_TID, "ts": c.t,
                           "args": {c.name: c.value}})
    return {
        "displayTimeUnit": "ms",
        "otherData": {"generator": "repro.obs",
                      "cycle_unit": "1 ts == 1 Def-3 cycle"},
        "traceEvents": events,
    }


# --------------------------------------------------------------------- #
# Validation
# --------------------------------------------------------------------- #

def _check(value: Any, schema: dict, path: str, errors: list[str]) -> None:
    t = schema.get("type")
    if t == "object":
        if not isinstance(value, dict):
            errors.append(f"{path}: expected object, got "
                          f"{type(value).__name__}")
            return
        for req in schema.get("required", ()):
            if req not in value:
                errors.append(f"{path}: missing required key {req!r}")
        for key, sub in schema.get("properties", {}).items():
            if key in value:
                _check(value[key], sub, f"{path}.{key}", errors)
    elif t == "array":
        if not isinstance(value, list):
            errors.append(f"{path}: expected array, got "
                          f"{type(value).__name__}")
            return
        sub = schema.get("items")
        if sub:
            for i, item in enumerate(value):
                _check(item, sub, f"{path}[{i}]", errors)
    elif t == "string":
        if not isinstance(value, str):
            errors.append(f"{path}: expected string, got "
                          f"{type(value).__name__}")
            return
        enum = schema.get("enum")
        if enum is not None and value not in enum:
            errors.append(f"{path}: {value!r} not in {enum}")
    elif t in ("integer", "number"):
        ok = isinstance(value, int) and not isinstance(value, bool) \
            if t == "integer" else (isinstance(value, (int, float))
                                    and not isinstance(value, bool))
        if not ok:
            errors.append(f"{path}: expected {t}, got "
                          f"{type(value).__name__}")
            return
        lo = schema.get("minimum")
        if lo is not None and value < lo:
            errors.append(f"{path}: {value} < minimum {lo}")


def validate_chrome_trace(trace: Any) -> list[str]:
    """All schema violations in ``trace`` (empty list == valid).

    Beyond the :data:`TRACE_SCHEMA` walk, the per-phase requirements:
    ``X`` events need ``ts``/``dur``/``tid``; ``C`` events need ``ts``
    and a non-empty ``args``; ``M`` names must be known metadata keys.
    """
    errors: list[str] = []
    _check(trace, TRACE_SCHEMA, "$", errors)
    if errors:
        return errors
    for i, ev in enumerate(trace["traceEvents"]):
        path = f"$.traceEvents[{i}]"
        ph = ev.get("ph")
        if ph == "X":
            for key in ("ts", "dur", "tid"):
                if key not in ev:
                    errors.append(f"{path}: X event missing {key!r}")
            if ev.get("cat") not in LANES:
                errors.append(f"{path}: X event cat {ev.get('cat')!r} "
                              f"is not a lane {LANES}")
        elif ph == "C":
            if "ts" not in ev:
                errors.append(f"{path}: C event missing 'ts'")
            if not ev.get("args"):
                errors.append(f"{path}: C event needs a non-empty args")
        elif ph == "M":
            if ev["name"] not in _METADATA_NAMES:
                errors.append(f"{path}: unknown metadata event "
                              f"{ev['name']!r}")
    return errors


def write_chrome_trace(trace: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
        f.write("\n")
