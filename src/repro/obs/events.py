"""The shared timeline-event model.

One vocabulary for every duration producer in the repo: a
:class:`Timeline` holds :class:`Span`\\ s on per-chip *lanes* and
:class:`CounterSample`\\ s.  Lanes mirror the Def-3 action order within a
step — a3 write-backs drain first, then a4/a5 DMA loads, then the a6
accelerator trigger — so a step occupies ``[t, t + step_duration)`` with
its ``write_back`` / ``dma_in`` / ``compute`` spans laid back-to-back in
that order and the invariant

    write_dur + load_dur + compute_dur == Def-3 step_duration

holds exactly (:func:`decompose_step` mirrors the weighted write-back
accounting of ``analysis.verifier._out_weights``: S1 output units are
patches — one spatial write each, ``c_out`` elements; S2 units are
(patch, kernel-group) cells — writes and elements both count the group's
kernels, cf. ``sim.s2.run_s2``).

Element attribution follows the simulators' DRAM counters exactly:
``dma_in`` elements are channel-expanded (``|I_slice| * C_in +
|K_sub| * kelem``), ``write_back`` elements are ``c_out`` per patch (S1)
or one per (patch, kernel) cell (S2) — so predicted-vs-simulated element
drift is an integer and zero means *exactly* reconciled.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import Step

#: Lane vocabulary, in intra-step execution order (``ici`` is the
#: inter-chip interconnect lane of multichip stages; single-chip
#: timelines simply never populate it).  ``fault`` and ``recovery`` are
#: the resilience lanes (``repro.resil``): ``fault`` spans cover wasted
#: work — a dead chip's in-flight stage, heartbeat detection latency,
#: DMA retry backoff — and ``recovery`` spans cover the repair — tail
#: re-planning and recovery-point restaging.  Fault-free timelines
#: simply never populate either.
LANES = ("dma_in", "compute", "write_back", "ici", "fault", "recovery")


@dataclasses.dataclass(frozen=True)
class Span:
    """One timed interval on a (chip, lane)."""

    name: str
    lane: str
    chip: int
    t0: float
    dur: float
    layer: int | None = None
    step: int | None = None
    elements: int = 0            # DRAM/ICI elements moved (0 for compute)
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def t1(self) -> float:
        return self.t0 + self.dur


@dataclasses.dataclass(frozen=True)
class CounterSample:
    """One sample of a monotone or gauge counter on a chip."""

    name: str
    chip: int
    t: float
    value: float


@dataclasses.dataclass(frozen=True)
class StepLanes:
    """The Def-3 lane decomposition of one step (see module note)."""

    write_dur: float
    write_elements: int
    load_dur: float
    load_elements: int
    compute_dur: float
    macs: int

    @property
    def total_dur(self) -> float:
        return self.write_dur + self.load_dur + self.compute_dur


def decompose_step(step: Step, spec: ConvSpec, hw: HardwareModel,
                   kernel_groups: "tuple[tuple[int, ...], ...] | None" = None,
                   ) -> StepLanes:
    """Split one step's Def-3 duration across the three on-chip lanes.

    ``kernel_groups`` marks an S2 schedule: the step's ``w`` mask indexes
    (patch, kernel-group) units and each written unit drains (and costs
    ``t_w`` for) one element per kernel of its group — the exact
    accounting of ``sim.s2.run_s2`` and ``analysis.verifier``.
    """
    kelem = spec.c_in * spec.h_k * spec.w_k
    n_pix = step.i_slice.bit_count()
    n_ker = step.k_sub.bit_count()
    load_dur = (n_pix + n_ker * kelem) * hw.t_l
    load_elements = n_pix * spec.c_in + n_ker * kelem

    if kernel_groups is None:
        wb_units = step.w.bit_count()
        write_dur = wb_units * hw.t_w
        write_elements = wb_units * spec.c_out
    else:
        g_count = len(kernel_groups)
        cells = 0
        mask = step.w
        while mask:
            low = mask & -mask
            unit = low.bit_length() - 1
            cells += len(kernel_groups[unit % g_count])
            mask ^= low
        write_dur = cells * hw.t_w
        write_elements = cells

    if step.computes:
        n_k = len(step.kernel_group) if step.kernel_group is not None \
            else spec.c_out
        compute_dur = hw.t_acc
        macs = len(step.group) * spec.nb_op_value * n_k
    else:
        compute_dur = 0.0
        macs = 0
    return StepLanes(write_dur=write_dur, write_elements=write_elements,
                     load_dur=load_dur, load_elements=load_elements,
                     compute_dur=compute_dur, macs=macs)


class Timeline:
    """An append-only collection of spans and counters, with the query
    surface the drift report and the invariant tests are built on."""

    def __init__(self, label: str):
        self.label = label
        self.spans: list[Span] = []
        self.counters: list[CounterSample] = []

    # -- construction -------------------------------------------------- #

    def add_span(self, name: str, lane: str, chip: int, t0: float,
                 dur: float, *, layer: int | None = None,
                 step: int | None = None, elements: int = 0,
                 **attrs: Any) -> Span | None:
        """Append a span; zero-duration zero-element spans are dropped
        (a step with nothing to write emits no ``write_back`` span)."""
        if lane not in LANES:
            raise ValueError(f"unknown lane {lane!r} (have {LANES})")
        if dur < 0:
            raise ValueError(f"negative span duration {dur} ({name})")
        if dur == 0 and elements == 0:
            return None
        span = Span(name=name, lane=lane, chip=chip, t0=t0, dur=dur,
                    layer=layer, step=step, elements=elements, attrs=attrs)
        self.spans.append(span)
        return span

    def add_counter(self, name: str, chip: int, t: float,
                    value: float) -> None:
        self.counters.append(CounterSample(name=name, chip=chip, t=t,
                                           value=value))

    def add_step(self, step: Step, spec: ConvSpec, hw: HardwareModel, *,
                 chip: int, layer: int | None, index: int, t0: float,
                 kernel_groups: "tuple[tuple[int, ...], ...] | None" = None,
                 ) -> float:
        """Emit one Def-3 step as its lane spans (a3 -> a4/a5 -> a6
        order, back-to-back) and return the step's end time."""
        lanes = decompose_step(step, spec, hw, kernel_groups)
        t = t0
        self.add_span(f"L{layer} s{index} wb", "write_back", chip, t,
                      lanes.write_dur, layer=layer, step=index,
                      elements=lanes.write_elements, w=step.w)
        t += lanes.write_dur
        self.add_span(f"L{layer} s{index} dma", "dma_in", chip, t,
                      lanes.load_dur, layer=layer, step=index,
                      elements=lanes.load_elements, i_slice=step.i_slice,
                      k_sub=step.k_sub)
        t += lanes.load_dur
        self.add_span(f"L{layer} s{index} acc", "compute", chip, t,
                      lanes.compute_dur, layer=layer, step=index,
                      group=step.group, macs=lanes.macs)
        return t + lanes.compute_dur

    # -- queries -------------------------------------------------------- #

    @property
    def end_time(self) -> float:
        return max((s.t1 for s in self.spans), default=0.0)

    def chips(self) -> list[int]:
        return sorted({s.chip for s in self.spans})

    def lanes_of(self, chip: int) -> set[str]:
        return {s.lane for s in self.spans if s.chip == chip}

    def layers(self) -> list[int]:
        return sorted({s.layer for s in self.spans if s.layer is not None})

    def select(self, *, layer: int | None = None, chip: int | None = None,
               lane: str | None = None) -> list[Span]:
        return [s for s in self.spans
                if (layer is None or s.layer == layer)
                and (chip is None or s.chip == chip)
                and (lane is None or s.lane == lane)]

    def span_sum(self, *, layer: int | None = None,
                 chip: int | None = None,
                 lane: str | None = None) -> float:
        return sum(s.dur for s in self.select(layer=layer, chip=chip,
                                              lane=lane))

    def element_sum(self, *, layer: int | None = None,
                    chip: int | None = None,
                    lane: str | None = None) -> int:
        return sum(s.elements for s in self.select(layer=layer, chip=chip,
                                                   lane=lane))

    def overlap_violations(self, tol: float = 1e-9) -> list[str]:
        """Spans on one (chip, lane) must never overlap — each lane is a
        serial resource.  Returns human-readable violations (empty ==
        invariant holds)."""
        out: list[str] = []
        by_lane: dict[tuple[int, str], list[Span]] = {}
        for s in self.spans:
            by_lane.setdefault((s.chip, s.lane), []).append(s)
        for (chip, lane), spans in sorted(by_lane.items()):
            spans = sorted(spans, key=lambda s: (s.t0, s.t1))
            for prev, cur in zip(spans, spans[1:]):
                if cur.t0 < prev.t1 - tol:
                    out.append(
                        f"{self.label}: chip{chip}/{lane}: "
                        f"{cur.name!r} starts at {cur.t0:g} before "
                        f"{prev.name!r} ends at {prev.t1:g}")
        return out

    def extend(self, spans: Iterable[Span]) -> None:
        self.spans.extend(spans)

    def __repr__(self) -> str:
        return (f"Timeline({self.label!r}, {len(self.spans)} spans, "
                f"{len(self.counters)} counters, end={self.end_time:g})")
