"""Planner metrics registry.

Absorbs the ad-hoc ``time.perf_counter()`` bookkeeping that used to live
inline in ``benchmarks.network_plan`` (stage wall-clocks) and gives
``core.network_planner`` structured instrumentation hooks (imported
*lazily* there — ``core`` must never depend on ``obs`` at module level).

Keys are ``/``-separated paths; :meth:`MetricsRegistry.snapshot` nests
them into plain dicts for JSON emission.  Timers *accumulate* across
``with`` blocks, so per-call instrumentation (every ``plan_network``
invocation) rolls up into per-stage totals for free.
"""
from __future__ import annotations

import contextlib
import time
from typing import Iterator


class MetricsRegistry:
    """Accumulating counters/gauges/timers keyed by ``a/b/c`` paths."""

    def __init__(self) -> None:
        self._values: dict[str, float] = {}

    def clear(self) -> None:
        self._values.clear()

    def set(self, key: str, value: float) -> None:
        self._values[key] = value

    def incr(self, key: str, by: float = 1) -> None:
        self._values[key] = self._values.get(key, 0) + by

    def get(self, key: str, default: float = 0.0) -> float:
        return self._values.get(key, default)

    @contextlib.contextmanager
    def timer(self, key: str) -> Iterator[None]:
        """Accumulate wall-clock seconds under ``key``."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.incr(key, time.perf_counter() - t0)

    def keys(self) -> list[str]:
        return sorted(self._values)

    def snapshot(self, prefix: str = "", round_to: int | None = 4) -> dict:
        """Nested-dict view of every key under ``prefix``."""
        out: dict = {}
        for key in self.keys():
            if prefix and not key.startswith(prefix + "/") \
                    and key != prefix:
                continue
            rel = key[len(prefix) + 1:] if prefix else key
            parts = rel.split("/") if rel else [key.rsplit("/", 1)[-1]]
            node = out
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            v = self._values[key]
            if round_to is not None and isinstance(v, float):
                v = round(v, round_to)
            node[parts[-1]] = v
        return out


#: The process-wide default registry — what the planner hooks and the
#: benchmark's ``--profile`` emission share.
REGISTRY = MetricsRegistry()
