"""Predicted-vs-simulated-vs-kernel drift report.

``python -m repro.obs.report --network tight4 --topology torus2x2``
plans the network (single-chip or on a cluster), executes the plan in
the functional simulator, statically traces the emitted Pallas kernels,
builds the three timelines on the shared event model, exports them as
one Chrome-trace/Perfetto JSON, and reconciles them per (layer, chip,
lane) — attributing any divergence to the first divergent step.

The paper's claim is *predictable* offloading: on a reconciled plan the
max |predicted − simulated| element drift is exactly 0 (DRAM traffic is
integral) and the duration drift is 0 within float tolerance.  The exit
code folds that in — nonzero drift, a schema-invalid trace, or a lane
missing from a chip all fail the run — which is what the CI obs smoke
step and the ``obs_trace_valid`` / ``max_drift_elements`` pins in
``BENCH_network_plan.json`` consume.

Load the written trace in https://ui.perfetto.dev (or
``chrome://tracing``): one process per (source, chip), one thread per
lane, 1 ts == 1 Def-3 cycle.

Drift semantics:

* ``predicted`` vs ``simulated`` — same step sequence, durations and
  element counts measured independently by the simulator; reconciles
  per step on every lane.
* ``kernel`` vs its own emitable plan (``kernels.emit`` at kerncheck's
  2x-Λ budget — kernels only exist for emitable plans) — ``dma_in``
  reconciles per step; ``write_back`` reconciles per layer (the kernel
  writes each output block during its grid step, the plan's a3 drains
  it at the next step); ``compute`` reconciles per layer.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Sequence

from repro.analysis import kerncheck
from repro.configs.clusters import make_cluster
from repro.configs.networks import NETWORKS
from repro.core.cost_model import HardwareModel, Topology
from repro.core.multichip import plan_multichip_network
from repro.core.network_planner import plan_network
from repro.obs import adapters
from repro.obs.chrome import (to_chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.events import LANES, Timeline
from repro.sim.multichip import simulate_multichip
from repro.sim.network import simulate_network

_TOL = 1e-9
_ONCHIP_LANES = ("dma_in", "compute", "write_back")


@dataclasses.dataclass(frozen=True)
class DriftRow:
    """One (layer, chip, lane) reconciliation line."""

    layer: int
    chip: int
    lane: str
    predicted_dur: float
    observed_dur: float
    predicted_elements: int
    observed_elements: int
    first_divergent_step: int | None = None

    @property
    def drift_cycles(self) -> float:
        return abs(self.predicted_dur - self.observed_dur)

    @property
    def drift_elements(self) -> int:
        return abs(self.predicted_elements - self.observed_elements)

    @property
    def clean(self) -> bool:
        """This lane's totals reconcile.  ``first_divergent_step`` is
        shared (layer, chip) context, judged by :attr:`ObsReport.ok` —
        it can be set while an individual lane's sums still match (and
        catches compensating per-step drift that cancels in the sums)."""
        return self.drift_elements == 0 and self.drift_cycles <= _TOL


def _first_divergent_step(pred: Timeline, obs: Timeline, *, layer: int,
                          chip: int) -> int | None:
    """First step index where any lane's span disagrees on duration
    (beyond tolerance) or on element count."""
    table: dict[tuple[int, str], list[float]] = {}
    for src, tl in enumerate((pred, obs)):
        for s in tl.select(layer=layer, chip=chip):
            if s.step is None:
                continue
            row = table.setdefault((s.step, s.lane), [0.0, 0, 0.0, 0])
            row[2 * src] += s.dur
            row[2 * src + 1] += s.elements
    for (step, _lane), (pd, pe, od, oe) in sorted(table.items()):
        if pe != oe or abs(pd - od) > _TOL:
            return step
    return None


def drift_rows(pred: Timeline, obs: Timeline,
               lanes: Sequence[str] = LANES,
               per_step: bool = True) -> list[DriftRow]:
    """Reconcile two timelines per (layer, chip, lane)."""
    rows = []
    keys = sorted({(s.layer, s.chip) for s in pred.spans + obs.spans
                   if s.layer is not None})
    for layer, chip in keys:
        div = _first_divergent_step(pred, obs, layer=layer, chip=chip) \
            if per_step else None
        for lane in lanes:
            sel = dict(layer=layer, chip=chip, lane=lane)
            rows.append(DriftRow(
                layer=layer, chip=chip, lane=lane,
                predicted_dur=pred.span_sum(**sel),
                observed_dur=obs.span_sum(**sel),
                predicted_elements=pred.element_sum(**sel),
                observed_elements=obs.element_sum(**sel),
                first_divergent_step=div))
    return rows


def fault_attribution_rows(pred: Timeline, faulted: Timeline
                           ) -> list[DriftRow]:
    """Degraded-run drift attribution (``repro.resil``): reconcile the
    fault-free *predicted* timeline against a faulted run per (layer,
    chip, lane).  The ``fault``/``recovery`` lanes are zero on the
    predicted side by construction, so their observed totals *are* the
    overhead the fault model added — wasted attempts, heartbeat
    detection, DMA retries, re-planning, restaging — while drift on the
    other lanes shows where the degraded plan executes differently
    (e.g. a survivor absorbing a dead chip's rows).  Per-step divergence
    is not judged: a faulted run legitimately diverges at the first
    fault, and the point of this table is to say by how much and why.
    """
    return drift_rows(pred, faulted, per_step=False)


def fault_overhead_by_lane(rows: "Sequence[DriftRow]"
                           ) -> dict[str, float]:
    """Sum each lane's |observed - predicted| duration drift — the
    attribution table's bottom line, pinned by ``faultsim``."""
    out: dict[str, float] = {}
    for r in rows:
        out[r.lane] = out.get(r.lane, 0.0) + (
            r.observed_dur - r.predicted_dur)
    return out


def kernel_drift_rows(plan_tl: Timeline, kern_tl: Timeline
                      ) -> list[DriftRow]:
    """Kernel-vs-plan reconciliation: per-step on ``dma_in``, per-layer
    on ``compute``/``write_back`` (one-step write skew, module note)."""
    rows = []
    layers = sorted({s.layer for s in kern_tl.spans if s.layer is not None})
    for layer in layers:
        div = None
        pred_dma = {s.step: s for s in plan_tl.select(layer=layer, chip=0,
                                                      lane="dma_in")}
        for s in sorted(kern_tl.select(layer=layer, chip=0, lane="dma_in"),
                        key=lambda s: s.step or 0):
            p = pred_dma.get(s.step)
            if p is None or p.elements != s.elements:
                div = s.step
                break
        for lane in _ONCHIP_LANES:
            sel = dict(layer=layer, chip=0, lane=lane)
            rows.append(DriftRow(
                layer=layer, chip=0, lane=lane,
                predicted_dur=plan_tl.span_sum(**sel),
                observed_dur=kern_tl.span_sum(**sel),
                predicted_elements=plan_tl.element_sum(**sel),
                observed_elements=kern_tl.element_sum(**sel),
                first_divergent_step=div if lane == "dma_in" else None))
    return rows


@dataclasses.dataclass
class ObsReport:
    """Everything one report run established."""

    network: str
    topology: str | None
    n_chips: int
    size_mem: int | None
    timelines: list[Timeline]
    rows: list[DriftRow]            # predicted vs simulated
    kernel_rows: list[DriftRow]     # emitable plan vs kernel trace
    trace: dict
    trace_errors: list[str]
    lanes_ok: bool
    overlap_errors: list[str]
    sim_correct: bool
    accounting_exact: bool

    @property
    def max_drift_elements(self) -> int:
        return max((r.drift_elements
                    for r in self.rows + self.kernel_rows), default=0)

    @property
    def max_drift_cycles(self) -> float:
        return max((r.drift_cycles
                    for r in self.rows + self.kernel_rows), default=0.0)

    @property
    def trace_valid(self) -> bool:
        return not self.trace_errors and self.lanes_ok \
            and not self.overlap_errors

    @property
    def ok(self) -> bool:
        return self.trace_valid and self.sim_correct \
            and self.accounting_exact and self.max_drift_elements == 0 \
            and self.max_drift_cycles <= _TOL \
            and all(r.first_divergent_step is None
                    for r in self.rows + self.kernel_rows)

    def render(self) -> str:
        where = f"{self.network}" + (
            f"@{self.topology} ({self.n_chips} chips)" if self.topology
            else " (single chip)")
        lines = [f"obs drift report: {where}  size_mem={self.size_mem}"]
        layers = sorted({r.layer for r in self.rows})
        for layer in layers:
            lrs = [r for r in self.rows if r.layer == layer]
            worst = max(lrs, key=lambda r: (r.drift_elements,
                                            r.drift_cycles))
            pred_cycles = sum(r.predicted_dur for r in lrs)
            sim_cycles = sum(r.observed_dur for r in lrs)
            status = "ok" if all(r.clean for r in lrs) else (
                f"DRIFT chip{worst.chip}/{worst.lane}"
                f" {worst.predicted_elements}->{worst.observed_elements}el"
                + (f" @step {worst.first_divergent_step}"
                   if worst.first_divergent_step is not None else ""))
            lines.append(
                f"  L{layer}: predicted {pred_cycles:g} cy, "
                f"simulated {sim_cycles:g} cy, "
                f"|drift| {max(r.drift_cycles for r in lrs):g} cy / "
                f"{max(r.drift_elements for r in lrs)} el  [{status}]")
        if self.kernel_rows:
            klayers = sorted({r.layer for r in self.kernel_rows})
            bad = [r for r in self.kernel_rows if not r.clean]
            lines.append(
                f"  kernel trace: {len(klayers)} layers vs emitable plan "
                f"— {'ok' if not bad else f'{len(bad)} lane(s) drift'}")
        lines.append(
            f"  trace: {len(self.trace['traceEvents'])} events, "
            f"{'valid' if not self.trace_errors else 'INVALID'}; "
            f"lanes {'complete' if self.lanes_ok else 'MISSING'}; "
            f"sim correct={self.sim_correct} "
            f"accounting_exact={self.accounting_exact}")
        lines.append(
            f"  max drift: {self.max_drift_elements} elements / "
            f"{self.max_drift_cycles:g} cycles -> "
            f"{'RECONCILED' if self.ok else 'FAIL'}")
        return "\n".join(lines)


def _check_lanes(pred: Timeline, n_chips: int) -> bool:
    """Every chip must carry every lane it is supposed to: the three
    on-chip lanes always, ``ici`` too when the plan moved any inter-chip
    traffic at all (a cluster plan with zero ICI everywhere is possible
    and has nothing to show on that lane)."""
    want = set(_ONCHIP_LANES)
    if any(s.lane == "ici" for s in pred.spans):
        want.add("ici")
    return set(pred.chips()) == set(range(n_chips)) and all(
        want <= pred.lanes_of(chip) for chip in range(n_chips))


def default_size_mem(network: str, multichip: bool) -> int | None:
    """The benchmark conventions: multichip runs use the tight budget of
    the chip sweep (half the largest kernel set Λ); single-chip runs use
    the paper's unconstrained Sec-7.1 setting."""
    if not multichip:
        return None
    return max(s.kernel_elements for s in NETWORKS[network]) // 2


def build_report(network: str, *, topology: str | None = None,
                 n_chips: int | None = None,
                 size_mem: int | None = None,
                 nbop_pe: int = 10 ** 9,
                 iters: int = 1500, restarts: int = 2, rng_seed: int = 0,
                 overlap: bool = True,
                 include_kernel: bool = True) -> ObsReport:
    """Plan, simulate, trace and reconcile one network (module note)."""
    specs = NETWORKS[network]
    if topology is not None:
        if n_chips is None:
            topo = Topology.parse(topology)
            n_chips = topo.dims[0] * topo.dims[1] \
                if topo.kind == "torus" else 4
        if size_mem is None:
            size_mem = default_size_mem(network, multichip=True)
        cluster = make_cluster(n_chips, nbop_pe=nbop_pe,
                               size_mem=size_mem, topology=topology)
        plan = plan_multichip_network(
            specs, cluster, name=network, polish_iters=iters,
            polish_restarts=restarts, rng_seed=rng_seed,
            include_single_chip_baseline=False, overlap=overlap,
            balance_rows=overlap)
        sim = simulate_multichip(plan, seed=rng_seed)
        pred = adapters.multichip_predicted_timeline(plan)
        obs_tl = adapters.multichip_simulated_timeline(sim)
    else:
        n_chips = 1
        hw = HardwareModel(nbop_pe=nbop_pe, size_mem=size_mem)
        plan = plan_network(specs, hw, name=network, polish_iters=iters,
                            polish_restarts=restarts, rng_seed=rng_seed)
        sim = simulate_network(plan, seed=rng_seed)
        pred = adapters.network_predicted_timeline(plan)
        obs_tl = adapters.network_simulated_timeline(sim)

    rows = drift_rows(pred, obs_tl)
    timelines = [pred, obs_tl]

    kernel_rows: list[DriftRow] = []
    if include_kernel:
        from repro.kernels.emit import plan_emitable_network
        eplan = plan_emitable_network(
            list(specs), kerncheck.network_budget(specs), name=network)
        kern_tl = adapters.kernel_timeline(eplan)
        plan_tl = adapters.network_predicted_timeline(
            eplan, label="kernel-plan")
        kernel_rows = kernel_drift_rows(plan_tl, kern_tl)
        timelines.append(kern_tl)

    trace = to_chrome_trace(timelines)
    overlap_errors = [v for tl in timelines
                      for v in tl.overlap_violations()]
    return ObsReport(
        network=network, topology=topology, n_chips=n_chips,
        size_mem=size_mem, timelines=timelines, rows=rows,
        kernel_rows=kernel_rows, trace=trace,
        trace_errors=validate_chrome_trace(trace),
        lanes_ok=_check_lanes(pred, n_chips),
        overlap_errors=overlap_errors,
        sim_correct=sim.correct,
        accounting_exact=sim.accounting_exact)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Predicted-vs-simulated-vs-kernel offload timeline "
                    "drift report (Chrome-trace/Perfetto export).")
    ap.add_argument("--network", required=True, choices=sorted(NETWORKS))
    ap.add_argument("--topology", default=None,
                    help="plan on a cluster: 'ring', 'biring' or "
                         "'torusRxC' (omit for single-chip)")
    ap.add_argument("--n-chips", type=int, default=None,
                    help="cluster size (default: the torus grid, or 4)")
    ap.add_argument("--size-mem", type=int, default=None,
                    help="on-chip budget (default: half the largest Λ "
                         "for cluster runs — the chip-sweep convention — "
                         "or unconstrained for single-chip)")
    ap.add_argument("--iters", type=int, default=1500)
    ap.add_argument("--restarts", type=int, default=2)
    ap.add_argument("--rng-seed", type=int, default=0)
    ap.add_argument("--serialized", action="store_true",
                    help="plan with the serialised (overlap=False) "
                         "accounting instead of overlap + balanced bands")
    ap.add_argument("--no-kernel", action="store_true",
                    help="skip the Pallas kernel-trace timeline")
    ap.add_argument("--out", default=None,
                    help="trace output path (default: benchmarks/results/"
                         "obs_trace_<network>[_<topology>].json)")
    ap.add_argument("--json", action="store_true",
                    help="print the drift rows as JSON instead of text")
    args = ap.parse_args(argv)

    report = build_report(
        args.network, topology=args.topology, n_chips=args.n_chips,
        size_mem=args.size_mem, iters=args.iters,
        restarts=args.restarts, rng_seed=args.rng_seed,
        overlap=not args.serialized, include_kernel=not args.no_kernel)

    out = args.out
    if out is None:
        suffix = f"_{args.topology}" if args.topology else ""
        out = f"benchmarks/results/obs_trace_{args.network}{suffix}.json"
    import os
    out_dir = os.path.dirname(out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    write_chrome_trace(report.trace, out)

    if args.json:
        import json
        print(json.dumps({
            "network": report.network, "topology": report.topology,
            "n_chips": report.n_chips, "size_mem": report.size_mem,
            "trace_valid": report.trace_valid,
            "max_drift_elements": report.max_drift_elements,
            "max_drift_cycles": report.max_drift_cycles,
            "rows": [dataclasses.asdict(r) for r in report.rows],
            "kernel_rows": [dataclasses.asdict(r)
                            for r in report.kernel_rows],
        }, indent=1))
    else:
        print(report.render())
    print(f"trace -> {out}  (load in https://ui.perfetto.dev)")
    for err in report.trace_errors[:10] + report.overlap_errors[:10]:
        print(f"  [trace] {err}", file=sys.stderr)
    return 0 if report.ok else 1


if __name__ == "__main__":                      # pragma: no cover
    sys.exit(main())
