"""AdamW with ZeRO-1-style sharded state.

Moments are f32 and inherit the parameters' 2-D (data, model) sharding — so
optimizer state is already fully sharded across the mesh (the ZeRO-1
property falls out of the storage sharding rather than a separate scatter).
Updates are applied in f32 and cast back to the param dtype (bf16 weights,
f32 moments; see DESIGN.md §5 for the master-weight trade-off)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params):
    return {
        "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape,
                                                          jnp.float32),
                          abstract_params),
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape,
                                                         jnp.float32),
                          abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_specs(param_spec_tree, axes=None):
    """Moment sharding = param sharding, plus ZeRO-1 across pods: on the
    multi-pod mesh the f32 moments additionally shard over "pod" on the
    dim that already carries "data" (params stay bf16-replicated per pod;
    the update's delta is gathered once per step — far cheaper than
    holding 2x f32 moments per pod)."""
    from jax.sharding import PartitionSpec as P
    import jax

    def extend(s):
        if axes is None or axes.pod is None:
            return s
        out = []
        for e in s:
            if e == axes.data:
                out.append((axes.pod, axes.data))
            elif isinstance(e, tuple) and axes.data in e \
                    and axes.pod not in e:
                out.append((axes.pod,) + tuple(e))
            else:
                out.append(e)
        return P(*out)

    mv = jax.tree.map(extend, param_spec_tree,
                      is_leaf=lambda x: isinstance(x, P))
    return {"m": mv, "v": mv, "step": P()}


def global_norm(grads) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step with global-norm clipping."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd_one(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), \
            m2, v2

    # NOTE: a scan-over-layers variant of the update was tried to shrink
    # the f32 elementwise temporaries; it broke XLA's donation aliasing of
    # m/v through the scan and *raised* peak memory by ~4 GB/device on
    # deepseek — reverted (EXPERIMENTS.md §Perf iteration 6).
    upd = upd_one

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params2 = jax.tree.unflatten(treedef, [n[0] for n in new])
    m2 = jax.tree.unflatten(treedef, [n[1] for n in new])
    v2 = jax.tree.unflatten(treedef, [n[2] for n in new])
    return params2, {"m": m2, "v": v2, "step": step}, gnorm
