"""Gradient compression for cross-pod reduction (DESIGN.md §5).

The multi-pod mesh reduces gradients over the slow inter-pod links; int8
quantisation with error feedback cuts those bytes 4x (bf16->int8 halves,
f32->int8 quarters) at negligible quality cost when the residual is carried
(1-bit/8-bit SGD literature).  The compressor is a pure pytree transform so
it composes with any optimizer:

    comp = ErrorFeedbackInt8()
    cstate = comp.init(grads_like)
    q, cstate = comp.compress(grads, cstate)     # before cross-pod psum
    grads_hat = comp.decompress(q)               # after

Random-k sparsification is provided for the extreme-bandwidth regime.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Quantized:
    values: Any          # int8 pytree
    scales: Any          # f32 per-tensor scales


class ErrorFeedbackInt8:
    """Per-tensor symmetric int8 quantisation with residual carry."""

    def init(self, grads_like):
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)

    def compress(self, grads, residual) -> tuple[Quantized, Any]:
        def one(g, r):
            x = g.astype(jnp.float32) + r
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            new_r = x - q.astype(jnp.float32) * scale
            return q, scale, new_r

        flat, treedef = jax.tree.flatten(grads)
        rflat = jax.tree.leaves(residual)
        qs, scales, rs = zip(*[one(g, r) for g, r in zip(flat, rflat)])
        return (Quantized(values=jax.tree.unflatten(treedef, qs),
                          scales=jax.tree.unflatten(treedef, scales)),
                jax.tree.unflatten(treedef, rs))

    def decompress(self, q: Quantized):
        return jax.tree.map(
            lambda v, s: v.astype(jnp.float32) * s, q.values, q.scales)

    @staticmethod
    def bytes_ratio(dtype=jnp.float32) -> float:
        return jnp.dtype(dtype).itemsize / 1.0      # int8 = 1 byte


class RandomK:
    """Memory-SGD style sparsifier (Stich et al.): transmit a random
    k-fraction of entries *unscaled* and carry the untransmitted mass in
    the residual — biased per step, mass-conserving over time."""

    def __init__(self, fraction: float = 0.1):
        self.fraction = fraction

    def init(self, grads_like, seed: int = 0):
        return {
            "residual": jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads_like),
            "key": jax.random.key(seed),
        }

    def compress(self, grads, state):
        key, sub = jax.random.split(state["key"])
        flat, treedef = jax.tree.flatten(grads)
        rflat = jax.tree.leaves(state["residual"])
        keys = jax.random.split(sub, len(flat))
        outs, rs = [], []
        for g, r, k in zip(flat, rflat, keys):
            x = g.astype(jnp.float32) + r
            mask = jax.random.bernoulli(k, self.fraction, g.shape)
            outs.append(jnp.where(mask, x, 0.0))
            rs.append(jnp.where(mask, 0.0, x))
        return (jax.tree.unflatten(treedef, outs),
                {"residual": jax.tree.unflatten(treedef, rs), "key": key})

    @staticmethod
    def decompress(q):
        return q
