"""Persistent, content-hashed plan cache (planner-as-a-service substrate).

``repro.plancache`` makes the solver's expensive per-layer searches
durable: every ``solver.solve_cached`` / ``solver.best_s2_cached`` result
is written to an on-disk store keyed by a content hash of the frozen
``(ConvSpec, p, HardwareModel, search-knobs)`` tuple, canonicalized so
default-equivalent calls collide.  A later process — a re-run sweep, a
degraded-mode re-plan, the ``repro.launch.plan_server`` CLI — answers the
same query from disk in milliseconds and bit-identically.

The package splits into:

``store``
    The on-disk store itself: one JSON file per entry, atomic writes
    (tmp file + ``os.replace``), a versioned schema, and typed corruption
    recovery — a bad entry raises :class:`CacheCorruptionError`
    internally, is evicted, and the query transparently re-solves; the
    store never trusts or crashes on a damaged file.  Activation is via
    the ``REPRO_PLAN_CACHE`` env var (a directory) or
    :func:`store.configure`.

``codec``
    Canonical-key construction (exact digest + the *family* digest that
    groups entries differing only in budget/``p`` — the nearest-scenario
    warm-start neighbourhood) and loss-free JSON serialization of
    ``SolveResult`` / ``S2Result`` strategies, plus
    :func:`codec.plan_fingerprint` for bit-identical plan comparisons.

``repro.core`` imports this package lazily (inside function bodies) and
only when the store is configured, so the default in-memory-LRU-only
behaviour is untouched.
"""
from repro.plancache.store import (  # noqa: F401
    ENV_VAR, SCHEMA_VERSION, CacheCorruptionError, CacheSchemaError,
    PlanCacheError, PlanStore, active_store, configure, reset)
