"""Canonical keys and loss-free JSON codecs for the persistent plan store.

Keys are **canonicalized**: every search knob is materialized with its
default applied, so ``solve_cached(spec, p, hw)`` and
``solve_cached(spec, p, hw, nb_data_reload=2, use_milp=True, ...)`` hash
to the same entry (``functools.lru_cache`` treats them as distinct; the
persistent layer must not).  Each key comes with a *family* digest — the
key minus the scenario axes sweeps vary (``p`` and ``hw.size_mem``) —
which names the warm-start neighbourhood: entries for the same layer and
knobs at neighbouring budgets/group sizes.

Serialization is exact: strategies reduce to their defining integer
tuples (``GroupedStrategy`` groups; ``S2Strategy`` kernel groups +
schedule) plus the 8-int ``ConvSpec``, and reconstruction re-runs the
frozen dataclasses' own ``__post_init__`` validation — a corrupted
payload fails loudly into :class:`~repro.plancache.store.CacheCorruptionError`
instead of producing an illegal strategy.  Floats round-trip bit-exactly
through JSON (shortest-repr), so a decoded ``SolveResult`` compares equal
to the solved one.
"""
from __future__ import annotations

from typing import Any

from repro.core import solver as solver_mod
from repro.core import strategies_s2 as s2_mod
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import GroupedStrategy
from repro.plancache.store import CacheCorruptionError, canonical_digest

#: ``solver.solve_cached`` knob defaults, applied before hashing so
#: default-equivalent calls collide.  Must match the solver signature.
SOLVE_KNOB_DEFAULTS: dict[str, Any] = {
    "nb_data_reload": 2,
    "time_limit": 30.0,
    "polish_iters": 30_000,
    "use_milp": True,
    "rng_seed": 0,
    "polish_restarts": 1,
}


# --------------------------------------------------------------------- #
# Canonical keys
# --------------------------------------------------------------------- #

def spec_key(spec: ConvSpec) -> list[int]:
    return [spec.c_in, spec.h_in, spec.w_in, spec.n_kernels,
            spec.h_k, spec.w_k, spec.s_h, spec.s_w]


def hw_key(hw: HardwareModel) -> dict[str, Any]:
    return {"nbop_pe": hw.nbop_pe, "size_mem": hw.size_mem,
            "t_l": hw.t_l, "t_w": hw.t_w, "t_acc": hw.t_acc}


def solve_key(spec: ConvSpec, p: int, hw: HardwareModel,
              **knobs: Any) -> tuple[dict, str]:
    """(canonical key, family digest) for a ``solve_cached`` query.  The
    family drops ``p`` and ``hw.size_mem`` — the axes budget/chip sweeps
    vary — so same-family entries are warm-start neighbours."""
    full = dict(SOLVE_KNOB_DEFAULTS)
    for name, value in knobs.items():
        if name not in SOLVE_KNOB_DEFAULTS:
            raise TypeError(f"unknown solve knob {name!r}")
        full[name] = value
    hwk = hw_key(hw)
    key = {"spec": spec_key(spec), "p": int(p), "hw": hwk, "knobs": full}
    family_hw = {k: v for k, v in hwk.items() if k != "size_mem"}
    family = {"spec": key["spec"], "hw": family_hw, "knobs": full}
    return key, canonical_digest(family)


def s2_key(spec: ConvSpec, hw: HardwareModel) -> tuple[dict, str]:
    """(canonical key, family digest) for a ``best_s2_cached`` query."""
    hwk = hw_key(hw)
    key = {"spec": spec_key(spec), "hw": hwk}
    family_hw = {k: v for k, v in hwk.items() if k != "size_mem"}
    family = {"spec": key["spec"], "hw": family_hw}
    return key, canonical_digest(family)


# --------------------------------------------------------------------- #
# Strategy / result codecs
# --------------------------------------------------------------------- #

def strategy_to_json(s: "GroupedStrategy | s2_mod.S2Strategy") -> dict:
    if isinstance(s, GroupedStrategy):
        return {"kind": "s1", "name": s.name, "spec": spec_key(s.spec),
                "groups": [list(g) for g in s.groups]}
    if isinstance(s, s2_mod.S2Strategy):
        return {"kind": "s2", "name": s.name, "spec": spec_key(s.spec),
                "kernel_groups": [list(g) for g in s.kernel_groups],
                "schedule": [[list(g), kg] for g, kg in s.schedule]}
    raise TypeError(f"unserializable strategy type {type(s).__name__}")


def strategy_from_json(d: dict) -> "GroupedStrategy | s2_mod.S2Strategy":
    try:
        kind = d["kind"]
        spec = ConvSpec(*(int(v) for v in d["spec"]))
        if kind == "s1":
            return GroupedStrategy(
                str(d["name"]), spec,
                tuple(tuple(int(i) for i in g) for g in d["groups"]))
        if kind == "s2":
            return s2_mod.S2Strategy(
                str(d["name"]), spec,
                tuple(tuple(int(i) for i in g)
                      for g in d["kernel_groups"]),
                tuple((tuple(int(i) for i in g), int(kg))
                      for g, kg in d["schedule"]))
    except CacheCorruptionError:
        raise
    except (KeyError, TypeError, ValueError, IndexError) as e:
        raise CacheCorruptionError(f"bad strategy payload: {e}") from e
    raise CacheCorruptionError(f"unknown strategy kind {kind!r}")


def _opt_float(v: Any) -> float | None:
    return None if v is None else float(v)


def solve_result_to_json(res: "solver_mod.SolveResult") -> dict:
    return {
        "strategy": strategy_to_json(res.strategy),
        "objective": res.objective,
        "lower_bound": res.lower_bound,
        "seed_objective": res.seed_objective,
        "milp_status": res.milp_status,
        "milp_objective": res.milp_objective,
        "polish_objective": res.polish_objective,
        "reload_ok": res.reload_ok,
        "mode": res.mode,
    }


def solve_result_from_json(d: dict) -> "solver_mod.SolveResult":
    try:
        return solver_mod.SolveResult(
            strategy=strategy_from_json(d["strategy"]),
            objective=float(d["objective"]),
            lower_bound=float(d["lower_bound"]),
            seed_objective=float(d["seed_objective"]),
            milp_status=str(d["milp_status"]),
            milp_objective=_opt_float(d["milp_objective"]),
            polish_objective=float(d["polish_objective"]),
            reload_ok=bool(d["reload_ok"]),
            mode=str(d["mode"]))
    except CacheCorruptionError:
        raise
    except (KeyError, TypeError, ValueError) as e:
        raise CacheCorruptionError(f"bad SolveResult payload: {e}") from e


def s2_result_to_json(res: "s2_mod.S2Result") -> dict:
    return {
        "strategy": strategy_to_json(res.strategy),
        "objective": res.objective,
        "peak_memory": res.peak_memory,
        "feasible_s1": res.feasible_s1,
        "seed_strategy": (None if res.seed_strategy is None
                          else strategy_to_json(res.seed_strategy)),
        "seed_objective": res.seed_objective,
        "milp_status": res.milp_status,
        "milp_objective": res.milp_objective,
    }


def s2_result_from_json(d: dict) -> "s2_mod.S2Result":
    try:
        strategy = strategy_from_json(d["strategy"])
        if not isinstance(strategy, s2_mod.S2Strategy):
            raise CacheCorruptionError("S2Result holds a non-S2 strategy")
        seed = d["seed_strategy"]
        seed_strategy = None if seed is None else strategy_from_json(seed)
        if seed_strategy is not None and \
                not isinstance(seed_strategy, s2_mod.S2Strategy):
            raise CacheCorruptionError("S2Result seed is a non-S2 strategy")
        return s2_mod.S2Result(
            strategy=strategy,
            objective=float(d["objective"]),
            peak_memory=int(d["peak_memory"]),
            feasible_s1=bool(d["feasible_s1"]),
            seed_strategy=seed_strategy,
            seed_objective=_opt_float(d["seed_objective"]),
            milp_status=str(d["milp_status"]),
            milp_objective=_opt_float(d["milp_objective"]))
    except CacheCorruptionError:
        raise
    except (KeyError, TypeError, ValueError) as e:
        raise CacheCorruptionError(f"bad S2Result payload: {e}") from e


# --------------------------------------------------------------------- #
# Plan fingerprints (bit-identical cold/warm comparison)
# --------------------------------------------------------------------- #

def plan_fingerprint(plan: Any) -> str:
    """Stable content hash of a plan's *decisions* — per-layer strategies,
    sharding modes, reuse choices and durations — independent of
    planning wall-clock and cache counters.  Works for ``NetworkPlan``
    and ``MultiChipPlan``; two plans with equal fingerprints schedule the
    same work identically."""
    rows: list[dict] = []
    for lp in plan.layers:
        if hasattr(lp, "shards"):              # MultiChipLayerPlan
            rows.append({
                "mode": lp.mode,
                "ici_elements": lp.ici_elements,
                "compute_duration": lp.compute_duration,
                "overlap": lp.overlap,
                "shards": [
                    {"chip": sh.chip, "p": sh.p,
                     "spec": spec_key(sh.spec),
                     "out_rows": (None if sh.out_rows is None
                                  else list(sh.out_rows)),
                     "kernel_range": (None if sh.kernel_range is None
                                      else list(sh.kernel_range)),
                     "gross_duration": sh.gross_duration,
                     "strategy": strategy_to_json(sh.result.strategy)}
                    for sh in lp.shards],
            })
        else:                                   # LayerPlan
            rows.append({
                "p": lp.p,
                "spec": spec_key(lp.spec),
                "strategy": strategy_to_json(lp.result.strategy),
                "reuse_input": lp.reuse_input,
                "reuse_output": lp.reuse_output,
                "window_rows": lp.window_rows,
                "duration": lp.duration,
            })
    return canonical_digest(
        {"layers": rows, "total_duration": plan.total_duration})
