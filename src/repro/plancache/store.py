"""On-disk plan store: content-hashed entries, atomic writes, typed
corruption recovery.

Layout — one JSON file per entry, flat in the store root::

    <root>/<kind>-<family digest[:16]>-<key digest[:24]>.json

``kind`` is the cache namespace (``solve`` / ``s2``), the *key* digest
hashes the full canonical key (spec + p + hardware + every search knob,
defaults applied), and the *family* digest hashes the key minus the
scenario axes that sweeps vary (``p`` and ``hw.size_mem``) — so the
same-family glob enumerates exactly the nearest-scenario warm-start
candidates for a new budget point.

Durability rules:

* **Atomic writes** — payloads land in a temp file in the store root and
  are ``os.replace``d into place, so concurrent writers race benignly
  (readers only ever see a complete file; the last complete write wins).
* **Versioned schema** — every payload records ``SCHEMA_VERSION``; an
  entry from another version raises :class:`CacheSchemaError` and is
  evicted (stale), never decoded.
* **Typed corruption recovery** — unparseable JSON, missing fields, or a
  payload the decoder rejects raise :class:`CacheCorruptionError`.
  :meth:`PlanStore.get` converts either error into an eviction plus a
  miss, so the caller transparently re-solves; a damaged cache can cost
  time, never correctness.

Counters (hits / misses / writes / evictions / corruptions / stale /
warm adoption) are kept per store instance and mirrored into the
``repro.obs.metrics`` registry under ``plancache/``.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Callable

#: Bump when the payload layout or the codec's serialization changes:
#: every existing entry becomes stale and is evicted on first touch.
SCHEMA_VERSION = 1

#: Env var holding the store root directory; unset/empty disables the
#: persistent layer entirely (the default — in-memory LRUs only).
ENV_VAR = "REPRO_PLAN_CACHE"


class PlanCacheError(Exception):
    """Base class for persistent-plan-cache errors."""


class CacheCorruptionError(PlanCacheError):
    """A cache entry that cannot be trusted: unparseable JSON, a missing
    field, or a payload the decoder rejects.  Always handled by eviction
    + re-solve; never propagated out of :meth:`PlanStore.get`."""

    def __init__(self, message: str, path: str | None = None) -> None:
        super().__init__(message)
        self.path = path


class CacheSchemaError(CacheCorruptionError):
    """An entry written under a different ``SCHEMA_VERSION`` (stale)."""


def canonical_digest(obj: Any) -> str:
    """sha256 of the canonical JSON encoding (sorted keys, no spaces) —
    the content hash used for entry file names."""
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


class PlanStore:
    """One store root; see the module note for layout and durability."""

    def __init__(self, root: "str | os.PathLike[str]") -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.writes = 0
        self.evictions = 0          # corrupt + stale, total files removed
        self.corruptions = 0
        self.stale = 0
        self.warm_considered = 0    # neighbour candidates repriced
        self.warm_adopted = 0       # ... that beat the cold search

    # -- paths --------------------------------------------------------- #

    def entry_path(self, kind: str, family_digest: str,
                   key_digest: str) -> Path:
        return self.root / f"{kind}-{family_digest[:16]}-{key_digest[:24]}.json"

    # -- low level ----------------------------------------------------- #

    def load_entry(self, path: "str | Path") -> dict:
        """Parse and structurally validate one entry file.

        Raises :class:`CacheSchemaError` for entries from another schema
        version and :class:`CacheCorruptionError` for anything else that
        cannot be trusted — the typed half of corruption recovery; the
        transparent half (evict + re-solve) lives in :meth:`get`."""
        try:
            with open(path, "r", encoding="utf-8") as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            raise CacheCorruptionError(
                f"unreadable cache entry {path}: {e}", path=str(path)) from e
        if not isinstance(payload, dict):
            raise CacheCorruptionError(
                f"cache entry {path} is not an object", path=str(path))
        schema = payload.get("schema")
        if schema != SCHEMA_VERSION:
            raise CacheSchemaError(
                f"cache entry {path} has schema {schema!r}, "
                f"expected {SCHEMA_VERSION}", path=str(path))
        if "key" not in payload or "result" not in payload:
            raise CacheCorruptionError(
                f"cache entry {path} is missing key/result fields",
                path=str(path))
        return payload

    def _evict(self, path: Path, *, stale: bool = False) -> None:
        try:
            os.unlink(path)
        except OSError:
            pass
        self.evictions += 1
        _metric("evictions")
        if stale:
            self.stale += 1
            _metric("stale")
        else:
            self.corruptions += 1
            _metric("corruptions")

    # -- public API ---------------------------------------------------- #

    def get(self, kind: str, key: dict, family_digest: str,
            decode: Callable[[dict], Any]) -> Any | None:
        """Exact-key lookup.  ``decode`` turns the stored ``result`` dict
        into the caller's object; any :class:`CacheCorruptionError` it
        (or the file layer) raises evicts the entry and returns None —
        the caller re-solves, never crashes on a bad entry."""
        path = self.entry_path(kind, family_digest, canonical_digest(key))
        if not path.exists():
            self.misses += 1
            _metric("misses")
            return None
        try:
            payload = self.load_entry(path)
            if payload["key"] != key:          # digest-prefix collision
                raise CacheCorruptionError(
                    f"cache entry {path} holds a different key",
                    path=str(path))
            value = decode(payload["result"])
        except CacheSchemaError:
            self._evict(path, stale=True)
            self.misses += 1
            _metric("misses")
            return None
        except CacheCorruptionError:
            self._evict(path)
            self.misses += 1
            _metric("misses")
            return None
        self.hits += 1
        _metric("hits")
        return value

    def put(self, kind: str, key: dict, family_digest: str,
            result: dict) -> None:
        """Atomic write (tmp file + ``os.replace``).  A failed write is
        dropped silently — the persistent layer is an accelerator, never
        a correctness dependency."""
        path = self.entry_path(kind, family_digest, canonical_digest(key))
        payload = {"schema": SCHEMA_VERSION, "kind": kind,
                   "key": key, "result": result}
        data = json.dumps(payload, sort_keys=True)
        try:
            fd, tmp = tempfile.mkstemp(
                dir=self.root, prefix=f".{kind}-", suffix=".tmp")
        except OSError:
            return
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                f.write(data)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return
        self.writes += 1
        _metric("writes")

    def neighbors(self, kind: str, family_digest: str, *,
                  exclude_key: dict | None = None,
                  limit: int = 32) -> list[tuple[dict, dict]]:
        """Same-family entries (same spec + knobs; budget/``p`` differ):
        the nearest-scenario warm-start candidates.  Corrupt/stale
        siblings are evicted on the way.  Returns ``(key, result)`` raw
        dicts; the caller decodes, sorts by scenario distance and
        reprices."""
        skip = None
        if exclude_key is not None:
            skip = self.entry_path(
                kind, family_digest, canonical_digest(exclude_key)).name
        out: list[tuple[dict, dict]] = []
        for path in sorted(self.root.glob(
                f"{kind}-{family_digest[:16]}-*.json")):
            if path.name == skip:
                continue
            try:
                payload = self.load_entry(path)
            except CacheSchemaError:
                self._evict(path, stale=True)
                continue
            except CacheCorruptionError:
                self._evict(path)
                continue
            out.append((payload["key"], payload["result"]))
            if len(out) >= limit:
                break
        return out

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.json"))

    def stats(self) -> dict:
        return {
            "root": str(self.root),
            "entries": len(self),
            "hits": self.hits,
            "misses": self.misses,
            "writes": self.writes,
            "evictions": self.evictions,
            "corruptions": self.corruptions,
            "stale": self.stale,
            "warm_considered": self.warm_considered,
            "warm_adopted": self.warm_adopted,
        }


def _metric(name: str, amount: "int | float" = 1) -> None:
    # lazy import: keep the store importable without pulling repro.obs in
    # contexts that only want the file layer
    from repro.obs.metrics import REGISTRY
    REGISTRY.incr(f"plancache/{name}", amount)


_active: PlanStore | None = None
_active_root: str | None = None


def active_store() -> PlanStore | None:
    """The process-wide store, governed by ``REPRO_PLAN_CACHE`` (a
    directory; unset/empty = disabled).  The env var is re-read on every
    call so tests and the plan server can flip it; the ``PlanStore``
    object (and its counters) is cached per root string.  An unusable
    root (e.g. mkdir denied) disables the layer instead of failing the
    solve."""
    global _active, _active_root
    root = os.environ.get(ENV_VAR) or None
    if root != _active_root:
        try:
            _active = PlanStore(root) if root else None
        except OSError:
            _active = None
        _active_root = root
    return _active


def configure(root: "str | os.PathLike[str] | None") -> PlanStore | None:
    """Programmatic enable/disable: sets/clears ``REPRO_PLAN_CACHE`` so
    ``active_store()`` (and any child tooling reading the env) agree."""
    if root is None:
        os.environ.pop(ENV_VAR, None)
    else:
        os.environ[ENV_VAR] = os.fspath(root)
    return active_store()


def reset() -> None:
    """Drop the cached ``PlanStore`` object (counters included) so the
    next ``active_store()`` call rebuilds it from the env — the
    in-process stand-in for a process restart in the persistence
    tests."""
    global _active, _active_root
    _active = None
    _active_root = None
