"""Fault injection and layer-granular recovery (``repro.resil``).

The planner/simulator stack of PRs 1-8 assumes a perfect machine.  This
package extends the Def-3 predictability discipline to the failure
cases a real fleet hits: a seeded deterministic :class:`FaultSchedule`
(chip death, ICI link degradation, VMEM budget shrink, transient DMA
failures) is injected into the functional cluster simulation, the
surviving topology is re-planned mid-network (warm-started from the
shared ``solve_cached`` LRU, verified by ``repro.analysis.verifier``),
and recovery is layer-granular: committed write-backs are the recovery
points, only in-flight work is recomputed, and the stitched outputs are
proved exactly-once and equal to the fault-free reference convolution.

Entry points: :func:`repro.resil.engine.run_faulted` and the CLI
``python -m repro.resil.faultsim``.
"""
from repro.resil.faults import (ChipDeath, ClusterExhaustedError,
                                DegradedInfeasibleError, DmaTransient,
                                FaultError, FaultEvent, FaultSchedule,
                                LinkDegrade, RecoveryCorruptionError,
                                VmemShrink)

__all__ = [
    "ChipDeath",
    "ClusterExhaustedError",
    "DegradedInfeasibleError",
    "DmaTransient",
    "FaultError",
    "FaultEvent",
    "FaultSchedule",
    "LinkDegrade",
    "RecoveryCorruptionError",
    "VmemShrink",
]
