"""The control plane of the simulated recovery loop.

``repro.runtime.fault_tolerance`` was seeded as a host-side scaffold
(heartbeats, straggler EWMA, elastic rescale) that nothing called.  The
fault-injection engine drives it here, on *simulated time*: the
controller's clock is the engine's Def-3 cycle cursor, so heartbeat
timeouts are priced in the same abstract cycles as everything else and
detection is deterministic — no wall-clock, no sleeps.

Per stage the engine reports a heartbeat (and the measured shard
duration) for every chip that finished; a chip that died mid-stage
reports nothing, and after ``detection_cycles`` of silence
:meth:`RecoveryController.detect_dead` names it.  The surviving mesh is
recorded as an :class:`ElasticPlan` — built directly over the survivors
(model axis 1, one data shard per chip), because the conv planner
re-shards over *every* survivor; ``plan_rescale``'s power-of-two policy
is the training-fleet variant and stays untouched.
"""
from __future__ import annotations

from repro.resil.faults import FaultError
from repro.runtime.fault_tolerance import (ElasticPlan, HeartbeatTracker,
                                           StragglerDetector)


class ControlPlaneError(FaultError):
    """The control plane and the fault injection disagree — e.g. the
    heartbeat tracker missed a death the schedule injected, or detected
    one that never happened.  Always an engine bug."""


class RecoveryController:
    """Heartbeats + straggler EWMA over the engine's cycle clock."""

    def __init__(self, chips: "list[int]", *,
                 detection_cycles: float = 256.0):
        self._now = 0.0
        self.detection_cycles = detection_cycles
        self.hb = HeartbeatTracker(chips, timeout_s=detection_cycles,
                                   clock=lambda: self._now)
        self.straggle = StragglerDetector(chips)
        self.dead: list[int] = []

    @property
    def now(self) -> float:
        return self._now

    def advance(self, cycles: float) -> None:
        if cycles < 0:
            raise ControlPlaneError(f"clock went backwards ({cycles})")
        self._now += cycles

    def stage_done(self, chips: "list[int]", stage: int,
                   durations: "dict[int, float]") -> None:
        """Chips that finished ``stage`` beat and report their measured
        shard duration (feeding the straggler EWMA)."""
        for chip in chips:
            self.hb.beat(chip, stage)
            if chip in durations:
                self.straggle.record(chip, durations[chip])

    def detect_dead(self) -> "list[int]":
        """Newly dead chips (silent longer than the timeout), removed
        from tracking so they are reported exactly once."""
        newly = [c for c in self.hb.dead_hosts() if c not in self.dead]
        for c in newly:
            self.dead.append(c)
            self.hb.last_seen.pop(c, None)
            self.hb.last_step.pop(c, None)
            # a dead chip must not keep tripping the straggler EWMA
            self.straggle.ewma.pop(c, None)
            self.straggle.count.pop(c, None)
        return newly

    def expect_death(self, chip: int) -> None:
        """Cross-check: the schedule killed ``chip`` — the heartbeat
        tracker must name exactly it once the timeout has elapsed."""
        newly = self.detect_dead()
        if newly != [chip]:
            raise ControlPlaneError(
                f"heartbeat tracker detected {newly}, schedule killed "
                f"chip {chip}")

    def elastic_plan(self, survivors: "list[int]") -> ElasticPlan:
        """The surviving mesh record: every survivor carries one shard
        (the conv planner re-shards over all of them)."""
        hosts = tuple(sorted(survivors))
        return ElasticPlan(hosts=hosts, data_shards=len(hosts),
                           model_shards=1,
                           shard_of_host={h: i for i, h in
                                          enumerate(hosts)})

    def stragglers(self) -> "list[int]":
        return self.straggle.stragglers()
