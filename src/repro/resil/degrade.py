"""Surviving-topology selection and cluster repricing.

On a chip death the fabric the planner priced no longer exists: a
``torus2x2`` with a dead corner is not a torus.  The degradation rules
pick the best *feasible* wiring for the survivors, conservatively — the
degraded cluster must never be priced better-connected than the physical
links that actually remain:

* a torus keeps a (smaller) torus only when the survivor count tiles a
  2-D grid with both axes >= 2 (``configs.clusters.torus_dims``);
  otherwise it falls back to a ring over the surviving chips, keeping
  the link direction (a bidirectional torus degrades to a bidirectional
  ring — its links were bidirectional to begin with);
* a ring stays a ring (one fewer chip; the fleet reroutes around the
  dead hop), keeping its direction;
* one survivor is a valid 1-ring (every collective prices to zero).

Link degradation and VMEM shrink reprice without rewiring:
``ClusterModel.degraded`` scales ``t_ici`` / ``size_mem`` and
revalidates the result.
"""
from __future__ import annotations

from repro.configs.clusters import torus_dims
from repro.core.cost_model import ClusterModel, Topology
from repro.resil.faults import ClusterExhaustedError


def surviving_topology(topo: Topology, n_survivors: int) -> Topology:
    """The best feasible wiring for ``n_survivors`` chips of a cluster
    that was wired as ``topo`` (see the module note for the rules)."""
    if n_survivors < 1:
        raise ClusterExhaustedError("no surviving chips to wire")
    bidir = topo.bidirectional
    if topo.kind == "torus" and n_survivors >= 4:
        dims = torus_dims(n_survivors)
        if dims is not None:
            return Topology("torus", dims, bidirectional=bidir)
    return Topology("ring", bidirectional=bidir)


def surviving_cluster(cluster: ClusterModel, n_dead: int = 1,
                      ) -> ClusterModel:
    """The cluster after ``n_dead`` chips died: fewer chips on the best
    feasible surviving wiring, same chips and link speed otherwise."""
    n_surv = cluster.n_chips - n_dead
    if n_surv < 1:
        raise ClusterExhaustedError(
            f"{n_dead} dead of {cluster.n_chips} chips — nothing left "
            f"to re-plan on")
    return cluster.degraded(
        n_chips=n_surv,
        topology=surviving_topology(cluster.topo, n_surv))


def repriced_cluster(cluster: ClusterModel, ici_factor: float,
                     ) -> ClusterModel:
    """Every ICI link ``ici_factor``x slower, wiring unchanged."""
    return cluster.degraded(t_ici_factor=ici_factor)


def shrunk_cluster(cluster: ClusterModel, mem_factor: float,
                   ) -> ClusterModel:
    """Per-chip budget shrunk to ``floor(size_mem * mem_factor)``."""
    return cluster.degraded(size_mem_factor=mem_factor)
