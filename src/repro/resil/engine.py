"""The fault-injection and layer-granular recovery engine.

:func:`run_faulted` executes a network on a cluster under a seeded
:class:`~repro.resil.faults.FaultSchedule`, stage (= layer) by stage:

1. **Fault-free plan** — the network is planned exactly as the benchmark
   plans it; its total duration is the baseline the degraded run is
   compared against.
2. **Boundary faults** (``LinkDegrade`` / ``VmemShrink``) are detected
   *before* their stage runs: the remaining layers are re-planned
   (``core.multichip.replan_suffix``, warm-started via the shared
   ``solve_cached`` LRU) on the repriced cluster.  Nothing is
   recomputed.
3. **Chip death** strikes *during* its stage: the whole attempt is
   wasted (its partial writes never reach the durable store), the
   control plane (heartbeats on the simulated cycle clock —
   ``resil.controller``) detects the silent chip after
   ``detection_cycles``, the surviving topology is chosen
   (``resil.degrade``), the tail is re-planned, the last committed
   activation is restaged to the survivors, and the stage is retried.
4. **DMA transients** re-issue a step's loads with exponential backoff
   (injected into ``sim.system.System.run`` for S1 shards; priced
   analytically for S2 shards — reads are idempotent either way).

**Recovery points.**  A committed layer output is durable: write-backs
go to a store in a separate fault domain (host DRAM — the standard
layer-checkpoint assumption), so a chip death never loses committed
layers and only the in-flight stage is recomputed.  The price of that
assumption is explicit: *every* re-plan pays a *restage* of the current
layer's input (the last committed activation) from the durable store
into the chips' DRAM at ``t_l`` per element — a suffix plan assumes the
engine's canonical replicated input layout (zero inbound ICI for its
first layer), and the restage is what makes that layout true; without
it a boundary re-plan could beat the fault-free baseline by silently
pocketing the inbound transfer it never paid.  That comes on top of
the deterministic re-plan latency
(``replan_cycles_per_layer x remaining layers`` — wall-clock planning
seconds are machine-dependent and are reported separately, never
entering the ledger or the fingerprint).

**Exactly-once outputs.**  Every committed element is counted in an
integer write-count array (must be exactly 1 everywhere — a wasted
attempt contributes 0, a recovery exactly 1), the stitched output of
every committed layer must equal the fault-free reference convolution
under the simulator's stitching discipline (``allclose`` at the
``sim.multichip`` tolerances — S1 einsum accumulation order differs
from the reference at float32 ULP level, so bitwise equality against
the *analytic* reference is not the invariant even fault-free), and the
whole faulted run is reproducible bit-for-bit: the report's
``fingerprint`` hashes the committed bytes and the ledger, and two runs
of the same schedule must agree (checked by ``faultsim`` and the
tests).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Sequence

import numpy as np

from repro.core.conv_spec import ConvSpec
from repro.core import solver as solver_mod
from repro.core.cost_model import ClusterModel
from repro.core.multichip import (MultiChipLayerPlan, MultiChipPlan,
                                  plan_multichip_network, replan_suffix)
from repro.obs.events import decompose_step
from repro.resil.controller import RecoveryController
from repro.resil.degrade import (repriced_cluster, shrunk_cluster,
                                 surviving_cluster)
from repro.resil.faults import (ChipDeath, ClusterExhaustedError,
                                DegradedInfeasibleError, DmaTransient,
                                FaultSchedule, LinkDegrade, VmemShrink)
from repro.sim.functional import reference_conv
from repro.sim.layer import ConvLayer
from repro.sim.multichip import LayerReport, run_shard

_RTOL = 1e-4        # the sim.multichip stitching tolerances
_ATOL = 1e-4
_ACC_TOL = 1e-6     # per-shard duration reconciliation


@dataclasses.dataclass
class StageAttempt:
    """One execution attempt of one global layer."""

    layer: int                        # global layer index
    t0: float                         # cycle the attempt started
    duration: float                   # modeled stage duration (lp.duration)
    phys_chips: tuple[int, ...]       # slot -> physical chip id
    wasted: bool = False              # chip death discarded this attempt
    dead_chip: int | None = None      # physical id of the chip that died
    detection: float = 0.0            # heartbeat latency paid (wasted only)
    retry_duration: float = 0.0       # DMA transients, summed over shards
    retry_elements: int = 0
    shard_durations: dict[int, float] = dataclasses.field(
        default_factory=dict)         # physical chip -> measured duration
    reports: list[LayerReport] = dataclasses.field(default_factory=list)
    lp: MultiChipLayerPlan | None = None   # the plan slice it executed

    @property
    def total(self) -> float:
        return self.duration + self.detection + self.retry_duration


@dataclasses.dataclass
class RecoveryAction:
    """One re-plan the engine performed (boundary fault or chip death)."""

    kind: str                         # 'chip_death'|'link_degrade'|...
    layer: int                        # first layer of the re-planned tail
    t0: float
    replan_cycles: float
    restage_cycles: float             # chip death only: recovery-point
    restage_elements: int             # activation restaged from the store
    new_topology: str
    n_chips: int
    elastic: "object | None" = None   # ElasticPlan (chip death only)
    planning_seconds: float = 0.0     # wall-clock, NOT in the ledger
    verified: bool = False
    solver_calls: int = 0             # this re-plan's own window only
    cache_hits: int = 0               # (LRU + persistent-store warmth)

    @property
    def total(self) -> float:
        return self.replan_cycles + self.restage_cycles


@dataclasses.dataclass
class FaultSimReport:
    """Everything one faulted run established."""

    name: str
    schedule: FaultSchedule
    baseline_duration: float          # fault-free plan total
    faulted_duration: float           # degraded ledger incl. recovery
    attempts: list[StageAttempt]
    recoveries: list[RecoveryAction]
    skipped_events: list[str]         # events whose slot did not exist
    committed: list[np.ndarray]       # per-layer stitched outputs
    write_counts_ok: bool             # every element committed exactly once
    layer_allclose: list[bool]        # stitched vs reference conv
    accounting_ok: bool               # measured == gross+pad_saved+retry
    stragglers_flagged: int
    findings: list[str]
    plans: list[MultiChipPlan]        # fault-free plan + every re-plan

    @property
    def recovery_exact(self) -> bool:
        """Exactly-once write semantics + stitched outputs equal to the
        fault-free reference conv (module note)."""
        return self.write_counts_ok and all(self.layer_allclose)

    @property
    def degraded_slowdown(self) -> float:
        if self.baseline_duration <= 0:
            return 1.0
        return self.faulted_duration / self.baseline_duration

    @property
    def no_free_lunch(self) -> bool:
        """Degraded duration never beats the fault-free baseline.  A
        pricing property, not a correctness invariant: reported, and
        asserted by the tests on the compute-dominated networks."""
        return self.faulted_duration >= self.baseline_duration - 1e-6

    @property
    def wasted_cycles(self) -> float:
        return sum(a.total for a in self.attempts if a.wasted)

    @property
    def recovery_cycles(self) -> float:
        return sum(r.total for r in self.recoveries)

    @property
    def retry_cycles(self) -> float:
        return sum(a.retry_duration for a in self.attempts)

    @property
    def recomputed_elements(self) -> int:
        """Output elements whose computation was discarded and redone."""
        out = 0
        for a in self.attempts:
            if a.wasted and a.lp is not None:
                spec = a.lp.spec
                out += spec.num_patches * spec.c_out
        return out

    @property
    def ok(self) -> bool:
        return self.recovery_exact and self.accounting_ok \
            and not self.findings

    @property
    def fingerprint(self) -> str:
        """Bit-for-bit reproducibility witness: same schedule + seed
        must reproduce this hash exactly (committed bytes + ledger)."""
        h = hashlib.sha256()
        for arr in self.committed:
            h.update(arr.tobytes())
        h.update(repr((self.baseline_duration, self.faulted_duration,
                       self.wasted_cycles, self.recovery_cycles,
                       self.retry_cycles,
                       [(a.layer, a.wasted, a.t0, a.total)
                        for a in self.attempts],
                       [(r.kind, r.layer, r.t0, r.total)
                        for r in self.recoveries])).encode())
        return h.hexdigest()

    def summary(self) -> str:
        sched = self.schedule.describe()
        return (f"faultsim: {self.name} [{sched}] "
                f"recovery_exact={self.recovery_exact} "
                f"exactly_once={self.write_counts_ok} "
                f"accounting_ok={self.accounting_ok} "
                f"no_free_lunch={self.no_free_lunch} "
                f"slowdown={self.degraded_slowdown:.3f}x "
                f"(baseline {self.baseline_duration:g} -> "
                f"faulted {self.faulted_duration:g}; wasted "
                f"{self.wasted_cycles:g} + recovery "
                f"{self.recovery_cycles:g} + retries "
                f"{self.retry_cycles:g}; recomputed "
                f"{self.recomputed_elements} elements; "
                f"{len(self.recoveries)} re-plans)")


def _stitch(lp: MultiChipLayerPlan, reports: "list[LayerReport]",
            ref_shape: "tuple[int, ...]",
            ) -> "tuple[np.ndarray, np.ndarray]":
    """Assemble shard outputs into the full output tensor plus the
    integer write-count array of the exactly-once proof."""
    assembled = np.full(ref_shape, np.nan, dtype=np.float32)
    counts = np.zeros(ref_shape, dtype=np.int32)
    for shard, rep in zip(lp.shards, reports):
        rows = slice(None) if shard.out_rows is None else \
            slice(*shard.out_rows)
        kers = slice(None) if shard.kernel_range is None else \
            slice(*shard.kernel_range)
        assembled[kers, rows, :] = rep.output
        counts[kers, rows, :] += 1
    return assembled, counts


def _s2_retry_price(shard, hw, step_idx: int, retries: int,
                    backoff_base: float) -> "tuple[float, int]":
    """Analytic retry charge for an S2 shard (no functional injection —
    a re-read is idempotent, only the ledger moves)."""
    steps = shard.strategy.to_steps()
    s = steps[min(step_idx, len(steps) - 1)]
    lanes = decompose_step(s, shard.spec, hw,
                           kernel_groups=shard.strategy.kernel_groups)
    dur = retries * lanes.load_dur \
        + backoff_base * (2 ** retries - 1)
    return dur, retries * lanes.load_elements


def run_faulted(specs: Sequence[ConvSpec], cluster: ClusterModel,
                schedule: FaultSchedule, *,
                name: str = "network", seed: int = 0,
                verify: "bool | None" = None,
                inject_corruption: "int | None" = None,
                **plan_kwargs) -> FaultSimReport:
    """Execute ``specs`` on ``cluster`` under ``schedule`` (module note).

    ``plan_kwargs`` are forwarded to every ``plan_multichip_network`` /
    ``replan_suffix`` call (polish budgets, rng_seed, ...).  ``verify``
    gates the static plan verifier on the fault-free plan AND every
    degraded re-plan (default: the ``REPRO_VERIFY_PLANS`` env knob); a
    degraded plan with an error-severity diagnostic raises
    ``PlanVerificationError`` out of this function.

    ``inject_corruption`` is the negative-path hook: after committing
    that global layer, one output element is corrupted and one write
    count is double-counted — the recovery checks must catch both (used
    by ``faultsim --inject-corruption`` and the tests; never set in
    production runs).
    """
    from repro.analysis.verifier import should_verify
    specs = list(specs)
    n_layers = len(specs)
    do_verify = should_verify(verify)
    plan_kwargs.setdefault("include_single_chip_baseline", False)

    plan0 = plan_multichip_network(specs, cluster, name=name,
                                   verify=do_verify, **plan_kwargs)
    baseline = plan0.total_duration

    boundary = [e for e in schedule.events
                if isinstance(e, (LinkDegrade, VmemShrink))]
    deaths = [e for e in schedule.events if isinstance(e, ChipDeath)]
    dmas = [e for e in schedule.events if isinstance(e, DmaTransient)]
    applied: set[int] = set()      # indices into schedule.events
    idx_of = {id(e): i for i, e in enumerate(schedule.events)}

    controller = RecoveryController(
        list(range(cluster.n_chips)),
        detection_cycles=schedule.detection_cycles)

    cur_plan, off, cur_cluster = plan0, 0, cluster
    phys = list(range(cluster.n_chips))     # slot -> physical chip id
    committed: list[np.ndarray] = [None] * n_layers  # type: ignore
    allclose_ok: list[bool] = [False] * n_layers
    counts_ok = True
    accounting_ok = True
    attempts: list[StageAttempt] = []
    recoveries: list[RecoveryAction] = []
    skipped: list[str] = []
    findings: list[str] = []
    plans = [plan0]
    stragglers = 0
    t = 0.0
    hw = cur_cluster.chip

    def _replan(gi: int, new_cluster: ClusterModel, kind: str,
                restage_elems: int = 0) -> RecoveryAction:
        nonlocal cur_plan, off, cur_cluster, hw
        wall0 = time.perf_counter()
        stats0 = solver_mod.cache_stats()
        try:
            cur_plan = replan_suffix(specs, new_cluster, start=gi,
                                     name=name, verify=do_verify,
                                     **plan_kwargs)
        except Exception as exc:
            from repro.core.network_planner import InfeasibleNetworkError
            if isinstance(exc, InfeasibleNetworkError):
                raise DegradedInfeasibleError(
                    f"{kind} at layer {gi}: degraded cluster "
                    f"({new_cluster.n_chips} chips, "
                    f"{new_cluster.topo.describe()}, "
                    f"size_mem={new_cluster.chip.size_mem}) fits no "
                    f"plan for the remaining layers") from exc
            raise
        off, cur_cluster, hw = gi, new_cluster, new_cluster.chip
        plans.append(cur_plan)
        # delta attribution: only this re-plan's window, so recovery hit
        # rates never claim the fault-free plan's (or each other's) hits
        replan_stats = solver_mod.cache_stats() - stats0
        from repro.obs.metrics import REGISTRY
        REGISTRY.incr("planner/stage/resil_replan/calls",
                      replan_stats.solve_calls)
        REGISTRY.incr("planner/stage/resil_replan/hits",
                      replan_stats.solve_hits)
        replan_cost = schedule.replan_cycles_per_layer * (n_layers - gi)
        restage_cost = restage_elems * hw.t_l
        rec = RecoveryAction(
            kind=kind, layer=gi, t0=t,
            replan_cycles=replan_cost,
            restage_cycles=restage_cost,
            restage_elements=restage_elems,
            new_topology=new_cluster.topo.describe(),
            n_chips=new_cluster.n_chips,
            planning_seconds=time.perf_counter() - wall0,
            verified=do_verify,
            solver_calls=replan_stats.solve_calls,
            cache_hits=replan_stats.solve_hits)
        recoveries.append(rec)
        return rec

    gi = 0
    while gi < n_layers:
        lp = cur_plan.layers[gi - off]

        # ---- boundary faults: detected before the stage runs -------- #
        pending = [e for e in boundary
                   if e.layer == gi and idx_of[id(e)] not in applied]
        if pending:
            new_cluster = cur_cluster
            kinds = []
            for e in pending:
                applied.add(idx_of[id(e)])
                if isinstance(e, LinkDegrade):
                    new_cluster = repriced_cluster(new_cluster, e.factor)
                    kinds.append("link_degrade")
                else:
                    new_cluster = shrunk_cluster(new_cluster, e.factor)
                    kinds.append("vmem_shrink")
            spec = specs[gi]
            rec = _replan(gi, new_cluster, "+".join(kinds),
                          restage_elems=spec.num_pixels * spec.c_in)
            t += rec.total
            controller.advance(rec.total)
            continue                     # re-read lp from the new plan

        # ---- chip death: strikes during the stage ------------------- #
        death = next(
            (e for e in deaths
             if e.layer == gi and idx_of[id(e)] not in applied), None)
        if death is not None:
            applied.add(idx_of[id(death)])
            if death.chip >= cur_cluster.n_chips:
                skipped.append(
                    f"ChipDeath(layer={death.layer}, chip={death.chip}):"
                    f" slot does not exist ({cur_cluster.n_chips} chips)")
            else:
                dead_phys = phys[death.chip]
                survivors = [p for p in phys if p != dead_phys]
                att = StageAttempt(
                    layer=gi, t0=t, duration=lp.duration,
                    phys_chips=tuple(phys), wasted=True,
                    dead_chip=dead_phys,
                    detection=schedule.detection_cycles, lp=lp)
                attempts.append(att)
                # survivors beat at stage end; the dead chip is silent
                controller.advance(lp.duration)
                controller.stage_done(survivors, gi, {})
                controller.advance(schedule.detection_cycles)
                controller.expect_death(dead_phys)
                t += att.total
                if not survivors:
                    raise ClusterExhaustedError(
                        f"last chip died at layer {gi}")
                new_cluster = surviving_cluster(cur_cluster)
                spec = specs[gi]
                rec = _replan(gi, new_cluster, "chip_death",
                              restage_elems=spec.num_pixels * spec.c_in)
                rec.elastic = controller.elastic_plan(survivors)
                t += rec.total
                controller.advance(rec.total)
                phys = survivors
                continue                 # retry the stage, degraded

        # ---- normal execution (possibly with DMA transients) -------- #
        full = ConvLayer.random(lp.spec, seed=seed + gi)
        ref_shape = (lp.spec.n_kernels, lp.spec.h_out, lp.spec.w_out)
        stage_dmas = [e for e in dmas
                      if e.layer == gi and idx_of[id(e)] not in applied]
        reports: list[LayerReport] = []
        shard_durs: dict[int, float] = {}
        retry_dur_total, retry_elems_total = 0.0, 0
        for shard in lp.shards:
            hits = [e for e in stage_dmas if e.chip == shard.chip]
            for e in hits:
                applied.add(idx_of[id(e)])
            retry_at: dict[int, int] = {}
            analytic_dur, analytic_elems = 0.0, 0
            if hits:
                if shard.mode == "s2":
                    for e in hits:
                        d, el = _s2_retry_price(
                            shard, hw, e.step, e.retries,
                            schedule.backoff_base_cycles)
                        analytic_dur += d
                        analytic_elems += el
                else:
                    n_steps = len(shard.strategy.to_steps())
                    for e in hits:
                        si = min(e.step, n_steps - 1)
                        retry_at[si] = retry_at.get(si, 0) + e.retries
            rep = run_shard(full, shard, hw, retry_at=retry_at or None,
                            backoff_base=schedule.backoff_base_cycles)
            reports.append(rep)
            rep_retry = getattr(rep, "retry_duration", 0.0) + analytic_dur
            rep_retry_el = getattr(rep, "retry_elements", 0) \
                + analytic_elems
            retry_dur_total += rep_retry
            retry_elems_total += rep_retry_el
            measured = rep.total_duration + analytic_dur
            shard_durs[phys[shard.chip]] = measured
            if abs(measured - shard.pad_saved - rep_retry
                   - shard.gross_duration) > _ACC_TOL:
                accounting_ok = False
                findings.append(
                    f"L{gi} chip{shard.chip}: measured duration "
                    f"{measured:g} != gross {shard.gross_duration:g} "
                    f"+ pad_saved {shard.pad_saved:g} "
                    f"+ retries {rep_retry:g}")
            if not rep.correct:
                findings.append(
                    f"L{gi} chip{shard.chip}: shard run incorrect "
                    f"(max_err={rep.max_abs_err:g})")
        for e in stage_dmas:
            if idx_of[id(e)] not in applied:
                applied.add(idx_of[id(e)])
                skipped.append(
                    f"DmaTransient(layer={e.layer}, chip={e.chip}): "
                    f"no shard on that slot")

        assembled, counts = _stitch(lp, reports, ref_shape)
        if inject_corruption == gi:
            assembled[0, 0, 0] = assembled[0, 0, 0] * 2.0 + 1.0
            counts[0, 0, 0] += 1
        if not bool(np.all(counts == 1)):
            counts_ok = False
            findings.append(
                f"L{gi}: exactly-once violated — write counts "
                f"min={int(counts.min())} max={int(counts.max())}")
        ref = reference_conv(full)
        allclose_ok[gi] = not np.any(np.isnan(assembled)) and bool(
            np.allclose(assembled, ref, rtol=_RTOL, atol=_ATOL))
        if not allclose_ok[gi]:
            findings.append(
                f"L{gi}: stitched output diverged from the fault-free "
                f"reference conv")
        committed[gi] = assembled

        att = StageAttempt(
            layer=gi, t0=t, duration=lp.duration,
            phys_chips=tuple(phys),
            retry_duration=retry_dur_total,
            retry_elements=retry_elems_total,
            shard_durations=shard_durs, reports=reports, lp=lp)
        attempts.append(att)
        controller.advance(att.total)
        controller.stage_done(list(phys), gi, shard_durs)
        if controller.stragglers():
            stragglers += 1
        t += att.total
        gi += 1

    t += cur_plan.final_gather_duration

    # any scheduled event that never found its stage (layer out of range)
    for i, e in enumerate(schedule.events):
        if i not in applied:
            skipped.append(f"{type(e).__name__}(layer={e.layer}): layer "
                           f"out of range ({n_layers} layers)")

    return FaultSimReport(
        name=name, schedule=schedule,
        baseline_duration=baseline, faulted_duration=t,
        attempts=attempts, recoveries=recoveries,
        skipped_events=skipped,
        committed=committed, write_counts_ok=counts_ok,
        layer_allclose=allclose_ok, accounting_ok=accounting_ok,
        stragglers_flagged=stragglers,
        findings=findings, plans=plans)
