"""Fault model: typed fault events and the seeded deterministic schedule.

Four fault kinds, each anchored to a *stage boundary* of the multichip
schedule (the network executes layer by layer — a stage — and recovery
is layer-granular, so stages are also the detection points):

=================  =====================================================
:class:`ChipDeath`      chip slot ``chip`` dies *during* stage ``layer``:
                        the whole attempt is wasted (its partial writes
                        never commit), the death is detected by the
                        heartbeat control plane at the stage boundary,
                        and the remaining layers are re-planned on the
                        surviving topology.
:class:`LinkDegrade`    from stage ``layer`` on, every ICI link moves
                        elements ``factor``x slower (``t_ici *=
                        factor``); detected *before* the stage runs
                        (link-level CRC/latency telemetry), so nothing
                        is recomputed — the tail is re-planned at the
                        degraded price.
:class:`VmemShrink`     from stage ``layer`` on, the per-chip on-chip
                        budget shrinks to ``floor(size_mem * factor)``
                        (e.g. a co-tenant claims VMEM); the tail is
                        re-planned under the tighter budget.
:class:`DmaTransient`   the DMA load of Def-3 step ``step`` of the
                        shard on chip slot ``chip`` in stage ``layer``
                        fails ``retries`` times before succeeding; each
                        retry re-reads the step's loads (idempotent —
                        DRAM reads have no side effects) and waits an
                        exponential backoff.  Purely a duration/traffic
                        fault: values are unchanged.
=================  =====================================================

``chip`` always names a *slot* of the plan currently executing (after a
recovery re-plan the surviving chips are renumbered ``0..n_surv-1``);
events whose slot does not exist in the current plan are recorded as
skipped, never silently dropped.

A :class:`FaultSchedule` is frozen and seeded: :meth:`FaultSchedule.random`
derives every event from ``random.Random(seed)`` so a faulted run is
reproducible bit-for-bit — the engine fingerprints its committed outputs
and ledger, and equality of fingerprints across runs is part of the
``faultsim`` exit criteria.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Union


class FaultError(RuntimeError):
    """Base class for every typed failure the resil subsystem raises."""


class ClusterExhaustedError(FaultError):
    """Every chip died — no surviving topology can run the remaining
    layers."""


class RecoveryCorruptionError(FaultError):
    """A recovery-correctness invariant broke: an output element was
    committed zero or multiple times, or the stitched output diverged
    from the fault-free reference convolution."""


class DegradedInfeasibleError(FaultError):
    """The degraded cluster cannot run the remaining layers (e.g. the
    shrunk VMEM budget fits no strategy) — recovery is impossible, not
    merely slow."""


class FaultScheduleError(FaultError):
    """A malformed fault schedule (bad factor, negative layer, ...)."""


@dataclasses.dataclass(frozen=True)
class ChipDeath:
    """Chip slot ``chip`` dies during stage ``layer``."""

    layer: int
    chip: int


@dataclasses.dataclass(frozen=True)
class LinkDegrade:
    """Every ICI link is ``factor``x slower from stage ``layer`` on."""

    layer: int
    factor: float


@dataclasses.dataclass(frozen=True)
class VmemShrink:
    """Per-chip budget shrinks to ``floor(size_mem * factor)`` from
    stage ``layer`` on."""

    layer: int
    factor: float


@dataclasses.dataclass(frozen=True)
class DmaTransient:
    """The loads of step ``step`` on chip slot ``chip`` in stage
    ``layer`` fail ``retries`` times before succeeding."""

    layer: int
    chip: int
    step: int
    retries: int


FaultEvent = Union[ChipDeath, LinkDegrade, VmemShrink, DmaTransient]


def _validate(events: "tuple[FaultEvent, ...]") -> None:
    deaths: set[int] = set()
    for e in events:
        if e.layer < 0:
            raise FaultScheduleError(f"negative layer in {e}")
        if isinstance(e, ChipDeath):
            if e.chip < 0:
                raise FaultScheduleError(f"negative chip in {e}")
            deaths.add(e.chip)
        elif isinstance(e, LinkDegrade):
            if e.factor < 1.0:
                raise FaultScheduleError(
                    f"LinkDegrade factor must be >= 1 (slower), got {e}")
        elif isinstance(e, VmemShrink):
            if not 0.0 < e.factor <= 1.0:
                raise FaultScheduleError(
                    f"VmemShrink factor must be in (0, 1], got {e}")
        elif isinstance(e, DmaTransient):
            if e.chip < 0 or e.step < 0 or e.retries < 1:
                raise FaultScheduleError(f"malformed DmaTransient {e}")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A seeded, deterministic set of fault events plus the recovery
    cost knobs the engine prices into the Def-3 ledger (all in abstract
    cycles, the same unit as ``t_l``/``t_w``/``t_acc``/``t_ici``):

    * ``detection_cycles`` — heartbeat timeout: how long after a stage
      ends the control plane declares a silent chip dead;
    * ``replan_cycles_per_layer`` — deterministic price of re-planning
      one remaining layer (planning wall-clock is machine-dependent, so
      the *ledger* uses this fixed rate; the measured seconds are
      reported separately and never enter the fingerprint);
    * ``backoff_base_cycles`` — DMA retry backoff: attempt ``a`` waits
      ``backoff_base_cycles * 2**(a-1)`` before re-issuing the load.
    """

    seed: int
    events: tuple[FaultEvent, ...]
    detection_cycles: float = 256.0
    replan_cycles_per_layer: float = 64.0
    backoff_base_cycles: float = 16.0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        _validate(self.events)

    @classmethod
    def random(cls, seed: int, *, n_layers: int, n_chips: int,
               n_events: int = 2,
               kinds: "tuple[str, ...]" = ("chip_death", "link_degrade",
                                           "vmem_shrink", "dma_transient"),
               **knobs: float) -> "FaultSchedule":
        """Draw ``n_events`` events deterministically from ``seed``.

        At most ``n_chips - 1`` chip deaths are drawn (the engine must
        always keep one survivor), and death slots are distinct within
        the schedule (a slot can only die once per plan epoch)."""
        if n_layers < 1 or n_chips < 1:
            raise FaultScheduleError(
                f"need n_layers >= 1 and n_chips >= 1, got "
                f"{n_layers}/{n_chips}")
        rng = random.Random(seed)
        events: list[FaultEvent] = []
        deaths: set[int] = set()
        for _ in range(n_events):
            kind = rng.choice(list(kinds))
            layer = rng.randrange(n_layers)
            if kind == "chip_death":
                free = sorted(set(range(n_chips)) - deaths)
                if len(free) <= 1 or len(deaths) >= n_chips - 1:
                    kind = "dma_transient"      # keep one survivor
                else:
                    chip = rng.choice(free)
                    deaths.add(chip)
                    events.append(ChipDeath(layer=layer, chip=chip))
                    continue
            if kind == "link_degrade":
                events.append(LinkDegrade(
                    layer=layer, factor=1.0 + rng.choice((1, 2, 3))))
            elif kind == "vmem_shrink":
                events.append(VmemShrink(
                    layer=layer, factor=rng.choice((0.9, 0.75, 0.6))))
            else:
                events.append(DmaTransient(
                    layer=layer, chip=rng.randrange(n_chips),
                    step=rng.randrange(4), retries=rng.randrange(1, 4)))
        events.sort(key=lambda e: (e.layer, type(e).__name__,
                                   getattr(e, "chip", -1)))
        return cls(seed=seed, events=tuple(events), **knobs)

    def describe(self) -> str:
        parts = [f"seed={self.seed}"]
        for e in self.events:
            parts.append(f"{type(e).__name__}{dataclasses.astuple(e)}")
        return " ".join(parts)
