"""Fault-injection CLI: ``python -m repro.resil.faultsim``.

Plans a registered network on a cluster, runs it under a seeded fault
schedule (``repro.resil.engine``), and checks every recovery-correctness
invariant the subsystem claims:

* **exactly-once** — every committed output element has write count 1;
* **exact recovery** — every stitched layer output equals the fault-free
  reference convolution under the simulator's stitching discipline;
* **accounting** — each shard's measured duration reconciles as
  ``gross + pad_saved + retries``;
* **verified re-plans** — the fault-free plan *and* every degraded
  re-plan pass ``repro.analysis.verifier`` (faultsim always verifies);
* **determinism** — the engine runs the schedule twice and the two
  bit-for-bit fingerprints (committed bytes + ledger) must agree;
* **valid trace** — the exported Perfetto timeline (fault-free predicted
  vs faulted, with ``fault``/``recovery`` lanes) passes the Chrome-trace
  schema validator.

The exit code folds all of the above in: any finding is nonzero, which
is what the CI faultsim smoke step consumes.  ``--inject-corruption L``
is the negative path — it corrupts one committed element and
double-counts one write after layer ``L``, and the run must *fail*
(used by the CI step and the tests to prove the checks have teeth).
``no_free_lunch`` (degraded duration never beats the baseline) is a
pricing property reported in the summary, not an exit criterion.

Scenarios (all placements drawn from ``random.Random(seed)``):

=================  ====================================================
``chip-death``     one chip dies mid-stage; detect, re-plan on the
                   surviving topology, restage, retry.
``link-degrade``   every ICI link 2x slower from a random stage on.
``vmem-shrink``    per-chip budget shrinks to 75% from a random stage.
``dma-transient``  one step's DMA loads fail twice before succeeding.
``mixed``          chip-death + link-degrade + dma-transient (default).
``random``         ``FaultSchedule.random`` with ``--events`` draws.
=================  ====================================================
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import random
import sys
from typing import Sequence

from repro.configs.clusters import make_cluster
from repro.configs.networks import NETWORKS
from repro.core.cost_model import Topology
from repro.obs.adapters import faulted_timeline, multichip_predicted_timeline
from repro.obs.chrome import (to_chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.report import (default_size_mem, fault_attribution_rows,
                              fault_overhead_by_lane)
from repro.resil.engine import FaultSimReport, run_faulted
from repro.resil.faults import (ChipDeath, DmaTransient, FaultSchedule,
                                LinkDegrade, VmemShrink)

SCENARIOS = ("mixed", "chip-death", "link-degrade", "vmem-shrink",
             "dma-transient", "random")


def build_schedule(scenario: str, seed: int, *, n_layers: int,
                   n_chips: int, n_events: int = 3) -> FaultSchedule:
    """Deterministic schedule for a named scenario (module note)."""
    if scenario == "random":
        return FaultSchedule.random(seed, n_layers=n_layers,
                                    n_chips=n_chips, n_events=n_events)
    rng = random.Random(seed)
    events: list = []
    if scenario in ("chip-death", "mixed"):
        events.append(ChipDeath(layer=rng.randrange(n_layers),
                                chip=rng.randrange(n_chips)))
    if scenario in ("link-degrade", "mixed"):
        events.append(LinkDegrade(layer=rng.randrange(n_layers),
                                  factor=2.0))
    if scenario == "vmem-shrink":
        events.append(VmemShrink(layer=rng.randrange(n_layers),
                                 factor=0.75))
    if scenario in ("dma-transient", "mixed"):
        events.append(DmaTransient(layer=rng.randrange(n_layers),
                                   chip=rng.randrange(n_chips),
                                   step=rng.randrange(4), retries=2))
    return FaultSchedule(seed=seed, events=tuple(events))


def run_checked(network: str, schedule: FaultSchedule, *,
                topology: str = "torus2x2", n_chips: int | None = None,
                size_mem: int | None = None, seed: int = 0,
                iters: int = 300, restarts: int = 1, rng_seed: int = 0,
                inject_corruption: int | None = None,
                ) -> "tuple[FaultSimReport, list[str]]":
    """Run the schedule twice (determinism check) with verification on;
    returns the first run's report plus every finding."""
    specs = NETWORKS[network]
    if n_chips is None:
        topo = Topology.parse(topology)
        n_chips = topo.dims[0] * topo.dims[1] if topo.kind == "torus" \
            else 4
    if size_mem is None:
        size_mem = default_size_mem(network, multichip=True)
    cluster = make_cluster(n_chips, size_mem=size_mem, topology=topology)
    kwargs = dict(name=network, seed=seed, verify=True,
                  polish_iters=iters, polish_restarts=restarts,
                  rng_seed=rng_seed, inject_corruption=inject_corruption)
    report = run_faulted(specs, cluster, schedule, **kwargs)
    twin = run_faulted(specs, cluster, schedule, **kwargs)
    findings = list(report.findings)
    if report.fingerprint != twin.fingerprint:
        findings.append(
            f"nondeterministic: fingerprint {report.fingerprint[:16]} "
            f"!= twin {twin.fingerprint[:16]}")
    return report, findings


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.resil.faultsim",
        description="Deterministic fault injection with layer-granular "
                    "recovery: exactly-once outputs, verified degraded "
                    "re-plans, Perfetto fault/recovery trace.")
    ap.add_argument("--network", required=True, choices=sorted(NETWORKS))
    ap.add_argument("--topology", default="torus2x2",
                    help="'ring', 'biring' or 'torusRxC' (default "
                         "torus2x2)")
    ap.add_argument("--n-chips", type=int, default=None,
                    help="cluster size (default: the torus grid, or 4)")
    ap.add_argument("--size-mem", type=int, default=None,
                    help="on-chip budget (default: half the largest Λ — "
                         "the chip-sweep convention)")
    ap.add_argument("--seed", type=int, default=0,
                    help="fault-schedule seed (also the sim data seed)")
    ap.add_argument("--scenario", default="mixed", choices=SCENARIOS)
    ap.add_argument("--events", type=int, default=3,
                    help="draws for --scenario random")
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--restarts", type=int, default=1)
    ap.add_argument("--rng-seed", type=int, default=0,
                    help="planner polish seed")
    ap.add_argument("--inject-corruption", type=int, default=None,
                    metavar="LAYER",
                    help="negative path: corrupt layer LAYER's committed "
                         "output — the run must FAIL")
    ap.add_argument("--out", default=None,
                    help="Perfetto trace path (default: benchmarks/"
                         "results/faultsim_<network>_<topology>.json)")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as JSON instead of text")
    args = ap.parse_args(argv)

    specs = NETWORKS[args.network]
    topo = Topology.parse(args.topology)
    n_chips = args.n_chips if args.n_chips is not None else (
        topo.dims[0] * topo.dims[1] if topo.kind == "torus" else 4)
    schedule = build_schedule(args.scenario, args.seed,
                              n_layers=len(specs), n_chips=n_chips,
                              n_events=args.events)

    report, findings = run_checked(
        args.network, schedule, topology=args.topology, n_chips=n_chips,
        size_mem=args.size_mem, seed=args.seed, iters=args.iters,
        restarts=args.restarts, rng_seed=args.rng_seed,
        inject_corruption=args.inject_corruption)

    pred = multichip_predicted_timeline(report.plans[0],
                                        label="fault-free-predicted")
    faulted = faulted_timeline(report)
    trace = to_chrome_trace([pred, faulted])
    findings.extend(f"trace: {e}" for e in validate_chrome_trace(trace))
    out = args.out or (f"benchmarks/results/faultsim_{args.network}"
                       f"_{args.topology}.json")
    out_dir = os.path.dirname(out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    write_chrome_trace(trace, out)

    rows = fault_attribution_rows(pred, faulted)
    overhead = fault_overhead_by_lane(rows)
    ok = report.ok and not findings

    if args.json:
        print(json.dumps({
            "network": args.network, "topology": args.topology,
            "n_chips": n_chips, "scenario": args.scenario,
            "seed": args.seed,
            "schedule": schedule.describe(),
            "ok": ok, "recovery_exact": report.recovery_exact,
            "exactly_once": report.write_counts_ok,
            "accounting_ok": report.accounting_ok,
            "no_free_lunch": report.no_free_lunch,
            "degraded_slowdown": report.degraded_slowdown,
            "baseline_duration": report.baseline_duration,
            "faulted_duration": report.faulted_duration,
            "wasted_cycles": report.wasted_cycles,
            "recovery_cycles": report.recovery_cycles,
            "retry_cycles": report.retry_cycles,
            "recomputed_elements": report.recomputed_elements,
            "replans": len(report.recoveries),
            "skipped_events": report.skipped_events,
            "fingerprint": report.fingerprint,
            "overhead_by_lane": overhead,
            "findings": findings,
        }, indent=1))
    else:
        print(report.summary())
        for rec in report.recoveries:
            print(f"  recovery L{rec.layer} [{rec.kind}]: re-plan "
                  f"{rec.replan_cycles:g} cy + restage "
                  f"{rec.restage_cycles:g} cy ({rec.restage_elements} "
                  f"el) -> {rec.n_chips} chips {rec.new_topology} "
                  f"verified={rec.verified}")
        for ev in report.skipped_events:
            print(f"  skipped: {ev}")
        lanes = ", ".join(f"{lane} {d:+g}"
                          for lane, d in sorted(overhead.items()) if d)
        print(f"  overhead by lane (faulted - predicted cycles): "
              f"{lanes or 'none'}")
        print(f"  determinism: twin fingerprint match = "
              f"{not any('nondeterministic' in f for f in findings)}")
        print(f"  trace -> {out}  (load in https://ui.perfetto.dev)")
        for f in findings:
            print(f"  FINDING: {f}", file=sys.stderr)
        print(f"  faultsim: {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":                      # pragma: no cover
    sys.exit(main())
