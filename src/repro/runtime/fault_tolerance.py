"""Fault tolerance & elasticity runtime (host-side control plane).

Pieces needed at 1000+ nodes, kept hardware-agnostic so the same logic runs
under a real multi-host launcher or in the single-process tests:

  * ``HeartbeatTracker`` — hosts report a monotonically increasing step;
    a host silent for longer than ``timeout_s`` is declared dead;
  * ``StragglerDetector`` — per-host step-time EWMA; a host whose step time
    exceeds ``factor`` x fleet median is flagged for mitigation (reorder
    its data shard, exclude from critical collectives, or preemptively
    evict);
  * ``ElasticPlan`` — given the surviving hosts, computes the new mesh
    shape and the (data-shard -> host) remap; the deterministic data
    pipeline (data/pipeline.py) and re-sharding checkpoint restore
    (checkpoint/checkpoint.py) make the rescale exactly-once;
  * ``TrainSupervisor`` — the restart loop: run steps, checkpoint every K,
    on failure shrink/regrow the mesh and restore from the newest commit.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Iterable


# --------------------------------------------------------------------- #
# Typed failures
# --------------------------------------------------------------------- #

class FaultToleranceError(RuntimeError):
    """Base class for control-plane misuse/impossibility errors."""


class UnknownHostError(FaultToleranceError):
    """A beat/record arrived from a host the tracker never registered —
    either a wiring bug or a zombie host that was already evicted.
    Silently resurrecting it would mask both, so it is an error."""


class NoSurvivorsError(FaultToleranceError):
    """Every host is gone: no mesh can be built.  Raised instead of
    returning an empty :class:`ElasticPlan` (which callers would loop on
    forever, restoring and re-planning a zero-host fleet)."""


# --------------------------------------------------------------------- #
# Failure detection
# --------------------------------------------------------------------- #

class HeartbeatTracker:
    def __init__(self, hosts: Iterable[int], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last_seen: dict[int, float] = {h: clock() for h in hosts}
        self.last_step: dict[int, int] = {h: -1 for h in hosts}

    def beat(self, host: int, step: int) -> None:
        if host not in self.last_seen:
            raise UnknownHostError(
                f"heartbeat from unregistered host {host}")
        self.last_seen[host] = self.clock()
        self.last_step[host] = max(self.last_step.get(host, -1), step)

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        return sorted(h for h, t in self.last_seen.items()
                      if now - t > self.timeout)

    def alive_hosts(self) -> list[int]:
        dead = set(self.dead_hosts())
        return sorted(h for h in self.last_seen if h not in dead)


class StragglerDetector:
    """EWMA step times; flag hosts slower than factor x fleet median."""

    def __init__(self, hosts: Iterable[int], alpha: float = 0.2,
                 factor: float = 1.5, warmup: int = 3):
        self.alpha = alpha
        self.factor = factor
        self.warmup = warmup
        self.ewma: dict[int, float] = {h: 0.0 for h in hosts}
        self.count: dict[int, int] = {h: 0 for h in hosts}

    def record(self, host: int, step_time_s: float) -> None:
        if host not in self.ewma:
            raise UnknownHostError(
                f"step-time report from unregistered host {host}")
        c = self.count.get(host, 0)
        prev = self.ewma.get(host, 0.0)
        self.ewma[host] = step_time_s if c == 0 else \
            (1 - self.alpha) * prev + self.alpha * step_time_s
        self.count[host] = c + 1

    def fleet_median(self) -> float:
        vals = sorted(v for h, v in self.ewma.items()
                      if self.count[h] >= self.warmup)
        if not vals:
            return 0.0
        return vals[len(vals) // 2]

    def stragglers(self) -> list[int]:
        med = self.fleet_median()
        if med <= 0:
            return []
        return sorted(h for h, v in self.ewma.items()
                      if self.count[h] >= self.warmup
                      and v > self.factor * med)


# --------------------------------------------------------------------- #
# Elastic rescale planning
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    hosts: tuple[int, ...]           # surviving hosts, rank order
    data_shards: int                 # new data-parallel degree
    model_shards: int                # unchanged TP degree
    shard_of_host: dict[int, int]    # host -> data shard index

    @property
    def world(self) -> int:
        return self.data_shards * self.model_shards


def plan_rescale(alive: Iterable[int], model_shards: int,
                 chips_per_host: int = 4) -> ElasticPlan:
    """Largest mesh we can build from the survivors: TP degree is fixed
    (weights layout), the data axis shrinks to the largest multiple that
    the surviving chip count supports."""
    if model_shards < 1 or chips_per_host < 1:
        raise FaultToleranceError(
            f"model_shards and chips_per_host must be >= 1, got "
            f"{model_shards}/{chips_per_host}")
    hosts = tuple(sorted(alive))
    if not hosts:
        raise NoSurvivorsError("no surviving hosts to build a mesh from")
    chips = len(hosts) * chips_per_host
    data = max(1, chips // model_shards)
    # data axis must evenly divide the global batch handling; keep a power
    # of two for collective efficiency.
    data = 1 << int(math.log2(data)) if data > 0 else 1
    used_hosts = hosts[: (data * model_shards) // chips_per_host]
    shard_of = {h: i % data for i, h in enumerate(used_hosts)}
    return ElasticPlan(hosts=used_hosts, data_shards=data,
                       model_shards=model_shards, shard_of_host=shard_of)


# --------------------------------------------------------------------- #
# Restart supervisor
# --------------------------------------------------------------------- #

@dataclasses.dataclass
class SupervisorReport:
    steps_done: int
    restarts: int
    rescales: list[int]              # data_shards after each rescale
    straggler_events: int


class TrainSupervisor:
    """Deterministic restart loop used by tests and the real launcher.

    ``run_step(step, plan) -> step_time_s`` may raise HostFailure to signal
    a lost host; the supervisor then replans the mesh, restores from the
    last checkpoint step, and continues."""

    def __init__(self, hosts: list[int], model_shards: int,
                 checkpoint_every: int = 10, chips_per_host: int = 4):
        self.hb = HeartbeatTracker(hosts, timeout_s=float("inf"))
        self.straggle = StragglerDetector(hosts)
        self.model_shards = model_shards
        self.chips_per_host = chips_per_host
        self.checkpoint_every = checkpoint_every

    def run(self, total_steps: int,
            run_step: Callable[[int, ElasticPlan], float],
            save: Callable[[int], None],
            restore: Callable[[], int],
            fail_host: Callable[[int], None] | None = None
            ) -> SupervisorReport:
        plan = plan_rescale(self.hb.alive_hosts(), self.model_shards,
                            self.chips_per_host)
        step, restarts, rescales, stragglers = 0, 0, [], 0
        while step < total_steps:
            try:
                dt = run_step(step, plan)
                for h in plan.hosts:
                    self.hb.beat(h, step)
                    self.straggle.record(h, dt)
                if self.straggle.stragglers():
                    stragglers += 1
                if (step + 1) % self.checkpoint_every == 0:
                    save(step + 1)
                step += 1
            except HostFailure as hf:
                restarts += 1
                # evict the host from *every* tracker: a dead host left
                # in the straggler EWMA would keep skewing the fleet
                # median (and could be flagged) forever after
                self.hb.last_seen.pop(hf.host, None)
                self.hb.last_step.pop(hf.host, None)
                self.straggle.ewma.pop(hf.host, None)
                self.straggle.count.pop(hf.host, None)
                if fail_host:
                    fail_host(hf.host)
                plan = plan_rescale(self.hb.alive_hosts(),
                                    self.model_shards,
                                    self.chips_per_host)
                rescales.append(plan.data_shards)
                step = restore()
        return SupervisorReport(steps_done=step, restarts=restarts,
                                rescales=rescales,
                                straggler_events=stragglers)


class HostFailure(RuntimeError):
    def __init__(self, host: int):
        super().__init__(f"host {host} failed")
        self.host = host
