"""Python-based simulator of the offloading process (paper Sec 6).

Mirrors the paper's class structure: the ``System`` orchestrator drives a
``Strategy`` step by step against an ``Accelerator`` (on-chip memory +
processing element) and a ``Dram``; the ``ConvLayer`` carries the problem
data.  The simulation is *functional*: real values are convolved, and the
final DRAM output is checked against a reference convolution.
"""
from repro.sim.accelerator import Accelerator, OnChipMemory
from repro.sim.dram import Dram
from repro.sim.layer import ConvLayer
from repro.sim.multichip import MultiChipSimReport, simulate_multichip
from repro.sim.network import NetworkSimReport, simulate_network
from repro.sim.system import SimReport, System
from repro.sim.functional import reference_conv

__all__ = ["Accelerator", "OnChipMemory", "Dram", "ConvLayer",
           "System", "SimReport", "reference_conv",
           "NetworkSimReport", "simulate_network",
           "MultiChipSimReport", "simulate_multichip"]
