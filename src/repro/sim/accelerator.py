"""Accelerator model: on-chip memory + processing element (paper Sec 6).

The on-chip memory stores *values* keyed by the same identifiers the
formalism uses (spatial pixel ids, kernel ids, output position ids), so a
formal ``Step`` drives the functional simulation directly.  Capacity is
checked in tensor elements at every point of the step sequence."""
from __future__ import annotations

import numpy as np

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel


class OnChipMemory:
    def __init__(self, spec: ConvSpec, capacity: int | None):
        self.spec = spec
        self.capacity = capacity
        self.pixels: dict[int, np.ndarray] = {}    # pixel id -> (C_in,)
        self.kernels: dict[int, np.ndarray] = {}   # kernel id -> (C_in,Hk,Wk)
        self.outputs: dict[int, np.ndarray] = {}   # patch id -> (C_out,)

    # --- occupancy in tensor elements ------------------------------------
    @property
    def used(self) -> int:
        s = self.spec
        return (len(self.pixels) * s.c_in
                + len(self.kernels) * s.c_in * s.h_k * s.w_k
                + len(self.outputs) * s.c_out)

    def check_capacity(self) -> None:
        if self.capacity is not None and self.used > self.capacity:
            raise MemoryError(
                f"on-chip memory overflow: {self.used} > {self.capacity}")

    # --- set-like mutations ----------------------------------------------
    def free_pixels(self, ids) -> None:
        for j in ids:
            del self.pixels[j]

    def free_kernels(self, ids) -> None:
        for k in ids:
            del self.kernels[k]

    def pop_outputs(self, ids) -> dict[int, np.ndarray]:
        return {p: self.outputs.pop(p) for p in ids}

    def store_pixel(self, j: int, v: np.ndarray) -> None:
        if j in self.pixels:
            raise RuntimeError(f"pixel {j} reloaded while resident")
        self.pixels[j] = v

    def store_kernel(self, k: int, v: np.ndarray) -> None:
        self.kernels[k] = v


class Accelerator:
    """PE + on-chip memory.  ``compute(group)`` realises action a6."""

    def __init__(self, spec: ConvSpec, hw: HardwareModel):
        self.spec = spec
        self.hw = hw
        self.mem = OnChipMemory(spec, hw.size_mem)
        self.total_macs = 0

    def compute(self, group) -> None:
        s = self.spec
        macs = len(group) * s.nb_op_value * s.c_out
        if macs > self.hw.nbop_pe:
            raise RuntimeError(
                f"PE overrun: step needs {macs} MACs > {self.hw.nbop_pe}")
        if len(self.mem.kernels) != s.n_kernels:
            raise RuntimeError("S1 compute requires all kernels resident")
        kern = np.stack([self.mem.kernels[k] for k in range(s.n_kernels)])
        for pid in group:
            h0, w0, h1, w1 = s.patch_bbox(pid)
            patch = np.empty((s.c_in, s.h_k, s.w_k), dtype=np.float32)
            for h in range(h0, h1):
                for w in range(w0, w1):
                    j = s.pixel_id(h, w)
                    if j not in self.mem.pixels:
                        raise RuntimeError(
                            f"patch {pid} needs pixel {j} not on-chip")
                    patch[:, h - h0, w - w0] = self.mem.pixels[j]
            # (N, C_in, Hk, Wk) . (C_in, Hk, Wk) -> (N,)
            self.mem.outputs[pid] = np.einsum(
                "nchw,chw->n", kern, patch).astype(np.float32)
        self.total_macs += macs
