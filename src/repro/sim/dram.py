"""Off-chip DRAM model (paper Sec 6).

Holds the full input/kernel tensors (assumed to fit, Sec 2.1) and receives
written-back output values.  Counts transferred elements so bandwidth-style
metrics can be derived."""
from __future__ import annotations

import numpy as np

from repro.sim.layer import ConvLayer


class Dram:
    def __init__(self, layer: ConvLayer):
        self.layer = layer
        s = layer.spec
        # outputs start undefined; the functional check requires every value
        # to be written back exactly once.
        self.output = np.full((s.c_out, s.h_out, s.w_out), np.nan,
                              dtype=np.float32)
        self.elements_read = 0      # DRAM -> on-chip
        self.elements_written = 0   # on-chip -> DRAM

    # --- loads ----------------------------------------------------------
    def read_pixel(self, h: int, w: int) -> np.ndarray:
        """All C_in channels of a spatial pixel (Remark 6: channels move
        together)."""
        self.elements_read += self.layer.spec.c_in
        return self.layer.input[:, h, w]

    def read_kernel(self, kid: int) -> np.ndarray:
        k = self.layer.kernels[kid]
        self.elements_read += k.size
        return k

    # --- write-back -----------------------------------------------------
    def write_output(self, pid: int, values: np.ndarray) -> None:
        """All C_out channels of output position ``pid``."""
        s = self.layer.spec
        i, j = s.patch_pos(pid)
        if not np.all(np.isnan(self.output[:, i, j])):
            raise RuntimeError(f"output {pid} written twice")
        self.output[:, i, j] = values
        self.elements_written += values.size
