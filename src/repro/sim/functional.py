"""Reference convolution oracle for the functional simulation check."""
from __future__ import annotations

import numpy as np

from repro.sim.layer import ConvLayer


def reference_conv(layer: ConvLayer) -> np.ndarray:
    """Direct cross-correlation (Def 8's output equation), numpy."""
    s = layer.spec
    out = np.zeros((s.c_out, s.h_out, s.w_out), dtype=np.float32)
    for i in range(s.h_out):
        for j in range(s.w_out):
            win = layer.input[:, i * s.s_h:i * s.s_h + s.h_k,
                              j * s.s_w:j * s.s_w + s.w_k]
            out[:, i, j] = np.einsum("nchw,chw->n", layer.kernels, win)
    return out


def reference_conv_jax(layer: ConvLayer) -> np.ndarray:
    """Independent oracle via jax.lax (used by the test suite)."""
    import jax.numpy as jnp
    from jax import lax

    s = layer.spec
    lhs = jnp.asarray(layer.input)[None]            # NCHW
    rhs = jnp.asarray(layer.kernels)                # OIHW
    out = lax.conv_general_dilated(
        lhs, rhs, window_strides=(s.s_h, s.s_w), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return np.asarray(out[0])
