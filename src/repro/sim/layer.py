"""Convolution layer description + data (paper Sec 6: "convolution layer
class contains all the parameters and data (patches, pixels and kernels)
required for computation")."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.conv_spec import ConvSpec


@dataclasses.dataclass
class ConvLayer:
    """Problem instance: spec + concrete tensors (input already padded)."""

    spec: ConvSpec
    input: np.ndarray      # (C_in, H_in, W_in)
    kernels: np.ndarray    # (N, C_in, H_K, W_K)

    def __post_init__(self):
        s = self.spec
        if self.input.shape != (s.c_in, s.h_in, s.w_in):
            raise ValueError(f"input shape {self.input.shape} != spec "
                             f"{(s.c_in, s.h_in, s.w_in)}")
        if self.kernels.shape != (s.n_kernels, s.c_in, s.h_k, s.w_k):
            raise ValueError(f"kernel shape {self.kernels.shape} != spec "
                             f"{(s.n_kernels, s.c_in, s.h_k, s.w_k)}")

    @classmethod
    def random(cls, spec: ConvSpec, seed: int = 0) -> "ConvLayer":
        rng = np.random.default_rng(seed)
        return cls(spec=spec,
                   input=rng.standard_normal(
                       (spec.c_in, spec.h_in, spec.w_in)).astype(np.float32),
                   kernels=rng.standard_normal(
                       (spec.n_kernels, spec.c_in, spec.h_k, spec.w_k)
                   ).astype(np.float32))
