"""Cluster simulation: execute every shard of a ``MultiChipPlan`` through
the existing single-chip machinery and reconcile the plan's accounting.

Each layer materialises ONE shared :class:`ConvLayer` and every shard's
sub-problem is carved out of it — a row band's halo-extended input window
(full kernel set), a kernel subset (full input), or a hybrid band x
kernel-group cell (both slicings at once, the 2-D torus grid) — then run
unchanged through the Sec-6 ``System`` (S1 strategies) or
``sim.s2.run_s2`` (kernel-group swapping).  The shard outputs are
stitched back into the full output tensor and compared against the full
layer's reference convolution, so band offsets, halo extents, and kernel
ranges are validated end to end, not just each shard in isolation.  The
reconciliation discipline matches ``sim.network``:

  * ``correct`` — every shard's functional run passes AND the stitched
    per-layer outputs equal the full reference convolution with no gaps;
  * ``accounting_exact`` — every shard's measured Def-3 duration equals
    the plan's ``gross_duration`` for that shard plus its analytic
    ``pad_saved`` (``same_pad`` edge bands skip padding-row first loads
    the functional simulator still performs), every layer's
    ``compute_duration`` equals the max over its shards, the plan's
    per-layer ICI charges equal an independent re-pricing of the chosen
    mode sequence (``core.multichip.ici_schedule`` — topology-priced
    collectives), and the total recomposes from the *measured* shard
    durations under each stage's own discipline — ``max(compute, ICI)``
    when the layer's ``overlap`` flag is set (the planner proved the
    exchange WAR-free), ``compute + ICI`` otherwise;
  * ``peak_within_budget`` — every shard's *measured* peak stays within
    the per-chip ``size_mem``;
  * ICI transfers themselves are analytic (the bottleneck-link element
    counts are exact integers by construction; there is no functional
    payload to move between simulated chips), exactly as the inter-layer
    reuse savings are analytic in ``sim.network``.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

from repro.core.multichip import MultiChipPlan, ShardPlan, ici_schedule
from repro.core.strategies_s2 import S2Strategy
from repro.sim.functional import reference_conv
from repro.sim.layer import ConvLayer
from repro.sim.s2 import S2Report, run_s2
from repro.sim.system import SimReport, System

LayerReport = Union[SimReport, S2Report]


def carve_shard(full: ConvLayer, shard: ShardPlan) -> ConvLayer:
    """The shard's sub-problem sliced out of the shared layer data: a
    row band's halo-extended window, a kernel subset, or both at once
    (hybrid grid cells)."""
    spec = full.spec
    if shard.out_rows is None and shard.kernel_range is None:
        return full                                # replicate
    inp = full.input
    kernels = full.kernels
    if shard.out_rows is not None:                 # row band window
        r0, _ = shard.out_rows
        h0 = r0 * spec.s_h
        inp = inp[:, h0:h0 + shard.spec.h_in, :]
    if shard.kernel_range is not None:             # kernel subset
        k0, k1 = shard.kernel_range
        kernels = kernels[k0:k1]
    return ConvLayer(spec=shard.spec, input=inp.copy(),
                     kernels=kernels.copy())


_carve_shard = carve_shard        # pre-PR-9 name, kept for callers


def run_shard(full: ConvLayer, shard: ShardPlan, hw, *, check: bool = True,
              retry_at: "dict[int, int] | None" = None,
              backoff_base: float = 16.0) -> LayerReport:
    """Carve ``shard``'s sub-problem out of the shared ``full`` layer and
    execute it through the single-chip machinery — the one execution path
    shared by :func:`simulate_multichip` and the fault-injection engine
    (``repro.resil.engine``), so a faulted re-execution of a shard is the
    same computation, bit for bit, as its fault-free run.

    ``retry_at`` injects transient DMA failures into S1 runs (see
    ``System.run``).  S2 shards take no functional injection — a re-read
    is idempotent either way, so the engine prices their retries
    analytically and only the duration ledger differs.
    """
    layer = carve_shard(full, shard)
    if isinstance(shard.strategy, S2Strategy):
        return run_s2(layer, hw, shard.strategy)
    return System(layer, hw).run(shard.strategy, check=check,
                                 retry_at=retry_at,
                                 backoff_base=backoff_base)


@dataclasses.dataclass
class MultiChipSimReport:
    plan: MultiChipPlan
    shard_reports: list[list[LayerReport]]   # [layer][shard]
    stitched_ok: list[bool]       # per layer: shards reassemble the output
    sim_compute_duration: float   # sum over layers of max-over-chips
    modeled_total_duration: float
    elements_read: int            # HBM traffic summed over all chips
    elements_written: int
    total_macs: int

    @property
    def correct(self) -> bool:
        return all(self.stitched_ok) and all(
            r.correct for reps in self.shard_reports for r in reps)

    @property
    def accounting_exact(self) -> bool:
        """Per-shard sim == plan gross + pad_saved (edge bands' skipped
        padding-row loads are analytic), per-layer compute == max shard,
        the plan's ICI charges match an independent re-pricing, and the
        total recomposes from *measured* shard durations under each
        stage's own discipline (``max(compute, ICI)`` when the layer's
        ``overlap`` flag is set, ``compute + ICI`` otherwise — the
        planner serialises halo exchanges it could not prove WAR-free,
        so the flags can differ across layers of one plan)."""
        total = self.plan.final_gather_duration
        for reps, lp in zip(self.shard_reports, self.plan.layers):
            for r, shard in zip(reps, lp.shards):
                if abs(r.total_duration - shard.pad_saved
                       - shard.gross_duration) > 1e-9:
                    return False
            compute = max(r.total_duration - s.pad_saved
                          for r, s in zip(reps, lp.shards))
            if abs(compute - lp.compute_duration) > 1e-9:
                return False
            if lp.overlap:
                total += max(compute, lp.ici_duration) - lp.savings
            else:
                total += compute + lp.ici_duration - lp.savings
        if abs(total - self.plan.total_duration) > 1e-6:
            return False
        per_layer, final = ici_schedule(
            [lp.spec for lp in self.plan.layers],
            [lp.mode for lp in self.plan.layers],
            [lp.active_chips for lp in self.plan.layers],
            self.plan.cluster)
        if final != self.plan.final_gather_elements:
            return False
        return all(e == lp.ici_elements
                   for e, lp in zip(per_layer, self.plan.layers))

    @property
    def peak_within_budget(self) -> bool:
        """Every shard's measured peak must respect the per-chip budget."""
        cap = self.plan.cluster.chip.size_mem
        if cap is None:
            return True
        return all(
            (r.peak_memory if isinstance(r, S2Report) else r.peak_footprint)
            <= cap for reps in self.shard_reports for r in reps)

    def summary(self) -> str:
        return (f"multichip sim: {self.plan.name} "
                f"chips={self.plan.cluster.n_chips} "
                f"layers={len(self.shard_reports)} correct={self.correct} "
                f"accounting_exact={self.accounting_exact} "
                f"peak_within_budget={self.peak_within_budget} "
                f"sim_compute={self.sim_compute_duration:g} "
                f"modeled_total={self.modeled_total_duration:g} "
                f"dram_rd={self.elements_read} dram_wr={self.elements_written}")


def simulate_multichip(plan: MultiChipPlan, seed: int = 0,
                       check: bool = True) -> MultiChipSimReport:
    """Run every shard of every layer functionally — against ONE shared
    layer instance per layer — stitch the shard outputs, and cross-check
    the cluster duration model (see the module note for the discipline)."""
    hw = plan.cluster.chip
    shard_reports: list[list[LayerReport]] = []
    stitched_ok: list[bool] = []
    for lp in plan.layers:
        full = ConvLayer.random(lp.spec, seed=seed + lp.index)
        ref = reference_conv(full)
        assembled = np.full_like(ref, np.nan)
        reps: list[LayerReport] = []
        for shard in lp.shards:
            rep = run_shard(full, shard, hw, check=check)
            reps.append(rep)
            rows = slice(None) if shard.out_rows is None else \
                slice(*shard.out_rows)
            kers = slice(None) if shard.kernel_range is None else \
                slice(*shard.kernel_range)
            assembled[kers, rows, :] = rep.output
        stitched_ok.append(
            not np.any(np.isnan(assembled)) and bool(
                np.allclose(assembled, ref, rtol=1e-4, atol=1e-4)))
        shard_reports.append(reps)
    return MultiChipSimReport(
        plan=plan,
        shard_reports=shard_reports,
        stitched_ok=stitched_ok,
        sim_compute_duration=sum(max(r.total_duration for r in reps)
                                 for reps in shard_reports),
        modeled_total_duration=plan.total_duration,
        elements_read=sum(r.elements_read
                          for reps in shard_reports for r in reps),
        elements_written=sum(r.elements_written
                             for reps in shard_reports for r in reps),
        total_macs=sum(r.total_macs
                       for reps in shard_reports for r in reps))
