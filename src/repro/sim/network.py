"""Whole-network functional simulation (Sec 6 extended to layer sequences).

Executes every layer of a ``NetworkPlan`` through the matching functional
simulator — the Sec-6 ``System`` for S1 strategies, ``sim.s2.run_s2`` for
S2 kernel-group-swapping strategies — with real values convolved, outputs
checked against the reference convolution, and the measured Def-3
durations reconciled with the plan's accounting.  Layers are materialised
independently (the pooling/stride adapters between network layers are
outside the paper's formalism), so the simulator validates the *per-layer*
schedules exactly and the inter-layer reuse terms analytically:

    sum(sim layer durations) == plan.gross_duration      (exact)
    plan.total_duration = gross - sum(reuse savings)     (by construction)
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.core.network_planner import NetworkPlan
from repro.core.strategies_s2 import S2Strategy
from repro.sim.layer import ConvLayer
from repro.sim.s2 import S2Report, run_s2
from repro.sim.system import SimReport, System

LayerReport = Union[SimReport, S2Report]


@dataclasses.dataclass
class NetworkSimReport:
    plan: NetworkPlan
    layer_reports: list[LayerReport]
    sim_gross_duration: float     # measured, no inter-layer reuse
    modeled_total_duration: float  # plan's prediction, with reuse
    elements_read: int
    elements_written: int
    total_macs: int

    @property
    def correct(self) -> bool:
        return all(r.correct for r in self.layer_reports)

    @property
    def accounting_exact(self) -> bool:
        """Plan gross duration must equal the simulator's, per layer."""
        return all(
            abs(r.total_duration - lp.gross_duration) < 1e-9
            for r, lp in zip(self.layer_reports, self.plan.layers))

    @property
    def peak_within_budget(self) -> bool:
        """Every layer's measured peak must respect ``hw.size_mem``."""
        cap = self.plan.hw.size_mem
        if cap is None:
            return True
        return all(
            (r.peak_memory if isinstance(r, S2Report) else r.peak_footprint)
            <= cap for r in self.layer_reports)

    def summary(self) -> str:
        return (f"network sim: {self.plan.name} "
                f"layers={len(self.layer_reports)} correct={self.correct} "
                f"accounting_exact={self.accounting_exact} "
                f"sim_gross={self.sim_gross_duration:g} "
                f"modeled_total={self.modeled_total_duration:g} "
                f"dram_rd={self.elements_read} dram_wr={self.elements_written}")


def simulate_network(plan: NetworkPlan, seed: int = 0,
                     check: bool = True) -> NetworkSimReport:
    """Run every planned layer strategy functionally and cross-check the
    plan's duration model against the simulator.  S2 layers (the tight
    memory fallback) run through the kernel-swapping executor."""
    reports: list[LayerReport] = []
    for lp in plan.layers:
        layer = ConvLayer.random(lp.spec, seed=seed + lp.index)
        if isinstance(lp.strategy, S2Strategy):
            reports.append(run_s2(layer, plan.hw, lp.strategy))
        else:
            reports.append(System(layer, plan.hw).run(lp.strategy,
                                                      check=check))
    return NetworkSimReport(
        plan=plan,
        layer_reports=reports,
        sim_gross_duration=sum(r.total_duration for r in reports),
        modeled_total_duration=plan.total_duration,
        elements_read=sum(r.elements_read for r in reports),
        elements_written=sum(r.elements_written for r in reports),
        total_macs=sum(r.total_macs for r in reports))
