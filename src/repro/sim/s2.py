"""Functional executor for S2 strategies (kernel-subset steps).

Outputs are per-(patch, kernel) scalars accumulated in a DRAM output
buffer; the final tensor must equal the reference convolution exactly —
the same functional-simulation contract as the S1 System, at the finer
granularity."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost_model import HardwareModel
from repro.core.strategies_s2 import S2Strategy
from repro.sim.functional import reference_conv
from repro.sim.layer import ConvLayer
from repro.sim.trace import StepTrace


@dataclasses.dataclass
class S2Report:
    output: np.ndarray
    correct: bool
    max_abs_err: float
    total_duration: float
    peak_memory: int
    elements_read: int
    elements_written: int
    kernel_loads: int         # total kernel fetch events (reload pressure)
    total_macs: int = 0
    traces: list[StepTrace] = dataclasses.field(default_factory=list)
    #   measured per-step lane breakdown, aligned 1:1 with the
    #   strategy's to_steps() (schedule iterations + terminal flush)


def run_s2(layer: ConvLayer, hw: HardwareModel,
           strategy: S2Strategy) -> S2Report:
    spec = layer.spec
    if not (spec is strategy.spec or spec == strategy.spec):
        raise ValueError("strategy spec does not match layer spec")
    kelem = spec.c_in * spec.h_k * spec.w_k
    out = np.full((spec.c_out, spec.h_out, spec.w_out), np.nan, np.float32)
    written = np.zeros((spec.c_out, spec.h_out, spec.w_out), bool)

    pixels: dict[int, np.ndarray] = {}
    kernels: dict[int, np.ndarray] = {}
    pending: dict[tuple[int, int], float] = {}   # (pid, kid) -> value
    reads = writes = kernel_loads = total_macs = 0
    duration = 0.0
    peak = 0
    # formal step view of the same schedule, for the per-step trace
    # ledger (to_steps() is the Def-16 lowering the planner prices)
    steps = strategy.to_steps()
    traces: list[StepTrace] = []

    def write_back(cells):
        nonlocal writes
        for (pid, kid), val in cells.items():
            i, j = spec.patch_pos(pid)
            if written[kid, i, j]:
                raise RuntimeError(f"output {(pid, kid)} written twice")
            out[kid, i, j] = val
            written[kid, i, j] = True
            writes += 1

    for step_idx, (g, kg) in enumerate(strategy.schedule):
        kids = strategy.kernel_groups[kg]
        need_pix = set(spec.pixels_of_mask(spec.group_mask(g)))
        # a1/a2: eager frees
        for j in list(pixels):
            if j not in need_pix:
                del pixels[j]
        for kid in list(kernels):
            if kid not in kids:
                del kernels[kid]
        # a3: write back the previous step's cells
        write_back(pending)
        dur_w = len(pending) * hw.t_w
        n_cells_written = len(pending)
        pending = {}
        # a4/a5: loads
        n_pix_loads = 0
        for j in need_pix:
            if j not in pixels:
                h, w = spec.pixel_pos(j)
                pixels[j] = layer.input[:, h, w]
                reads += spec.c_in
                n_pix_loads += 1
        n_ker_loads = 0
        for kid in kids:
            if kid not in kernels:
                kernels[kid] = layer.kernels[kid]
                reads += kelem
                n_ker_loads += 1
                kernel_loads += 1
        # a6: compute the (patch x kernel-subset) cells
        macs = len(g) * spec.nb_op_value * len(kids)
        if macs > hw.nbop_pe:
            raise RuntimeError(f"PE overrun: {macs} > {hw.nbop_pe}")
        total_macs += macs
        for pid in g:
            h0, w0, h1, w1 = spec.patch_bbox(pid)
            patch = np.stack([pixels[spec.pixel_id(h, w)]
                              for h in range(h0, h1)
                              for w in range(w0, w1)], axis=1)
            patch = patch.reshape(spec.c_in, spec.h_k, spec.w_k)
            for kid in kids:
                pending[(pid, kid)] = float(
                    np.einsum("chw,chw->", kernels[kid], patch))
        used = (len(pixels) * spec.c_in + len(kernels) * kelem
                + len(pending))
        if hw.size_mem is not None and used > hw.size_mem:
            raise MemoryError(f"on-chip overflow: {used} > {hw.size_mem}")
        peak = max(peak, used)
        dur_l = (n_pix_loads + n_ker_loads * kelem) * hw.t_l
        duration += dur_l + dur_w + hw.t_acc
        traces.append(StepTrace(
            index=step_idx, step=steps[step_idx], mem_elements=used,
            duration=dur_l + dur_w + hw.t_acc,
            load_duration=dur_l, write_duration=dur_w,
            compute_duration=hw.t_acc,
            read_elements=n_pix_loads * spec.c_in + n_ker_loads * kelem,
            written_elements=n_cells_written))
    write_back(pending)
    flush_dur = len(pending) * hw.t_w
    duration += flush_dur
    traces.append(StepTrace(
        index=len(strategy.schedule), step=steps[-1], mem_elements=0,
        duration=flush_dur, write_duration=flush_dur,
        written_elements=len(pending)))

    ref = reference_conv(layer)
    ok = bool(written.all()) and bool(
        np.allclose(out, ref, rtol=1e-4, atol=1e-4))
    err = float(np.max(np.abs(out - ref))) if written.all() else float("nan")
    return S2Report(output=out, correct=ok, max_abs_err=err,
                    total_duration=duration, peak_memory=peak,
                    elements_read=reads, elements_written=writes,
                    kernel_loads=kernel_loads, total_macs=total_macs,
                    traces=traces)
