"""System orchestrator (paper Sec 6, Fig 10).

At each step the system 1) reads the current step from the strategy, 2) frees
the unnecessary elements in the on-chip memory, 3) writes the results to the
DRAM, 4) loads the necessary elements from DRAM to on-chip memory,
5) triggers the accelerator, 6) loops.  Alongside the functional execution it
re-runs the *formal* semantics (`repro.core.formalism`) and asserts both
agree on the memory state at every step."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import MemoryState, Step, apply_step
from repro.core.strategies import GroupedStrategy
from repro.sim.accelerator import Accelerator
from repro.sim.dram import Dram
from repro.sim.functional import reference_conv
from repro.sim.layer import ConvLayer
from repro.sim.trace import StepTrace


class StateMismatchError(RuntimeError):
    """Formal step semantics (Def 2) disagreed with the functional memory
    model mid-run — always a simulator or strategy-lowering bug."""


@dataclasses.dataclass
class SimReport:
    output: np.ndarray
    correct: bool
    max_abs_err: float
    total_duration: float
    peak_footprint: int
    elements_read: int
    elements_written: int
    total_macs: int
    traces: list[StepTrace]
    retry_duration: float = 0.0   # injected DMA retries (repro.resil):
    retry_elements: int = 0       # included in total_duration /
    #   elements_read; zero on every fault-free run

    def summary(self) -> str:
        return (f"steps={len(self.traces)} duration={self.total_duration:g} "
                f"peak_mem={self.peak_footprint} "
                f"dram_rd={self.elements_read} dram_wr={self.elements_written} "
                f"macs={self.total_macs} correct={self.correct} "
                f"(max_err={self.max_abs_err:.2e})")


class System:
    """Executes a strategy (user-defined or solver-produced) functionally."""

    def __init__(self, layer: ConvLayer, hw: HardwareModel):
        self.layer = layer
        self.hw = hw

    def run(self, strategy: GroupedStrategy | list[Step],
            check: bool = True,
            retry_at: "dict[int, int] | None" = None,
            backoff_base: float = 16.0) -> SimReport:
        """Execute the strategy step by step.

        ``retry_at`` injects transient DMA failures (``repro.resil``):
        step index -> number of failed attempts before the load
        succeeds.  Each retry re-issues the step's DRAM reads (reads are
        idempotent — the fetched values are identical, so the output is
        unchanged) and waits ``backoff_base * 2**(attempt-1)`` cycles;
        the extra duration and re-read elements are recorded on the
        step's trace and in ``SimReport.retry_duration`` /
        ``retry_elements``, on top of the fault-free Def-3 ledger.
        """
        spec = self.layer.spec
        steps = (strategy.to_steps()
                 if isinstance(strategy, GroupedStrategy) else strategy)
        retry_at = retry_at or {}
        dram = Dram(self.layer)
        acc = Accelerator(spec, self.hw)
        formal = MemoryState()
        traces: list[StepTrace] = []
        total_duration = 0.0
        peak = 0
        for idx, s in enumerate(steps):
            read0, written0 = dram.elements_read, dram.elements_written
            # 2) free
            acc.mem.free_pixels(spec.pixels_of_mask(s.f_inp))
            acc.mem.free_kernels(spec.pixels_of_mask(s.f_ker))
            # 3) write back
            n_wb = 0
            for pid, vals in acc.mem.pop_outputs(
                    spec.pixels_of_mask(s.w)).items():
                dram.write_output(pid, vals)
                n_wb += 1
            # 4) load
            n_pix = n_ker = 0
            for j in spec.pixels_of_mask(s.i_slice):
                h, w = spec.pixel_pos(j)
                acc.mem.store_pixel(j, dram.read_pixel(h, w))
                n_pix += 1
            for k in spec.pixels_of_mask(s.k_sub):
                acc.mem.store_kernel(k, dram.read_kernel(k))
                n_ker += 1
            peak = max(peak, acc.mem.used)
            acc.mem.check_capacity()
            # 5) compute
            if s.computes:
                acc.compute(s.group)
                peak = max(peak, acc.mem.used)
                acc.mem.check_capacity()
            # formal semantics must agree with the functional memory state
            formal = apply_step(formal, s)
            if set(spec.pixels_of_mask(formal.inp)) != set(acc.mem.pixels):
                raise StateMismatchError(f"step {idx}: input state mismatch")
            if set(spec.pixels_of_mask(formal.ker)) != set(acc.mem.kernels):
                raise StateMismatchError(f"step {idx}: kernel state mismatch")
            if set(spec.pixels_of_mask(formal.out)) != set(acc.mem.outputs):
                raise StateMismatchError(f"step {idx}: output state mismatch")
            # measured lane breakdown (Def-3 a3 -> a4/a5 -> a6), counted
            # from what the system actually did — NOT recomputed from the
            # plan, so the obs drift report compares independent numbers
            kelem = spec.c_in * spec.h_k * spec.w_k
            write_dur = n_wb * self.hw.t_w
            load_dur = (n_pix + n_ker * kelem) * self.hw.t_l
            acc_dur = self.hw.t_acc if s.computes else 0.0
            # injected transient DMA failures: re-issue this step's reads
            # (idempotent — values discarded, the resident copies stand)
            # and pay exponential backoff per failed attempt
            n_retries = retry_at.get(idx, 0)
            retry_dur = 0.0
            retry_read0 = dram.elements_read
            for attempt in range(1, n_retries + 1):
                for j in spec.pixels_of_mask(s.i_slice):
                    h, w = spec.pixel_pos(j)
                    dram.read_pixel(h, w)
                for k in spec.pixels_of_mask(s.k_sub):
                    dram.read_kernel(k)
                retry_dur += load_dur + backoff_base * 2 ** (attempt - 1)
            retry_elems = dram.elements_read - retry_read0
            total_duration += write_dur + load_dur + acc_dur + retry_dur
            traces.append(StepTrace(
                index=idx, step=s, mem_elements=acc.mem.used,
                duration=write_dur + load_dur + acc_dur + retry_dur,
                load_duration=load_dur, write_duration=write_dur,
                compute_duration=acc_dur,
                read_elements=dram.elements_read - read0,
                written_elements=dram.elements_written - written0,
                retries=n_retries, retry_duration=retry_dur,
                retry_elements=retry_elems))

        max_err = 0.0
        ok = True
        if check:
            ref = reference_conv(self.layer)
            if np.any(np.isnan(dram.output)):
                ok = False
                max_err = float("nan")
            else:
                max_err = float(np.max(np.abs(dram.output - ref)))
                ok = bool(np.allclose(dram.output, ref, rtol=1e-4,
                                      atol=1e-4))
        return SimReport(
            output=dram.output, correct=ok, max_abs_err=max_err,
            total_duration=total_duration,
            peak_footprint=peak,
            elements_read=dram.elements_read,
            elements_written=dram.elements_written,
            total_macs=acc.total_macs,
            traces=traces,
            retry_duration=sum(t.retry_duration for t in traces),
            retry_elements=sum(t.retry_elements for t in traces))
