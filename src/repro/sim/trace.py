"""Step-by-step trace + visualisation (paper Sec 6 / Fig 9), on the
shared timeline-event model of :mod:`repro.obs.events`.

:class:`StepTrace` is what the functional simulators *measure* per step
— lane-decomposed durations (write-back / DMA-in / compute, the Def-3
a3 -> a4/a5 -> a6 order) and DRAM element counts — the raw material the
``repro.obs`` adapters turn into timelines and the drift report
reconciles against the plan's predictions.

The ASCII renderers consume timeline *spans* (``compute`` spans carry
the step's patch group, ``dma_in`` spans its I_slice bitmask), so they
render any span source — a strategy, a simulator run, a sliced multichip
shard — and degrade gracefully on *partial* schedules: output positions
no compute span claims render as ``"?"`` padded to the same cell width
as assigned ones.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import Step
from repro.core.strategies import GroupedStrategy
from repro.obs.events import Span, Timeline


@dataclasses.dataclass
class StepTrace:
    """One simulated step's measured lane breakdown."""

    index: int
    step: Step
    mem_elements: int
    duration: float
    load_duration: float = 0.0
    write_duration: float = 0.0
    compute_duration: float = 0.0
    read_elements: int = 0
    written_elements: int = 0
    retries: int = 0                 # injected DMA transients (repro.resil)
    retry_duration: float = 0.0      # re-issued loads + exponential backoff
    retry_elements: int = 0          # elements re-read by the retries

    def describe(self, spec: ConvSpec) -> str:
        s = self.step
        retry = (f" + retry {self.retry_duration:g}x{self.retries}"
                 if self.retries else "")
        return (f"step {self.index:3d}: "
                f"free_inp={s.f_inp.bit_count():3d} "
                f"free_ker={s.f_ker.bit_count():2d} "
                f"write={s.w.bit_count():3d} "
                f"load_inp={s.i_slice.bit_count():3d} "
                f"load_ker={s.k_sub.bit_count():2d} "
                f"compute={len(s.group):3d}p "
                f"mem={self.mem_elements:5d} dur={self.duration:g} "
                f"(wb {self.write_duration:g} + dma {self.load_duration:g}"
                f" + acc {self.compute_duration:g}{retry})")


# --------------------------------------------------------------------- #
# Strategy -> timeline (rendering-grade; the obs adapters build the
# fully-attributed planning/simulation timelines)
# --------------------------------------------------------------------- #

def strategy_timeline(strategy, hw: HardwareModel | None = None, *,
                      chip: int = 0, layer: int | None = None,
                      label: str | None = None) -> Timeline:
    """Lower any strategy (S1 ``GroupedStrategy`` or S2) to a timeline
    via its Def-3 step sequence.  ``hw`` defaults to the unit cost model
    (t_l = t_w = t_acc = 1), which is all the renderers need."""
    hw = hw or HardwareModel(nbop_pe=1)
    tl = Timeline(label or getattr(strategy, "name", "strategy"))
    kernel_groups = getattr(strategy, "kernel_groups", None)
    t = 0.0
    for idx, s in enumerate(strategy.to_steps()):
        t = tl.add_step(s, strategy.spec, hw, chip=chip, layer=layer,
                        index=idx, t0=t, kernel_groups=kernel_groups)
    return tl


# --------------------------------------------------------------------- #
# ASCII renderers (paper Fig 9 analogues), span-driven
# --------------------------------------------------------------------- #

def render_spans_group_grid(spans: Iterable[Span], spec: ConvSpec, *,
                            title: str) -> str:
    """Each output position labelled by the step whose ``compute`` span
    claims it; positions no span claims render ``"?"`` at the same cell
    width (partial schedules — e.g. one chip's row band of a sliced
    layer — stay legible)."""
    compute = [s for s in spans if s.lane == "compute"]
    n_steps = max((0 if s.step is None else s.step for s in compute),
                  default=0) + 1
    cell = max(2, len(str(max(1, n_steps - 1))))
    grid = [["?" for _ in range(spec.w_out)] for _ in range(spec.h_out)]
    for s in compute:
        for pid in s.attrs.get("group", ()):
            i, j = spec.patch_pos(pid)
            grid[i][j] = str(s.step if s.step is not None else "?")
    lines = [title]
    for row in grid:
        lines.append(" ".join(v.rjust(cell) for v in row))
    return "\n".join(lines)


def render_spans_input_heatmap(spans: Iterable[Span], spec: ConvSpec, *,
                               title: str) -> str:
    """Input-pixel load counts accumulated from the ``dma_in`` spans'
    I_slice masks (reload pressure visualisation)."""
    loads: dict[int, int] = {}
    for s in spans:
        if s.lane != "dma_in":
            continue
        mask = s.attrs.get("i_slice", 0)
        while mask:
            low = mask & -mask
            j = low.bit_length() - 1
            loads[j] = loads.get(j, 0) + 1
            mask ^= low
    lines = [title]
    for h in range(spec.h_in):
        lines.append(" ".join(
            str(loads.get(spec.pixel_id(h, w), 0))
            for w in range(spec.w_in)))
    return "\n".join(lines)


def render_group_grid(strategy: GroupedStrategy) -> str:
    """ASCII analogue of the paper's Fig 9: each output position labelled
    by the step (group) that computes it."""
    tl = strategy_timeline(strategy)
    return render_spans_group_grid(
        tl.spans, strategy.spec,
        title=f"strategy={strategy.name} groups={strategy.n_steps} "
              f"(output grid, value = computing step)")


def render_input_heatmap(strategy: GroupedStrategy) -> str:
    """Input-pixel load counts (reload pressure visualisation)."""
    tl = strategy_timeline(strategy)
    return render_spans_input_heatmap(
        tl.spans, strategy.spec,
        title=f"input load counts (H_in x W_in), strategy={strategy.name}")
