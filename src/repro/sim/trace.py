"""Step-by-step trace + visualisation (paper Sec 6 / Fig 9)."""
from __future__ import annotations

import dataclasses

from repro.core.conv_spec import ConvSpec
from repro.core.formalism import Step
from repro.core.strategies import GroupedStrategy


@dataclasses.dataclass
class StepTrace:
    index: int
    step: Step
    mem_elements: int
    duration: float

    def describe(self, spec: ConvSpec) -> str:
        s = self.step
        return (f"step {self.index:3d}: "
                f"free_inp={s.f_inp.bit_count():3d} "
                f"free_ker={s.f_ker.bit_count():2d} "
                f"write={s.w.bit_count():3d} "
                f"load_inp={s.i_slice.bit_count():3d} "
                f"load_ker={s.k_sub.bit_count():2d} "
                f"compute={len(s.group):3d}p "
                f"mem={self.mem_elements:5d} dur={self.duration:g}")


def render_group_grid(strategy: GroupedStrategy) -> str:
    """ASCII analogue of the paper's Fig 9: each output position labelled by
    the step (group) that computes it."""
    spec = strategy.spec
    cell = max(2, len(str(strategy.n_steps - 1)))
    grid = [["?" * 1 for _ in range(spec.w_out)] for _ in range(spec.h_out)]
    for k, g in enumerate(strategy.groups):
        for pid in g:
            i, j = spec.patch_pos(pid)
            grid[i][j] = str(k)
    lines = [f"strategy={strategy.name} groups={strategy.n_steps} "
             f"(output grid, value = computing step)"]
    for row in grid:
        lines.append(" ".join(v.rjust(cell) for v in row))
    return "\n".join(lines)


def render_input_heatmap(strategy: GroupedStrategy) -> str:
    """Input-pixel load counts (reload pressure visualisation)."""
    spec = strategy.spec
    loads = strategy.loads_per_pixel()
    lines = [f"input load counts (H_in x W_in), strategy={strategy.name}"]
    for h in range(spec.h_in):
        lines.append(" ".join(
            str(loads.get(spec.pixel_id(h, w), 0)) for w in range(spec.w_in)))
    return "\n".join(lines)
