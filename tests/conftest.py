"""Shared test configuration.

Tier-1 speed: paper-sized polish budgets (30k iters x multi-restart)
dominate solver-test wall-clock without changing any assertion — every
solver assertion is an inequality against a seed or bound that holds for
any iteration count.  An autouse fixture therefore caps the polish budget
reaching ``solver.solve`` / ``solver.polish``; set ``REPRO_FULL_POLISH=1``
to run the paper-sized budgets.  Profile the suite with
``pytest -q --durations=10``.
"""
import os

import pytest

# Every plan the suite builds is statically verified (ISSUE 6): the
# planners re-check their own output against repro.analysis.verifier and
# raise PlanVerificationError on any error-severity diagnostic.
os.environ.setdefault("REPRO_VERIFY_PLANS", "1")

from repro.core import solver, strategies_s2  # noqa: E402

_MAX_ITERS = 1_500
_MAX_RESTARTS = 2
_MAX_S2_ITERS = 400


@pytest.fixture(autouse=True)
def _fast_polish(monkeypatch):
    """Cap polish iterations/restarts for every solver entry point (the
    LRU-cached paths call the module globals, so they are capped too)."""
    if os.environ.get("REPRO_FULL_POLISH"):
        yield
        return

    orig_solve = solver.solve

    def capped_solve(spec, p, hw, nb_data_reload=2, size_mem=None,
                     time_limit=30.0, polish_iters=30_000,
                     milp_var_limit=60_000, use_milp=True, rng_seed=0,
                     polish_restarts=1, polish_workers=None):
        return orig_solve(
            spec, p, hw, nb_data_reload=nb_data_reload, size_mem=size_mem,
            time_limit=time_limit,
            polish_iters=min(polish_iters, _MAX_ITERS),
            milp_var_limit=milp_var_limit, use_milp=use_milp,
            rng_seed=rng_seed,
            polish_restarts=min(polish_restarts, _MAX_RESTARTS),
            polish_workers=polish_workers)

    orig_polish = solver.polish

    def capped_polish(seed, p, hw, nb_data_reload=2, iters=30_000,
                      rng_seed=0):
        return orig_polish(seed, p, hw, nb_data_reload,
                           iters=min(iters, _MAX_ITERS), rng_seed=rng_seed)

    monkeypatch.setattr(solver, "solve", capped_solve)
    monkeypatch.setattr(solver, "polish", capped_polish)
    monkeypatch.setattr(
        strategies_s2, "DEFAULT_POLISH_ITERS",
        min(strategies_s2.DEFAULT_POLISH_ITERS, _MAX_S2_ITERS))
    yield


@pytest.fixture(scope="session", autouse=True)
def _shutdown_polish_pools():
    """Join the long-lived polish process pools at session end so pytest
    exits promptly (also registered via atexit in repro.core.solver)."""
    yield
    solver.shutdown_pools()
