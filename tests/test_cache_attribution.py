"""Per-stage cache attribution (ISSUE 10 satellite): interleaved
planning stages must each report their own solve window — the
single-chip baseline no longer claims (or is claimed by) the
multichip DP's hits, and degraded re-plans carry their own counters."""
import dataclasses

from repro.configs.clusters import make_cluster
from repro.configs.networks import NETWORKS
from repro.core import solver
from repro.core.cost_model import HardwareModel
from repro.core.multichip import plan_multichip_network
from repro.core.network_planner import plan_network
from repro.obs.metrics import REGISTRY
from repro.resil.engine import RecoveryAction, run_faulted
from repro.resil.faults import ChipDeath, FaultSchedule

FAST = dict(polish_iters=60, polish_restarts=1)

STAGES = ("solve", "refine", "baseline", "multichip", "single_baseline",
          "resil_replan")


def _stage_snapshot():
    return {s: (REGISTRY.get(f"planner/stage/{s}/calls"),
                REGISTRY.get(f"planner/stage/{s}/hits"))
            for s in STAGES}


def _delta(before, after):
    return {s: (after[s][0] - before[s][0], after[s][1] - before[s][1])
            for s in STAGES}


def test_multichip_attribution_excludes_single_baseline():
    """plan.solver_calls / plan.cache_hits must be the DP's own window;
    the single-chip baseline's solves land in their own stage counter
    instead of inflating (or stealing hits from) the DP's."""
    specs = NETWORKS["tight2"]
    size_mem = max(s.kernel_elements for s in specs) // 2
    cluster = make_cluster(2, size_mem=size_mem)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    before = _stage_snapshot()
    plan = plan_multichip_network(specs, cluster, name="tight2",
                                  include_single_chip_baseline=True,
                                  verify=False, **FAST)
    d = _delta(before, _stage_snapshot())
    assert d["multichip"] == (plan.solver_calls, plan.cache_hits)
    assert plan.solver_calls >= 1
    # the baseline ran, and its window is separate from the DP's
    assert d["single_baseline"][0] >= 1
    assert plan.single_chip_duration is not None


def test_network_planner_stage_split_sums_to_plan_totals():
    """The solve pass and the refinement loop each get a delta window;
    their sum is exactly what the plan reports, and the S2 baseline
    stage is tracked on its own axis."""
    specs = NETWORKS["tight2"]
    hw = HardwareModel(nbop_pe=10 ** 9,
                       size_mem=max(s.kernel_elements for s in specs) * 2)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    before = _stage_snapshot()
    plan = plan_network(specs, hw, name="tight2", **FAST)
    d = _delta(before, _stage_snapshot())
    assert d["solve"][0] + d["refine"][0] == plan.solver_calls
    assert d["solve"][1] + d["refine"][1] == plan.cache_hits
    assert d["solve"][0] == len(specs)
    # the DP/baseline stages of *other* planners stayed silent
    assert d["multichip"] == (0, 0) and d["single_baseline"] == (0, 0)


def test_recovery_action_carries_its_own_solver_window():
    """A chip death forces a degraded re-plan; the RecoveryAction must
    report that re-plan's own solver calls, not the run's cumulative
    planner traffic."""
    fields = {f.name for f in dataclasses.fields(RecoveryAction)}
    assert {"solver_calls", "cache_hits"} <= fields
    specs = NETWORKS["tight2"]
    size_mem = max(s.kernel_elements for s in specs) // 2
    cluster = make_cluster(2, size_mem=size_mem)
    before = _stage_snapshot()
    rep = run_faulted(specs, cluster,
                      FaultSchedule(seed=0, events=(
                          ChipDeath(layer=1, chip=1),)),
                      name="tight2", **FAST)
    d = _delta(before, _stage_snapshot())
    replans = [r for r in rep.recoveries if r.kind == "chip_death"]
    assert replans
    assert sum(r.solver_calls for r in replans) == d["resil_replan"][0]
    assert sum(r.cache_hits for r in replans) == d["resil_replan"][1]
    assert all(r.solver_calls >= 1 for r in replans)
