"""End-to-end dry-run integration: run the actual dryrun module in a
subprocess (it must own jax initialisation for the 512-device flag) on the
two cheapest cells and validate the JSON contract §Roofline consumes."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape,multi_pod", [
    ("tinyllama-1.1b", "decode_32k", False),
    ("mamba2-2.7b", "long_500k", True),
])
def test_dryrun_cell_subprocess(tmp_path, arch, shape, multi_pod):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", str(tmp_path)]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=1200)
    assert r.returncode == 0, r.stderr[-2000:]
    tag = "pod" if multi_pod else "single"
    path = tmp_path / f"{arch}_{shape}_{tag}.json"
    with open(path) as f:
        cell = json.load(f)
    assert cell["status"] == "ok"
    assert cell["chips"] == (512 if multi_pod else 256)
    a = cell["analyzed"]
    assert a["matmul_flops_per_device"] > 0
    assert a["bytes_accessed_per_device"] > a["matmul_flops_per_device"] * 0
    assert a["unknown_trip_loops"] == 0
    assert cell["memory"]["peak_device_bytes"] > 0
    # §Roofline must be derivable from the JSON
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    import roofline
    row = roofline.derive(cell)
    assert row["dominant"] in ("compute", "memory", "collective")
    assert 0.0 <= row["roofline_fraction"] <= 1.0


def test_dryrun_skip_cell(tmp_path):
    """long_500k on a full-attention arch must produce a SKIP record."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "tinyllama-1.1b", "--shape", "long_500k",
           "--out", str(tmp_path)]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0
    with open(tmp_path / "tinyllama-1.1b_long_500k_single.json") as f:
        cell = json.load(f)
    assert cell["status"] == "skipped"
    assert "sub-quadratic" in cell["reason"]
