"""Plan -> kernel emission (ISSUE 7): ``as_grid`` recognises exactly the
uniform sweep strategies, ``grid_solve`` only returns kernel-feasible
plans, ``emit_layer_kernel`` refuses what no kernel realises, and every
emitted layer of the registered networks executes (interpret mode) to
the reference convolution."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.networks import NETWORKS
from repro.core.conv_spec import ConvSpec
from repro.core.strategies import row_by_row, tiled, zigzag
from repro.kernels import ref
from repro.kernels.emit import (
    KernelEmitError, emit_layer_kernel, grid_solve, kernel_vmem_elements,
    plan_emitable_network)

RNG = np.random.default_rng(7)
SPEC = ConvSpec(2, 10, 12, 3, 3, 3)


# --------------------------------------------------------------------- #
# Strategy -> grid recognition
# --------------------------------------------------------------------- #

def test_as_grid_recognises_zigzag_and_row_sweeps():
    for t in (2, 5, SPEC.w_out):
        meta = zigzag(SPEC, t).as_grid()
        assert meta is not None
        assert (meta.t_run, meta.h_out, meta.w_out_tiles) == \
            (t, SPEC.h_out, SPEC.w_out // t)
        assert meta.order == "zigzag"
        assert meta.grid == (SPEC.h_out, SPEC.w_out // t)
    meta = row_by_row(SPEC, 5).as_grid()
    assert meta is not None and meta.order == "row"


def test_as_grid_rejects_non_grid_strategies():
    assert tiled(SPEC, 6).as_grid() is None            # 2-D tiles
    assert zigzag(SPEC, 7).as_grid() is None           # 7 does not divide 12
    zz = zigzag(SPEC, 4)
    shuffled = dataclasses.replace(
        zz, groups=list(reversed(zz.groups)))
    assert shuffled.as_grid() is None                  # right runs, bad order


# --------------------------------------------------------------------- #
# Emitable solving
# --------------------------------------------------------------------- #

def test_grid_solve_respects_kernel_vmem_budget():
    from repro.core.cost_model import HardwareModel
    tight = HardwareModel(nbop_pe=1 << 20,
                          size_mem=kernel_vmem_elements(SPEC, 2))
    res = grid_solve(SPEC, 10, tight)
    meta = res.strategy.as_grid()
    assert meta is not None
    assert kernel_vmem_elements(SPEC, meta.t_run) <= tight.size_mem
    roomy = HardwareModel(nbop_pe=1 << 20, size_mem=10 ** 9)
    wide = grid_solve(SPEC, SPEC.w_out, roomy)
    assert wide.objective <= res.objective


def test_grid_solve_raises_when_nothing_fits():
    from repro.core.cost_model import HardwareModel
    hw = HardwareModel(nbop_pe=1 << 20,
                       size_mem=kernel_vmem_elements(SPEC, 1) - 1)
    with pytest.raises(ValueError, match="no emitable"):
        grid_solve(SPEC, 4, hw)


# --------------------------------------------------------------------- #
# Emission refusals
# --------------------------------------------------------------------- #

def _planned_layer(spec=SPEC):
    from repro.core.cost_model import HardwareModel
    hw = HardwareModel(nbop_pe=1 << 20,
                       size_mem=kernel_vmem_elements(spec, spec.w_out))
    plan = plan_emitable_network([spec], hw, name="one")
    return plan.layers[0]


def test_emit_refuses_s2_plans():
    lp = _planned_layer()
    bad = dataclasses.replace(
        lp, result=dataclasses.replace(lp.result, mode="s2"))
    with pytest.raises(KernelEmitError, match="swapping"):
        emit_layer_kernel(bad)


def test_emit_refuses_non_grid_strategies():
    lp = _planned_layer()
    bad = dataclasses.replace(
        lp, result=dataclasses.replace(lp.result, strategy=tiled(SPEC, 6)))
    with pytest.raises(KernelEmitError, match="not a uniform grid"):
        emit_layer_kernel(bad)


def test_emit_refuses_row_order_with_overlapping_rows():
    lp = _planned_layer()
    bad = dataclasses.replace(
        lp, result=dataclasses.replace(lp.result,
                                       strategy=row_by_row(SPEC, 5)))
    with pytest.raises(KernelEmitError, match="row-order"):
        emit_layer_kernel(bad)


def test_emit_allows_row_order_single_tile():
    spec = ConvSpec(1, 8, 6, 2, 3, 3)        # w_out == 4, one tile of 4
    lp = _planned_layer(spec)
    row = dataclasses.replace(
        lp, result=dataclasses.replace(lp.result,
                                       strategy=row_by_row(spec, 4)))
    emitted = emit_layer_kernel(row)
    assert emitted.order in ("zigzag", "row")
    assert emitted.t_run == 4


# --------------------------------------------------------------------- #
# End to end: emitted kernels reproduce the reference convolution
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("name", ["lenet5", "tight2", "tight4"])
def test_emitted_network_layers_match_reference(name):
    from repro.analysis.kerncheck import network_budget
    specs = list(NETWORKS[name])
    plan = plan_emitable_network(specs, network_budget(specs), name=name)
    for lp in plan.layers:
        emitted = emit_layer_kernel(lp)
        spec = lp.spec
        x = RNG.standard_normal(
            (spec.c_in, spec.h_in, spec.w_in)).astype(np.float32)
        w = RNG.standard_normal(
            (spec.c_out, spec.c_in, spec.h_k, spec.w_k)).astype(np.float32)
        out = emitted.run(jnp.asarray(x), jnp.asarray(w))
        exp = ref.conv2d(jnp.asarray(x), jnp.asarray(w), spec.s_h,
                         spec.s_w)
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)
