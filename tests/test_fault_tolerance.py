"""Unit tests for the host-side fault-tolerance scaffold
(``repro.runtime.fault_tolerance``) — resurrected as the control plane
of ``repro.resil``.  All clocks are fake: no sleeps anywhere."""
import pytest

from repro.runtime import fault_tolerance as ft


# --------------------------- HeartbeatTracker -------------------------- #

def test_host_that_never_beats_is_detected():
    """Registration stamps the construction time, so a host that dies
    before its first beat is still declared dead at timeout."""
    t = [0.0]
    hb = ft.HeartbeatTracker([0, 1], timeout_s=10, clock=lambda: t[0])
    t[0] = 10.0
    hb.beat(0, 0)
    assert hb.dead_hosts() == []            # exactly at timeout: alive
    t[0] = 10.5
    assert hb.dead_hosts() == [1]
    assert hb.alive_hosts() == [0]


def test_timeout_boundary_is_strict():
    """``now - last == timeout`` is alive — the resil engine beats
    survivors exactly at stage end + detection window and must not see
    them flagged alongside the genuinely silent chip."""
    t = [0.0]
    hb = ft.HeartbeatTracker([0], timeout_s=5, clock=lambda: t[0])
    t[0] = 5.0
    assert hb.dead_hosts() == []
    t[0] = 5.0 + 1e-9
    assert hb.dead_hosts() == [0]


def test_beat_from_unknown_host_raises():
    hb = ft.HeartbeatTracker([0, 1], timeout_s=10, clock=lambda: 0.0)
    with pytest.raises(ft.UnknownHostError):
        hb.beat(7, 0)


def test_beat_keeps_monotonic_step():
    hb = ft.HeartbeatTracker([0], timeout_s=10, clock=lambda: 0.0)
    hb.beat(0, 5)
    hb.beat(0, 3)                           # stale/reordered report
    assert hb.last_step[0] == 5


# --------------------------- StragglerDetector ------------------------- #

def test_ewma_first_sample_is_the_sample():
    """The EWMA must seed from the first observation, not blend it with
    the 0.0 placeholder (which would undercount every host forever)."""
    sd = ft.StragglerDetector([0], alpha=0.2)
    sd.record(0, 4.0)
    assert sd.ewma[0] == 4.0
    sd.record(0, 2.0)
    assert sd.ewma[0] == pytest.approx(0.8 * 4.0 + 0.2 * 2.0)


def test_record_from_unknown_host_raises():
    sd = ft.StragglerDetector([0])
    with pytest.raises(ft.UnknownHostError):
        sd.record(9, 1.0)


def test_no_stragglers_before_warmup():
    sd = ft.StragglerDetector([0, 1, 2], warmup=3)
    for _ in range(2):
        sd.record(0, 1.0)
        sd.record(1, 1.0)
        sd.record(2, 10.0)
    assert sd.fleet_median() == 0.0
    assert sd.stragglers() == []
    sd.record(0, 1.0)
    sd.record(1, 1.0)
    sd.record(2, 10.0)
    assert sd.stragglers() == [2]


# ------------------------------ ElasticPlan ---------------------------- #

def test_plan_rescale_no_survivors_raises():
    with pytest.raises(ft.NoSurvivorsError):
        ft.plan_rescale([], model_shards=4)


def test_plan_rescale_validates_degrees():
    with pytest.raises(ft.FaultToleranceError):
        ft.plan_rescale([0, 1], model_shards=0)
    with pytest.raises(ft.FaultToleranceError):
        ft.plan_rescale([0, 1], model_shards=2, chips_per_host=0)


def test_plan_rescale_single_host():
    plan = ft.plan_rescale([5], model_shards=1, chips_per_host=4)
    assert plan.hosts == (5,)
    assert plan.data_shards == 4 and plan.world == 4


# ---------------------------- TrainSupervisor -------------------------- #

def _run_supervisor(sup, total, fail_at):
    state = {"ckpt": 0}
    armed = dict(fail_at)

    def run_step(step, plan):
        if step in armed:
            raise ft.HostFailure(armed.pop(step))
        return 1.0

    return sup.run(total, run_step, lambda s: state.update(ckpt=s),
                   lambda: state["ckpt"])


def test_supervisor_evicts_dead_host_from_all_trackers():
    """A dead host must leave the straggler EWMA too — otherwise its
    frozen step time skews the fleet median after every restart."""
    sup = ft.TrainSupervisor(hosts=[0, 1, 2, 3], model_shards=1,
                             checkpoint_every=2, chips_per_host=4)
    for _ in range(5):
        sup.straggle.record(3, 50.0)        # host 3 was crawling...
        for h in (0, 1, 2):
            sup.straggle.record(h, 1.0)
    rep = _run_supervisor(sup, 6, fail_at={2: 3})    # ...then it dies
    assert rep.steps_done == 6 and rep.restarts == 1
    assert 3 not in sup.hb.last_seen and 3 not in sup.hb.last_step
    assert 3 not in sup.straggle.ewma and 3 not in sup.straggle.count
    assert sup.straggle.stragglers() == []


def test_supervisor_all_hosts_dead_raises_not_loops():
    sup = ft.TrainSupervisor(hosts=[0, 1], model_shards=1,
                             checkpoint_every=10, chips_per_host=4)
    with pytest.raises(ft.NoSurvivorsError):
        _run_supervisor(sup, 10, fail_at={0: 0, 1: 1})


def test_supervisor_resumes_from_checkpoint():
    sup = ft.TrainSupervisor(hosts=list(range(4)), model_shards=2,
                             checkpoint_every=3, chips_per_host=4)
    rep = _run_supervisor(sup, 10, fail_at={7: 2})
    assert rep.steps_done == 10
    assert rep.restarts == 1
    assert len(rep.rescales) == 1 and rep.rescales[0] <= 8
