"""Unit tests for the offloading formalism (paper Sec 2) against the
paper's own worked examples."""
import pytest

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import (MemoryState, Step, StepError, apply_step,
                                  run_steps, step_duration)
from repro.core.strategies import row_by_row, zigzag


EX_SPEC = ConvSpec(c_in=2, h_in=5, w_in=5, n_kernels=2, h_k=3, w_k=3)
EX_HW = HardwareModel(nbop_pe=120)


def test_example1_patch_count():
    # Example 1: 2x5x5 input, two 2x3x3 kernels, stride 1 -> 9 patches.
    assert EX_SPEC.num_patches == 9
    assert EX_SPEC.num_pixels == 25          # Example 3 (Remark 6)
    assert EX_SPEC.nb_op_value == 18         # Def 13: 2*3*3


def test_example1_patch_windows():
    # P_{0,0}, P_{1,1}, P_{2,2} of Fig 7.
    assert EX_SPEC.patch_bbox(EX_SPEC.patch_id(0, 0)) == (0, 0, 3, 3)
    assert EX_SPEC.patch_bbox(EX_SPEC.patch_id(1, 1)) == (1, 1, 4, 4)
    assert EX_SPEC.patch_bbox(EX_SPEC.patch_id(2, 2)) == (2, 2, 5, 5)


def _spatial(spec, mask):
    return sorted(spec.pixel_pos(j) for j in spec.pixels_of_mask(mask))


def test_example2_row_by_row_step2():
    steps = row_by_row(EX_SPEC, 2).to_steps()
    s2 = steps[1]
    assert _spatial(EX_SPEC, s2.f_inp) == [(0, 0), (0, 1)]
    assert _spatial(EX_SPEC, s2.i_slice) == [
        (0, 4), (1, 4), (2, 4), (3, 0), (3, 1), (3, 2)]
    assert s2.w.bit_count() == 2             # outputs of g_1
    assert s2.k_sub == 0
    # delta(s_2) = 6 t_l + 2 t_w + t_acc  (paper Example 2)
    assert step_duration(s2, EX_SPEC, EX_HW) == 6 + 2 + 1


def test_example2_zigzag_step2():
    steps = zigzag(EX_SPEC, 2).to_steps()
    s2 = steps[1]
    assert _spatial(EX_SPEC, s2.f_inp) == [
        (0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (2, 1)]
    assert _spatial(EX_SPEC, s2.i_slice) == [
        (0, 4), (1, 4), (2, 4), (3, 2), (3, 3), (3, 4)]
    assert step_duration(s2, EX_SPEC, EX_HW) == 6 + 2 + 1


def test_example2_memory_footprints():
    # M_2^inp: Row = 32 elements, ZigZag = 24 elements (paper Example 2).
    r = run_steps(row_by_row(EX_SPEC, 2).to_steps(), EX_SPEC, EX_HW)
    z = run_steps(zigzag(EX_SPEC, 2).to_steps(), EX_SPEC, EX_HW)
    assert r.states[1].inp.bit_count() * EX_SPEC.c_in == 32
    assert z.states[1].inp.bit_count() * EX_SPEC.c_in == 24


def test_nb_patches_max_formula():
    # Sec 4.2 formula: floor(120 / (18 * 2)) = 3.  (The paper's prose says
    # 2 for this example — inconsistent with its own definition; we follow
    # the definition.  See EXPERIMENTS.md.)
    assert EX_HW.nb_patches_max_s1(EX_SPEC.nb_op_value, EX_SPEC.c_out) == 3


def test_semantics_reject_bad_steps():
    m = MemoryState()
    with pytest.raises(StepError):
        apply_step(m, Step(f_inp=1))          # freeing what is not loaded
    m2 = apply_step(m, Step(i_slice=0b11))
    with pytest.raises(StepError):
        apply_step(m2, Step(i_slice=0b01))    # reload while resident
    with pytest.raises(StepError):
        apply_step(m2, Step(w=1))             # write-back of nothing


def test_run_rejects_incomplete_strategy():
    spec = ConvSpec(1, 4, 4, 1, 3, 3)
    hw = HardwareModel(nbop_pe=10**6)
    strat = row_by_row(spec, 2)
    steps = strat.to_steps()[:-2]             # drop last compute + flush
    with pytest.raises(StepError):
        run_steps(steps, spec, hw)


def test_memory_empty_after_last_step():
    r = run_steps(zigzag(EX_SPEC, 2).to_steps(), EX_SPEC, EX_HW)
    assert r.states[-1].empty


def test_s1_kernels_resident_until_last_step():
    steps = row_by_row(EX_SPEC, 2).to_steps()
    r = run_steps(steps, EX_SPEC, EX_HW)
    for st in r.states[:-1]:
        assert st.ker.bit_count() == EX_SPEC.n_kernels
    assert steps[0].k_sub.bit_count() == EX_SPEC.n_kernels
    assert all(s.k_sub == 0 for s in steps[1:])
    assert steps[-1].f_ker.bit_count() == EX_SPEC.n_kernels
