"""hlo_stats must (1) agree with XLA cost_analysis on loop-free programs and
(2) correctly multiply while-loop bodies by trip counts (which
cost_analysis does NOT — the reason hlo_stats exists)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_stats


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_matches_cost_analysis_loop_free():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)

    def f(a, b):
        return jnp.tanh(a @ b)

    c = _compile(f, a, b)
    st = hlo_stats.analyze(c.as_text())
    true_flops = 2 * 256 * 512 * 128
    assert abs(st.flops - true_flops) / true_flops < 0.01
    ca = hlo_stats.cost_analysis_dict(c)
    # XLA counts the tanh as transcendental, not flops; dots dominate.
    assert abs(st.flops - ca["flops"]) / ca["flops"] < 0.05
    assert st.unknown_trip_loops == 0


def test_scan_trip_count_multiplied():
    L, D = 7, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    c = _compile(f, jax.ShapeDtypeStruct((32, D), jnp.float32),
                 jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    st = hlo_stats.analyze(c.as_text())
    true_flops = L * 2 * 32 * D * D
    assert abs(st.flops - true_flops) / true_flops < 0.02, st.flops
    # the point of this module: cost_analysis undercounts the loop
    assert hlo_stats.cost_analysis_dict(c)["flops"] < 0.5 * true_flops


def test_nested_scans():
    L1, L2, D = 3, 5, 32

    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, None, length=L2)
            return ci, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y.sum()

    c = _compile(f, jax.ShapeDtypeStruct((16, D), jnp.float32),
                 jax.ShapeDtypeStruct((L1, D, D), jnp.float32))
    st = hlo_stats.analyze(c.as_text())
    true_flops = L1 * L2 * 2 * 16 * D * D
    assert abs(st.flops - true_flops) / true_flops < 0.05, st.flops


def test_collectives_counted_with_trips():
    import os
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (dryrun subprocess covers this)")


def test_bytes_reasonable_scale():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

    def f(x):
        return (x * 2 + 1).sum()

    c = _compile(f, a)
    st = hlo_stats.analyze(c.as_text())
    # at least reads the input once; at most a few copies
    assert st.bytes_accessed >= 4 * 1024 * 1024
    assert st.bytes_accessed <= 16 * 4 * 1024 * 1024


def test_grad_of_scan_counts_forward_and_backward():
    L, D = 6, 48

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    g = jax.grad(f, argnums=(0, 1))
    c = _compile(g, jax.ShapeDtypeStruct((8, D), jnp.float32),
                 jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    st = hlo_stats.analyze(c.as_text())
    fwd = L * 2 * 8 * D * D
    # fwd + 2 backward matmuls per layer = 3x fwd
    assert st.flops > 2.5 * fwd
    assert st.flops < 4.0 * fwd
