"""ILP formulation (Sec 5) + solver tests."""
import itertools

import numpy as np
import pytest

from repro.core import ilp, solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import run_steps
from repro.core.strategies import GroupedStrategy, k_min, lower_bound

HW = HardwareModel(nbop_pe=10**9)


def brute_force_optimal(spec, p, k):
    """Exhaustive search over ordered partitions into exactly k groups of
    size <= p (tiny instances only)."""
    best = None
    ids = list(range(spec.num_patches))

    def rec(remaining, groups):
        nonlocal best
        if len(groups) == k:
            if remaining:
                return
            strat = GroupedStrategy("bf", spec, tuple(groups))
            obj = strat.objective(HW)
            if best is None or obj < best:
                best = obj
            return
        for size in range(1, p + 1):
            for combo in itertools.combinations(remaining, size):
                rec([x for x in remaining if x not in combo],
                    groups + [tuple(combo)])

    rec(ids, [])
    return best


def test_ilp_matches_brute_force_tiny():
    spec = ConvSpec(1, 4, 4, 1, 3, 3)          # 4 patches
    p, k = 2, 2
    model = ilp.build_ilp(spec, p, k=k, nb_data_reload=2)
    strat, status, _ = solver.solve_milp(model, time_limit=30)
    assert status == "optimal"
    assert strat.objective(HW) == brute_force_optimal(spec, p, k)


def test_ilp_solution_satisfies_all_constraints():
    spec = ConvSpec(1, 6, 6, 1, 3, 3)
    p = 4
    model = ilp.build_ilp(spec, p, nb_data_reload=2)
    strat, status, _ = solver.solve_milp(model, time_limit=60)
    assert status in ("optimal", "feasible")
    assert strat.max_group_size() <= p                      # eq. 4
    assert strat.n_steps == k_min(spec, p)                  # Sec 7.1 setup
    assert strat.max_reloads() <= 2                         # eq. 9
    run_steps(strat.to_steps(), spec, HW)                   # executable


def test_ilp_memory_constraint_respected():
    spec = ConvSpec(1, 5, 5, 1, 3, 3)
    p = 3
    cap = spec.kernel_elements + 3 * 9 + p                  # tight-ish
    model = ilp.build_ilp(spec, p, nb_data_reload=3, size_mem=cap)
    strat, status, _ = solver.solve_milp(model, time_limit=60)
    if strat is None:
        pytest.skip(f"infeasible at cap={cap}")
    for g in strat.groups:
        used = (spec.group_mask(g).bit_count() * spec.c_in
                + spec.kernel_elements + len(g) * spec.c_out)
        assert used <= cap


def test_polish_improves_or_equals_seed():
    spec = ConvSpec(1, 8, 8, 1, 3, 3)
    from repro.core.strategies import zigzag
    seed = zigzag(spec, 4)
    polished = solver.polish(seed, 4, HW, iters=4000, rng_seed=1)
    assert polished.objective(HW) <= seed.objective(HW)
    run_steps(polished.to_steps(), spec, HW)


def test_solve_end_to_end_reports():
    spec = ConvSpec(1, 6, 6, 1, 3, 3)
    res = solver.solve(spec, p=4, hw=HW, time_limit=10, polish_iters=3000)
    assert res.objective <= res.seed_objective
    assert res.objective >= res.lower_bound
    assert 0.0 <= res.gap
    run_steps(res.strategy.to_steps(), spec, HW)


def test_variable_count_formula():
    # paper Sec 7.1: N_var = K*(3*(H_in*W_in) + H_out*W_out); our model
    # eliminates pxl_I so we carry K*(2*J + |X|) binaries with J = covered
    # pixels <= H_in*W_in.
    spec = ConvSpec(1, 8, 8, 1, 3, 3)
    k = k_min(spec, 4)
    model = ilp.build_ilp(spec, 4, k=k)
    assert model.num_vars <= ilp.n_var_literal(spec, k)
    assert model.num_vars == k * (2 * len(model.pixels) + spec.num_patches)
