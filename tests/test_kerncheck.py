"""Kernel contract checker (ISSUE 7): the symbolic trace of every
emitted conv kernel is contract-equivalent to its plan, the standalone
GeMM/decode schedules check clean, and every rule is provoked by a
seeded mutation — shifted DMA region, shifted window, double write,
dropped/extra wait, inflated occupancy or traffic — caught as a
structured ERROR diagnostic with the right rule id."""
import copy
import dataclasses
import json

import pytest

from repro.analysis import access, kerncheck
from repro.analysis.kerncheck import (
    build_conv_trace, check_block_matmul, check_conv_trace, check_decode,
    check_network, network_budget, run_all)
from repro.configs.networks import NETWORKS
from repro.core.conv_spec import ConvSpec
from repro.kernels.emit import (KernelEmitError, emit_layer_kernel,
                                plan_emitable_network)

SPECS = [ConvSpec(2, 8, 8, 3, 3, 3), ConvSpec(3, 6, 6, 4, 3, 3)]


@pytest.fixture(scope="module")
def emitted_layer():
    """(trace, strategy, budget) of a real emitted layer, to mutate."""
    hw = network_budget(SPECS)
    plan = plan_emitable_network(SPECS, hw, name="mini")
    lp = plan.layers[0]
    trace = build_conv_trace(emit_layer_kernel(lp))
    return trace, lp.strategy, hw.size_mem


def _rules(diags):
    return {d.rule for d in diags}


def _shift_box(region: access.Region, axis: int, by: int) -> access.Region:
    box = list(region.box)
    lo, hi = box[axis]
    box[axis] = (lo + by, hi + by)
    return access.Region(region.tensor, tuple(box))


# --------------------------------------------------------------------- #
# Positive: every registered network proves clean
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_registered_network_checks_clean(name):
    report = check_network(name)
    assert report.ok, report.render()
    assert report.checked_layers == len(NETWORKS[name])
    assert report.checked_steps > 0


def test_clean_trace_has_no_diagnostics(emitted_layer):
    trace, strategy, budget = emitted_layer
    assert check_conv_trace(trace, strategy, budget, layer=0) == []


def test_run_all_covers_networks_and_standalone_kernels():
    report = run_all(["tight2"])
    assert report.ok, report.render()
    assert report.checked_layers == len(NETWORKS["tight2"])


def test_cli_exit_codes(capsys):
    assert kerncheck.main(["--network", "tight2"]) == 0
    assert "OK" in capsys.readouterr().out
    assert kerncheck.main(["--network", "tight2", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["ok"] is True


# --------------------------------------------------------------------- #
# Seeded mutations: one per rule, each caught with the precise rule id
# --------------------------------------------------------------------- #

def test_shifted_dma_region_fires_step_islice(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    k = len(bad.steps) // 2
    bad.steps[k] = dataclasses.replace(
        bad.steps[k], x_load=_shift_box(bad.steps[k].x_load, 2, 1))
    assert "kern/step-islice" in _rules(
        check_conv_trace(bad, strategy, budget, layer=0))


def test_shifted_window_fires_residency(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    bad.steps[1] = dataclasses.replace(
        bad.steps[1], window=_shift_box(bad.steps[1].window, 1, 1))
    assert "kern/residency" in _rules(
        check_conv_trace(bad, strategy, budget, layer=0))


def test_shifted_output_block_fires_write_back(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    bad.steps[2] = dataclasses.replace(
        bad.steps[2], out=bad.steps[0].out)        # double-writes block 0
    rules = _rules(check_conv_trace(bad, strategy, budget, layer=0))
    assert "kern/write-back" in rules


def test_double_write_breaks_write_once_coverage(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    bad.steps[3] = dataclasses.replace(bad.steps[3], out=bad.steps[0].out)
    diags = check_conv_trace(bad, strategy, budget, layer=0)
    cover = [d for d in diags if d.rule == "kern/write-back"
             and "write-once" in d.message]
    assert cover and dict(cover[0].data)["missing"] > 0
    assert dict(cover[0].data)["multi"] > 0


def test_dropped_wait_fires_hazard(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    waits = [i for i, e in enumerate(bad.events)
             if isinstance(e, access.DmaWait)]
    del bad.events[waits[1]]
    kinds = {dict(d.data)["kind"] for d in
             check_conv_trace(bad, strategy, budget, layer=0)
             if d.rule == "kern/hazard"}
    assert kinds & {"raw", "war", "waw", "leak"}


def test_extra_wait_fires_lost_wait(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    waits = [i for i, e in enumerate(bad.events)
             if isinstance(e, access.DmaWait)]
    bad.events.insert(waits[-1] + 1,
                      access.DmaWait(bad.events[waits[-1]].sem,
                                     bad.events[waits[-1]].step))
    kinds = {dict(d.data)["kind"] for d in
             check_conv_trace(bad, strategy, budget, layer=0)
             if d.rule == "kern/hazard"}
    assert "lost-wait" in kinds


def test_oversized_occupancy_fires_vmem(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    bad.vmem_elements = budget + 1
    diags = [d for d in check_conv_trace(bad, strategy, budget, layer=0)
             if d.rule == "kern/vmem"]
    assert diags and dict(diags[0].data)["budget"] == budget


def test_extra_traffic_fires_conservation(emitted_layer):
    trace, strategy, budget = emitted_layer
    bad = copy.deepcopy(trace)
    bad.steps[1] = dataclasses.replace(
        bad.steps[1], lam_elements=bad.steps[1].lam_elements + 5)
    rules = _rules(check_conv_trace(bad, strategy, budget, layer=0))
    assert rules == {"kern/traffic"}


def test_emit_failure_becomes_diagnostic(monkeypatch):
    def boom(lp):
        raise KernelEmitError(f"layer {lp.index}: no kernel")
    monkeypatch.setattr(kerncheck, "emit_layer_kernel", boom)
    report = check_network("mini", SPECS)
    assert not report.ok
    assert {d.rule for d in report.errors} == {"kern/emit"}


# --------------------------------------------------------------------- #
# Standalone kernels: positive + mutated schedules
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("order", ["mnk", "nmk", "kmn", "mkn"])
def test_block_matmul_schedule_clean(order):
    assert check_block_matmul(256, 128, 256, bm=64, bn=64, bk=64,
                              order=order) == []


def test_block_matmul_broken_cmap_fires_coverage(monkeypatch):
    from repro.kernels.block_matmul import matmul_grid

    def broken(m, n, k, *, bm, bn, bk, order):
        grid, amap, bmap, _, axis = matmul_grid(m, n, k, bm=bm, bn=bn,
                                                bk=bk, order=order)
        return grid, amap, bmap, lambda *ids: (0, 0), axis
    monkeypatch.setattr(kerncheck, "matmul_grid", broken)
    diags = check_block_matmul(256, 128, 256, bm=64, bn=64, bk=64,
                               order="mnk")
    assert diags and _rules(diags) == {"kern/coverage"}


def test_decode_schedule_clean():
    assert check_decode(8, 64, 2048, bkv=256) == []


def test_decode_repeating_kv_block_fires_coverage(monkeypatch):
    from repro.kernels.flash_decode import decode_specs

    def broken(g, d, s, bkv):
        grid, qmap, _, omap = decode_specs(g, d, s, bkv)
        return grid, qmap, lambda i: (0, 0), omap
    monkeypatch.setattr(kerncheck, "decode_specs", broken)
    diags = check_decode(8, 64, 2048, bkv=256)
    assert diags and _rules(diags) == {"kern/coverage"}
