"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import planner
from repro.core.conv_spec import ConvSpec
from repro.kernels import KernelShapeError, ops, ref
from repro.kernels import block_matmul as _bm
from repro.kernels import conv2d_offload as _conv
from repro.kernels import flash_decode as _fd

RNG = np.random.default_rng(42)


# --------------------------- conv2d_offload --------------------------- #

@pytest.mark.parametrize("c_in,h,w,n,kh,kw,sh,sw,t_run", [
    (1, 6, 6, 1, 3, 3, 1, 1, 2),
    (3, 12, 14, 5, 3, 3, 1, 1, 4),
    (2, 9, 11, 4, 2, 2, 1, 1, 5),
    (4, 16, 16, 8, 5, 5, 1, 1, 4),
    (2, 11, 13, 3, 3, 3, 2, 2, 3),
    (1, 8, 8, 2, 1, 1, 1, 1, 8),
])
def test_conv_shapes(c_in, h, w, n, kh, kw, sh, sw, t_run):
    x = RNG.standard_normal((c_in, h, w)).astype(np.float32)
    k = RNG.standard_normal((n, c_in, kh, kw)).astype(np.float32)
    out = ops.conv2d(x, k, t_run=t_run, s_h=sh, s_w=sw)
    exp = ref.conv2d(jnp.asarray(x), jnp.asarray(k), sh, sw)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("order", ["zigzag", "row"])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_conv_orders_dtypes(order, dtype):
    x = RNG.standard_normal((2, 10, 12)).astype(dtype)
    k = RNG.standard_normal((3, 2, 3, 3)).astype(dtype)
    out = ops.conv2d(x, k, t_run=5, order=order)
    exp = ref.conv2d(jnp.asarray(x), jnp.asarray(k))
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_conv_planner_t_run():
    x = RNG.standard_normal((2, 10, 12)).astype(np.float32)
    k = RNG.standard_normal((3, 2, 3, 3)).astype(np.float32)
    out = ops.conv2d(x, k)          # planner chooses t_run
    exp = ref.conv2d(jnp.asarray(x), jnp.asarray(k))
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


# ----------------------------- block_matmul --------------------------- #

@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (200, 150, 300, 64, 64, 64),
    (128, 128, 128, 128, 128, 128),
    (96, 257, 130, 32, 64, 64),
])
@pytest.mark.parametrize("order", ["mnk", "nmk", "mkn", "knm"])
def test_matmul_shapes_orders(m, n, k, bm, bn, bk, order):
    a = RNG.standard_normal((m, k)).astype(np.float32)
    b = RNG.standard_normal((k, n)).astype(np.float32)
    out = ops.matmul(a, b, bm=bm, bn=bn, bk=bk, order=order)
    np.testing.assert_allclose(out, a @ b, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    a = RNG.standard_normal((64, 96)).astype(dtype)
    b = RNG.standard_normal((96, 64)).astype(dtype)
    out = ops.matmul(a, b, bm=32, bn=32, bk=32, order="mnk")
    exp = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    tol = 1e-3 if dtype == np.float32 else 2.0
    np.testing.assert_allclose(np.asarray(out, np.float32), exp,
                               rtol=tol, atol=tol)


# ----------------------------- flash_decode --------------------------- #

@pytest.mark.parametrize("b,hq,hkv,d,s,bkv", [
    (1, 4, 4, 32, 128, 64),       # MHA
    (2, 8, 2, 64, 256, 64),       # GQA 4:1
    (2, 8, 1, 64, 256, 128),      # MQA
    (1, 16, 4, 128, 512, 256),
])
def test_decode_attention(b, hq, hkv, d, s, bkv):
    q = RNG.standard_normal((b, hq, d)).astype(np.float32)
    k = RNG.standard_normal((b, s, hkv, d)).astype(np.float32)
    v = RNG.standard_normal((b, s, hkv, d)).astype(np.float32)
    lengths = RNG.integers(1, s + 1, size=(b,)).astype(np.int32)
    out = ops.decode_attention(q, k, v, jnp.asarray(lengths), bkv=bkv)
    g = hq // hkv
    for bi in range(b):
        for h in range(hq):
            exp = ref.decode_attention(
                jnp.asarray(q[bi, h:h + 1]), jnp.asarray(k[bi, :, h // g]),
                jnp.asarray(v[bi, :, h // g]), int(lengths[bi]))[0]
            np.testing.assert_allclose(out[bi, h], exp, rtol=2e-3, atol=2e-3)


def test_decode_attention_full_length_default():
    q = RNG.standard_normal((1, 4, 32)).astype(np.float32)
    k = RNG.standard_normal((1, 128, 4, 32)).astype(np.float32)
    v = RNG.standard_normal((1, 128, 4, 32)).astype(np.float32)
    out = ops.decode_attention(q, k, v, bkv=32)
    exp = ops.decode_attention(q, k, v, jnp.asarray([128], jnp.int32),
                               bkv=32)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


# ------------------------------- planner ------------------------------ #

def test_planner_matmul_fits_vmem_and_prefers_reuse():
    p = planner.plan_matmul(8192, 8192, 8192, dtype_bytes=2)
    assert p.vmem_bytes <= planner.TPU_V5E.vmem_bytes
    # compute-bound at this size: overlapped duration == flops/peak
    assert abs(p.duration_overlapped - p.flops / planner.TPU_V5E.peak_flops) \
        / p.duration_overlapped < 1e-6
    # bytes moved must be >= the compulsory traffic (A+B+C once)
    compulsory = 2 * (8192 * 8192 * 3)
    assert p.hbm_bytes >= compulsory


def test_planner_decode_attention_is_memory_bound():
    p = planner.plan_decode_attention(32768, 128, 8, dtype_bytes=2)
    t_mem = p.hbm_bytes / planner.TPU_V5E.hbm_bw
    assert p.duration_overlapped == t_mem      # decode: always memory-bound
    assert 32768 % p.tiles["bkv"] == 0


def test_planner_conv_prefers_wider_runs():
    spec = ConvSpec(3, 64, 64, 8, 3, 3)
    p = planner.plan_conv(spec, dtype_bytes=4)
    assert p.tiles["t"] > 1                    # grouping beats S1-baseline
    assert p.vmem_bytes <= planner.TPU_V5E.vmem_bytes


def test_planner_duration_models_ordering():
    p = planner.plan_matmul(1024, 1024, 1024, dtype_bytes=2)
    assert p.duration_overlapped <= p.duration_additive


# ----------------------- conv2d_offload_planned ----------------------- #

@pytest.mark.parametrize("order", ["zigzag", "row"])
@pytest.mark.parametrize("c_in,h,w,n,kh,kw,sh,sw,t_run", [
    (2, 10, 12, 3, 3, 3, 1, 1, 5),     # col-delta within rows + row turns
    (1, 9, 9, 2, 3, 3, 1, 1, 7),       # one tile per row: row-delta only
    (2, 11, 13, 3, 3, 3, 2, 2, 3),     # strides 2: every window disjoint rows
    (3, 12, 14, 4, 5, 3, 1, 2, 2),     # tall kernel, stride-2 columns
    (1, 8, 8, 2, 1, 1, 1, 1, 4),       # 1x1 kernel: full fetch per tile
    (2, 13, 11, 3, 3, 3, 3, 1, 9),     # s_h >= h_k: no row-to-row reuse
])
def test_conv_planned_delta_fetch_matches_ref(order, c_in, h, w, n, kh, kw,
                                              sh, sw, t_run):
    """The double-buffered delta-fetch kernel (the one kerncheck proves)
    must equal the reference conv across stride/order/tile crossings —
    the same geometry cases the static trace enumerates."""
    x = RNG.standard_normal((c_in, h, w)).astype(np.float32)
    k = RNG.standard_normal((n, c_in, kh, kw)).astype(np.float32)
    out = _conv.conv2d_offload_planned(jnp.asarray(x), jnp.asarray(k),
                                       t_run=t_run, s_h=sh, s_w=sw,
                                       order=order, interpret=True)
    exp = ref.conv2d(jnp.asarray(x), jnp.asarray(k), sh, sw)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


def test_kernel_geometry_errors_are_typed():
    """Bare asserts were replaced by KernelShapeError raises (lint L006
    now covers kernels/): bad geometry must raise the typed error, not
    AssertionError, and survive python -O."""
    x = jnp.zeros((2, 8, 8), jnp.float32)
    k = jnp.zeros((3, 2, 3, 3), jnp.float32)
    with pytest.raises(KernelShapeError):
        _conv.conv2d_offload_planned(x, k, t_run=4, order="spiral",
                                     interpret=True)
    with pytest.raises(KernelShapeError):      # t_run does not divide w_out
        _conv.conv2d_offload_planned(x, k, t_run=4, s_w=1, s_h=1,
                                     order="zigzag", interpret=True)
    with pytest.raises(KernelShapeError):      # channel mismatch
        _conv.conv2d_offload(x, jnp.zeros((3, 1, 3, 3), jnp.float32),
                             t_run=3, interpret=True)
    a = jnp.zeros((64, 64), jnp.float32)
    with pytest.raises(KernelShapeError):      # tiles must divide dims
        _bm.block_matmul(a, a, bm=48, bn=32, bk=32, order="mnk",
                         interpret=True)
    with pytest.raises(KernelShapeError):      # bad order permutation
        _bm.block_matmul(a, a, bm=32, bn=32, bk=32, order="mmk",
                         interpret=True)
    q = jnp.zeros((4, 32), jnp.float32)
    kv = jnp.zeros((128, 16), jnp.float32)
    with pytest.raises(KernelShapeError):      # head-dim mismatch
        _fd.decode_attention(q, kv, kv, jnp.int32(128), bkv=64,
                             interpret=True)
