"""Launch-layer tests: input specs for every cell, model-flops sanity,
mesh builders, end-to-end smoke train/serve drivers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import steps
from repro.launch.model_flops import model_flops
from repro.models import registry
from repro.models.common import SHAPES, Axes, cell_applicable


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_cover_every_cell(arch, shape):
    """Every applicable (arch x shape) must produce abstract inputs +
    partition specs without touching devices."""
    api = registry.get(arch)
    cell = SHAPES[shape]
    ok, why = cell_applicable(api.cfg, cell)
    if not ok:
        assert "SKIP" in why
        return
    inputs, spec_tree = api.input_specs(cell, axes=None)
    assert jax.tree.structure(inputs) == jax.tree.structure(
        spec_tree, is_leaf=lambda x: x is None or hasattr(x, "index"))
    for leaf in jax.tree.leaves(inputs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if cell.kind == "train":
        toks = inputs["tokens"]
        assert toks.shape[0] == cell.global_batch
    if cell.kind == "decode":
        assert inputs["tokens"].shape == (cell.global_batch, 1)
        assert "cache" in inputs


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_model_flops_sane(arch):
    """MODEL_FLOPS ordering: train > prefill >> decode; all positive."""
    api = registry.get(arch)
    vals = {}
    for name, cell in SHAPES.items():
        if not cell_applicable(api.cfg, cell)[0]:
            continue
        vals[name] = model_flops(api, cell)
        assert vals[name] > 0, (arch, name)
    assert vals["train_4k"] > vals["decode_32k"]
    assert vals["prefill_32k"] > vals["decode_32k"]


def test_model_flops_dense_matches_6nd():
    """tinyllama train: 6·N·D within 2x of the raw parameter count bound."""
    api = registry.get("tinyllama-1.1b")
    n_params = 1.1e9
    tokens = 256 * 4096
    mf = model_flops(api, SHAPES["train_4k"])
    assert 0.8 * 6 * n_params * tokens < mf < 3 * 6 * n_params * tokens


def test_abstract_train_args_no_allocation():
    api = registry.get("deepseek-v2-236b")     # 236B params: must not alloc
    params, opt, inputs = steps.abstract_train_args(api, SHAPES["train_4k"])
    for leaf in jax.tree.leaves((params, opt, inputs)):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    total = sum(np.prod(l.shape) * l.dtype.itemsize
                for l in jax.tree.leaves(params))
    assert total > 200e9                        # it really is ~236B x 2B


def test_decode_param_layout_swap():
    """spfsdp decode layout: 2-D weights move model to the contraction dim."""
    from jax.sharding import PartitionSpec as P
    api = registry.get("qwen2-7b")
    axes = Axes()
    train_specs = api.param_specs(axes)
    dec_specs = api.param_specs(axes, layout="decode")
    tl = jax.tree.leaves(train_specs)
    dl = jax.tree.leaves(dec_specs)
    assert any(t != d for t, d in zip(tl, dl))
    # TP archs keep the train layout
    api2 = registry.get("dbrx-132b")
    assert jax.tree.leaves(api2.param_specs(axes)) == \
        jax.tree.leaves(api2.param_specs(axes, layout="decode"))


def test_smoke_mesh_and_axes():
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh()
    assert set(mesh.axis_names) == {"data", "model"}
    ax = Axes.for_mesh(mesh)
    assert ax.pod is None and ax.batch == "data"


def test_train_driver_end_to_end(tmp_path):
    """Full train loop with checkpoint + restart resume."""
    from repro.launch.train import train
    d = str(tmp_path)
    l1 = train("tinyllama-1.1b", smoke=True, steps=4, batch=2, seq_len=32,
               ckpt_dir=d, checkpoint_every=2, log_every=100)
    assert len(l1) == 4
    # resume: should start from step 4 and do nothing more
    l2 = train("tinyllama-1.1b", smoke=True, steps=4, batch=2, seq_len=32,
               ckpt_dir=d, checkpoint_every=2, log_every=100)
    assert l2 == []


def test_serve_driver_end_to_end():
    from repro.launch.serve import serve
    gen = serve("tinyllama-1.1b", smoke=True, batch=2, prompt_len=16,
                gen_len=4)
    assert gen.shape == (2, 4)
    assert not np.any(gen < 0)


def test_collective_parse_roundtrip():
    from repro.launch.dryrun import _shape_bytes, collective_bytes
    hlo = """
  %ag = bf16[4,1024]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[128]{0} all-reduce-start(%y), to_apply=%add
  %ar.2 = f32[128]{0} all-reduce-done(%ar.1)
  %cp = u32[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 4 * 1024 * 2
    assert got["all-reduce"] == 128 * 4          # -start counted, -done not
    assert got["collective-permute"] == 16
