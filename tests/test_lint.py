"""Repo lint (ISSUE 6): each AST rule fires on a minimal violating file,
the pragma suppressions work, and — the actual CI gate — the repo's own
``src/repro`` tree is clean."""
import pathlib
import textwrap

from repro.analysis.lint import main, run_lint

BAD_SOURCE = textwrap.dedent('''
    import dataclasses
    import functools
    import random
    import numpy as np


    @dataclasses.dataclass(frozen=True)
    class Frozen:
        x: float = 0.0

        def mutate(self):
            self.x = 1.0                       # L001

        def __post_init__(self):
            object.__setattr__(self, "x", 2.0)  # allowed


    def compare(duration, other):
        ok = duration == 0                     # allowed: emptiness guard
        return ok or duration != other         # L002


    def draw():
        a = random.random()                    # L003
        b = np.random.rand(3)                  # L003
        rng = np.random.default_rng(0)         # allowed: seeded
        det = random.Random(7)                 # allowed: seeded
        return a, b, rng, det


    @functools.lru_cache
    def cached(xs: list):                      # L004
        return len(xs)


    def guard(total_duration):
        assert total_duration >= 0             # L006 (under core/)
        return total_duration


    def dead_api():                            # L005: never referenced
        return 1


    def pinned_api():  # lint: public-api
        return 2


    USES = (Frozen, Frozen.mutate, compare, draw, cached, guard)
''')


def _lint_bad(tmp_path) -> dict[str, list]:
    bad = tmp_path / "core" / "bad.py"
    bad.parent.mkdir()
    bad.write_text(BAD_SOURCE)
    findings = run_lint([bad], base=tmp_path)
    by_rule: dict[str, list] = {}
    for f in findings:
        by_rule.setdefault(f.rule.split(" ")[0], []).append(f)
    return by_rule


def test_every_rule_fires_on_the_bad_file(tmp_path):
    by_rule = _lint_bad(tmp_path)
    assert set(by_rule) == {"L001", "L002", "L003", "L004", "L005", "L006"}
    assert "self.x" in by_rule["L001"][0].message
    assert len(by_rule["L002"]) == 1           # the ==0 guard is allowed
    assert len(by_rule["L003"]) == 2           # seeded calls are allowed
    assert "cached" in by_rule["L004"][0].message
    assert [f.message for f in by_rule["L005"]] \
        and all("dead_api" in f.message for f in by_rule["L005"])
    assert len(by_rule["L006"]) == 1


def test_pragma_suppresses_dead_api(tmp_path):
    by_rule = _lint_bad(tmp_path)
    assert not any("pinned_api" in f.message for f in by_rule["L005"])


def test_bare_assert_in_kernels_fires_l006(tmp_path):
    """kernels/ joined the L006 scope: wrapper-level shape checks must
    raise KernelShapeError, not assert (asserts vanish under -O and the
    kerncheck contract relies on typed geometry failures)."""
    k = tmp_path / "kernels" / "dev.py"
    k.parent.mkdir()
    k.write_text("def f(x):\n    assert x.ndim == 2\n    return x\n")
    findings = run_lint([k], base=tmp_path)
    assert [f.rule for f in findings] == ["L006 bare-assert"]


def test_bare_assert_in_runtime_and_resil_fires_l006(tmp_path):
    """runtime/ and resil/ joined the L006 scope with the fault-injection
    subsystem: recovery invariants must raise typed FaultError /
    FaultToleranceError subclasses, never assert."""
    for pkg in ("runtime", "resil"):
        f = tmp_path / pkg / "dev.py"
        f.parent.mkdir()
        f.write_text("def f(x):\n    assert x >= 0\n    return x\n")
        findings = run_lint([f], base=tmp_path)
        assert [x.rule for x in findings] == ["L006 bare-assert"], pkg


def test_asserts_outside_lint_scope_are_allowed(tmp_path):
    m = tmp_path / "models" / "net.py"
    m.parent.mkdir()
    m.write_text("def f(x):\n    assert x.ndim == 2\n    return x\n")
    assert run_lint([m], base=tmp_path) == []


def test_findings_render_with_path_and_line(tmp_path):
    by_rule = _lint_bad(tmp_path)
    f = by_rule["L006"][0]
    assert f.render().startswith(f"core/bad.py:{f.line}: L006")
    assert f.to_json()["rule"].startswith("L006")


def test_repo_source_tree_is_clean():
    """The CI gate: src/repro (with benchmarks/ + examples/ as the L005
    usage universe) lints clean."""
    assert main([]) == 0


def test_parse_error_is_reported_not_raised(tmp_path):
    bad = tmp_path / "oops.py"
    bad.write_text("def broken(:\n")
    findings = run_lint([bad], base=tmp_path)
    assert findings and findings[0].rule.startswith("L000")
