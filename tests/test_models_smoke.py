"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, and prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.optim import adamw

KEY = jax.random.key(0)


def _batch(cfg, b=2, t=16):
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(
                jax.random.key(3), (b, t, cfg.d_model)).astype(jnp.bfloat16),
            "tokens": jnp.ones((b, cfg.dec_seq), jnp.int32),
            "labels": jnp.ones((b, cfg.dec_seq), jnp.int32),
        }
    toks = jax.random.randint(jax.random.key(2), (b, t), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_forward_no_nan(arch):
    api = registry.get_reduced(arch)
    params = api.init_params(KEY)
    loss = api.loss_fn(params, _batch(api.cfg))
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "dbrx-132b",
                                  "mamba2-2.7b", "zamba2-2.7b",
                                  "whisper-medium", "deepseek-v2-236b"])
def test_train_step_reduces_loss(arch):
    """A few AdamW steps on a fixed batch must reduce the loss."""
    api = registry.get_reduced(arch)
    params = api.init_params(KEY)
    batch = _batch(api.cfg)
    opt_cfg = adamw.AdamWConfig(lr=5e-3)
    state = adamw.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch))(params)
        params, state, gnorm = adamw.update(params, grads, state, opt_cfg)
        return params, state, loss

    losses = []
    for _ in range(4):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert not any(np.isnan(l) for l in losses), (arch, losses)
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", [a for a in registry.ARCH_IDS
                                  if a != "whisper-medium"])
def test_decode_matches_prefill(arch):
    """Decoding token T with the prefill cache == prefilling T+1 tokens."""
    api = registry.get_reduced(arch)
    cfg = api.cfg
    params = api.init_params(jax.random.key(1))
    b, t = 2, 8
    toks = jax.random.randint(jax.random.key(2), (b, t + 1), 0, cfg.vocab)
    _, cache = api.prefill_fn(params, {"tokens": toks[:, :t]}, max_len=16)
    logits_d, _ = api.decode_fn(params, cache, toks[:, t:t + 1],
                                jnp.int32(t))
    logits_full, _ = api.prefill_fn(params, {"tokens": toks[:, :t + 1]},
                                    max_len=16)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    rel = float(jnp.max(jnp.abs(logits_d - logits_full))) / scale
    # exact for GQA; small bf16 drift for absorbed-MLA / recurrent-SSD paths
    tol = 0.0 if cfg.family in ("dense", "vlm") or \
        (cfg.family == "moe" and not cfg.mla) else 0.02
    assert rel <= tol + 1e-6, (arch, rel)


def test_whisper_decode_chain():
    api = registry.get_reduced("whisper-medium")
    cfg = api.cfg
    params = api.init_params(KEY)
    frames = jax.random.normal(jax.random.key(3),
                               (2, 16, cfg.d_model)).astype(jnp.bfloat16)
    logits, cache = api.prefill_fn(params, {"frames": frames})
    assert logits.shape == (2, cfg.padded_vocab)
    for pos in range(1, 5):
        logits, cache = api.decode_fn(params, cache,
                                      jnp.ones((2, 1), jnp.int32),
                                      jnp.int32(pos))
        assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_cell_applicability_matrix(arch):
    """long_500k only for sub-quadratic archs (DESIGN.md skip table)."""
    api = registry.get(arch)
    cells = dict((c.name, ok) for c, ok, _ in api.applicable_cells())
    assert cells["train_4k"] and cells["prefill_32k"] and cells["decode_32k"]
    assert cells["long_500k"] == (arch in ("mamba2-2.7b", "zamba2-2.7b"))


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned numbers."""
    expect = {
        "deepseek-v2-236b": (60, 5120, 128, 1536, 102400),
        "dbrx-132b": (40, 6144, 48, 10752, 100352),
        "qwen2.5-32b": (64, 5120, 40, 27648, 152064),
        "tinyllama-1.1b": (22, 2048, 32, 5632, 32000),
        "qwen2-7b": (28, 3584, 28, 18944, 152064),
        "qwen2.5-14b": (48, 5120, 40, 13824, 152064),
        "chameleon-34b": (48, 8192, 64, 22016, 65536),
        "zamba2-2.7b": (54, 2560, 32, 10240, 32000),
        "whisper-medium": (24, 1024, 16, 4096, 51865),
    }
    for arch, (nl, dm, nh, dff, v) in expect.items():
        cfg = registry.get(arch).cfg
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.d_ff,
                cfg.vocab) == (nl, dm, nh, dff, v), arch
    m = registry.get("mamba2-2.7b").cfg
    assert (m.n_layers, m.d_model, m.vocab, m.ssm_state) == \
        (64, 2560, 50280, 128)
    ds = registry.get("deepseek-v2-236b").cfg
    assert (ds.n_experts, ds.top_k, ds.kv_lora_rank) == (160, 6, 512)
    db = registry.get("dbrx-132b").cfg
    assert (db.n_experts, db.top_k, db.n_kv_heads) == (16, 4, 8)
