"""Multi-chip sharded planner (ISSUE 3): shard geometry, ICI pricing,
the 1-chip == plan_network regression, the 4-chip-beats-1-chip tight
config with full simulator reconciliation, and cluster-model validation."""
import pytest

from repro.configs import tight
from repro.configs.clusters import make_cluster
from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import TPU_V5E, ClusterModel, HardwareModel
from repro.core.multichip import (MODES, halo_elements, ici_schedule,
                                  kernel_shard_specs,
                                  plan_multichip_network, row_shard_specs)
from repro.core.network_planner import InfeasibleNetworkError, plan_network
from repro.sim import simulate_multichip

SMALL_NET = (ConvSpec(1, 10, 10, 2, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3))

FAST = dict(polish_iters=600, polish_restarts=1)

TIGHT_BUDGET = max(s.kernel_elements for s in tight.LAYERS) // 2


# --------------------------------------------------------------------- #
# ClusterModel
# --------------------------------------------------------------------- #

def test_cluster_model_validation():
    chip = HardwareModel(nbop_pe=10 ** 9)
    with pytest.raises(ValueError):
        ClusterModel(chip=chip, n_chips=0)
    with pytest.raises(ValueError):
        ClusterModel(chip=chip, n_chips=2, t_ici=-1.0)
    with pytest.raises(ValueError):
        ClusterModel(chip=chip, n_chips=2, topology="torus2d")
    assert ClusterModel(chip=chip, n_chips=4, t_ici=2.0).n_chips == 4


def test_tpu_as_cluster_units():
    """t_ici prices one element over one ICI link in the same seconds
    unit as t_l; the ratio is the HBM/ICI bandwidth ratio (~16 on v5e)."""
    cluster = TPU_V5E.as_cluster(4)
    assert cluster.n_chips == 4
    assert cluster.t_ici == pytest.approx(2 / TPU_V5E.ici_bw_per_link)
    assert cluster.t_ici / cluster.chip.t_l == pytest.approx(
        TPU_V5E.hbm_bw / TPU_V5E.ici_bw_per_link)


# --------------------------------------------------------------------- #
# Shard geometry
# --------------------------------------------------------------------- #

def test_row_shard_specs_cover_output_rows():
    spec = ConvSpec(3, 12, 12, 4, 3, 3)          # h_out = 10
    shards = row_shard_specs(spec, 4)
    assert [s.h_out for _, _, s in shards] == [3, 3, 2, 2]
    assert sum(s.h_out for _, _, s in shards) == spec.h_out
    r_prev = 0
    for chip, (r0, r1), sspec in shards:
        assert r0 == r_prev and r1 > r0
        r_prev = r1
        # halo-extended input window of the band
        assert sspec.h_in == (sspec.h_out - 1) * spec.s_h + spec.h_k
        assert sspec.w_in == spec.w_in and sspec.c_in == spec.c_in
        assert sspec.n_kernels == spec.n_kernels
    assert r_prev == spec.h_out


def test_row_shard_specs_strided_and_idle_chips():
    spec = ConvSpec(2, 11, 11, 3, 3, 3, s_h=2, s_w=2)   # h_out = 5
    shards = row_shard_specs(spec, 8)            # more chips than rows
    assert len(shards) == 5                      # 3 chips idle
    assert all(s.h_out == 1 for _, _, s in shards)
    assert all(s.h_in == spec.h_k for _, _, s in shards)


def test_kernel_shard_specs_cover_kernels():
    spec = ConvSpec(3, 8, 8, 10, 3, 3)
    shards = kernel_shard_specs(spec, 4)
    assert [s.n_kernels for _, _, s in shards] == [3, 3, 2, 2]
    k_prev = 0
    for chip, (k0, k1), sspec in shards:
        assert k0 == k_prev and k1 - k0 == sspec.n_kernels
        k_prev = k1
        assert (sspec.h_in, sspec.w_in) == (spec.h_in, spec.w_in)
    assert k_prev == spec.n_kernels
    # more chips than kernels: idle chips
    assert len(kernel_shard_specs(spec, 16)) == 10


def test_halo_elements_stride_cases():
    assert halo_elements(ConvSpec(4, 10, 10, 2, 3, 3)) == 2 * 10 * 4
    assert halo_elements(ConvSpec(4, 11, 11, 2, 3, 3, s_h=2, s_w=2)) \
        == 1 * 11 * 4
    # stride covers the kernel: bands do not overlap, no halo
    assert halo_elements(ConvSpec(4, 12, 12, 2, 3, 3, s_h=3, s_w=3)) == 0


# --------------------------------------------------------------------- #
# 1-chip regression: exact plan_network equality
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("specs,size_mem", [
    (SMALL_NET, None),
    (tight.LAYERS_SMALL, max(s.kernel_elements
                             for s in tight.LAYERS_SMALL) - 1),
])
def test_one_chip_reproduces_plan_network_exactly(specs, size_mem):
    cluster = make_cluster(1, size_mem=size_mem)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    net = plan_network(list(specs), cluster.chip, rng_seed=3, **FAST)
    mc = plan_multichip_network(list(specs), cluster, rng_seed=3, **FAST)
    assert mc.total_duration == net.total_duration
    assert mc.network_plan is not None
    assert mc.mode_string == "R" * len(specs)
    for mlp, lp in zip(mc.layers, net.layers):
        assert len(mlp.shards) == 1
        assert mlp.shards[0].strategy == lp.strategy
        assert mlp.duration == pytest.approx(lp.duration)
    # no ICI anywhere on one chip
    assert mc.ici_duration == 0.0
    # the delegated plan passes the cluster simulator's reconciliation
    rep = simulate_multichip(mc)
    assert rep.correct and rep.accounting_exact and rep.peak_within_budget
    assert rep.modeled_total_duration == net.total_duration


# --------------------------------------------------------------------- #
# The tight-config acceptance: 4 chips beat 1 chip, simulator-confirmed
# --------------------------------------------------------------------- #

def test_four_chip_beats_one_chip_on_tight_config():
    """configs/tight.py LAYERS at half the largest Λ: the single chip is
    forced into S2 kernel swapping on the deep layers; four chips shard
    the kernel set back into S1 territory and win despite ICI."""
    specs = tight.LAYERS
    c1 = make_cluster(1, size_mem=TIGHT_BUDGET)
    c4 = make_cluster(4, size_mem=TIGHT_BUDGET)
    p1 = plan_multichip_network(specs, c1, **FAST)
    p4 = plan_multichip_network(specs, c4, **FAST)
    assert p4.total_duration < p1.total_duration
    assert p4.n_sharded_layers >= 1
    assert p4.single_chip_duration == pytest.approx(p1.total_duration)
    assert p4.speedup_vs_single_chip > 1.0
    # sharding restores S1 feasibility the single chip lost
    one_chip_s2 = sum(1 for lp in p1.layers
                      for s in lp.shards if s.mode == "s2")
    assert one_chip_s2 >= 1
    for lp in p4.layers:
        if lp.mode == "channel":
            assert all(s.mode == "s1" for s in lp.shards)
    # full functional + accounting + per-chip memory reconciliation
    rep = simulate_multichip(p4)
    assert rep.correct
    assert rep.accounting_exact
    assert rep.peak_within_budget


def test_stitched_check_catches_shard_geometry_bugs():
    """The cluster simulator carves every shard out of ONE shared layer
    and stitches the outputs against the full reference — so a wrong
    band offset must flip ``correct`` to False (guards the guard)."""
    import dataclasses

    specs = tight.LAYERS
    c4 = make_cluster(4, size_mem=TIGHT_BUDGET)
    plan = plan_multichip_network(specs, c4, **FAST,
                                  include_single_chip_baseline=False)
    assert simulate_multichip(plan).correct
    for li, lp in enumerate(plan.layers):
        if lp.mode == "row":
            s0 = lp.shards[0]
            bad_shard = dataclasses.replace(
                s0, out_rows=(s0.out_rows[0] + 1, s0.out_rows[1] + 1))
            bad_layer = dataclasses.replace(
                lp, shards=(bad_shard,) + lp.shards[1:])
            bad_plan = dataclasses.replace(
                plan, layers=plan.layers[:li] + (bad_layer,)
                + plan.layers[li + 1:])
            assert not simulate_multichip(bad_plan).correct
            break
    else:
        pytest.fail("expected a row-sharded layer in the tight plan")


def test_sharded_layers_respect_per_chip_budget():
    specs = tight.LAYERS
    c4 = make_cluster(4, size_mem=TIGHT_BUDGET)
    p4 = plan_multichip_network(specs, c4, **FAST,
                                include_single_chip_baseline=False)
    assert p4.peak_footprint <= TIGHT_BUDGET
    for lp in p4.layers:
        for s in lp.shards:
            assert s.strategy.peak_footprint_elements() <= TIGHT_BUDGET


# --------------------------------------------------------------------- #
# ICI pricing
# --------------------------------------------------------------------- #

def test_ici_cost_monotone_and_replicate_collapse():
    """Raising t_ici never helps, and an ICI expensive enough makes the
    DP fall back to the all-replicate chain (whose ICI is zero: the
    activation stays on chip 0 end to end)."""
    specs = tight.LAYERS
    totals = []
    for factor in (0.0, 4.0, 1e6):
        cluster = make_cluster(4, size_mem=TIGHT_BUDGET, ici_factor=factor)
        plan = plan_multichip_network(specs, cluster, **FAST,
                                      include_single_chip_baseline=False)
        totals.append(plan.total_duration)
    assert totals == sorted(totals)
    expensive = make_cluster(4, size_mem=TIGHT_BUDGET, ici_factor=1e6)
    plan = plan_multichip_network(specs, expensive, **FAST,
                                  include_single_chip_baseline=False)
    assert plan.mode_string == "R" * len(specs)
    assert plan.ici_duration == 0.0


def test_ici_schedule_matches_plan_charges():
    """The pure re-pricing function must reproduce exactly the ICI the
    planner charged along the chosen mode sequence."""
    specs = tight.LAYERS
    cluster = make_cluster(4, size_mem=TIGHT_BUDGET)
    plan = plan_multichip_network(specs, cluster, **FAST,
                                  include_single_chip_baseline=False)
    per_layer, final = ici_schedule(
        [lp.spec for lp in plan.layers],
        [lp.mode for lp in plan.layers],
        [lp.active_chips for lp in plan.layers], cluster)
    assert per_layer == [lp.ici_elements for lp in plan.layers]
    assert final == plan.final_gather_elements
    assert plan.total_duration == pytest.approx(
        sum(lp.compute_duration for lp in plan.layers)
        + (sum(per_layer) + final) * cluster.t_ici)


def test_layer_zero_pays_no_ici():
    """The host stages the network input in every chip's DRAM, so the
    first layer is ICI-free in any mode."""
    specs = tight.LAYERS
    cluster = make_cluster(4, size_mem=TIGHT_BUDGET)
    for mode in MODES:
        try:
            plan = plan_multichip_network(
                specs[:1], cluster, modes=(mode,), **FAST,
                include_single_chip_baseline=False)
        except InfeasibleNetworkError:
            continue
        assert plan.layers[0].ici_elements == 0


# --------------------------------------------------------------------- #
# Determinism / errors
# --------------------------------------------------------------------- #

def test_deterministic_under_fixed_seed():
    specs = tight.LAYERS_SMALL
    cluster = make_cluster(2, size_mem=TIGHT_BUDGET)
    solver.solve_cached.cache_clear()
    a = plan_multichip_network(specs, cluster, rng_seed=11, **FAST)
    solver.solve_cached.cache_clear()
    b = plan_multichip_network(specs, cluster, rng_seed=11, **FAST)
    assert a.total_duration == b.total_duration
    assert a.mode_string == b.mode_string


def test_infeasible_cluster_raises_with_context():
    cluster = make_cluster(4, size_mem=8)
    with pytest.raises(InfeasibleNetworkError,
                       match=r"layer 0 .*size_mem=8.*4 chips"):
        plan_multichip_network(SMALL_NET, cluster, **FAST)


def test_empty_network_rejected():
    with pytest.raises(ValueError, match="empty"):
        plan_multichip_network([], make_cluster(2), **FAST)
