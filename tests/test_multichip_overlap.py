"""Multi-chip overlap + duration-balanced bands (ISSUE 4): the 1-chip
delegation stays exact under the new flags, balanced band heights never
exceed the row-balanced max-over-chips duration, the overlap accounting
reconciles exactly in the cluster simulator, and ``overlap=False``
reproduces the serialised per-layer identity bit-exactly."""
import pytest

from repro.configs import tight
from repro.configs.clusters import make_cluster
from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.multichip import (balanced_row_heights,
                                  band_solve_duration,
                                  plan_multichip_network, row_shard_specs)
from repro.core.network_planner import plan_network
from repro.sim import simulate_multichip

FAST = dict(polish_iters=600, polish_restarts=1)

TIGHT_BUDGET = max(s.kernel_elements for s in tight.LAYERS) // 2


# --------------------------------------------------------------------- #
# 1-chip delegation under the new flags
# --------------------------------------------------------------------- #

def test_one_chip_with_overlap_flags_reproduces_plan_network():
    specs = tight.LAYERS_SMALL
    cluster = make_cluster(1, size_mem=TIGHT_BUDGET)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    net = plan_network(list(specs), cluster.chip, rng_seed=5, **FAST)
    mc = plan_multichip_network(list(specs), cluster, rng_seed=5,
                                overlap=True, balance_rows=True, **FAST)
    assert mc.total_duration == net.total_duration
    assert mc.overlap and mc.balance_rows
    for mlp, lp in zip(mc.layers, net.layers):
        assert mlp.shards[0].strategy == lp.strategy
        assert mlp.duration == pytest.approx(lp.duration)
    rep = simulate_multichip(mc)
    assert rep.correct and rep.accounting_exact and rep.peak_within_budget


# --------------------------------------------------------------------- #
# Duration-balanced bands
# --------------------------------------------------------------------- #

def test_balanced_heights_tile_rows_and_never_exceed_row_balance():
    """The balanced partition covers every output row with the right
    number of bands, and its max solved band duration is <= the
    near-even (row-balanced) split's."""
    hw = make_cluster(1, size_mem=TIGHT_BUDGET).chip
    kwargs = dict(nb_data_reload=2, time_limit=5.0, polish_iters=300,
                  use_milp=False, rng_seed=0, polish_restarts=1)
    for spec, n_chips in ((tight.TIGHT_L3, 3), (tight.TIGHT_L2, 4),
                          (ConvSpec(3, 12, 12, 4, 3, 3), 4)):
        heights = balanced_row_heights(spec, hw, n_chips, 16, kwargs)
        assert heights is not None
        n = min(n_chips, spec.h_out)
        assert len(heights) == n
        assert sum(heights) == spec.h_out
        assert min(heights) >= 1

        def max_dur(hts):
            return max(band_solve_duration(spec, r, hw, 16, kwargs)
                       for r in hts)
        even = [r1 - r0 for _, (r0, r1), _ in row_shard_specs(spec, n)]
        assert max_dur(heights) <= max_dur(even) + 1e-9
        # the shard geometry accepts the balanced heights
        shards = row_shard_specs(spec, n_chips, heights)
        assert [s.h_out for _, _, s in shards] == heights


def test_row_shard_specs_rejects_bad_heights():
    spec = ConvSpec(3, 12, 12, 4, 3, 3)      # h_out = 10
    with pytest.raises(ValueError):
        row_shard_specs(spec, 4, heights=[5, 5, 5, 5])
    with pytest.raises(ValueError):
        row_shard_specs(spec, 4, heights=[10, 0, 0, 0])
    with pytest.raises(ValueError):
        row_shard_specs(spec, 4, heights=[5, 5])


# --------------------------------------------------------------------- #
# Overlap accounting
# --------------------------------------------------------------------- #

def _plans(overlap, balance):
    cluster = make_cluster(4, size_mem=TIGHT_BUDGET)
    return plan_multichip_network(
        tight.LAYERS, cluster, include_single_chip_baseline=False,
        overlap=overlap, balance_rows=balance, **FAST)


def test_overlap_never_slower_and_strictly_faster_with_ici():
    ser = _plans(False, False)
    ovl = _plans(True, True)
    assert ovl.total_duration <= ser.total_duration
    # the tight config shards, so some stage pays ICI the overlap hides
    assert ser.ici_duration > 0
    assert ovl.total_duration < ser.total_duration


def test_serialized_accounting_identity_unchanged():
    """overlap=False: every layer's duration is exactly compute + ICI
    (the PR-3 serialised model) and the total is their sum plus the
    final gather — the bit-exact reproduction path."""
    ser = _plans(False, False)
    assert not ser.overlap
    total = ser.final_gather_duration
    for lp in ser.layers:
        assert lp.duration == pytest.approx(
            lp.compute_duration + lp.ici_duration)
        total += lp.duration
    assert total == pytest.approx(ser.total_duration)


def test_overlap_accounting_identity_and_sim_reconciliation():
    """overlap=True: a stage the planner proved WAR-free (per-layer
    ``lp.overlap``) prices max(compute, ICI); a halo exchange it could
    not prove safe stays serialised at compute + ICI.  The cluster
    simulator's accounting_exact must recompose the total from measured
    shard durations under each stage's own discipline."""
    ovl = _plans(True, True)
    assert ovl.overlap
    total = ovl.final_gather_duration
    for lp in ovl.layers:
        if lp.overlap:
            assert lp.duration == pytest.approx(
                max(lp.compute_duration, lp.ici_duration))
        else:
            assert lp.duration == pytest.approx(
                lp.compute_duration + lp.ici_duration)
        total += lp.duration
    assert total == pytest.approx(ovl.total_duration)
    rep = simulate_multichip(ovl)
    assert rep.correct
    assert rep.accounting_exact
    assert rep.peak_within_budget


def test_overlap_accounting_detects_wrong_totals():
    """Guard the guard: perturbing the plan total must flip
    accounting_exact under the overlap discipline."""
    import dataclasses

    ovl = _plans(True, False)
    bad = dataclasses.replace(ovl, total_duration=ovl.total_duration + 1.0)
    assert not simulate_multichip(bad).accounting_exact
