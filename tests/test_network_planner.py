"""Network-level planner: determinism, solve-cache reuse, dominance over
the per-layer-greedy baseline, inter-layer reuse gating, exact agreement
of the duration model with the Sec-6 simulator, and memory feasibility —
the S2 kernel-swapping fallback plus row-window cascading (ISSUE 2)."""
import pytest

from repro.configs import lenet5, resnet8, tight
from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import (InfeasibleNetworkError,
                                        activation_fits,
                                        greedy_network_duration,
                                        plan_network, resolve_group_size,
                                        row_window_rows)
from repro.core.strategies import best_heuristic
from repro.sim import simulate_network

HW = HardwareModel(nbop_pe=10 ** 9, size_mem=None)

SMALL_NET = (ConvSpec(1, 10, 10, 2, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3))     # repeated layer for the cache

FAST = dict(polish_iters=800, polish_restarts=2)


def test_deterministic_under_fixed_seed():
    solver.solve_cached.cache_clear()
    a = plan_network(SMALL_NET, HW, rng_seed=7, **FAST)
    solver.solve_cached.cache_clear()
    b = plan_network(SMALL_NET, HW, rng_seed=7, **FAST)
    assert a.total_duration == b.total_duration
    assert [lp.strategy for lp in a.layers] == \
        [lp.strategy for lp in b.layers]


def test_solve_cache_hits_on_repeated_layers():
    solver.solve_cached.cache_clear()
    plan = plan_network(SMALL_NET, HW, **FAST)
    # layers 1 and 2 share a spec: one miss, one hit
    assert plan.solver_calls == 3
    assert plan.cache_hits == 1
    # planning the same network again is all hits
    plan2 = plan_network(SMALL_NET, HW, **FAST)
    assert plan2.cache_hits == 3
    assert plan2.total_duration == plan.total_duration


def test_network_beats_per_layer_greedy_baseline():
    """Network objective <= sum of per-layer best-heuristic objectives
    (same full Def-3 accounting, no reuse) — per layer and in aggregate."""
    plan = plan_network(SMALL_NET, HW, **FAST)
    for lp in plan.layers:
        greedy = best_heuristic(lp.spec, lp.p, HW).full_duration(HW)
        assert lp.gross_duration <= greedy
    assert plan.baseline_duration == greedy_network_duration(SMALL_NET, HW)
    assert plan.total_duration <= plan.gross_duration <= \
        plan.baseline_duration


def test_reuse_only_when_activation_fits_budget():
    # unconstrained: every adjacent pair reuses
    plan = plan_network(SMALL_NET, HW, **FAST)
    assert all(lp.reuse_output for lp in plan.layers[:-1])
    assert all(lp.reuse_input for lp in plan.layers[1:])
    assert not plan.layers[-1].reuse_output    # nothing follows the last
    assert not plan.layers[0].reuse_input      # network input is in DRAM

    # a budget that fits each layer alone but not layer + held activation:
    # reuse must be dropped, never claimed infeasibly
    spec = SMALL_NET[1]
    tight_mem = max(s.kernel_elements + s.num_pixels * s.c_in
                    + 3 * 16 * s.c_out for s in SMALL_NET)
    tight = HardwareModel(nbop_pe=10 ** 9, size_mem=tight_mem)
    plan_t = plan_network(SMALL_NET, tight, **FAST)
    for prev, nxt in zip(plan_t.layers, plan_t.layers[1:]):
        if prev.reuse_output:
            assert activation_fits(prev.spec, prev.strategy,
                                   nxt.spec, nxt.strategy, tight)
        assert prev.reuse_output == nxt.reuse_input
    assert plan_t.total_duration <= plan_t.gross_duration

    # a budget smaller than any held activation: zero reuse claimed
    tiny = HardwareModel(nbop_pe=10 ** 9, size_mem=1)
    strat = best_heuristic(spec, 4, tiny)
    assert not activation_fits(spec, strat, spec, strat, tiny)


def test_duration_model_matches_simulator_exactly():
    """Per-layer gross durations must equal the Sec-6 simulator's measured
    Def-3 durations, and the functional outputs must be correct."""
    plan = plan_network(SMALL_NET, HW, **FAST)
    rep = simulate_network(plan)
    assert rep.correct
    assert rep.accounting_exact
    assert rep.sim_gross_duration == pytest.approx(plan.gross_duration)


def test_plans_paper_networks():
    """LeNet-5 and ResNet-8 configs plan end-to-end and beat greedy."""
    for layers in (lenet5.LAYERS, resnet8.LAYERS):
        plan = plan_network(layers, HW, polish_iters=300, polish_restarts=1)
        assert plan.n_layers == len(layers)
        assert plan.total_duration < plan.baseline_duration
        crit = plan.critical_path()
        assert len(crit) == plan.n_layers
        assert crit[0][1] == max(lp.duration for lp in plan.layers)
        assert plan.report()


def test_tight_budget_falls_back_to_s2_and_stays_feasible():
    """Regression (ISSUE 2): a budget smaller than the largest layer's
    kernel set used to produce an infeasible S1 plan silently; now the
    planner must emit a feasible plan using S2 for that layer."""
    net = tight.LAYERS_SMALL
    budget = max(s.kernel_elements for s in net) - 1
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=budget)
    plan = plan_network(net, hw, **FAST)
    assert plan.n_s2_layers >= 1
    assert plan.peak_footprint <= budget
    for lp in plan.layers:
        assert lp.strategy.peak_footprint_elements() <= budget
        assert lp.duration >= 0
    # plan must not lose to the feasible per-layer-greedy baseline
    assert plan.total_duration <= plan.baseline_duration
    # exact functional + accounting + memory validation through the sims
    rep = simulate_network(plan)
    assert rep.correct
    assert rep.accounting_exact
    assert rep.peak_within_budget


def test_infeasible_budget_raises_not_silent():
    """plan_network / greedy_network_duration raise instead of returning
    an infeasible schedule when nothing fits."""
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=4)
    with pytest.raises(InfeasibleNetworkError):
        plan_network(SMALL_NET, hw, **FAST)
    with pytest.raises(InfeasibleNetworkError):
        greedy_network_duration(SMALL_NET, hw)


def test_savings_clamped_and_durations_nonnegative():
    """input_load_saved never exceeds the strategy's measured first-load
    traffic and no layer's net duration goes negative, across budgets."""
    for size_mem in (None, 600, 1200, 2400, 4800):
        hw = HardwareModel(nbop_pe=10 ** 9, size_mem=size_mem)
        try:
            plan = plan_network(SMALL_NET, hw, **FAST)
        except InfeasibleNetworkError:
            continue
        for lp in plan.layers:
            assert lp.duration >= 0
            assert lp.input_load_saved <= \
                lp.strategy.first_load_duration(hw) + 1e-9
            assert lp.write_back_saved <= \
                lp.strategy.write_back_duration(hw) + 1e-9


def test_row_window_cascade_partial_savings():
    """When the full activation does not fit, a halo-extended row window
    is held instead: partial first-load savings, no write-back savings.
    (Budget chosen for the joint (p, strategy) planner: at tighter budgets
    it now prefers a cheaper larger-footprint S2 schedule over windowing.)"""
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=4400)
    plan = plan_network(lenet5.LAYERS, hw, **FAST)
    windowed = [lp for lp in plan.layers if lp.window_rows]
    assert windowed, "expected a row-window cascade at this budget"
    for lp in windowed:
        assert not lp.reuse_input          # partial, not full residency
        assert lp.window_rows >= lp.spec.h_k   # halo-extended minimum
        assert 0 < lp.input_load_saved <= \
            lp.strategy.first_load_duration(hw)
        # the producer of a windowed consumer still writes back
        assert not plan.layers[lp.index - 1].reuse_output
    rep = simulate_network(plan)
    assert rep.correct and rep.accounting_exact and rep.peak_within_budget


def _resident_during(plan, i):
    """Everything resident while layer i executes: its held input map
    (full or window) plus — when it also holds its output — the
    accumulating output map next to its working set, else its full peak
    footprint (write-back buffers included)."""
    lp = plan.layers[i]
    held_in = 0
    if i > 0:
        prev = plan.layers[i - 1].spec
        if lp.reuse_input:
            held_in = max(prev.num_patches * prev.c_out,
                          lp.spec.num_pixels * lp.spec.c_in)
        elif lp.window_rows:
            held_in = lp.window_rows * lp.spec.w_in * lp.spec.c_in
    if lp.reuse_output:
        nxt = plan.layers[i + 1].spec
        held_out = max(lp.spec.num_patches * lp.spec.c_out,
                       nxt.num_pixels * nxt.c_in)
        return held_in + held_out + lp.strategy.peak_working_set_elements()
    return held_in + lp.strategy.peak_footprint_elements()


def test_combined_residency_within_budget():
    """A middle layer holding both its input map and its accumulating
    output map must still fit the budget — pairwise-only reuse checks
    used to overcommit memory on chains of three or more layers."""
    for specs in (SMALL_NET, tight.LAYERS):
        big = max(s.kernel_elements for s in specs)
        for frac in (0.5, 1.0, 1.5, 2.0, 3.0, 6.0):
            hw = HardwareModel(nbop_pe=10 ** 9, size_mem=int(big * frac))
            try:
                plan = plan_network(specs, hw, **FAST)
            except InfeasibleNetworkError:
                continue
            for i in range(plan.n_layers):
                assert _resident_during(plan, i) <= hw.size_mem, \
                    (hw.size_mem, i)


def test_row_window_rows_fit_condition():
    """Window sizing: bounded by the spare budget next to both layers'
    working sets, at least h_k rows, at most the consumer's input."""
    spec = SMALL_NET[1]
    strat = best_heuristic(spec, 4, HW)
    # unconstrained: full residency path, no window needed
    assert row_window_rows(spec, strat, spec, strat, HW) == 0
    # generous budget: full input window
    roomy = HardwareModel(nbop_pe=10 ** 9, size_mem=10 ** 6)
    assert row_window_rows(spec, strat, spec, strat, roomy) == spec.h_in
    # just enough spare for fewer than h_k rows: no window
    base = strat.peak_footprint_elements()
    barely = HardwareModel(
        nbop_pe=10 ** 9,
        size_mem=base + (spec.h_k - 1) * spec.w_in * spec.c_in)
    assert row_window_rows(spec, strat, spec, strat, barely) == 0


def test_reuse_aware_refinement_never_loses_to_raw_assembly():
    """The reuse-aware refinement (re-solving a consumer under a
    tightened cap to unblock inter-layer reuse) must only ever lower the
    total: plan_network's result is <= the assembly of the raw per-layer
    joint-search results."""
    from repro.core.network_planner import _assemble_layers, _resolve_ps

    for specs, size_mem in ((lenet5.LAYERS, 2400), (tight.LAYERS, 9216)):
        hw = HardwareModel(nbop_pe=10 ** 9, size_mem=size_mem)
        solver.solve_cached.cache_clear()
        solver.best_s2_cached.cache_clear()
        plan = plan_network(specs, hw, **FAST)
        ps = _resolve_ps(specs, hw, None, 16)
        raw = [solver.solve_cached(s, pp, hw, time_limit=10.0,
                                   use_milp=False, polish_iters=800,
                                   polish_restarts=2)
               for s, pp in zip(specs, ps)]
        _, raw_total, _ = _assemble_layers(specs, ps, raw, hw, True)
        assert plan.total_duration <= raw_total + 1e-9
        # refined plans stay feasible
        for lp in plan.layers:
            assert lp.strategy.peak_footprint_elements() <= size_mem
            assert lp.duration >= 0


def test_resolve_group_size_respects_pe_and_cap():
    spec = ConvSpec(1, 10, 10, 2, 3, 3)
    small_pe = HardwareModel(nbop_pe=spec.nb_op_value * spec.c_out * 3)
    assert resolve_group_size(spec, small_pe) == 3
    big_pe = HardwareModel(nbop_pe=10 ** 12)
    assert resolve_group_size(spec, big_pe, max_group=8) == 8
    assert resolve_group_size(spec, big_pe, max_group=None) == \
        spec.num_patches
    # PE below one full S1 patch row: group size 1 (solver goes S2)
    tiny_pe = HardwareModel(nbop_pe=spec.nb_op_value * spec.c_out - 1)
    assert resolve_group_size(spec, tiny_pe) == 1
