"""Network-level planner: determinism, solve-cache reuse, dominance over
the per-layer-greedy baseline, inter-layer reuse gating, and exact
agreement of the duration model with the Sec-6 simulator."""
import pytest

from repro.configs import lenet5, resnet8
from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import (activation_fits,
                                        greedy_network_duration,
                                        plan_network, resolve_group_size)
from repro.core.strategies import best_heuristic
from repro.sim import simulate_network

HW = HardwareModel(nbop_pe=10 ** 9, size_mem=None)

SMALL_NET = (ConvSpec(1, 10, 10, 2, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3),
             ConvSpec(2, 8, 8, 4, 3, 3))     # repeated layer for the cache

FAST = dict(polish_iters=800, polish_restarts=2)


def test_deterministic_under_fixed_seed():
    solver.solve_cached.cache_clear()
    a = plan_network(SMALL_NET, HW, rng_seed=7, **FAST)
    solver.solve_cached.cache_clear()
    b = plan_network(SMALL_NET, HW, rng_seed=7, **FAST)
    assert a.total_duration == b.total_duration
    assert [lp.strategy for lp in a.layers] == \
        [lp.strategy for lp in b.layers]


def test_solve_cache_hits_on_repeated_layers():
    solver.solve_cached.cache_clear()
    plan = plan_network(SMALL_NET, HW, **FAST)
    # layers 1 and 2 share a spec: one miss, one hit
    assert plan.solver_calls == 3
    assert plan.cache_hits == 1
    # planning the same network again is all hits
    plan2 = plan_network(SMALL_NET, HW, **FAST)
    assert plan2.cache_hits == 3
    assert plan2.total_duration == plan.total_duration


def test_network_beats_per_layer_greedy_baseline():
    """Network objective <= sum of per-layer best-heuristic objectives
    (same full Def-3 accounting, no reuse) — per layer and in aggregate."""
    plan = plan_network(SMALL_NET, HW, **FAST)
    for lp in plan.layers:
        greedy = best_heuristic(lp.spec, lp.p, HW).full_duration(HW)
        assert lp.gross_duration <= greedy
    assert plan.baseline_duration == greedy_network_duration(SMALL_NET, HW)
    assert plan.total_duration <= plan.gross_duration <= \
        plan.baseline_duration


def test_reuse_only_when_activation_fits_budget():
    # unconstrained: every adjacent pair reuses
    plan = plan_network(SMALL_NET, HW, **FAST)
    assert all(lp.reuse_output for lp in plan.layers[:-1])
    assert all(lp.reuse_input for lp in plan.layers[1:])
    assert not plan.layers[-1].reuse_output    # nothing follows the last
    assert not plan.layers[0].reuse_input      # network input is in DRAM

    # a budget that fits each layer alone but not layer + held activation:
    # reuse must be dropped, never claimed infeasibly
    spec = SMALL_NET[1]
    tight_mem = max(s.kernel_elements + s.num_pixels * s.c_in
                    + 3 * 16 * s.c_out for s in SMALL_NET)
    tight = HardwareModel(nbop_pe=10 ** 9, size_mem=tight_mem)
    plan_t = plan_network(SMALL_NET, tight, **FAST)
    for prev, nxt in zip(plan_t.layers, plan_t.layers[1:]):
        if prev.reuse_output:
            assert activation_fits(prev.spec, prev.strategy,
                                   nxt.spec, nxt.strategy, tight)
        assert prev.reuse_output == nxt.reuse_input
    assert plan_t.total_duration <= plan_t.gross_duration

    # a budget smaller than any held activation: zero reuse claimed
    tiny = HardwareModel(nbop_pe=10 ** 9, size_mem=1)
    strat = best_heuristic(spec, 4, tiny)
    assert not activation_fits(spec, strat, spec, strat, tiny)


def test_duration_model_matches_simulator_exactly():
    """Per-layer gross durations must equal the Sec-6 simulator's measured
    Def-3 durations, and the functional outputs must be correct."""
    plan = plan_network(SMALL_NET, HW, **FAST)
    rep = simulate_network(plan)
    assert rep.correct
    assert rep.accounting_exact
    assert rep.sim_gross_duration == pytest.approx(plan.gross_duration)


def test_plans_paper_networks():
    """LeNet-5 and ResNet-8 configs plan end-to-end and beat greedy."""
    for layers in (lenet5.LAYERS, resnet8.LAYERS):
        plan = plan_network(layers, HW, polish_iters=300, polish_restarts=1)
        assert plan.n_layers == len(layers)
        assert plan.total_duration < plan.baseline_duration
        crit = plan.critical_path()
        assert len(crit) == plan.n_layers
        assert crit[0][1] == max(lp.duration for lp in plan.layers)
        assert plan.report()


def test_resolve_group_size_respects_pe_and_cap():
    spec = ConvSpec(1, 10, 10, 2, 3, 3)
    small_pe = HardwareModel(nbop_pe=spec.nb_op_value * spec.c_out * 3)
    assert resolve_group_size(spec, small_pe) == 3
    big_pe = HardwareModel(nbop_pe=10 ** 12)
    assert resolve_group_size(spec, big_pe, max_group=8) == 8
    assert resolve_group_size(spec, big_pe, max_group=None) == \
        spec.num_patches
