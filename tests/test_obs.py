"""repro.obs: the unified offload timeline (ISSUE 8).

Locks the event model to the repo's independent duration authorities:
lane spans never self-overlap, per-layer span sums equal the static
verifier's Def-3 duration ledger *exactly*, multichip ICI spans
reconcile with ``core.multichip.ici_schedule``, the Chrome-trace export
validates against the pinned schema (and mutations are caught), the
drift report is zero on reconciled plans, the span-driven renderers
degrade to ``"?"`` on partial schedules, and the ``--profile`` key
vocabulary stays byte-stable across the metrics-registry migration.
"""
import json
import os
import sys

import pytest

from repro.analysis import verifier
from repro.configs.clusters import make_cluster
from repro.configs.networks import NETWORKS
from repro.core import strategies_s2 as s2
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.multichip import ici_schedule, plan_multichip_network
from repro.core.network_planner import plan_network
from repro.core.strategies import row_by_row, zigzag
from repro.obs import LANES, MetricsRegistry, Timeline
from repro.obs import adapters
from repro.obs.chrome import (to_chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.report import build_report, drift_rows
from repro.sim import ConvLayer
from repro.sim.s2 import run_s2
from repro.sim.system import System
from repro.sim.trace import (render_group_grid, render_spans_group_grid,
                             strategy_timeline)

BIG = HardwareModel(nbop_pe=10 ** 9, size_mem=None)
SPEC = ConvSpec(c_in=2, h_in=7, w_in=7, n_kernels=6, h_k=3, w_k=3)


# ------------------------------------------------------------------ #
# Span sums vs the verifier's duration ledger (exact, not approx: the
# unit cost model prices integer cycles, floats are exact)
# ------------------------------------------------------------------ #

@pytest.mark.parametrize("builder,p", [(row_by_row, 3), (zigzag, 5)])
def test_s1_span_sum_equals_verifier_ledger(builder, p):
    strat = builder(SPEC, p)
    tl = strategy_timeline(strat, BIG, layer=0)
    walk = verifier.walk_steps(SPEC, BIG, strat.to_steps())
    assert not walk.aborted
    assert tl.span_sum(layer=0) == walk.total_duration
    for idx, dur in enumerate(walk.durations):
        assert sum(s.dur for s in tl.spans if s.step == idx) == dur


@pytest.mark.parametrize("builder,p,kg", [(s2.kernel_major, 3, 2),
                                          (s2.patch_major, 4, 3)])
def test_s2_span_sum_equals_verifier_ledger(builder, p, kg):
    strat = builder(SPEC, p, kg)
    tl = strategy_timeline(strat, BIG, layer=0)
    walk = verifier.walk_steps(SPEC, BIG, strat.to_steps(),
                               kernel_groups=strat.kernel_groups)
    assert not walk.aborted
    assert tl.span_sum(layer=0) == walk.total_duration
    for idx, dur in enumerate(walk.durations):
        assert sum(s.dur for s in tl.spans if s.step == idx) == dur


def test_simulated_spans_match_predicted_spans_exactly():
    """The simulator's measured lane durations and DRAM element counts
    per step equal the plan's decomposition — S1 and S2."""
    layer = ConvLayer.random(SPEC, seed=3)
    for strat in (zigzag(SPEC, 4), s2.kernel_major(SPEC, 3, 2)):
        pred = strategy_timeline(strat, BIG, layer=0)
        if isinstance(strat, s2.S2Strategy):
            traces = run_s2(layer, BIG, strat).traces
        else:
            traces = System(layer, BIG).run(strat).traces
        sim_tl = Timeline("sim")
        adapters.add_sim_layer(sim_tl, traces, BIG, chip=0, layer=0,
                               t0=0.0)
        for lane in ("dma_in", "compute", "write_back"):
            assert pred.span_sum(layer=0, lane=lane) == \
                sim_tl.span_sum(layer=0, lane=lane)
            assert pred.element_sum(layer=0, lane=lane) == \
                sim_tl.element_sum(layer=0, lane=lane)


# ------------------------------------------------------------------ #
# Lane serialization
# ------------------------------------------------------------------ #

def test_lanes_never_self_overlap_network():
    plan = plan_network(NETWORKS["tight2"], BIG, name="tight2",
                        polish_iters=60, polish_restarts=1)
    tl = adapters.network_predicted_timeline(plan)
    assert tl.overlap_violations() == []
    assert tl.end_time == plan.gross_duration


def test_lanes_never_self_overlap_multichip():
    specs = NETWORKS["tight2"]
    size_mem = max(s.kernel_elements for s in specs) // 2
    cluster = make_cluster(2, size_mem=size_mem, topology="ring")
    plan = plan_multichip_network(specs, cluster, name="tight2",
                                  polish_iters=60, polish_restarts=1,
                                  include_single_chip_baseline=False)
    tl = adapters.multichip_predicted_timeline(plan)
    assert tl.overlap_violations() == []


def test_overlapping_spans_are_flagged():
    tl = Timeline("t")
    tl.add_span("a", "compute", 0, 0.0, 2.0)
    tl.add_span("b", "compute", 0, 1.0, 2.0)     # overlaps a
    tl.add_span("c", "compute", 1, 1.0, 2.0)     # other chip: fine
    assert len(tl.overlap_violations()) == 1


# ------------------------------------------------------------------ #
# Multichip ICI spans vs the pricing function
# ------------------------------------------------------------------ #

def test_multichip_ici_spans_reconcile_with_ici_schedule():
    specs = NETWORKS["tight2"]
    size_mem = max(s.kernel_elements for s in specs) // 2
    cluster = make_cluster(4, size_mem=size_mem, topology="torus2x2")
    plan = plan_multichip_network(specs, cluster, name="tight2",
                                  polish_iters=60, polish_restarts=1,
                                  include_single_chip_baseline=False)
    per_layer, final = ici_schedule(
        [lp.spec for lp in plan.layers],
        [lp.mode for lp in plan.layers],
        [lp.active_chips for lp in plan.layers], cluster)
    tl = adapters.multichip_predicted_timeline(plan)
    for lp, elems in zip(plan.layers, per_layer):
        assert lp.ici_elements == elems
        spans = tl.select(layer=lp.index, lane="ici")
        if elems == 0:
            assert spans == []
            continue
        assert len(spans) == len(lp.shards)      # one span per chip
        for s in spans:
            assert s.elements == elems
            assert s.dur == lp.ici_duration
    gather = [s for s in tl.select(lane="ici") if s.layer is None]
    assert sum(s.elements for s in gather) == \
        final * (len(plan.layers[-1].shards) if final else 0)


# ------------------------------------------------------------------ #
# Chrome trace export
# ------------------------------------------------------------------ #

def test_chrome_trace_validates_and_mutations_are_caught(tmp_path):
    tl = strategy_timeline(zigzag(SPEC, 4), BIG, layer=0)
    trace = to_chrome_trace([tl])
    assert validate_chrome_trace(trace) == []
    path = os.path.join(tmp_path, "trace.json")
    write_chrome_trace(trace, path)
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == []

    bad_phase = json.loads(json.dumps(trace))
    bad_phase["traceEvents"][0]["ph"] = "Q"
    assert validate_chrome_trace(bad_phase)

    missing_key = json.loads(json.dumps(trace))
    del missing_key["traceEvents"][-1]["pid"]
    assert validate_chrome_trace(missing_key)

    bad_lane = json.loads(json.dumps(trace))
    for ev in bad_lane["traceEvents"]:
        if ev["ph"] == "X":
            ev["cat"] = "warp_drive"
            break
    assert validate_chrome_trace(bad_lane)

    negative_ts = json.loads(json.dumps(trace))
    for ev in negative_ts["traceEvents"]:
        if ev["ph"] == "X":
            ev["ts"] = -1.0
            break
    assert validate_chrome_trace(negative_ts)


def test_chrome_trace_covers_all_lanes_per_chip():
    """Every (timeline, chip) process in the export carries its spans as
    thread rows indexed by the LANES order."""
    specs = NETWORKS["tight2"]
    size_mem = max(s.kernel_elements for s in specs) // 2
    cluster = make_cluster(2, size_mem=size_mem, topology="ring")
    plan = plan_multichip_network(specs, cluster, name="tight2",
                                  polish_iters=60, polish_restarts=1,
                                  include_single_chip_baseline=False)
    tl = adapters.multichip_predicted_timeline(plan)
    trace = to_chrome_trace([tl])
    assert validate_chrome_trace(trace) == []
    name_of = {e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
               if e["ph"] == "M" and e["name"] == "process_name"}
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    for chip in tl.chips():
        pids = {pid for pid, n in name_of.items()
                if n.endswith(f"chip{chip}")}
        lanes = {e["cat"] for e in xs if e["pid"] in pids}
        assert {"dma_in", "compute", "write_back"} <= lanes


# ------------------------------------------------------------------ #
# Drift report
# ------------------------------------------------------------------ #

def test_drift_report_zero_on_reconciled_single_chip():
    rep = build_report("tight2", iters=60, restarts=1)
    assert rep.sim_correct and rep.accounting_exact
    assert rep.trace_valid, rep.trace_errors
    assert rep.max_drift_elements == 0
    assert rep.max_drift_cycles == 0.0
    assert rep.ok
    assert all(r.first_divergent_step is None for r in rep.rows)


def test_drift_report_zero_on_reconciled_multichip():
    rep = build_report("tight2", topology="ring", n_chips=2,
                       iters=60, restarts=1, include_kernel=False)
    assert rep.ok
    assert rep.max_drift_elements == 0


def test_drift_rows_attribute_divergence_to_first_step():
    """A tampered simulated timeline is pinned to the step, lane and
    chip where it first deviates."""
    strat = zigzag(SPEC, 4)
    pred = strategy_timeline(strat, BIG, layer=0)
    tampered = Timeline("tampered")
    victim = None
    for s in pred.spans:
        if victim is None and s.lane == "dma_in" and s.step == 2:
            victim = s
            tampered.add_span(s.name, s.lane, s.chip, s.t0, s.dur + 1.0,
                              layer=s.layer, step=s.step,
                              elements=s.elements + 7)
        else:
            tampered.extend([s])
    assert victim is not None
    rows = drift_rows(pred, tampered)
    bad = [r for r in rows if not r.clean]
    assert bad and all(r.lane == "dma_in" for r in bad)
    assert {r.first_divergent_step for r in rows} == {2}
    assert max(r.drift_elements for r in bad) == 7


# ------------------------------------------------------------------ #
# Renderers on the event model
# ------------------------------------------------------------------ #

def test_render_group_grid_matches_strategy_and_has_no_placeholders():
    out = render_group_grid(zigzag(SPEC, 4))
    assert "?" not in out
    body = out.splitlines()[1:]
    assert len(body) == SPEC.h_out
    assert all(len(r.split()) == SPEC.w_out for r in body)


def test_render_partial_schedule_pads_placeholder_to_cell_width():
    """Unassigned output positions render '?' at the same cell width as
    assigned ones, so partial schedules (e.g. one shard's band) align."""
    strat = zigzag(ConvSpec(c_in=1, h_in=14, w_in=14, n_kernels=1,
                            h_k=3, w_k=3), 7)
    tl = strategy_timeline(strat)
    assert strat.n_steps > 10          # 2-digit step labels force cell=2
    compute = [s for s in tl.spans if s.lane == "compute"]
    kept = [s for s in tl.spans
            if s.lane != "compute" or (s.step or 0) < len(compute) // 2]
    out = render_spans_group_grid(kept, strat.spec, title="partial")
    lines = out.splitlines()[1:]
    assert any("?" in ln for ln in lines)
    # every cell (assigned label or '?') is right-justified to the same
    # 2-char width, so all rows are the same length and columns align
    w_out = strat.spec.w_out
    assert all(len(ln) == 3 * w_out - 1 for ln in lines)
    for ln in lines:
        cells = [ln[3 * i:3 * i + 2] for i in range(w_out)]
        assert all(c == " ?" or c.strip().isdigit() for c in cells)
    assert any(" ?" in ln for ln in lines)


# ------------------------------------------------------------------ #
# Metrics registry + profile key stability
# ------------------------------------------------------------------ #

def test_metrics_registry_accumulates_and_nests():
    reg = MetricsRegistry()
    reg.incr("a/b", 2)
    reg.incr("a/b", 3)
    reg.set("a/c/d", 1.23456)
    with reg.timer("t/x"):
        pass
    with reg.timer("t/x"):
        pass
    snap = reg.snapshot()
    assert snap["a"]["b"] == 5
    assert snap["a"]["c"]["d"] == 1.2346       # rounded
    assert reg.get("t/x") >= 0                 # accumulated twice
    assert reg.keys() == ["a/b", "a/c/d", "t/x"]
    reg.clear()
    assert reg.keys() == []


def test_profile_keys_byte_stable_vs_pr3_vocabulary():
    """The --profile payload built from the registry keeps the frozen
    key vocabulary the perf trajectory diffs (``planner_seconds`` /
    ``stages`` / ``lru``); per-call planner detail is additive only."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(repo, "benchmarks"))
    import network_plan as bench
    bench.REGISTRY.clear()
    for k in ("networks_s", "mem_sweep_s", "chip_sweep_s"):
        with bench.REGISTRY.timer(f"bench/{k}"):
            pass
    bench._record_lru_stats()
    profile = bench.build_profile()
    assert set(profile) <= {"planner_seconds", "stages", "lru", "planner"}
    assert set(profile["stages"]) == \
        {"networks_s", "mem_sweep_s", "chip_sweep_s"}
    assert set(profile["lru"]) == {"solve_cached", "best_s2_cached"}
    for lru in profile["lru"].values():
        assert set(lru) == {"hits", "misses", "hit_rate",
                            "evictions", "maxsize"}
        assert isinstance(lru["hits"], int)
        assert isinstance(lru["evictions"], int)
    # the planner hooks fire on every plan_network call
    bench.REGISTRY.clear()
    plan_network([SPEC], BIG, name="one", polish_iters=40,
                 polish_restarts=1)
    assert bench.REGISTRY.get("planner/plan_network_calls") == 1
    assert bench.REGISTRY.get("planner/solve_s") > 0
    detail = bench.REGISTRY.snapshot("planner")
    assert {"plan_network_calls", "solve_s", "refine_s",
            "baseline_s"} <= set(detail)


def test_counters_exported_and_monotone_traffic():
    plan = plan_network(NETWORKS["tight2"], BIG, name="tight2",
                        polish_iters=60, polish_restarts=1)
    tl = adapters.network_predicted_timeline(plan)
    reads = [c.value for c in tl.counters
             if c.name == "dram_read_elements"]
    assert reads == sorted(reads) and reads[-1] > 0
    trace = to_chrome_trace([tl])
    assert any(e["ph"] == "C" for e in trace["traceEvents"])
    assert len(LANES) == 6      # 4 execution lanes + fault + recovery
