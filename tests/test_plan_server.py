"""Plan server (``repro.launch.plan_server``): sweep queries answered
from the persistent cache, verified and bit-identical warm vs cold
(ISSUE 10)."""
import json
import os

import pytest

from repro.configs.networks import NETWORKS
from repro.configs.tight import budget_points
from repro.core import solver
from repro.launch import plan_server
from repro.launch.plan_server import PlanQuery, PlanService, resolve_topology
from repro.plancache import store as store_mod


@pytest.fixture
def plan_cache(tmp_path):
    prev = os.environ.get(store_mod.ENV_VAR)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    store = store_mod.configure(tmp_path / "cache")
    yield store
    if prev is None:
        store_mod.configure(None)
    else:
        store_mod.configure(prev)
    store_mod.reset()
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()


def _budgets(network, n=2):
    return budget_points(NETWORKS[network])[-n:]


# ------------------------------------------------------------------ #
# Topology resolution / sweep shape
# ------------------------------------------------------------------ #

def test_resolve_topology_grid():
    assert resolve_topology("ring", 1) == "ring"
    assert resolve_topology("torus2x2", 1) == "ring"   # 1 chip: no links
    assert resolve_topology("torus2x2", 4) == "torus2x2"
    assert resolve_topology("torus2x2", 3) is None     # grid mismatch
    assert resolve_topology("torus", 4) == "torus2x2"
    assert resolve_topology("biring", 4) == "biring"


def test_sweep_dedups_single_chip_wirings(plan_cache):
    """At n_chips=1 every wiring resolves to the same scenario — it must
    be planned once, not once per requested topology."""
    svc = PlanService()
    budgets = _budgets("tight2", n=1)
    rows = svc.sweep("tight2", budgets=budgets,
                     topologies=("ring", "torus2x2", "biring"),
                     chip_counts=(1,), polish_iters=50)
    assert len(rows) == len(budgets)
    assert all(r["topology"] == "ring" and r["n_chips"] == 1 for r in rows)


def test_unknown_network_rejected():
    with pytest.raises(KeyError):
        PlanService().query(PlanQuery(network="nope"))


# ------------------------------------------------------------------ #
# Query rows: verification, fingerprints, cache attribution
# ------------------------------------------------------------------ #

def test_query_verified_row_with_attribution(plan_cache):
    svc = PlanService()
    q = PlanQuery(network="tight2", size_mem=_budgets("tight2", n=1)[0],
                  polish_iters=50)
    row = svc.query(q)
    assert row["feasible"] and row["verified"]
    assert row["solver_calls"] >= 1
    assert isinstance(row["fingerprint"], str) and len(row["fingerprint"]) >= 16
    # same query again: the LRU answers, zero extra store traffic
    row2 = svc.query(q)
    assert row2["fingerprint"] == row["fingerprint"]
    assert row2["cache_hits"] >= 1


def test_warm_sweep_bit_identical_and_served_from_store(plan_cache):
    """Cold sweep populates the store; after an in-process 'restart'
    (LRUs emptied, store object rebuilt) the warm sweep must replay
    bit-identical plans from disk."""
    svc = PlanService()
    kw = dict(budgets=_budgets("tight2"), topologies=("ring",),
              chip_counts=(1,), polish_iters=50)
    cold = svc.sweep("tight2", **kw)
    assert len(plan_cache) >= 1
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    store_mod.reset()
    warm = svc.sweep("tight2", **kw)
    store = store_mod.active_store()
    assert store.hits >= 1
    assert [r["feasible"] for r in warm] == [r["feasible"] for r in cold]
    for c, w in zip(cold, warm):
        if c["feasible"]:
            assert w["fingerprint"] == c["fingerprint"]
            assert w["total_duration"] == c["total_duration"]


# ------------------------------------------------------------------ #
# CLI
# ------------------------------------------------------------------ #

def test_cli_exit_zero_and_json_out(tmp_path, plan_cache, capsys):
    out = tmp_path / "sweep.json"
    rc = plan_server.main([
        "--network", "tight2", "--budgets", "auto",
        "--topologies", "ring", "--chips", "1",
        "--iters", "50", "--out", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    (sweep,) = payload["sweeps"]
    assert sweep["network"] == "tight2"
    assert all(r["verified"] for r in sweep["rows"] if r["feasible"])
    assert payload["cache"]["lru"]["solve_cached"]["misses"] >= 0
    assert "plan_server" in capsys.readouterr().out
