"""Persistent plan cache (``repro.plancache``): content-hashed keys,
cold/warm restart round-trips (bit-identical), typed corruption recovery
(evict + transparent re-solve), schema-version invalidation, atomic
concurrent writes, and the never-worse warm-start rule (ISSUE 10)."""
import dataclasses
import json
import os
import threading

import pytest

from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.plancache import (CacheCorruptionError, CacheSchemaError,
                             PlanStore)
from repro.plancache import codec
from repro.plancache import store as store_mod

SPEC = ConvSpec(3, 10, 10, 4, 3, 3)
HW = HardwareModel(nbop_pe=10 ** 9, size_mem=600)
KNOBS = dict(polish_iters=200, use_milp=False)


@pytest.fixture
def plan_cache(tmp_path):
    """A throwaway configured store; restores the env and clears every
    in-memory layer afterwards."""
    prev = os.environ.get(store_mod.ENV_VAR)
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    store = store_mod.configure(tmp_path / "cache")
    yield store
    if prev is None:
        store_mod.configure(None)
    else:
        store_mod.configure(prev)
    store_mod.reset()
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()


def _restart():
    """In-process stand-in for a process restart: both LRUs emptied and
    the store object (with its counters) rebuilt from the env."""
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    store_mod.reset()
    return store_mod.active_store()


def _entry_files(store):
    return sorted(store.root.glob("*.json"))


# ------------------------------------------------------------------ #
# Keys
# ------------------------------------------------------------------ #

def test_default_equivalent_keys_collide():
    """Omitted knobs hash identically to explicitly-passed defaults —
    the canonicalization lru_cache itself does not do."""
    bare_key, bare_fam = codec.solve_key(SPEC, 4, HW)
    full_key, full_fam = codec.solve_key(
        SPEC, 4, HW, nb_data_reload=2, time_limit=30.0,
        polish_iters=30_000, use_milp=True, rng_seed=0, polish_restarts=1)
    assert bare_key == full_key and bare_fam == full_fam
    assert store_mod.canonical_digest(bare_key) == \
        store_mod.canonical_digest(full_key)


def test_family_digest_groups_budget_and_p_neighbors():
    """The family digest drops exactly the warm-start axes (p and
    size_mem): neighbours share it, different knobs/specs do not."""
    _, fam = codec.solve_key(SPEC, 4, HW, **KNOBS)
    _, fam_mem = codec.solve_key(
        SPEC, 4, dataclasses.replace(HW, size_mem=900), **KNOBS)
    _, fam_p = codec.solve_key(SPEC, 2, HW, **KNOBS)
    assert fam == fam_mem == fam_p
    _, fam_knob = codec.solve_key(SPEC, 4, HW, polish_iters=100,
                                  use_milp=False)
    _, fam_spec = codec.solve_key(ConvSpec(3, 12, 12, 4, 3, 3), 4, HW,
                                  **KNOBS)
    assert fam_knob != fam and fam_spec != fam


def test_unknown_knob_rejected():
    with pytest.raises(TypeError):
        codec.solve_key(SPEC, 4, HW, not_a_knob=1)


# ------------------------------------------------------------------ #
# Cold/warm round-trip
# ------------------------------------------------------------------ #

def test_cold_warm_restart_round_trip_bit_identical(plan_cache):
    cold = solver.solve_cached(SPEC, 4, HW, **KNOBS)
    assert plan_cache.misses == 1 and plan_cache.writes == 1
    store = _restart()
    warm = solver.solve_cached(SPEC, 4, HW, **KNOBS)
    assert store.hits == 1 and store.misses == 0
    assert warm == cold                    # bit-identical SolveResult
    assert warm.strategy == cold.strategy


def test_s2_round_trip_under_sub_kernel_budget(plan_cache):
    """A budget below the kernel set forces the S2 path; its S2Result
    (schedule + kernel groups) must survive the disk round-trip."""
    tight = HardwareModel(nbop_pe=10 ** 9, size_mem=60)
    assert tight.size_mem < SPEC.kernel_elements
    cold = solver.best_s2_cached(SPEC, tight)
    _restart()
    warm = solver.best_s2_cached(SPEC, tight)
    assert warm == cold
    assert warm.strategy.kernel_groups == cold.strategy.kernel_groups
    assert warm.strategy.schedule == cold.strategy.schedule


def test_lru_hit_never_touches_store(plan_cache):
    solver.solve_cached(SPEC, 4, HW, **KNOBS)
    before = plan_cache.stats()
    solver.solve_cached(SPEC, 4, HW, **KNOBS)     # LRU layer answers
    assert plan_cache.stats() == before


# ------------------------------------------------------------------ #
# Corruption recovery
# ------------------------------------------------------------------ #

def test_truncated_entry_typed_error_and_transparent_resolve(plan_cache):
    cold = solver.solve_cached(SPEC, 4, HW, **KNOBS)
    (path,) = _entry_files(plan_cache)
    path.write_text(path.read_text()[: 40])        # truncate mid-JSON
    with pytest.raises(CacheCorruptionError) as ei:
        plan_cache.load_entry(path)
    assert ei.value.path == str(path)
    assert not isinstance(ei.value, CacheSchemaError)
    store = _restart()
    again = solver.solve_cached(SPEC, 4, HW, **KNOBS)
    assert again == cold                            # re-solved, not crashed
    assert store.corruptions == 1 and store.evictions == 1
    assert store.hits == 0


def test_garbage_payload_evicted_not_trusted(plan_cache):
    solver.solve_cached(SPEC, 4, HW, **KNOBS)
    (path,) = _entry_files(plan_cache)
    payload = json.loads(path.read_text())
    payload["result"]["strategy"]["groups"] = [[999999]]   # illegal pixel
    path.write_text(json.dumps(payload))
    store = _restart()
    res = solver.solve_cached(SPEC, 4, HW, **KNOBS)
    assert res.strategy.spec == SPEC                # decoded fresh solve
    assert store.corruptions == 1


def test_schema_version_bump_invalidates(plan_cache):
    solver.solve_cached(SPEC, 4, HW, **KNOBS)
    (path,) = _entry_files(plan_cache)
    payload = json.loads(path.read_text())
    payload["schema"] = store_mod.SCHEMA_VERSION + 1
    path.write_text(json.dumps(payload))
    with pytest.raises(CacheSchemaError):
        plan_cache.load_entry(path)
    store = _restart()
    solver.solve_cached(SPEC, 4, HW, **KNOBS)
    assert store.stale == 1 and store.hits == 0
    # the stale file was replaced by a fresh current-schema entry
    (path2,) = _entry_files(store)
    assert json.loads(path2.read_text())["schema"] == \
        store_mod.SCHEMA_VERSION


def test_concurrent_writers_atomic(tmp_path):
    """N racing writers to the same key: the store must end with one
    complete, parseable entry (os.replace atomicity) and no tmp litter."""
    store = PlanStore(tmp_path / "race")
    key, fam = codec.s2_key(SPEC, HW)
    results = [{"v": i, "blob": "x" * 5000} for i in range(8)]
    threads = [threading.Thread(
        target=store.put, args=("s2", key, fam, {"result": r}))
        for r in results]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.writes == 8
    (path,) = _entry_files(store)
    payload = store.load_entry(path)               # parses: no torn write
    assert payload["key"] == key
    assert payload["result"] in [{"result": r} for r in results]
    assert not list(store.root.glob("*.tmp"))


def test_disabled_without_env(tmp_path):
    prev = os.environ.get(store_mod.ENV_VAR)
    try:
        store_mod.configure(None)
        assert store_mod.active_store() is None
        solver.solve_cached.cache_clear()
        solver.solve_cached(SPEC, 4, HW, **KNOBS)
        assert not list(tmp_path.glob("*.json"))
    finally:
        if prev is not None:
            store_mod.configure(prev)
        store_mod.reset()
        solver.solve_cached.cache_clear()


# ------------------------------------------------------------------ #
# Warm-started delta re-planning
# ------------------------------------------------------------------ #

def test_neighbor_warm_start_considered_and_never_worse(plan_cache):
    """A delta query (same spec, shifted budget) reprices the cached
    neighbour; whatever it adopts must not lose to the cold search."""
    solver.solve_cached(SPEC, 4, HW, **KNOBS)
    cold_neighbor = HardwareModel(nbop_pe=10 ** 9, size_mem=560)
    store_mod.reset()
    solver.solve_cached.cache_clear()
    solver.best_s2_cached.cache_clear()
    res = solver.solve_cached(SPEC, 4, cold_neighbor, **KNOBS)
    store = store_mod.active_store()
    assert store.warm_considered >= 1
    # never-worse: adopted or not, the result beats the pure cold solve
    fresh = solver._solve_fresh(SPEC, 4, cold_neighbor, **KNOBS)
    assert res.strategy.full_duration(cold_neighbor) <= \
        fresh.strategy.full_duration(cold_neighbor) + 1e-9
    assert res.strategy.peak_footprint_elements() <= 560


def test_neighbor_ranking_prefers_closest_budget():
    key_near = {"spec": codec.spec_key(SPEC), "p": 4,
                "hw": {**codec.hw_key(HW), "size_mem": 590}, "knobs": {}}
    key_far = {"spec": codec.spec_key(SPEC), "p": 4,
               "hw": {**codec.hw_key(HW), "size_mem": 60}, "knobs": {}}
    ranked = sorted([key_far, key_near],
                    key=lambda k: solver._neighbor_rank(k, 4, HW))
    assert ranked[0] is key_near
