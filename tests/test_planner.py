"""Planner property tests (hypothesis): the offloading-schedule chooser
must always respect VMEM, cover the problem, and price durations
consistently with the paper's model.  Deterministic planner tests live in
test_planner_basic.py; this module skips cleanly without hypothesis."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import planner
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import TPU_V5E


@settings(max_examples=25, deadline=None)
@given(m=st.integers(128, 8192), n=st.integers(128, 8192),
       k=st.integers(128, 8192), dtype_bytes=st.sampled_from([2, 4]))
def test_property_matmul_plan_invariants(m, n, k, dtype_bytes):
    p = planner.plan_matmul(m, n, k, dtype_bytes=dtype_bytes)
    assert p.vmem_bytes <= TPU_V5E.vmem_bytes
    assert p.flops == 2 * m * n * k
    # compulsory traffic lower bound: A+B read once, C written once
    assert p.hbm_bytes >= dtype_bytes * (m * k + k * n + m * n)
    assert p.duration_overlapped <= p.duration_additive
    assert p.duration_overlapped >= p.flops / TPU_V5E.peak_flops - 1e-12
    assert p.steps >= 1


@settings(max_examples=25, deadline=None)
@given(s_log=st.integers(9, 19), d=st.sampled_from([64, 128, 256]),
       g=st.integers(1, 16))
def test_property_decode_plan_invariants(s_log, d, g):
    s = 1 << s_log
    p = planner.plan_decode_attention(s, d, g, dtype_bytes=2)
    assert s % p.tiles["bkv"] == 0
    assert p.vmem_bytes <= TPU_V5E.vmem_bytes
    # decode is memory-bound: duration == KV bytes / bw
    assert abs(p.duration_overlapped - p.hbm_bytes / TPU_V5E.hbm_bw) < 1e-12


@settings(max_examples=20, deadline=None)
@given(hw_in=st.integers(8, 40), c_in=st.integers(1, 8),
       n=st.integers(1, 16), kk=st.sampled_from([1, 3, 5]))
def test_property_conv_plan_invariants(hw_in, c_in, n, kk):
    hypothesis.assume(hw_in > kk)
    spec = ConvSpec(c_in, hw_in, hw_in, n, kk, kk)
    p = planner.plan_conv(spec, dtype_bytes=2)
    assert 1 <= p.tiles["t"] <= spec.w_out
    assert p.vmem_bytes <= TPU_V5E.vmem_bytes
    # bytes at least: unique input pixels + kernels + output, once each
    lb = 2 * (spec.all_pixels_mask.bit_count() * c_in
              + spec.kernel_elements + spec.num_patches * n)
    assert p.hbm_bytes >= lb
