"""Planner tests that need no hypothesis: deterministic pricing checks and
the TPU chip-model translation."""
from repro.core import planner
from repro.core.cost_model import TPU_V5E


def test_gemm_order_pricing_matches_intuition():
    """For tall-skinny C with huge K, an A-revisiting order beats naive
    re-streaming — the planner must see that (the paper's 'strategy choice
    matters' claim transplanted to GeMM)."""
    # square big matmul: output-stationary should win (C never RMW'd)
    p = planner.plan_matmul(8192, 8192, 8192)
    assert p.order.endswith("k")


def test_tpu_hardware_model_translation():
    hw = TPU_V5E.as_hardware_model(dtype_bytes=2)
    assert hw.nbop_pe == int(197e12 / 2)
    assert abs(hw.t_l - 2 / 819e9) < 1e-18
    assert hw.size_mem == 128 * 1024 * 1024 // 2


def test_chip_model_roofline_crossover():
    """Arithmetic-intensity crossover: ops with AI above peak/bw are
    compute-bound in the planner's overlapped model."""
    crossover = TPU_V5E.peak_flops / TPU_V5E.hbm_bw      # ~240 flops/byte
    p_big = planner.plan_matmul(8192, 8192, 8192)        # AI >> crossover
    assert p_big.duration_overlapped == p_big.flops / TPU_V5E.peak_flops
    p_small = planner.plan_matmul(128, 128, 128)         # AI << crossover
    assert p_small.duration_overlapped > \
        p_small.flops / TPU_V5E.peak_flops
