"""Polish-pool lifecycle (ISSUE 10 satellite): a broken pool is evicted
and rebuilt alone (siblings keep their workers), the atexit shutdown
bars resurrection, and the solve-LRU size is env-configurable."""
import os

import pytest

from repro.core import solver
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.strategies import GroupedStrategy

SPEC = ConvSpec(3, 6, 6, 2, 3, 3)
HW = HardwareModel(nbop_pe=10 ** 9, size_mem=400)


def _seed() -> GroupedStrategy:
    return GroupedStrategy(
        "seed", SPEC, tuple((i,) for i in range(SPEC.num_patches)))


@pytest.fixture
def fresh_pools():
    """Empty pool registry before and after, never leaking the final
    flag between tests."""
    solver.shutdown_pools()
    prev_final = solver._POOLS_FINAL
    solver._POOLS_FINAL = False
    yield
    solver._POOLS_FINAL = prev_final
    solver.shutdown_pools()


def test_broken_pool_evicted_and_rebuilt_alone(fresh_pools):
    """Killing one pool's workers must not clear the whole registry:
    polish_multi retries on a fresh replacement pool and the sibling
    pool (different size) keeps its object — and its warm workers."""
    ref = solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2,
                              workers=2)
    other = solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2,
                                workers=1)
    key2, key1 = solver._pool_key(2), solver._pool_key(1)
    assert set(solver._POOLS) == {key2, key1}
    broken, sibling = solver._POOLS[key2], solver._POOLS[key1]
    for proc in broken._processes.values():
        proc.kill()
    got = solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2,
                              workers=2)
    assert got == ref                       # deterministic across retry
    assert solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2,
                               workers=1) == other
    assert solver._POOLS[key1] is sibling   # sibling survived untouched
    assert solver._POOLS[key2] is not broken


def test_final_shutdown_bars_resurrection(fresh_pools):
    """After the atexit-style final shutdown, polish_multi still returns
    the identical best-of-restarts result — serially, without building
    a pool mid-teardown."""
    ref = solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2,
                              workers=2)
    solver.shutdown_pools(final=True)
    assert not solver._POOLS
    got = solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2,
                              workers=2)
    assert got == ref
    assert not solver._POOLS                # no resurrection


def test_nonfinal_shutdown_allows_rebuild(fresh_pools):
    """The test-hook shutdown (conftest calls it between sessions) frees
    workers but later calls may build pools again."""
    solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2, workers=2)
    solver.shutdown_pools()
    assert not solver._POOLS
    solver.polish_multi(_seed(), 2, HW, iters=10, restarts=2, workers=2)
    assert solver._POOLS


# ------------------------------------------------------------------ #
# REPRO_SOLVE_CACHE_SIZE
# ------------------------------------------------------------------ #

@pytest.fixture
def cache_size_env():
    prev = os.environ.get("REPRO_SOLVE_CACHE_SIZE")
    yield
    if prev is None:
        os.environ.pop("REPRO_SOLVE_CACHE_SIZE", None)
    else:
        os.environ["REPRO_SOLVE_CACHE_SIZE"] = prev
    solver.reconfigure_caches()


def test_cache_size_env_resizes_and_counts_evictions(cache_size_env):
    os.environ["REPRO_SOLVE_CACHE_SIZE"] = "4"
    solver.reconfigure_caches()
    assert solver.solve_cached.cache_info().maxsize == 4
    for mem in range(300, 360, 10):        # 6 distinct keys into 4 slots
        solver.solve_cached(SPEC, 2,
                            HardwareModel(nbop_pe=10 ** 9, size_mem=mem),
                            polish_iters=20, use_milp=False)
    info = solver.solve_cached.cache_info()
    assert info.currsize == 4
    assert info.misses - info.currsize == 2    # the --profile eviction count


@pytest.mark.parametrize("raw,maxsize", [
    ("0", None),          # <= 0: unbounded
    ("-3", None),
    ("", 256),            # empty/garbage: default
    ("not-a-number", 256),
])
def test_cache_size_env_edge_values(cache_size_env, raw, maxsize):
    os.environ["REPRO_SOLVE_CACHE_SIZE"] = raw
    solver.reconfigure_caches()
    assert solver.solve_cached.cache_info().maxsize == maxsize
    assert solver.best_s2_cached.cache_info().maxsize == maxsize
