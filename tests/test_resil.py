"""Fault-injection engine tests (``repro.resil``): scenario coverage,
recovery invariants, the control-plane cross-check, obs integration and
the CLI exit-code contract.  Deterministic twins live in
test_resil_basic.py; hypothesis properties in test_resil_props.py."""
import numpy as np
import pytest

from repro.configs.clusters import make_cluster
from repro.configs.networks import NETWORKS
from repro.obs.adapters import faulted_timeline, multichip_predicted_timeline
from repro.obs.chrome import to_chrome_trace, validate_chrome_trace
from repro.obs.report import fault_attribution_rows, fault_overhead_by_lane
from repro.resil import faultsim
from repro.resil.controller import ControlPlaneError, RecoveryController
from repro.resil.degrade import surviving_topology
from repro.resil.engine import run_faulted
from repro.resil.faults import (ChipDeath, ClusterExhaustedError,
                                DmaTransient, FaultSchedule,
                                FaultScheduleError, LinkDegrade, VmemShrink)

FAST = dict(polish_iters=60, polish_restarts=1)


def _cluster(network, topology, n_chips):
    size_mem = max(s.kernel_elements for s in NETWORKS[network]) // 2
    return make_cluster(n_chips, size_mem=size_mem, topology=topology)


def _run(network, topology, n_chips, schedule, **kw):
    kw = {**FAST, **kw}
    return run_faulted(NETWORKS[network], _cluster(network, topology,
                                                   n_chips),
                       schedule, name=network, **kw)


# ------------------------------ scenarios ------------------------------ #

def test_chip_death_recovers_on_degraded_topology():
    sch = FaultSchedule(seed=0, events=(ChipDeath(layer=1, chip=2),))
    rep = _run("tight4", "torus2x2", 4, sch)
    assert rep.ok and rep.recovery_exact and rep.write_counts_ok
    assert rep.no_free_lunch
    wasted = [a for a in rep.attempts if a.wasted]
    assert len(wasted) == 1 and wasted[0].dead_chip == 2
    assert wasted[0].detection == sch.detection_cycles
    (rec,) = rep.recoveries
    assert rec.kind == "chip_death" and rec.n_chips == 3
    assert "ring" in rec.new_topology          # 3 chips: no sub-torus
    assert rec.restage_elements > 0 and rec.verified
    assert rec.elastic is not None
    assert rec.elastic.hosts == (0, 1, 3)      # physical survivors
    assert rep.recomputed_elements == \
        NETWORKS["tight4"][1].num_patches * NETWORKS["tight4"][1].c_out
    # every layer committed exactly once despite the wasted attempt
    assert all(c is not None and not np.any(np.isnan(c))
               for c in rep.committed)


def test_link_degrade_replans_without_recompute():
    sch = FaultSchedule(seed=0, events=(LinkDegrade(layer=1, factor=3.0),))
    rep = _run("tight4", "torus2x2", 4, sch)
    assert rep.ok and not any(a.wasted for a in rep.attempts)
    (rec,) = rep.recoveries
    assert rec.kind == "link_degrade"
    assert rep.recomputed_elements == 0
    # no recompute, but the re-plan still restages its input: a suffix
    # plan assumes the replicated input layout, and without paying for
    # it a degraded re-plan could beat the fault-free baseline
    assert rec.restage_cycles > 0
    assert rep.plans[1].cluster.t_ici == rep.plans[0].cluster.t_ici * 3.0
    assert rep.faulted_duration >= rep.baseline_duration - 1e-6


def test_vmem_shrink_replans_under_tighter_budget():
    sch = FaultSchedule(seed=0, events=(VmemShrink(layer=1, factor=0.75),))
    rep = _run("tight4", "torus2x2", 4, sch)
    assert rep.ok
    (rec,) = rep.recoveries
    assert rec.kind == "vmem_shrink"
    assert rep.plans[1].cluster.chip.size_mem == \
        int(rep.plans[0].cluster.chip.size_mem * 0.75)


def test_dma_transient_pure_duration_fault():
    sch = FaultSchedule(seed=0, events=(
        DmaTransient(layer=0, chip=1, step=1, retries=2),))
    rep = _run("tight4", "torus2x2", 4, sch)
    assert rep.ok and not rep.recoveries
    assert rep.retry_cycles > 0
    # values unchanged, only the ledger moved: exactly the retry cost
    assert rep.faulted_duration == pytest.approx(
        rep.baseline_duration + rep.retry_cycles)


def test_combined_boundary_faults_single_replan():
    sch = FaultSchedule(seed=0, events=(LinkDegrade(layer=2, factor=2.0),
                                        VmemShrink(layer=2, factor=0.9)))
    rep = _run("tight4", "torus2x2", 4, sch)
    assert rep.ok and len(rep.recoveries) == 1
    assert rep.recoveries[0].kind == "link_degrade+vmem_shrink"


def test_cluster_exhausted_raises():
    sch = FaultSchedule(seed=0, events=(ChipDeath(layer=0, chip=1),
                                        ChipDeath(layer=1, chip=0)))
    with pytest.raises(ClusterExhaustedError):
        _run("tight2", "ring", 2, sch)


def test_out_of_range_and_missing_slot_events_are_skipped():
    sch = FaultSchedule(seed=0, events=(
        ChipDeath(layer=0, chip=9),                 # no such slot
        DmaTransient(layer=1, chip=7, step=0, retries=1),
        LinkDegrade(layer=99, factor=2.0)))         # no such layer
    rep = _run("tight2", "ring", 2, sch)
    assert rep.ok and not rep.recoveries
    assert len(rep.skipped_events) == 3
    assert rep.faulted_duration == pytest.approx(rep.baseline_duration)


def test_injected_corruption_is_caught():
    sch = FaultSchedule(seed=0, events=())
    rep = _run("tight2", "ring", 2, sch, inject_corruption=1)
    assert not rep.ok and not rep.recovery_exact
    assert not rep.write_counts_ok
    assert any("exactly-once" in f for f in rep.findings)
    assert any("diverged" in f for f in rep.findings)


def test_schedule_validation():
    with pytest.raises(FaultScheduleError):
        FaultSchedule(seed=0, events=(LinkDegrade(layer=0, factor=0.5),))
    with pytest.raises(FaultScheduleError):
        FaultSchedule(seed=0, events=(VmemShrink(layer=0, factor=1.5),))
    with pytest.raises(FaultScheduleError):
        FaultSchedule(seed=0, events=(ChipDeath(layer=-1, chip=0),))
    with pytest.raises(FaultScheduleError):
        FaultSchedule(seed=0, events=(
            DmaTransient(layer=0, chip=0, step=0, retries=0),))


def test_random_schedule_keeps_a_survivor():
    for seed in range(6):
        sch = FaultSchedule.random(seed, n_layers=4, n_chips=2,
                                   n_events=5)
        deaths = [e for e in sch.events if isinstance(e, ChipDeath)]
        assert len(deaths) <= 1                 # n_chips - 1


# ------------------------- surviving topology -------------------------- #

def test_surviving_topology_prefers_sub_torus():
    from repro.core.cost_model import Topology
    torus = Topology.parse("torus2x4")
    assert surviving_topology(torus, 4).kind == "torus"
    assert surviving_topology(torus, 7).kind == "ring"    # 7 is prime
    assert surviving_topology(torus, 3).kind == "ring"
    ring = Topology.parse("ring")
    assert surviving_topology(ring, 3).kind == "ring"


# --------------------------- control plane ----------------------------- #

def test_controller_detects_exactly_the_dead_chip():
    rc = RecoveryController([0, 1, 2, 3], detection_cycles=100.0)
    rc.advance(500.0)
    rc.stage_done([0, 1, 3], stage=0, durations={0: 5.0, 1: 5.0, 3: 9.0})
    rc.advance(100.0)
    rc.expect_death(2)                          # silent past the timeout
    assert rc.dead == [2]
    assert rc.detect_dead() == []               # reported exactly once
    # survivors keep beating without tripping anything
    rc.advance(50.0)
    rc.stage_done([0, 1, 3], stage=1, durations={})
    assert rc.detect_dead() == []


def test_controller_cross_check_mismatch_raises():
    rc = RecoveryController([0, 1], detection_cycles=10.0)
    rc.advance(100.0)                           # both silent -> both dead
    with pytest.raises(ControlPlaneError):
        rc.expect_death(0)
    rc2 = RecoveryController([0, 1], detection_cycles=10.0)
    rc2.stage_done([0, 1], stage=0, durations={})
    with pytest.raises(ControlPlaneError):
        rc2.expect_death(1)                     # nobody actually died
    with pytest.raises(ControlPlaneError):
        rc2.advance(-1.0)


def test_controller_elastic_plan_over_survivors():
    rc = RecoveryController([0, 1, 2, 3])
    plan = rc.elastic_plan([3, 0, 1])
    assert plan.hosts == (0, 1, 3)
    assert plan.data_shards == 3 and plan.model_shards == 1
    assert plan.shard_of_host == {0: 0, 1: 1, 3: 2}


# --------------------------- obs integration --------------------------- #

def test_faulted_timeline_exports_valid_trace_with_fault_lanes():
    sch = FaultSchedule(seed=0, events=(
        ChipDeath(layer=1, chip=2),
        DmaTransient(layer=2, chip=0, step=0, retries=1)))
    rep = _run("tight4", "torus2x2", 4, sch)
    assert rep.ok
    pred = multichip_predicted_timeline(rep.plans[0])
    tl = faulted_timeline(rep)
    assert any(s.lane == "fault" for s in tl.spans)
    assert any(s.lane == "recovery" for s in tl.spans)
    trace = to_chrome_trace([pred, tl])
    assert validate_chrome_trace(trace) == []
    # attribution: the recovery lane carries exactly the priced recovery
    rows = fault_attribution_rows(pred, tl)
    overhead = fault_overhead_by_lane(rows)
    assert overhead["recovery"] == pytest.approx(rep.recovery_cycles)
    assert overhead["fault"] > 0


# -------------------------------- CLI ---------------------------------- #

def test_faultsim_cli_exit_codes(tmp_path, capsys):
    out = str(tmp_path / "trace.json")
    argv = ["--network", "tight2", "--topology", "ring", "--n-chips",
            "2", "--seed", "0", "--scenario", "dma-transient",
            "--iters", "40", "--restarts", "1", "--out", out]
    assert faultsim.main(argv) == 0
    assert "faultsim: OK" in capsys.readouterr().out
    assert faultsim.main(argv + ["--inject-corruption", "0"]) == 1
    assert "FINDING" in capsys.readouterr().err


def test_faultsim_build_schedule_deterministic():
    a = faultsim.build_schedule("mixed", 7, n_layers=4, n_chips=4)
    b = faultsim.build_schedule("mixed", 7, n_layers=4, n_chips=4)
    assert a == b
    kinds = {type(e) for e in a.events}
    assert kinds == {ChipDeath, LinkDegrade, DmaTransient}


# ------------------- acceptance sweep (all networks) ------------------- #

@pytest.mark.parametrize("topology,n_chips", [("ring", 4),
                                              ("torus2x2", 4)])
@pytest.mark.parametrize("network", sorted(NETWORKS))
def test_every_network_recovers_under_seeded_faults(network, topology,
                                                    n_chips):
    """The PR's acceptance gate: every registered network, on ring and
    torus2x2, recovers with exact stitched outputs and clean verified
    re-plans under 3 random fault seeds."""
    specs = NETWORKS[network]
    for seed in range(3):
        sch = FaultSchedule.random(seed, n_layers=len(specs),
                                   n_chips=n_chips, n_events=2)
        rep = _run(network, topology, n_chips, sch, seed=seed,
                   verify=True)
        assert rep.ok, (network, topology, seed, rep.findings)
        assert rep.recovery_exact and rep.write_counts_ok
        assert all(r.verified for r in rep.recoveries)
