"""Deterministic twins for the fault-injection subsystem: the same
seeded schedule must reproduce the same faulted run bit-for-bit
(committed bytes + ledger fingerprint), and the retry injection must
reconcile exactly against the fault-free run."""
import numpy as np
import pytest

from repro.configs.clusters import make_cluster
from repro.configs.networks import NETWORKS
from repro.core.multichip import plan_multichip_network, replan_suffix
from repro.resil.degrade import (repriced_cluster, shrunk_cluster,
                                 surviving_cluster)
from repro.resil.engine import run_faulted
from repro.resil.faults import (ChipDeath, ClusterExhaustedError,
                                DmaTransient, FaultSchedule, LinkDegrade)
from repro.sim.layer import ConvLayer
from repro.sim.multichip import carve_shard, run_shard, simulate_multichip

FAST = dict(polish_iters=60, polish_restarts=1)


def _cluster(network="tight2", topology="ring", n=2):
    size_mem = max(s.kernel_elements for s in NETWORKS[network]) // 2
    return make_cluster(n, size_mem=size_mem, topology=topology)


def test_faulted_run_fingerprint_is_reproducible():
    sch = FaultSchedule.random(3, n_layers=2, n_chips=2, n_events=2)
    runs = [run_faulted(NETWORKS["tight2"], _cluster(), sch,
                        name="tight2", **FAST) for _ in range(2)]
    assert runs[0].fingerprint == runs[1].fingerprint
    for a, b in zip(runs[0].committed, runs[1].committed):
        assert np.array_equal(a, b)            # bit-for-bit


def test_different_seed_changes_schedule():
    a = FaultSchedule.random(0, n_layers=4, n_chips=4, n_events=3)
    b = FaultSchedule.random(0, n_layers=4, n_chips=4, n_events=3)
    c = FaultSchedule.random(1, n_layers=4, n_chips=4, n_events=3)
    assert a == b
    assert a.events != c.events
    assert "seed=0" in a.describe()


def test_fault_free_schedule_reproduces_the_plain_simulation():
    """Zero events: the engine must agree with simulate_multichip both
    on the ledger and on every committed element."""
    specs = NETWORKS["tight2"]
    cluster = _cluster()
    sch = FaultSchedule(seed=0, events=())
    rep = run_faulted(specs, cluster, sch, name="tight2", **FAST)
    assert rep.ok and not rep.recoveries
    assert rep.faulted_duration == pytest.approx(rep.baseline_duration)
    plan = plan_multichip_network(specs, cluster, name="tight2",
                                  include_single_chip_baseline=False,
                                  **FAST)
    sim = simulate_multichip(plan, seed=0)
    assert plan.total_duration == pytest.approx(rep.baseline_duration)
    assert sim.correct and sim.accounting_exact


def test_retry_injection_reconciles_exactly():
    """run_shard with retries = the fault-free run + the priced retry
    duration, with identical output values (reads are idempotent)."""
    specs = NETWORKS["tight2"]
    cluster = _cluster()
    plan = plan_multichip_network(specs, cluster, name="tight2",
                                  include_single_chip_baseline=False,
                                  **FAST)
    lp = plan.layers[0]
    full = ConvLayer.random(lp.spec, seed=0)
    shard = next(s for s in lp.shards if s.mode == "s1")
    base = run_shard(full, shard, cluster.chip)
    retried = run_shard(full, shard, cluster.chip,
                        retry_at={0: 2}, backoff_base=16.0)
    assert np.array_equal(base.output, retried.output)
    assert retried.retry_duration > 0
    assert retried.total_duration == pytest.approx(
        base.total_duration + retried.retry_duration)
    assert retried.elements_read == \
        base.elements_read + retried.retry_elements
    # carve_shard is the shared (and public) carving path
    carved = carve_shard(full, shard)
    assert carved.spec == shard.spec


def test_degraded_cluster_constructors():
    cluster = _cluster("tight4", "torus2x2", 4)
    surv = surviving_cluster(cluster)
    assert surv.n_chips == 3 and surv.topo.kind == "ring"
    assert repriced_cluster(cluster, 2.0).t_ici == cluster.t_ici * 2.0
    shrunk = shrunk_cluster(cluster, 0.5)
    assert shrunk.chip.size_mem == cluster.chip.size_mem // 2
    one = surviving_cluster(_cluster(), n_dead=1)
    assert one.n_chips == 1
    with pytest.raises(ClusterExhaustedError):
        surviving_cluster(one)


def test_replan_suffix_plans_the_tail_only():
    specs = NETWORKS["tight4"]
    cluster = _cluster("tight4", "torus2x2", 4)
    tail = replan_suffix(specs, cluster, start=2, name="tight4", **FAST)
    assert len(tail.layers) == 2
    assert [lp.spec for lp in tail.layers] == list(specs[2:])
    with pytest.raises(ValueError):
        replan_suffix(specs, cluster, start=4, name="tight4", **FAST)


def test_recovery_ledger_is_deterministic_pricing():
    """Chip-death recovery cost = replan rate x remaining layers +
    restage at t_l per input element — no wall-clock in the ledger."""
    sch = FaultSchedule(seed=0, events=(ChipDeath(layer=1, chip=0),),
                        detection_cycles=128.0,
                        replan_cycles_per_layer=32.0)
    specs = NETWORKS["tight2"]
    rep = run_faulted(specs, _cluster(), sch, name="tight2", **FAST)
    (rec,) = rep.recoveries
    assert rec.replan_cycles == 32.0 * (len(specs) - 1)
    spec = specs[1]
    assert rec.restage_cycles == pytest.approx(
        spec.num_pixels * spec.c_in * rep.plans[1].cluster.chip.t_l)
    (wasted,) = [a for a in rep.attempts if a.wasted]
    assert wasted.detection == 128.0
    assert rep.faulted_duration == pytest.approx(
        sum(a.total for a in rep.attempts)
        + sum(r.total for r in rep.recoveries)
        + rep.plans[-1].final_gather_duration)


def test_dma_backoff_is_exponential():
    sch1 = FaultSchedule(seed=0, events=(
        DmaTransient(layer=0, chip=0, step=0, retries=1),),
        backoff_base_cycles=16.0)
    sch3 = FaultSchedule(seed=0, events=(
        DmaTransient(layer=0, chip=0, step=0, retries=3),),
        backoff_base_cycles=16.0)
    specs = NETWORKS["tight2"]
    r1 = run_faulted(specs, _cluster(), sch1, name="tight2", **FAST)
    r3 = run_faulted(specs, _cluster(), sch3, name="tight2", **FAST)
    # backoff sums 16*(2^n - 1); the load re-reads scale linearly
    b1 = r1.retry_cycles - 16.0 * 1
    b3 = r3.retry_cycles - 16.0 * 7
    assert b1 > 0 and b3 == pytest.approx(3 * b1)


def test_link_degrade_and_death_compose():
    sch = FaultSchedule(seed=0, events=(LinkDegrade(layer=0, factor=2.0),
                                        ChipDeath(layer=1, chip=1)))
    rep = run_faulted(NETWORKS["tight4"],
                      _cluster("tight4", "torus2x2", 4), sch,
                      name="tight4", **FAST)
    assert rep.ok and len(rep.recoveries) == 2
    assert [r.kind for r in rep.recoveries] == ["link_degrade",
                                                "chip_death"]
    # the death's re-plan keeps the degraded link price
    assert rep.plans[2].cluster.t_ici == rep.plans[0].cluster.t_ici * 2.0
