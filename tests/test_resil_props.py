"""Hypothesis property tests of the fault-injection engine.  The
deterministic twins live in test_resil_basic.py so the invariants stay
covered without the hypothesis extra; this module skips cleanly when it
is missing.

Every example plans + simulates tight2 on a 2-chip ring — the cheapest
registered configuration — and the shared ``solve_cached`` LRU means
repeated examples re-plan from cache, so the budgets stay small.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.configs.clusters import make_cluster
from repro.configs.networks import NETWORKS
from repro.resil.engine import run_faulted
from repro.resil.faults import (ChipDeath, DmaTransient, FaultSchedule,
                                LinkDegrade, VmemShrink)

SPECS = NETWORKS["tight2"]
N_CHIPS = 2
FAST = dict(polish_iters=40, polish_restarts=1)


def _cluster():
    size_mem = max(s.kernel_elements for s in SPECS) // 2
    return make_cluster(N_CHIPS, size_mem=size_mem, topology="ring")


def _events():
    layer = st.integers(0, len(SPECS) - 1)
    chip = st.integers(0, N_CHIPS - 1)
    return st.one_of(
        st.builds(ChipDeath, layer=layer, chip=chip),
        st.builds(LinkDegrade, layer=layer,
                  factor=st.sampled_from((2.0, 3.0, 4.0))),
        st.builds(VmemShrink, layer=layer,
                  factor=st.sampled_from((0.9, 0.75))),
        st.builds(DmaTransient, layer=layer, chip=chip,
                  step=st.integers(0, 3), retries=st.integers(1, 3)))


def _schedules(events=_events()):
    def ok(evs):
        return sum(isinstance(e, ChipDeath) for e in evs) <= N_CHIPS - 1
    return st.lists(events, min_size=0, max_size=3).filter(ok).map(
        lambda evs: FaultSchedule(seed=0, events=tuple(evs)))


@settings(max_examples=12, deadline=None)
@given(sch=_schedules(), seed=st.integers(0, 3))
def test_recovery_is_exact_and_verified(sch, seed):
    """(a) + (b): under any admissible schedule the stitched outputs
    equal the fault-free reference conv exactly once, the per-shard
    accounting reconciles, and every degraded re-plan passes the static
    verifier (verify=True raises on any error diagnostic)."""
    rep = run_faulted(SPECS, _cluster(), sch, name="tight2", seed=seed,
                      verify=True, **FAST)
    assert rep.ok, rep.findings
    assert rep.recovery_exact and rep.write_counts_ok
    assert rep.accounting_ok
    assert all(r.verified for r in rep.recoveries)
    assert all(c is not None for c in rep.committed)


@settings(max_examples=10, deadline=None)
@given(sch=_schedules(st.one_of(
    st.builds(ChipDeath, layer=st.integers(0, len(SPECS) - 1),
              chip=st.integers(0, N_CHIPS - 1)),
    st.builds(DmaTransient, layer=st.integers(0, len(SPECS) - 1),
              chip=st.integers(0, N_CHIPS - 1),
              step=st.integers(0, 3), retries=st.integers(1, 3)))))
def test_no_free_lunch_under_recompute_faults(sch):
    """(c): chip deaths and DMA transients only ever add work — wasted
    attempts, detection, restage, retries — so the degraded duration
    never beats the fault-free baseline.  (Boundary faults re-plan the
    tail and are covered by the pricing tests; the property here is the
    recompute path.)"""
    rep = run_faulted(SPECS, _cluster(), sch, name="tight2", **FAST)
    assert rep.no_free_lunch
    assert rep.faulted_duration >= rep.baseline_duration - 1e-6
    if any(isinstance(e, ChipDeath) for e in sch.events):
        assert rep.wasted_cycles > 0 or rep.skipped_events


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100))
def test_random_schedules_are_deterministic(seed):
    a = FaultSchedule.random(seed, n_layers=len(SPECS), n_chips=N_CHIPS,
                             n_events=3)
    b = FaultSchedule.random(seed, n_layers=len(SPECS), n_chips=N_CHIPS,
                             n_events=3)
    assert a == b
    rep1 = run_faulted(SPECS, _cluster(), a, name="tight2", **FAST)
    rep2 = run_faulted(SPECS, _cluster(), b, name="tight2", **FAST)
    assert rep1.fingerprint == rep2.fingerprint
