"""Row-window cascading edge cases (ISSUE 3 satellite): strided
consumers, 1-row windows (1x1 kernels), windows taller than the producer
activation, and the InfeasibleNetworkError message-content regression."""
import pytest

from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.network_planner import (InfeasibleNetworkError,
                                        greedy_network_duration,
                                        plan_network, row_window_rows)
from repro.core.strategies import best_heuristic

HW = HardwareModel(nbop_pe=10 ** 9, size_mem=None)
FAST = dict(polish_iters=600, polish_restarts=1)


def _budget_for_rows(prev_s, nxt_s, nxt, rows):
    """size_mem that leaves exactly ``rows`` input rows of spare next to
    both layers' peaks (the row_window_rows fit condition)."""
    base = max(prev_s.peak_footprint_elements(),
               nxt_s.peak_footprint_elements())
    return base + rows * nxt.w_in * nxt.c_in


def test_window_rows_with_strided_consumer():
    """A stride-2 consumer still gets a halo-extended window: at least
    h_k input rows, never more than its input height."""
    prev = ConvSpec(2, 12, 12, 4, 3, 3)
    nxt = ConvSpec(4, 10, 10, 4, 3, 3, s_h=2, s_w=2)
    prev_s = best_heuristic(prev, 4, HW)
    nxt_s = best_heuristic(nxt, 4, HW)
    # spare for 5 rows: admissible (>= h_k = 3)
    hw = HardwareModel(nbop_pe=10 ** 9,
                       size_mem=_budget_for_rows(prev_s, nxt_s, nxt, 5))
    rows = row_window_rows(prev, prev_s, nxt, nxt_s, hw)
    assert nxt.h_k <= rows <= nxt.h_in
    assert rows == 5
    # spare for h_k - 1 rows only: no admissible window
    hw2 = HardwareModel(
        nbop_pe=10 ** 9,
        size_mem=_budget_for_rows(prev_s, nxt_s, nxt, nxt.h_k - 1))
    assert row_window_rows(prev, prev_s, nxt, nxt_s, hw2) == 0
    # the planner keeps every saving clamped on the strided pair
    plan = plan_network((prev, nxt), hw, **FAST)
    for lp in plan.layers:
        assert lp.duration >= 0
        assert lp.input_load_saved <= \
            lp.strategy.first_load_duration(hw) + 1e-9


def test_one_row_window_with_1x1_kernel():
    """h_k = 1 (1x1 conv): a single resident row is already a legal
    halo-extended window."""
    prev = ConvSpec(2, 8, 8, 4, 1, 1)
    nxt = ConvSpec(4, 8, 8, 8, 1, 1)
    prev_s = best_heuristic(prev, 4, HW)
    nxt_s = best_heuristic(nxt, 4, HW)
    hw = HardwareModel(nbop_pe=10 ** 9,
                       size_mem=_budget_for_rows(prev_s, nxt_s, nxt, 1))
    assert row_window_rows(prev, prev_s, nxt, nxt_s, hw) == 1
    # one fewer element: nothing fits
    hw2 = HardwareModel(nbop_pe=10 ** 9, size_mem=hw.size_mem - 1)
    assert row_window_rows(prev, prev_s, nxt, nxt_s, hw2) == 0


def test_window_clamped_to_consumer_input_height():
    """A consumer whose input is taller than the producer's activation
    (pooling/padding mismatch): the window never claims more rows than
    the consumer's input has, and savings stay clamped in a plan."""
    prev = ConvSpec(1, 8, 8, 2, 3, 3)       # 6x6 output
    nxt = ConvSpec(2, 12, 12, 2, 3, 3)      # 12-row input
    prev_s = best_heuristic(prev, 4, HW)
    nxt_s = best_heuristic(nxt, 4, HW)
    hw = HardwareModel(nbop_pe=10 ** 9,
                       size_mem=_budget_for_rows(prev_s, nxt_s, nxt, 1000))
    rows = row_window_rows(prev, prev_s, nxt, nxt_s, hw)
    assert rows == nxt.h_in                  # clamped, not 1000
    plan = plan_network((prev, nxt), hw, **FAST)
    for lp in plan.layers:
        assert lp.duration >= 0
        assert lp.input_load_saved <= \
            lp.strategy.first_load_duration(hw) + 1e-9


def test_infeasible_error_message_names_layer_and_budget():
    """Regression: the error must carry enough context to act on — the
    failing layer's index/shape and the budget that rejected it."""
    net = (ConvSpec(1, 10, 10, 2, 3, 3), ConvSpec(2, 8, 8, 4, 3, 3))
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=4)
    with pytest.raises(InfeasibleNetworkError,
                       match=r"layer 0 \(1x10x10->2\).*size_mem=4"):
        plan_network(net, hw, **FAST)
    with pytest.raises(InfeasibleNetworkError, match=r"size_mem=4"):
        greedy_network_duration(net, hw)
