"""S2 functional property test (hypothesis): kernel subsets swapping
through on-chip memory compute the exact convolution for any shape.
Deterministic S2 tests live in test_s2_basic.py; this module skips cleanly
without hypothesis."""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import strategies_s2 as s2
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.sim import ConvLayer
from repro.sim.s2 import run_s2

BIG = HardwareModel(nbop_pe=10 ** 9, size_mem=None)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 7), nk=st.sampled_from([2, 4, 6]),
       p=st.integers(1, 4), kg=st.sampled_from([1, 2]),
       seed=st.integers(0, 3),
       builder=st.sampled_from([s2.kernel_major, s2.patch_major]))
def test_property_s2_functional_correct(n, nk, p, kg, seed, builder):
    spec = ConvSpec(1, n, n, nk, 3, 3)
    strat = builder(spec, p, kg)
    layer = ConvLayer.random(spec, seed=seed)
    rep = run_s2(layer, BIG, strat)
    assert rep.correct, rep.max_abs_err
