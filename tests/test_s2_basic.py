"""S2 strategies (paper Sec 9 future work) — deterministic tests: formal
semantics, memory-budget claims, and the dataflow trade.  (The functional
property test lives in test_s2.py and needs hypothesis.)"""
import pytest

from repro.core import strategies_s2 as s2
from repro.core.conv_spec import ConvSpec
from repro.core.cost_model import HardwareModel
from repro.core.formalism import run_steps
from repro.sim import ConvLayer
from repro.sim.s2 import run_s2

BIG = HardwareModel(nbop_pe=10 ** 9, size_mem=None)


def _spec(n_kernels=4):
    return ConvSpec(c_in=2, h_in=6, w_in=6, n_kernels=n_kernels,
                    h_k=3, w_k=3)


def test_s2_formal_semantics_execute():
    spec = _spec()
    for builder in (s2.kernel_major, s2.patch_major):
        strat = builder(spec, p=3, kg_size=2)
        res = run_steps(strat.to_steps(), spec, BIG,
                        validate=False)          # out ids are (pid, kg) units
        assert res.states[-1].empty
        # every (patch, kernel-group) unit computed exactly once
        computed = 0
        for s in strat.to_steps():
            assert (computed & s.out) == 0
            computed |= s.out
        assert computed.bit_count() == spec.num_patches * 2


def test_s2_runs_where_s1_cannot():
    """The headline claim: S1 needs all kernels resident; S2 fits a budget
    smaller than the kernel set itself."""
    spec = ConvSpec(c_in=2, h_in=6, w_in=6, n_kernels=8, h_k=3, w_k=3)
    # budget below kernel_elements: S1 is infeasible by construction
    budget = spec.kernel_elements - 1
    res = s2.best_s2(spec, HardwareModel(nbop_pe=10 ** 9, size_mem=budget))
    assert not res.feasible_s1
    assert res.peak_memory <= budget
    hw = HardwareModel(nbop_pe=10 ** 9, size_mem=budget)
    rep = run_s2(ConvLayer.random(spec), hw, res.strategy)
    assert rep.correct
    assert rep.peak_memory <= budget


def test_s2_dataflow_trade():
    """kernel_major loads each kernel once but re-reads the input per
    kernel group; patch_major is the transpose.  Small kernels + big input
    -> kernel_major's input re-reads dominate -> patch_major wins, and
    vice versa."""
    hw = BIG
    # big input, few small kernels, few patch groups: re-cycling kernels
    # per patch group is far cheaper than re-sweeping the input per kernel
    spec_a = ConvSpec(1, 16, 16, 2, 3, 3)
    pm = s2.patch_major(spec_a, 49, 1).objective(hw)
    km = s2.kernel_major(spec_a, 49, 1).objective(hw)
    assert pm < km
    # tiny input, many big kernels, several patch groups: re-cycling the
    # kernel set per patch group (patch_major) is the expensive direction
    spec_b = ConvSpec(8, 5, 5, 16, 3, 3)
    pm_b = s2.patch_major(spec_b, 3, 1).objective(hw)
    km_b = s2.kernel_major(spec_b, 3, 1).objective(hw)
    assert km_b < pm_b


def test_s2_kernel_reload_counts():
    spec = _spec(n_kernels=4)
    layer = ConvLayer.random(spec)
    km = run_s2(layer, BIG, s2.kernel_major(spec, 2, 2))
    pm = run_s2(layer, BIG, s2.patch_major(spec, 2, 2))
    assert km.kernel_loads == spec.n_kernels          # once each
    n_patch_groups = -(-spec.num_patches // 2)
    assert pm.kernel_loads == spec.n_kernels * n_patch_groups


def test_s2_objective_matches_simulator_duration():
    spec = _spec()
    strat = s2.patch_major(spec, 3, 2)
    rep = run_s2(ConvLayer.random(spec), BIG, strat)
    # objective counts loads + t_acc; simulator additionally counts t_w
    assert rep.total_duration == pytest.approx(
        strat.objective(BIG) + spec.num_patches * spec.c_out * BIG.t_w)


def test_s2_reduces_duration_under_tight_memory():
    """Under a tight budget, the S2 search still finds a runnable strategy
    and its duration lower-bounds gracefully vs the unconstrained best."""
    spec = ConvSpec(2, 8, 8, 8, 3, 3)
    hw_free = HardwareModel(nbop_pe=10 ** 9, size_mem=None)
    free = s2.best_s2(spec, hw_free)
    tight = s2.best_s2(spec, HardwareModel(
        nbop_pe=10 ** 9, size_mem=free.peak_memory // 2))
    assert tight.objective >= free.objective
    assert tight.peak_memory <= free.peak_memory // 2


def test_nb_patches_max_s2_scales_inverse_with_kernels():
    spec = _spec(n_kernels=8)
    hw = HardwareModel(nbop_pe=spec.nb_op_value * 8 * 3)
    assert s2.nb_patches_max_s2(spec, hw, 1) == 24
    assert s2.nb_patches_max_s2(spec, hw, 8) == 3
